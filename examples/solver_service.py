"""Solver-as-a-service demo: many concurrent primal-dual problems through
the batched serving engine.

A multi-tenant request stream — mixed shapes, mixed regularizers, mixed
prox families — is bucketed by (padded shape, format, prox family), padded
into fixed slot batches, and advanced by one jit'd vmapped A2 step per
bucket with per-slot early exit (each problem stops at ITS feasibility
tolerance) and continuous admission (freed slots immediately take queued
requests).  One request is re-solved standalone to show the engine returns
the same iterates as solve_tol.

    PYTHONPATH=src python examples/solver_service.py
"""
import numpy as np
import jax.numpy as jnp

from repro.configs.base import PaperProblemConfig
from repro.core.prox import get_prox
from repro.core.solver import solve_tol
from repro.operators import make_solver_ops
from repro.serve import SolveRequest, SolverEngine
from repro.sparse import make_lasso


def main():
    rng = np.random.default_rng(0)
    shapes = [(192, 48), (128, 32), (96, 24)]
    proxes = [("l1", 0.1), ("l1", 0.05), ("sq_l2", 0.5)]
    reqs = []
    for i in range(18):
        m, n = shapes[i % len(shapes)]
        name, reg = proxes[i % len(proxes)]
        cfg = PaperProblemConfig(name=f"tenant-{i}", m=m, n=n, nnz=m * 8,
                                 reg=reg)
        coo, b, _ = make_lasso(cfg, seed=int(rng.integers(1 << 30)))
        reqs.append(SolveRequest(uid=i, coo=coo, b=b, prox=name, reg=reg,
                                 gamma0=1000.0, tol=1e-2,
                                 max_iterations=4000))

    eng = SolverEngine(slots=4, fmt="ell", backend="jnp", check_every=16)
    for r in reqs:
        key = eng.submit(r)
        print(f"submit req {r.uid:2d}: m={r.coo.m:3d} n={r.coo.n:2d} "
              f"prox={r.prox}/{r.reg} -> bucket "
              f"({key.m_pad}x{key.n_pad}, k={key.width}/{key.width_t}, "
              f"{key.prox})")

    done = eng.run()
    print(f"\nserved {len(done)} requests over {len(eng.buckets)} buckets x "
          f"{eng.slots} slots ({eng.stats['iterations']} slot-iterations, "
          f"{eng.stats['steps']} engine ticks)")
    for r in sorted(done, key=lambda r: r.uid):
        print(f"  req {r.uid:2d}: k={r.iterations:4d} "
              f"feas={r.feasibility:.4f} ||x||_0="
              f"{int(np.sum(np.abs(r.x) > 1e-6))}/{r.coo.n}")

    # the engine's contract: same iterates as a standalone solve_tol
    r = sorted(done, key=lambda r: r.uid)[0]
    ops = make_solver_ops(r.coo, "ell", "jnp")
    s = solve_tol(ops, get_prox(r.prox, reg=r.reg), r.b, r.lg, r.gamma0,
                  max_iterations=r.max_iterations, tol=r.tol,
                  check_every=16)
    err = float(jnp.max(jnp.abs(jnp.asarray(r.x) - s.xbar)))
    print(f"\nreq {r.uid} vs standalone solve_tol: k {r.iterations} vs "
          f"{int(s.k)}, max|dx| = {err:.2e} (identical stopping iteration, "
          f"iterates to float tolerance)")


if __name__ == "__main__":
    main()

"""Solver-as-a-service demo: a fleet of declarative Problems through the
batched serving engine.

A multi-tenant request stream — mixed shapes, mixed regularizers, mixed
prox families — is stated as `repro.api.Problem`s.  `pd.solve_many` routes
the fleet through the slot-batched engine (bucketed by padded shape /
format / prox family, one jit'd masked A2 step per bucket, per-slot early
exit, continuous admission); the engine itself admits Problems directly
via `serve.create_engine("solver")` when you want the bucket-level view.
One problem is re-solved standalone to show the engine returns the same
iterates as a single-problem plan.

``--devices N`` serves the fleet on a mesh of N (forced host) devices:
buckets land round-robin and any problem above the sharded-placement
threshold (here shrunk with ``--shard-above``) is partitioned mesh-wide.
The flag must be processed before jax initialises, hence the argv peek
ahead of the repro imports.

``--arrival-rate R`` replays the same fleet OPEN-LOOP: seeded Poisson
arrivals at R req/s through ``repro.serve.OpenLoopFrontend`` (bounded
wait queue, priority-aware admission, planner-reasoned backpressure),
with ``--deadline`` bounding each tenant's patience and ``--slo`` setting
the goodput threshold of the final latency report.

    PYTHONPATH=src python examples/solver_service.py [--devices 4]
    PYTHONPATH=src python examples/solver_service.py \
        --arrival-rate 50 --deadline 2.0 --slo 0.5
"""
import argparse


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--shard-above", type=int, default=None)
    ap.add_argument("--fmt", default="ell", choices=("ell", "bcsr"),
                    help="bucket storage/kernel format (bcsr = MXU path)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    metavar="RPS",
                    help="also run the fleet open-loop at this offered "
                         "Poisson rate (req/s)")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="open-loop relative deadline per request "
                         "(seconds after arrival)")
    ap.add_argument("--slo", type=float, default=None, metavar="S",
                    help="open-loop latency SLO for the goodput report")
    return ap.parse_known_args()[0]


ARGS = _parse_args()
from repro.launch.devices import force_host_devices  # noqa: E402 (pre-jax)

force_host_devices(ARGS.devices)

import numpy as np

import repro as pd
from repro.configs.base import PaperProblemConfig
from repro.serve import create_engine


def make_problems(num: int = 18) -> list[pd.Problem]:
    from repro.sparse import make_lasso

    rng = np.random.default_rng(0)
    shapes = [(192, 48), (128, 32), (96, 24)]
    proxes = [("l1", 0.1), ("l1", 0.05), ("sq_l2", 0.5)]
    probs = []
    for i in range(num):
        m, n = shapes[i % len(shapes)]
        name, reg = proxes[i % len(proxes)]
        cfg = PaperProblemConfig(name=f"tenant-{i}", m=m, n=n, nnz=m * 8,
                                 reg=reg)
        coo, b, _ = make_lasso(cfg, seed=int(rng.integers(1 << 30)))
        probs.append(pd.Problem(coo, b, prox=name, reg=reg, gamma0=1000.0))
    return probs


def main():
    probs = make_problems()

    # the facade's fleet path: solve_many picks the engine when the fleet
    # is servable (named prox families, concrete matrices, tol set)
    results = pd.solve_many(probs, tol=1e-2, max_iterations=4000,
                            check_every=16, slots=4)
    print(f"solve_many: {len(results)} problems via "
          f"execution={results[0].plan.execution!r} "
          f"({results[0].plan.params['buckets']} buckets x "
          f"{results[0].plan.params['slots']} slots)")
    for i, (p, r) in enumerate(zip(probs, results)):
        print(f"  req {i:2d}: m={p.m:3d} n={p.n:2d} prox={p.prox_name}/"
              f"{p.reg} k={r.iterations:4d} feas={r.feasibility:.4f} "
              f"||x||_0={int(np.sum(np.abs(np.asarray(r.x)) > 1e-6))}/{p.n}")

    # under the hood: the engine admits Problems directly and shows its
    # bucketing + placement decisions (mesh-wide with --devices)
    eng = create_engine("solver", slots=4, fmt=ARGS.fmt, backend="jnp",
                        check_every=16, devices=ARGS.devices,
                        shard_above=ARGS.shard_above)
    for p in probs[:6]:
        key = eng.submit(p)         # a Problem is the engine's request type
        kind = type(key).__name__
        body = (f", body={key.fmt}/{key.strategy}"
                if hasattr(key, "strategy") else "")
        print(f"submit {p} -> {kind}({key.m_pad}x{key.n_pad}, "
              f"k={key.width}, {key.prox}{body}, "
              f"{eng.bucket_slot_bytes(key)}B/slot) "
              f"on {len(eng.devices)} device(s)")
    eng.run()

    # open-loop replay: the same tenants arriving on their own clock
    if ARGS.arrival_rate is not None:
        from repro.serve import OpenLoopFrontend, WallClock, poisson_arrivals

        reqs = [p.to_request(uid=i, tol=1e-2, max_iterations=4000)
                for i, p in enumerate(make_problems())]
        fe = OpenLoopFrontend(
            eng, poisson_arrivals(reqs, rate=ARGS.arrival_rate, seed=0,
                                  deadline=ARGS.deadline),
            clock=WallClock())
        rep = fe.run(slo=ARGS.slo)
        p50, p99 = rep["p50_latency_s"], rep["p99_latency_s"]
        print(f"\nopen-loop @{ARGS.arrival_rate:g} req/s: "
              f"{rep['completed']}/{rep['offered']} completed, "
              f"{rep['expired']} expired, p50={(p50 or 0)*1e3:.1f}ms "
              f"p99={(p99 or 0)*1e3:.1f}ms "
              f"goodput={rep['goodput_rps']:.1f} req/s")

    # the engine's contract: same iterates as a standalone single plan
    r0 = results[0]
    ref = probs[0].solve(tol=1e-2, max_iterations=4000, check_every=16,
                         format="ell", backend="jnp")
    err = float(np.max(np.abs(np.asarray(r0.x) - np.asarray(ref.x))))
    print(f"\nreq 0 vs standalone plan: k {r0.iterations} vs "
          f"{ref.iterations}, max|dx| = {err:.2e} (identical stopping "
          "iteration, iterates to float tolerance)")


if __name__ == "__main__":
    main()

"""Quickstart: solve a paper-style LASSO/basis-pursuit instance with the
smoothed accelerated primal-dual solver (A2, fused — the paper's optimized
schedule), on Pallas kernel ops, and verify A1 == A2.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.configs.paper_problems import small_config
from repro.core.gap import certificates
from repro.core.prox import get_prox
from repro.core.solver import solve
from repro.operators import make_solver_ops, select_format
from repro.sparse import col_partitioned_ell, ell_col_norms_sq, make_lasso


def main():
    cfg = small_config()
    print(f"problem: m={cfg.m} n={cfg.n} nnz={cfg.nnz} (Table-1 style, "
          f"uniform-sparse)")
    coo, b, x_true = make_lasso(cfg, seed=0)

    # paper init steps 1-2: Lg = sum_i ||A_i||^2, local per column block
    ellt = col_partitioned_ell(coo, parts=1)
    lg = float(jnp.sum(ell_col_norms_sq(ellt)))
    prox = get_prox("l1", reg=cfg.reg)

    # operator registry: the roofline selector picks the storage format
    # (ELL vs tiled BCSR) from matrix statistics; "pallas" = fused kernels
    plan = select_format(coo)
    print(f"selector: format={plan.format} params={plan.params}")
    ops = make_solver_ops(coo, plan.format, "pallas", prox=prox, reg=cfg.reg,
                          **{"band_size": 512, **plan.params})

    state, hist = solve(ops, prox, b, lg, gamma0=1000.0, iterations=600,
                        algorithm="a2", record_every=100)
    for k, feas, obj in zip(np.asarray(hist["k"]),
                            np.asarray(hist["feasibility"]),
                            np.asarray(hist["objective"])):
        print(f"  k={k:4d}  ||Ax-b||={feas:9.4f}  f(x)={obj:9.4f}")

    cert = certificates(ops, prox, b, lg, 1000.0, state)
    rel = float(jnp.linalg.norm(state.xbar - x_true)
                / jnp.linalg.norm(x_true))
    print(f"final: feasibility={float(cert['feasibility']):.4f} "
          f"gap={float(cert['gap']):.4f} recovery_rel_err={rel:.4f}")

    # the paper's Matlab check: A1 (faithful) == A2 (fused)
    dops = make_solver_ops(coo, "dense", "jnp")
    s1, _ = solve(dops, prox, b, lg, 1000.0, iterations=100,
                  algorithm="a1")
    s2, _ = solve(dops, prox, b, lg, 1000.0, iterations=100,
                  algorithm="a2")
    print(f"A1 vs A2 max|dx| = {float(jnp.max(jnp.abs(s1.xbar - s2.xbar))):.2e}"
          " (identical iterates, as the paper verifies in Matlab)")


if __name__ == "__main__":
    main()

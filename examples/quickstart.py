"""Quickstart: solve a paper-style LASSO/basis-pursuit instance with the
smoothed accelerated primal-dual solver (A2, fused — the paper's optimized
schedule), on Pallas kernel ops, and verify A1 == A2.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.configs.paper_problems import small_config
from repro.core.gap import certificates
from repro.core.prox import get_prox
from repro.core.solver import dense_ops, solve
from repro.kernels import kernel_ops
from repro.sparse import (
    coo_to_banded, coo_to_dense, coo_to_ell, col_partitioned_ell,
    ell_col_norms_sq, make_lasso,
)


def main():
    cfg = small_config()
    print(f"problem: m={cfg.m} n={cfg.n} nnz={cfg.nnz} (Table-1 style, "
          f"uniform-sparse)")
    coo, b, x_true = make_lasso(cfg, seed=0)

    # paper init steps 1-2: Lg = sum_i ||A_i||^2, local per column block
    ellt = col_partitioned_ell(coo, parts=1)
    lg = float(jnp.sum(ell_col_norms_sq(ellt)))
    prox = get_prox("l1", reg=cfg.reg)

    ops = kernel_ops(coo_to_ell(coo, pad_to=8),
                     coo_to_banded(coo, band_size=512, pad_to=8),
                     prox, cfg.reg)

    state, hist = solve(ops, prox, b, lg, gamma0=1000.0, iterations=600,
                        algorithm="a2", record_every=100)
    for k, feas, obj in zip(np.asarray(hist["k"]),
                            np.asarray(hist["feasibility"]),
                            np.asarray(hist["objective"])):
        print(f"  k={k:4d}  ||Ax-b||={feas:9.4f}  f(x)={obj:9.4f}")

    cert = certificates(ops, prox, b, lg, 1000.0, state)
    rel = float(jnp.linalg.norm(state.xbar - x_true)
                / jnp.linalg.norm(x_true))
    print(f"final: feasibility={float(cert['feasibility']):.4f} "
          f"gap={float(cert['gap']):.4f} recovery_rel_err={rel:.4f}")

    # the paper's Matlab check: A1 (faithful) == A2 (fused)
    d = jnp.asarray(coo_to_dense(coo))
    s1, _ = solve(dense_ops(d), prox, b, lg, 1000.0, iterations=100,
                  algorithm="a1")
    s2, _ = solve(dense_ops(d), prox, b, lg, 1000.0, iterations=100,
                  algorithm="a2")
    print(f"A1 vs A2 max|dx| = {float(jnp.max(jnp.abs(s1.xbar - s2.xbar))):.2e}"
          " (identical iterates, as the paper verifies in Matlab)")


if __name__ == "__main__":
    main()

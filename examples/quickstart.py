"""Quickstart: state the problem, let the planner pick the execution design.

The facade (`repro.api`) is the paper's system pitch in one line: you
declare `min f(x) s.t. Ax = b` as a `Problem`, the planner turns intent
(`SolveSpec`) into an inspectable `ExecutionPlan` — storage format via the
roofline selector, backend, Lipschitz constant, schedule — and `solve()`
compiles it down to the A2 kernel layer and returns a `Result` with gap
certificates.  Any decision can be overridden and re-solved; A1 and A2
produce identical iterates (the paper's Matlab check).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro as pd
from repro.configs.paper_problems import small_config
from repro.sparse import make_lasso


def main():
    cfg = small_config()
    print(f"problem: m={cfg.m} n={cfg.n} nnz={cfg.nnz} (Table-1 style, "
          f"uniform-sparse)")
    coo, b, x_true = make_lasso(cfg, seed=0)

    # declare the problem; the planner estimates Lg (paper init steps 1-2),
    # picks the storage format from matrix statistics, and schedules A2
    prob = pd.Problem(coo, b, prox="l1", reg=cfg.reg, gamma0=1000.0)
    plan = prob.plan(iterations=600, record_every=100)
    print(plan)
    print(plan.explain())

    res = plan.solve()
    for k, feas, obj in zip(np.asarray(res.history["k"]),
                            np.asarray(res.history["feasibility"]),
                            np.asarray(res.history["objective"])):
        print(f"  k={k:4d}  ||Ax-b||={feas:9.4f}  f(x)={obj:9.4f}")

    cert = res.certificates()
    rel = float(np.linalg.norm(np.asarray(res.x) - np.asarray(x_true))
                / np.linalg.norm(np.asarray(x_true)))
    print(f"final: feasibility={cert['feasibility']:.4f} "
          f"gap={cert['gap']:.4f} recovery_rel_err={rel:.4f} "
          f"({res.timings['solve_s']*1e3:.0f}ms solve)")

    # override round-trip — the paper's Matlab check, A1 == A2, through the
    # same plan with two decisions swapped
    s1 = plan.override(algorithm="a1", format="dense", iterations=100).solve()
    s2 = plan.override(algorithm="a2", format="dense", iterations=100).solve()
    dx = float(np.max(np.abs(np.asarray(s1.x) - np.asarray(s2.x))))
    print(f"A1 vs A2 max|dx| = {dx:.2e} (identical iterates, as the paper "
          "verifies in Matlab)")


if __name__ == "__main__":
    main()

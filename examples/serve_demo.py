"""Batched serving demo: continuous batching over decode slots, three
different architecture families sharing one engine.

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import Request, TokenEngine


def run_arch(arch: str, n_requests: int = 5, max_new: int = 8):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = TokenEngine(model, slots=4, max_len=48)
    eng.init_state(params)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(2, 8))
        shape = (plen, cfg.num_codebooks) if cfg.num_codebooks else (plen,)
        r = Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=shape).astype(np.int32),
                    max_new_tokens=max_new)
        reqs.append(r)
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"[{arch:20s}] {n_requests} reqs, {toks} tokens, {dt:5.2f}s "
          f"({toks/dt:6.1f} tok/s) sample={reqs[0].out[:4]}")


def main():
    for arch in ("qwen3-4b", "falcon-mamba-7b", "musicgen-medium"):
        run_arch(arch)


if __name__ == "__main__":
    main()

"""Lasso (and friends) through the coordinate-descent solver family.

Declaring ``loss=`` on a Problem states an ERM objective instead of the
constrained form ``min f(x) s.t. Ax = b``; the planner's face-off rule
(`repro.plan.decide_solver_family`) routes it to primal RCD or dual SDCA
over CSC operands and records why — forced where the math forces it
(lasso has no strongly-convex dual, the hinge is nonsmooth in the
primal), scored by epoch cost x nnz imbalance for logistic.  The same
declarations serve a fleet through the batched engine next to A2
constraint traffic (DESIGN.md "Solver families").

    PYTHONPATH=src python examples/lasso_rcd.py
"""
import numpy as np

import repro as pd
from repro.plan import SolveSpec
from repro.sparse import random_coo
from repro.sparse.formats import coo_to_dense
from repro.solvers import dense_reference, reference_objective


def main():
    rs = np.random.default_rng(0)

    # -- lasso: min 1/2||Ax-b||^2 + reg||x||_1 ----------------------------
    coo = random_coo(96, 24, row_nnz=5, seed=0)
    b = rs.standard_normal(96).astype(np.float32)
    prob = pd.Problem(coo, b, reg=0.1, loss="lasso")
    plan = prob.plan(tol=1e-6, max_iterations=20_000)
    print(plan)
    print("  ", plan.reasons["solver_family"])

    res = plan.solve()
    ref = dense_reference(coo_to_dense(coo), b, 0.1, "lasso")
    err = float(np.max(np.abs(np.asarray(res.x, np.float64) - ref)))
    print(f"lasso: epochs={res.iterations} resid={res.feasibility:.2e} "
          f"|x - x_fista|={err:.2e} f(x)={res.objective:.4f}")
    assert err < 1e-4

    # -- logistic: the face-off decides, and stays overridable ------------
    labels = np.where(rs.random(96) < 0.5, -1.0, 1.0).astype(np.float32)
    logit = pd.Problem(coo, labels, reg=0.3, loss="logistic")
    pl = logit.plan(tol=1e-5)
    print("\nlogistic face-off:", pl.reasons["solver_family"])
    r1 = pl.solve()                                        # planner's side
    r2 = pl.override(solver_family="rcd_dual").solve()     # the other side
    gap = abs(reference_objective(coo_to_dense(coo), labels, 0.3,
                                  "logistic", np.asarray(r1.x))
              - reference_objective(coo_to_dense(coo), labels, 0.3,
                                    "logistic", np.asarray(r2.x)))
    print(f"primal vs dual objective gap: {gap:.2e}")
    assert gap < 1e-4

    # -- a mixed fleet through the serving engine --------------------------
    fleet = [prob, logit,
             pd.Problem(random_coo(64, 16, row_nnz=4, seed=3),
                        np.where(rs.random(64) < 0.5, -1.0, 1.0)
                        .astype(np.float32), reg=0.5, loss="svm")]
    results = pd.solve_many(fleet, SolveSpec(tol=1e-4,
                                             max_iterations=20_000))
    for p, r in zip(fleet, results):
        print(f"served loss={p.loss:8s} epochs={r.iterations:5d} "
              f"resid={r.feasibility:.2e} via {r.plan.execution}")
        assert r.feasibility < 1e-4


if __name__ == "__main__":
    main()

"""End-to-end driver: the paper's workload, distributed.

Solves a scaled Table-1 dataset with every distribution strategy on 8
simulated devices and compares iterate agreement + wall time + the
per-iteration collective signature (the MR1-4/Spark comparison, Section 5
of the paper, reproduced on a JAX mesh).

    PYTHONPATH=src python examples/distributed_solver.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.paper_problems import PaperProblemConfig
from repro.core.distributed import build_problem, make_step_fn, solve_distributed
from repro.core.prox import get_prox
from repro.core.solver import PDState, solve
from repro.operators import make_solver_ops
from repro.roofline.analysis import collective_stats
from repro.sparse import make_lasso


def main():
    cfg = PaperProblemConfig(name="d1/100", m=10_000, n=1_000, nnz=100_000,
                             reg=0.1, gamma0=100.0)
    coo, b, x_true = make_lasso(cfg, seed=0)
    lg = float(jnp.sum(coo.vals ** 2))
    prox = get_prox("l1", reg=cfg.reg)
    ref, _ = solve(make_solver_ops(coo, "dense", "jnp"), prox, b, lg,
                   cfg.gamma0, iterations=100)

    devs = np.array(jax.devices())
    mesh1 = Mesh(devs.reshape(8), ("p",))
    mesh2 = Mesh(devs.reshape(2, 4), ("data", "model"))
    print(f"{'strategy':10s} {'alg':3s} {'err vs dense':>12s} {'t/iter':>9s} "
          f"{'wire B/iter':>12s}  collective signature")
    for strategy, mesh in [("rowpart", mesh1), ("colpart", mesh1),
                           ("dualpart", mesh1), ("block2d", mesh2)]:
        for alg in ("a1", "a2"):
            t0 = time.perf_counter()
            xbar, state = solve_distributed(coo, b, prox, mesh, strategy,
                                            gamma0=cfg.gamma0,
                                            iterations=100, algorithm=alg)
            dt = (time.perf_counter() - t0) / 100
            err = float(jnp.max(jnp.abs(xbar - ref.xbar)))
            problem = build_problem(coo, mesh, strategy)
            step = make_step_fn(problem, prox, cfg.gamma0, algorithm=alg)
            xs = jax.ShapeDtypeStruct((problem.n_pad,), jnp.float32)
            ys = jax.ShapeDtypeStruct((problem.m_pad,), jnp.float32)
            st = PDState(xbar=xs, xstar=xs, yhat=ys,
                         gamma=jax.ShapeDtypeStruct((), jnp.float32),
                         k=jax.ShapeDtypeStruct((), jnp.int32))
            comp = step.lower(problem.operands, ys, st).compile()
            cs = collective_stats(comp.as_text(), default_group=8)
            sig = ",".join(f"{k.split('-')[-1]}:{v:.0f}"
                           for k, v in sorted(cs.by_op.items()))
            print(f"{strategy:10s} {alg:3s} {err:12.2e} {dt*1e3:7.1f}ms "
                  f"{cs.wire_bytes:12.3e}  {sig}")
    print("\nNote the A2 rows: fewer forward-op collectives per iteration — "
          "the paper's linearity fusion, visible on the wire.")


if __name__ == "__main__":
    main()

"""End-to-end driver: the paper's workload, distributed through the facade.

Solves a scaled Table-1 dataset with every distribution strategy on 8
simulated devices — each strategy is one `override` away on the same
declarative Problem — and compares iterate agreement + wall time + the
per-iteration collective signature (the MR1-4/Spark comparison, Section 5
of the paper, reproduced on a JAX mesh).

    PYTHONPATH=src python examples/distributed_solver.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

import repro as pd
from repro.configs.paper_problems import PaperProblemConfig
from repro.core.distributed import build_problem, make_step_fn
from repro.core.prox import get_prox
from repro.core.solver import PDState
from repro.roofline.analysis import collective_stats
from repro.sparse import make_lasso


def main():
    cfg = PaperProblemConfig(name="d1/100", m=10_000, n=1_000, nnz=100_000,
                             reg=0.1, gamma0=100.0)
    coo, b, x_true = make_lasso(cfg, seed=0)
    prob = pd.Problem(coo, b, prox="l1", reg=cfg.reg, gamma0=cfg.gamma0)
    ref = prob.solve(iterations=100, format="dense", backend="jnp")

    devs = np.array(jax.devices())
    mesh1 = Mesh(devs.reshape(8), ("p",))
    mesh2 = Mesh(devs.reshape(2, 4), ("data", "model"))
    prox = get_prox("l1", reg=cfg.reg)
    print(f"{'strategy':10s} {'alg':3s} {'err vs dense':>12s} {'t/iter':>9s} "
          f"{'wire B/iter':>12s}  collective signature")
    for strategy, mesh in [("rowpart", mesh1), ("colpart", mesh1),
                           ("dualpart", mesh1), ("block2d", mesh2)]:
        for alg in ("a1", "a2"):
            res = prob.solve(iterations=100, strategy=strategy, mesh=mesh,
                             algorithm=alg)
            dt = res.timings["solve_s"] / 100
            err = float(jnp.max(jnp.abs(res.x - ref.x)))
            # collective signature of one compiled step (kernel layer)
            problem = build_problem(coo, mesh, strategy)
            step = make_step_fn(problem, prox, cfg.gamma0, algorithm=alg)
            xs = jax.ShapeDtypeStruct((problem.n_pad,), jnp.float32)
            ys = jax.ShapeDtypeStruct((problem.m_pad,), jnp.float32)
            st = PDState(xbar=xs, xstar=xs, yhat=ys,
                         gamma=jax.ShapeDtypeStruct((), jnp.float32),
                         k=jax.ShapeDtypeStruct((), jnp.int32))
            comp = step.lower(problem.operands, ys, st).compile()
            cs = collective_stats(comp.as_text(), default_group=8)
            sig = ",".join(f"{k.split('-')[-1]}:{v:.0f}"
                           for k, v in sorted(cs.by_op.items()))
            print(f"{strategy:10s} {alg:3s} {err:12.2e} {dt*1e3:7.1f}ms "
                  f"{cs.wire_bytes:12.3e}  {sig}")
    print("\nNote the A2 rows: fewer forward-op collectives per iteration — "
          "the paper's linearity fusion, visible on the wire.")


if __name__ == "__main__":
    main()

"""Consensus-constrained LM training with the paper's A2 schedule.

The paper cites consensus optimization as a target application of (1).
Here each of 4 data-parallel shards trains its OWN replica of a small LM;
the constraint theta_i = z (as Ax = b) is enforced by the primal-dual
dual variables, with ONE psum per outer iteration regardless of how many
local SGD (inexact-prox) steps run — the paper's reduce-the-barriers idea
applied to training. Compare: lockstep DDP needs one all-reduce per SGD
step.

    PYTHONPATH=src python examples/consensus_lm.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core.consensus import (
    ConsensusConfig, consensus_gap, consensus_init, consensus_step,
)
from repro.distributed import shard_map
from repro.models import build_model


def main():
    cfg = reduced(get_config("qwen3-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_rep, B, S, steps = 4, 2, 32, 60

    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(n_rep, B, S)).astype(np.int32)

    def loss_fn(p, batch):
        return model.loss(p, {"tokens": batch})

    ccfg = ConsensusConfig(gamma0=0.3, inner_steps=4, inner_lr=0.05)
    mesh = Mesh(np.array(jax.devices()).reshape(n_rep), ("data",))

    def run(tokens):
        batch = tokens[0]
        state, lg = consensus_init(loss_fn, params, batch, ccfg, n_rep)

        def body(s, _):
            s = consensus_step(loss_fn, s, batch, ccfg, lg)
            metrics = (consensus_gap(s),
                       jax.lax.pmean(loss_fn(s.z_bar, batch), "data"))
            return s, metrics

        state, (gaps, losses) = jax.lax.scan(body, state, jnp.arange(steps))
        return state.z_bar, gaps, losses

    f = jax.jit(shard_map(run, mesh=mesh, in_specs=(P("data"),),
                              out_specs=(P(), P(), P())))
    z, gaps, losses = f(jnp.asarray(toks))
    print(f"{'iter':>5s} {'consensus gap':>14s} {'mean loss':>10s}")
    for k in range(0, steps, 10):
        print(f"{k:5d} {float(gaps[k]):14.3e} {float(losses[k]):10.4f}")
    print(f"{steps:5d} {float(gaps[-1]):14.3e} {float(losses[-1]):10.4f}")
    # the gap starts ~0 (identical replicas), grows while the shards pull
    # apart, then the dual variables rein it back in — assert the decline
    # from the peak, not against the degenerate start
    assert float(gaps[-1]) < 0.8 * float(gaps.max()), "consensus must tighten"
    assert float(losses[-1]) < float(losses[0]), "loss must improve"
    print("\nreplicas converged to a consensus model (theta_i -> z) while "
          "training — 1 psum per outer iteration.")


if __name__ == "__main__":
    main()

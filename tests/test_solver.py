"""Solver validation: A1 == A2 == numpy reference; O(1/k^2) feasibility;
basis-pursuit recovery; kernel-ops equivalence; certificates."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.paper_problems import small_config
from repro.core.gap import certificates
from repro.core.prox import get_prox
from repro.core.reference import a1_reference, smoothed_gap
from repro.core.solver import dense_ops, ell_ops, solve, solve_tol
from repro.kernels import kernel_ops
from repro.sparse import (
    coo_to_banded, coo_to_dense, coo_to_ell, col_partitioned_ell, make_lasso,
)

CFG = small_config()


@pytest.fixture(scope="module")
def problem():
    coo, b, x_true = make_lasso(CFG, seed=3)
    d = coo_to_dense(coo).astype(np.float64)
    lg = float((d ** 2).sum())
    return coo, d, b, x_true, lg


def test_a1_equals_a2(problem):
    """The paper's Matlab check: A1 and A2 produce identical iterates.
    (A1 carries ybar, A2 carries yhat — compare through dual_point.)"""
    from repro.core.gap import dual_point
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    ops = dense_ops(jnp.asarray(d, jnp.float32))
    s1, _ = solve(ops, prox, b, lg, 100.0, iterations=120, algorithm="a1")
    s2, _ = solve(ops, prox, b, lg, 100.0, iterations=120, algorithm="a2")
    np.testing.assert_allclose(s1.xbar, s2.xbar, atol=2e-5)
    np.testing.assert_allclose(s1.xstar, s2.xstar, atol=2e-5)
    np.testing.assert_allclose(dual_point(ops, b, lg, s1, "a1"),
                               dual_point(ops, b, lg, s2, "a2"), atol=2e-5)


def test_matches_numpy_reference(problem):
    coo, d, b, x_true, lg = problem
    ref = a1_reference(d, np.asarray(b), reg=CFG.reg, gamma0=100.0,
                       iterations=120)
    prox = get_prox("l1", reg=CFG.reg)
    s2, _ = solve(dense_ops(jnp.asarray(d, jnp.float32)), prox, b, lg, 100.0,
                  iterations=120, algorithm="a2")
    np.testing.assert_allclose(np.asarray(s2.xbar), ref["xbar"], atol=5e-4)


def test_sparse_ops_equal_dense(problem):
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    ell, ellt = coo_to_ell(coo), col_partitioned_ell(coo, parts=1)
    s_sp, _ = solve(ell_ops(ell, ellt), prox, b, lg, 100.0, iterations=60)
    s_de, _ = solve(dense_ops(jnp.asarray(d, jnp.float32)), prox, b, lg,
                    100.0, iterations=60)
    np.testing.assert_allclose(s_sp.xbar, s_de.xbar, atol=1e-5)


def test_kernel_ops_equal_dense(problem):
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    kops = kernel_ops(coo_to_ell(coo, pad_to=8),
                      coo_to_banded(coo, band_size=512, pad_to=8),
                      prox, CFG.reg, block_rows=256, block_cols=128)
    s_k, _ = solve(kops, prox, b, lg, 100.0, iterations=60)
    s_d, _ = solve(dense_ops(jnp.asarray(d, jnp.float32)), prox, b, lg,
                   100.0, iterations=60)
    np.testing.assert_allclose(s_k.xbar, s_d.xbar, atol=1e-4)


@pytest.mark.parametrize("algorithm", ["a1", "a2"])
def test_registry_backends_identical_iterates(problem, algorithm):
    """A1/A2 iterates across registry-obtained backends: the jnp ELL path
    is the reference; the kernel and BCSR paths agree to float tolerance,
    and re-building the SAME (format, backend) twice is bitwise-stable."""
    from repro.operators import make_solver_ops

    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    runs = {}
    for name, kw in [("ell/jnp", dict(fmt="ell", backend="jnp")),
                     ("ell/pallas", dict(fmt="ell", backend="pallas",
                                         block_rows=256, block_cols=128)),
                     ("bcsr/pallas", dict(fmt="bcsr", backend="pallas",
                                          bm=8, bn=32))]:
        ops = make_solver_ops(coo, prox=prox, reg=CFG.reg, **kw)
        s, _ = solve(ops, prox, b, lg, 100.0, iterations=60,
                     algorithm=algorithm)
        runs[name] = np.asarray(s.xbar)
        ops2 = make_solver_ops(coo, prox=prox, reg=CFG.reg, **kw)
        s2, _ = solve(ops2, prox, b, lg, 100.0, iterations=60,
                      algorithm=algorithm)
        np.testing.assert_array_equal(runs[name], np.asarray(s2.xbar))
    np.testing.assert_allclose(runs["ell/pallas"], runs["ell/jnp"], atol=1e-4)
    np.testing.assert_allclose(runs["bcsr/pallas"], runs["ell/jnp"], atol=1e-4)


def test_feasibility_rate_order_k2(problem):
    """Paper claim: accelerated O(1/k^2); fit the decay exponent."""
    coo, d, b, x_true, lg = problem
    ref = a1_reference(d, np.asarray(b), reg=CFG.reg, gamma0=1000.0,
                       iterations=600, record=True)
    ks = np.array([h["k"] for h in ref["history"]], float)
    feas = np.array([h["feasibility"] for h in ref["history"]])
    sel = ks >= 100
    slope = np.polyfit(np.log(ks[sel]), np.log(feas[sel]), 1)[0]
    assert slope < -1.5, f"feasibility decay slope {slope} (want ~ -2)"


def test_gap_decays_polynomially(problem):
    """|G_{gamma_k,beta_k}| decays ~ 1/k (the smoothed-gap certificate);
    assert the fitted log-log slope is clearly negative."""
    coo, d, b, x_true, lg = problem
    ref = a1_reference(d, np.asarray(b), reg=CFG.reg, gamma0=100.0,
                       iterations=600, record=True)
    ks = np.array([h["k"] for h in ref["history"]], float)
    gaps = np.abs(np.array([h["gap"] for h in ref["history"]]))
    sel = ks >= 50
    slope = np.polyfit(np.log(ks[sel]), np.log(np.maximum(gaps[sel], 1e-12)),
                       1)[0]
    assert slope < -0.5, f"|gap| decay slope {slope}"
    assert gaps[-1] < 0.3 * gaps.max()   # well past the transient peak


def test_basis_pursuit_recovery(problem):
    """b = A x_true with m >> n: iterates approach x_true."""
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    s, _ = solve(dense_ops(jnp.asarray(d, jnp.float32)), prox, b, lg, 1000.0,
                 iterations=800)
    err = float(jnp.linalg.norm(s.xbar - x_true) / jnp.linalg.norm(x_true))
    assert err < 0.05, f"recovery rel err {err}"


def test_solve_tol_stops_early(problem):
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    s = solve_tol(dense_ops(jnp.asarray(d, jnp.float32)), prox, b, lg,
                  1000.0, max_iterations=4000, tol=3e-2, check_every=16)
    assert int(s.k) < 4000
    feas = float(jnp.linalg.norm(jnp.asarray(d, jnp.float32) @ s.xbar - b))
    assert feas / float(jnp.linalg.norm(b)) < 3.5e-2


def test_solve_tol_hits_tolerance(problem):
    """The returned iterate satisfies the RELATIVE criterion the loop
    tests: ||A xbar - b|| / max(1, ||b||) < tol."""
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    ops = dense_ops(jnp.asarray(d, jnp.float32))
    tol = 5e-2
    s = solve_tol(ops, prox, b, lg, 1000.0, max_iterations=4000, tol=tol,
                  check_every=8)
    rel = float(jnp.linalg.norm(ops.matvec(s.xbar) - b)
                / jnp.maximum(jnp.linalg.norm(b), 1.0))
    assert rel < tol
    assert int(s.k) > 0


def test_solve_tol_respects_max_iterations(problem):
    """An unreachable tolerance stops exactly at the max_iterations
    boundary (k lands on the check_every grid)."""
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    ops = dense_ops(jnp.asarray(d, jnp.float32))
    s = solve_tol(ops, prox, b, lg, 1000.0, max_iterations=40, tol=1e-12,
                  check_every=8)
    assert int(s.k) == 40


def test_solve_tol_never_overruns_max_iterations(problem):
    """Regression: with max_iterations OFF the check_every grid, the final
    partial block must be clamped to min(check_every, max_iterations - k)
    — historically the cond only gated full blocks, overrunning the budget
    by up to check_every - 1 steps."""
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    ops = dense_ops(jnp.asarray(d, jnp.float32))
    for maxit, ce in ((10, 8), (21, 8), (5, 16), (40, 16)):
        s = solve_tol(ops, prox, b, lg, 1000.0, max_iterations=maxit,
                      tol=1e-12, check_every=ce)
        assert int(s.k) == maxit, (maxit, ce, int(s.k))


def test_batched_solve_tol_never_overruns_ragged_max_iterations(problem):
    """The per-slot variant: ragged max_iterations freeze each slot at
    exactly its own budget inside the check block."""
    from repro.core.solver import batched_solve_tol
    from repro.operators import make_operator, stack_coos

    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    m_pad, n_pad = d.shape
    a, at = stack_coos([coo, coo, coo], "ell", m_pad, n_pad, pad_to=8)
    ops = make_operator("stacked_ell", "jnp", a, at).solver_ops()
    maxit = jnp.asarray([10, 21, 64], jnp.int32)
    st = batched_solve_tol(ops, prox, jnp.stack([b, b, b]),
                           jnp.full((3,), lg), jnp.full((3,), 1000.0),
                           max_iterations=maxit, tol=1e-12, check_every=8)
    assert [int(k) for k in st.k] == [10, 21, 64]


def test_solve_tol_check_every_granularity(problem):
    """k is a multiple of check_every, and coarser checking overshoots the
    fine-grained stopping point by less than one check interval."""
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    ops = dense_ops(jnp.asarray(d, jnp.float32))
    ks = {}
    for ce in (1, 4, 16):
        s = solve_tol(ops, prox, b, lg, 1000.0, max_iterations=4000,
                      tol=3e-2, check_every=ce)
        ks[ce] = int(s.k)
        assert ks[ce] % ce == 0
    assert ks[1] <= ks[4] <= ks[16]
    assert ks[16] - ks[1] < 16


def test_certificates_match_reference(problem):
    coo, d, b, x_true, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    ops = dense_ops(jnp.asarray(d, jnp.float32))
    s, _ = solve(ops, prox, b, lg, 100.0, iterations=150)
    cert = certificates(ops, prox, b, lg, 100.0, s)
    ref = a1_reference(d, np.asarray(b), reg=CFG.reg, gamma0=100.0,
                       iterations=150, record=True)
    assert abs(float(cert["gap"]) - ref["history"][-1]["gap"]) < 5e-2
    assert abs(float(cert["feasibility"])
               - ref["history"][-1]["feasibility"]) < 1e-2


def test_dummy_prox_runs(problem):
    """The paper's throughput prox (Section 5) — exercised for parity."""
    coo, d, b, x_true, lg = problem
    prox = get_prox("dummy")
    s, _ = solve(dense_ops(jnp.asarray(d, jnp.float32)), prox, b, lg, 1.0,
                 iterations=10)
    assert np.all(np.isfinite(np.asarray(s.xbar)))

"""Batched solver serving: stacked operators == single-problem oracles;
engine results == standalone solve_tol on ragged shape mixes; masked
early-exit semantics; bucketing policy."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.base import PaperProblemConfig
from repro.core.prox import get_prox
from repro.core.solver import (
    batched_feasibility, batched_init, batched_solve, batched_solve_tol,
    batched_step, dense_ops, solve, solve_tol,
)
from repro.operators import make_operator, stack_coos
from repro.serve import BATCHED_PROX_FAMILIES, SolveRequest, SolverEngine
from repro.sparse import coo_to_dense, make_lasso, stacked_ell_matvec

M_PAD, N_PAD = 96, 24


def _mk_problem(i, m, n, row_nnz=6):
    cfg = PaperProblemConfig(name="t", m=m, n=n, nnz=m * row_nnz, reg=0.1)
    return make_lasso(cfg, seed=i)


@pytest.fixture(scope="module")
def ragged():
    """Three ragged problems padded into one (M_PAD, N_PAD) bucket."""
    shapes = [(96, 24), (64, 16), (80, 20)]
    probs = [_mk_problem(i, m, n) for i, (m, n) in enumerate(shapes)]
    coos = [p[0] for p in probs]
    bs = [p[1] for p in probs]
    bmat = jnp.stack([jnp.pad(b, (0, M_PAD - b.shape[0])) for b in bs])
    lg = jnp.array([float(jnp.sum(c.vals * c.vals)) for c in coos])
    return coos, bs, bmat, lg


def test_stacked_operators_match_dense_oracle(ragged):
    coos, bs, bmat, lg = ragged
    x = jnp.stack([jnp.asarray(np.random.default_rng(0).standard_normal(
        (N_PAD,)), jnp.float32) for _ in coos])
    y = jnp.stack([jnp.asarray(np.random.default_rng(1).standard_normal(
        (M_PAD,)), jnp.float32) for _ in coos])
    a, at = stack_coos(coos, "ell", M_PAD, N_PAD, pad_to=8)
    ab, atb = stack_coos(coos, "bcsr", M_PAD, N_PAD, bm=8, bn=8)
    dense = [np.zeros((M_PAD, N_PAD), np.float32) for _ in coos]
    for d, c in zip(dense, coos):
        d[:c.m, :c.n] = coo_to_dense(c)
    for fmt, backend, args in [("stacked_ell", "jnp", (a, at)),
                               ("stacked_ell", "pallas", (a, at)),
                               ("stacked_bcsr", "jnp", (ab, atb)),
                               ("stacked_bcsr", "pallas", (ab, atb))]:
        op = make_operator(fmt, backend, *args)
        fwd = np.asarray(op.matvec(x))
        bwd = np.asarray(op.rmatvec(y))
        for i, d in enumerate(dense):
            np.testing.assert_allclose(fwd[i], d @ np.asarray(x[i]),
                                       atol=1e-4, err_msg=f"{fmt}/{backend}")
            np.testing.assert_allclose(bwd[i], d.T @ np.asarray(y[i]),
                                       atol=1e-4, err_msg=f"{fmt}/{backend}")


def test_batched_fused_dual_matches_composed(ragged):
    """The batch-grid fused kernel (per-slot coefficient rows) == the
    composed c0*yhat + A(c1*xstar + c2*xbar) - c3*b reference."""
    coos, bs, bmat, lg = ragged
    a, at = stack_coos(coos, "ell", M_PAD, N_PAD, pad_to=8)
    op = make_operator("stacked_ell", "pallas", a, at)
    rng = np.random.default_rng(2)
    B = len(coos)
    xstar = jnp.asarray(rng.standard_normal((B, N_PAD)), jnp.float32)
    xbar = jnp.asarray(rng.standard_normal((B, N_PAD)), jnp.float32)
    yhat = jnp.asarray(rng.standard_normal((B, M_PAD)), jnp.float32)
    cs = [jnp.asarray(rng.standard_normal((B, 1)), jnp.float32)
          for _ in range(4)]
    got = op.fused_dual(yhat, xstar, xbar, bmat, *cs)
    want = (cs[0] * yhat + stacked_ell_matvec(a, cs[1] * xstar + cs[2] * xbar)
            - cs[3] * bmat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("algorithm", ["a1", "a2"])
def test_batched_solve_matches_sequential(ragged, algorithm):
    """Fixed-iteration batched iterates == per-problem solve within 1e-5
    on the ragged mix (padding is exact, slots are independent)."""
    coos, bs, bmat, lg = ragged
    prox = get_prox("l1", reg=0.1)
    a, at = stack_coos(coos, "ell", M_PAD, N_PAD, pad_to=8)
    ops = make_operator("stacked_ell", "jnp", a, at).solver_ops()
    st = batched_solve(ops, prox, bmat, lg, jnp.full((len(coos),), 100.0),
                       iterations=60, algorithm=algorithm)
    for i, (c, b) in enumerate(zip(coos, bs)):
        d = jnp.asarray(coo_to_dense(c))
        s, _ = solve(dense_ops(d), prox, b, float(lg[i]), 100.0,
                     iterations=60, algorithm=algorithm)
        np.testing.assert_allclose(np.asarray(st.xbar[i, :c.n]),
                                   np.asarray(s.xbar), atol=1e-5)
        if c.n < N_PAD:     # padded coordinates never move off zero
            assert float(jnp.max(jnp.abs(st.xbar[i, c.n:]))) == 0.0


def test_batched_solve_tol_matches_sequential(ragged):
    """Per-slot early exit stops at the same iteration as solve_tol and
    returns the same iterates."""
    coos, bs, bmat, lg = ragged
    prox = get_prox("l1", reg=0.1)
    a, at = stack_coos(coos, "ell", M_PAD, N_PAD, pad_to=8)
    ops = make_operator("stacked_ell", "jnp", a, at).solver_ops()
    st = batched_solve_tol(ops, prox, bmat, lg,
                           jnp.full((len(coos),), 1000.0),
                           max_iterations=4000, tol=3e-2, check_every=16)
    for i, (c, b) in enumerate(zip(coos, bs)):
        d = jnp.asarray(coo_to_dense(c))
        s = solve_tol(dense_ops(d), prox, b, float(lg[i]), 1000.0,
                      max_iterations=4000, tol=3e-2, check_every=16)
        assert int(st.k[i]) == int(s.k)
        np.testing.assert_allclose(np.asarray(st.xbar[i, :c.n]),
                                   np.asarray(s.xbar), atol=1e-5)


def test_masked_step_freezes_slots(ragged):
    """A frozen slot's state is bitwise unchanged by further steps."""
    coos, bs, bmat, lg = ragged
    prox = get_prox("l1", reg=0.1)
    a, at = stack_coos(coos, "ell", M_PAD, N_PAD, pad_to=8)
    ops = make_operator("stacked_ell", "jnp", a, at).solver_ops()
    g0 = jnp.full((len(coos),), 100.0)
    st = batched_init(ops, prox, bmat, lg, g0)
    mask = jnp.array([True, False, True])
    st2 = batched_step(ops, prox, bmat, lg, g0, st, mask=mask)
    np.testing.assert_array_equal(np.asarray(st2.xbar[1]),
                                  np.asarray(st.xbar[1]))
    assert int(st2.k[1]) == 0 and int(st2.k[0]) == 1
    assert float(jnp.max(jnp.abs(st2.xbar[0] - st.xbar[0]))) > 0.0


def _mk_requests(num, shapes, **kw):
    reqs = []
    for i in range(num):
        m, n = shapes[i % len(shapes)]
        coo, b, _ = _mk_problem(100 + i, m, n)
        reqs.append(SolveRequest(uid=i, coo=coo, b=b, gamma0=1000.0,
                                 tol=3e-2, max_iterations=4000, **kw))
    return reqs


@pytest.mark.parametrize("fmt,backend", [("ell", "jnp"), ("ell", "pallas"),
                                         ("bcsr", "jnp")])
def test_engine_matches_solve_tol(fmt, backend):
    """More ragged requests than slots (continuous admission): every
    request stops at the standalone solve_tol iteration with iterates
    within 1e-5."""
    reqs = _mk_requests(6, [(96, 24), (64, 16), (80, 20)])
    eng = SolverEngine(slots=2, fmt=fmt, backend=backend, check_every=16)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(reqs) and all(r.done for r in done)
    for r in done:
        d = jnp.asarray(coo_to_dense(r.coo))
        s = solve_tol(dense_ops(d), get_prox(r.prox, reg=r.reg), r.b, r.lg,
                      r.gamma0, max_iterations=r.max_iterations, tol=r.tol,
                      check_every=16)
        assert r.iterations == int(s.k), (fmt, backend, r.uid)
        np.testing.assert_allclose(r.x, np.asarray(s.xbar), atol=1e-5)
        assert r.feasibility < r.tol


def test_engine_respects_max_iterations():
    """An unreachable tolerance stops at max_iterations (on the
    check_every grid, like solve_tol)."""
    reqs = _mk_requests(2, [(64, 16)], )
    for r in reqs:
        r.tol = 1e-12
        r.max_iterations = 32
    eng = SolverEngine(slots=2, check_every=16)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.iterations == 32 for r in done)
    assert all(not r.feasibility < 1e-12 for r in done)


def test_engine_bucketing_policy():
    """Nearby shapes collapse into one bucket; prox family splits it."""
    eng = SolverEngine(slots=2)
    r1 = _mk_requests(1, [(90, 20)])[0]
    r2 = _mk_requests(1, [(70, 17)])[0]
    r3 = _mk_requests(1, [(90, 20)], prox="sq_l2", reg=0.5)[0]
    k1, k2, k3 = eng.submit(r1), eng.submit(r2), eng.submit(r3)
    assert k1.m_pad == k2.m_pad == 128 and k1.n_pad == k2.n_pad == 32
    assert (k1.m_pad, k1.n_pad) == (k3.m_pad, k3.n_pad) and k1 != k3
    done = eng.run()
    assert len(done) == 3 and len(eng.buckets) >= 2


def test_engine_mixed_prox_families():
    """l1 and sq_l2 tenants in one stream both converge to their own
    standalone results."""
    reqs = (_mk_requests(2, [(64, 16)])
            + _mk_requests(2, [(64, 16)], prox="sq_l2", reg=0.5))
    for i, r in enumerate(reqs):
        r.uid = i
    eng = SolverEngine(slots=4, check_every=16)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 4
    for r in done:
        d = jnp.asarray(coo_to_dense(r.coo))
        s = solve_tol(dense_ops(d), get_prox(r.prox, reg=r.reg), r.b, r.lg,
                      r.gamma0, max_iterations=r.max_iterations, tol=r.tol,
                      check_every=16)
        assert r.iterations == int(s.k)
        np.testing.assert_allclose(r.x, np.asarray(s.xbar), atol=1e-5)


def test_engine_evicts_idle_buckets():
    """Draining then evicting frees the bucket; resubmitting the same
    shape rebuilds it and still matches the standalone solve."""
    eng = SolverEngine(slots=2, check_every=16)
    for r in _mk_requests(2, [(64, 16)]):
        eng.submit(r)
    eng.run()
    assert len(eng.buckets) == 1
    assert eng.evict_idle_buckets() == 1
    assert not eng.buckets
    r = _mk_requests(1, [(64, 16)])[0]
    eng.submit(r)
    done = eng.run()
    assert len(done) == 1
    d = jnp.asarray(coo_to_dense(r.coo))
    s = solve_tol(dense_ops(d), get_prox(r.prox, reg=r.reg), r.b, r.lg,
                  r.gamma0, max_iterations=r.max_iterations, tol=r.tol,
                  check_every=16)
    assert r.iterations == int(s.k)
    np.testing.assert_allclose(r.x, np.asarray(s.xbar), atol=1e-5)


def test_engine_ragged_max_iterations_exact():
    """Regression: per-slot max_iterations off the check_every grid stop
    at EXACTLY their budget (slots freeze mid-block), like the clamped
    solve_tol."""
    reqs = _mk_requests(2, [(64, 16)])
    for r, maxit in zip(reqs, (10, 21)):
        r.tol = 1e-12
        r.max_iterations = maxit
    eng = SolverEngine(slots=2, check_every=16)
    for r in reqs:
        eng.submit(r)
    done = {r.uid: r for r in eng.run()}
    assert [done[r.uid].iterations for r in reqs] == [10, 21]


def test_engine_streams_oversized_requests_on_one_device():
    """A request above the per-device capacity (decide_placement ->
    "sharded") on a 1-device engine cannot be sharded OR stay resident:
    it runs in a streamed bucket (operand cache dropped every tick) and
    still matches the standalone solve_tol exactly."""
    from repro.plan import decide_placement

    reqs = _mk_requests(2, [(96, 24)])       # nnz = 96*6 > shard_above
    eng = SolverEngine(slots=2, check_every=16, shard_above=500,
                       devices=1)            # pin: streamed, never sharded
    keys = [eng.submit(r) for r in reqs]
    _, why = decide_placement(96, 24, reqs[0].coo.nnz, 1, 500)
    assert "streams" in why
    done = eng.run()
    bucket = eng.buckets[keys[0]]
    assert not bucket.resident and bucket.dev is None
    for r in done:
        d = jnp.asarray(coo_to_dense(r.coo))
        s = solve_tol(dense_ops(d), get_prox(r.prox, reg=r.reg), r.b, r.lg,
                      r.gamma0, max_iterations=r.max_iterations, tol=r.tol,
                      check_every=16)
        assert r.iterations == int(s.k)
        np.testing.assert_allclose(r.x, np.asarray(s.xbar), atol=1e-5)


def test_byte_budget_streams_what_slot_count_would_admit():
    """Byte-based ``device_budget`` admission: a BCSR bucket whose TILE
    bytes exceed the device's budget is served streamed even though its
    nnz is far below the shard threshold and slot-count accounting would
    happily admit it resident — while the SAME budget holds the ELL twin
    (an order of magnitude fewer bytes for the same nonzeros) resident.
    Results must match the standalone solve either way."""
    reqs = _mk_requests(2, [(96, 24)])
    probe = SolverEngine(slots=2, fmt="bcsr", check_every=16, devices=1)
    bcsr_slot = probe.bucket_slot_bytes(probe.bucket_key(reqs[0]))
    ell_probe = SolverEngine(slots=2, fmt="ell", check_every=16, devices=1)
    ell_slot = ell_probe.bucket_slot_bytes(ell_probe.bucket_key(reqs[0]))
    assert ell_slot < bcsr_slot  # the gap slot counting cannot see
    budget = bcsr_slot - 1       # holds >= 1 ELL slot, < 1 BCSR slot
    assert budget >= ell_slot

    eng = SolverEngine(slots=2, fmt="bcsr", check_every=16,
                       device_budget=budget, devices=1)
    keys = [eng.submit(r) for r in reqs]
    done = eng.run()
    assert not eng.buckets[keys[0]].resident     # streamed, not admitted
    for r in done:
        d = jnp.asarray(coo_to_dense(r.coo))
        s = solve_tol(dense_ops(d), get_prox(r.prox, reg=r.reg), r.b, r.lg,
                      r.gamma0, max_iterations=r.max_iterations, tol=r.tol,
                      check_every=16)
        assert r.iterations == int(s.k)
        np.testing.assert_allclose(r.x, np.asarray(s.xbar), atol=1e-5)

    eng2 = SolverEngine(slots=2, fmt="ell", check_every=16,
                        device_budget=budget, devices=1)
    keys2 = [eng2.submit(r) for r in _mk_requests(2, [(96, 24)])]
    eng2.run()
    assert eng2.buckets[keys2[0]].resident       # same bytes admit ELL


def test_plan_records_bucket_body_and_operand_bytes():
    """Every plan over a concrete matrix records which serving bucket
    body its placement maps to and the resident operand-byte cost (the
    engine's byte-budget admission unit) as reasons."""
    from repro.api import Problem

    coo, b, _ = _mk_problem(0, 64, 16)
    pl = Problem(coo, b, prox="l1", reg=0.1).plan(tol=1e-2)
    assert "bucket_body" in pl.reasons, pl.reasons
    assert "operand_bytes" in pl.reasons, pl.reasons
    assert "bytes" in pl.reasons["operand_bytes"]


def test_engine_rejects_unservable_prox():
    r = _mk_requests(1, [(64, 16)])[0]
    r.prox = "group_l1"
    eng = SolverEngine()
    with pytest.raises(KeyError, match="not servable"):
        eng.submit(r)
    assert "group_l1" not in BATCHED_PROX_FAMILIES


def test_batched_feasibility_matches_per_problem(ragged):
    coos, bs, bmat, lg = ragged
    prox = get_prox("l1", reg=0.1)
    a, at = stack_coos(coos, "ell", M_PAD, N_PAD, pad_to=8)
    ops = make_operator("stacked_ell", "jnp", a, at).solver_ops()
    st = batched_solve(ops, prox, bmat, lg, jnp.full((len(coos),), 100.0),
                       iterations=20)
    feas = np.asarray(batched_feasibility(ops, bmat, st))
    for i, (c, b) in enumerate(zip(coos, bs)):
        d = jnp.asarray(coo_to_dense(c))
        want = float(jnp.linalg.norm(d @ st.xbar[i, :c.n] - b)
                     / jnp.maximum(jnp.linalg.norm(b), 1.0))
        np.testing.assert_allclose(feas[i], want, rtol=1e-4)


# ---------------------------------------------------------------------------
# property: continuous-admission slot discipline under arbitrary ragged
# submit/freeze interleavings (hypothesis when installed; the deterministic
# regression below drives the same runner on fixed schedules either way)
# ---------------------------------------------------------------------------

# shared across the engines the runner builds, so repeated property
# examples with the identical bucket config reuse one compiled executable
_INTERLEAVE_AOT: dict = {}


def _run_interleaving(ops):
    """Drive a 2-slot engine through ``ops`` — each positive int submits
    that many requests, each 0 is one engine tick — then drain, checking
    the continuous-admission invariants after every event:

      * a slot is only ever (re)assigned while free: ``_write_slot`` on a
        live slot, or on a slot whose previous tenant was never harvested
        (a double-assign of one freeing), trips an assert;
      * bucket occupancy stays consistent: the active mask and the
        slot->request map agree after every tick;
      * every submitted uid is harvested exactly once, with a result.

    Ragged-ness comes from per-request iteration budgets (8/16/24 with
    check_every=8), so slots free at different ticks regardless of how
    the schedule interleaves submits between them.
    """
    eng = SolverEngine(slots=2, fmt="ell", check_every=8,
                       min_rows=16, min_cols=8)
    eng._aot_cache = _INTERLEAVE_AOT
    submitted, harvested = [], []
    tenancy: dict = {}              # (key, slot) -> uid living there

    real_write = eng._write_slot

    def checked_write(key, bucket, slot, req):
        assert not bucket.active[slot], \
            f"uid {req.uid} written over LIVE slot {slot}"
        prev = tenancy.get((key, slot))
        freed = {r.uid for r in harvested} | {r.uid for r in eng.completed}
        assert prev is None or prev in freed, \
            f"slot {slot} reassigned (uid {prev} -> {req.uid}) before " \
            f"its tenant was harvested"
        tenancy[(key, slot)] = req.uid
        return real_write(key, bucket, slot, req)

    eng._write_slot = checked_write

    def check_occupancy():
        for key, bucket in eng.buckets.items():
            live = set(np.nonzero(bucket.active)[0].tolist())
            assert set(bucket.requests) == live, \
                f"bucket {key}: occupants {sorted(bucket.requests)} != " \
                f"active mask {sorted(live)}"

    uid = 0
    for op in ops + [0] * 64:       # trailing ticks drain everything
        if op:
            for _ in range(op):
                m, n = [(16, 8), (12, 8), (8, 8)][uid % 3]
                coo, b, _ = _mk_problem(300 + uid, m, n, row_nnz=4)
                eng.submit(SolveRequest(
                    uid=uid, coo=coo, b=b, gamma0=1000.0, tol=1e-6,
                    max_iterations=8 * (1 + uid % 3)))
                submitted.append(uid)
                uid += 1
        else:
            alive = eng.step()
            check_occupancy()
            harvested.extend(eng.completed)
            eng.completed = []
            if not alive and not submitted:
                break
    assert not eng.step(), "engine not drained by trailing ticks"
    harvested.extend(eng.completed)
    uids = [r.uid for r in harvested]
    assert sorted(uids) == sorted(submitted), \
        f"harvest mismatch: {sorted(uids)} != {sorted(submitted)}"
    assert len(set(uids)) == len(uids), f"uid harvested twice: {uids}"
    assert all(r.done and r.x is not None for r in harvested)


def test_interleaved_admission_deterministic_schedules():
    """Fixed schedules covering the shapes hypothesis would explore:
    burst-then-drain, submit-while-stepping, more work than slots, and
    submits landing exactly when a slot frees (tick 1 retires the
    8-iteration request; the next submit must reuse its slot cleanly)."""
    _run_interleaving([4])                    # burst, trailing drain
    _run_interleaving([1, 0, 1, 0, 1, 0])     # steady trickle
    _run_interleaving([3, 0, 0, 2, 0, 1])     # refill freed slots mid-run
    _run_interleaving([2, 0, 1, 0, 0, 0, 3])  # late burst after drain


def test_interleaved_admission_property():
    """Property form of the same invariants: arbitrary ragged schedules.
    Runs wherever hypothesis is installed (CI pins it); the deterministic
    schedules above keep the runner exercised without it."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(st.lists(st.integers(min_value=0, max_value=3),
                        min_size=1, max_size=10))
    def run(ops):
        _run_interleaving(ops)

    run()

"""Sparse formats/partitioners vs dense oracles + hypothesis invariants."""
import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.sparse import (
    banded_rmatvec, banded_to_dense, block_partitioned_ell, col_norms_sq,
    col_partitioned_ell, coo_matvec, coo_rmatvec, coo_to_banded, coo_to_dense,
    coo_to_ell, ell_col_norms_sq, ell_matvec, ell_rmatvec, ell_to_dense,
    random_coo, row_partitioned_ell,
)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 60), n=st.integers(3, 40), seed=st.integers(0, 999))
def test_ell_roundtrip_and_matvec(m, n, seed):
    k = min(4, n)
    coo = random_coo(m, n, k, seed=seed)
    d = coo_to_dense(coo)
    ell = coo_to_ell(coo, pad_to=8)
    np.testing.assert_allclose(ell_to_dense(ell), d, atol=1e-6)
    x = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(ell_matvec(ell, jnp.asarray(x)), d @ x,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(coo_matvec(coo, jnp.asarray(x)), d @ x,
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(8, 64), n=st.integers(3, 30),
       band=st.sampled_from([4, 8, 16]), seed=st.integers(0, 999))
def test_banded_rmatvec(m, n, band, seed):
    coo = random_coo(m, n, min(3, n), seed=seed)
    d = coo_to_dense(coo)
    bell = coo_to_banded(coo, band_size=band, pad_to=4)
    np.testing.assert_allclose(banded_to_dense(bell)[:m], d, atol=1e-6)
    y = np.random.default_rng(seed).standard_normal(m).astype(np.float32)
    np.testing.assert_allclose(banded_rmatvec(bell, jnp.asarray(y)), d.T @ y,
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(8, 40), n=st.integers(4, 24), r=st.sampled_from([2, 4]),
       c=st.sampled_from([2, 4]), seed=st.integers(0, 99))
def test_block_partition_reconstructs(m, n, r, c, seed):
    coo = random_coo(m, n, min(3, n), seed=seed)
    d = coo_to_dense(coo)
    ev, ec, mp, npad = block_partitioned_ell(coo, r, c)
    dd = np.zeros((mp, npad), np.float32)
    mb, nb = mp // r, npad // c
    ev_, ec_ = np.asarray(ev), np.asarray(ec)
    for i in range(r):
        for j in range(c):
            for row in range(mb):
                for s in range(ev_.shape[3]):
                    dd[i * mb + row, j * nb + ec_[i, j, row, s]] += \
                        ev_[i, j, row, s]
    np.testing.assert_allclose(dd[:m, :n], d, atol=1e-6)


def test_col_norms_match_dense():
    coo = random_coo(50, 20, 4, seed=7)
    d = coo_to_dense(coo)
    np.testing.assert_allclose(col_norms_sq(coo), (d ** 2).sum(0), rtol=1e-4)
    at = col_partitioned_ell(coo, parts=4)
    np.testing.assert_allclose(ell_col_norms_sq(at)[:20], (d ** 2).sum(0),
                               rtol=1e-4)


def test_row_partition_pads_to_parts():
    coo = random_coo(37, 13, 3, seed=1)
    ell = row_partitioned_ell(coo, parts=8)
    assert ell.vals.shape[0] % 8 == 0
    d = coo_to_dense(coo)
    y = ell_matvec(ell, jnp.asarray(
        np.random.default_rng(0).standard_normal(13).astype(np.float32)))
    assert np.allclose(np.asarray(y)[37:], 0.0)  # padded rows contribute 0


def test_generator_statistics_match_table1():
    """Row/col degree concentration like the paper's Table 1."""
    coo = random_coo(2000, 100, 10, seed=0)
    rows = np.bincount(np.asarray(coo.rows), minlength=2000)
    cols = np.bincount(np.asarray(coo.cols), minlength=100)
    assert rows.min() == rows.max() == 10          # exact per-row nnz
    assert abs(cols.mean() - 200.0) < 1e-9         # nnz/n
    assert cols.min() > 100 and cols.max() < 320   # concentrated (Table 1)

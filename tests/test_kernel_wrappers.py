"""Padding edge cases for the jit'd kernel wrappers (repro.kernels.ops):
m not a multiple of block_rows, tiny m (< 8), zero-nnz rows/cols, and
dtype preservation through ell_spmv / banded_spmv_t / bcsr_spmv."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import banded_spmv_t, bcsr_spmv, ell_spmv
from repro.sparse import (
    COO, coo_to_banded, coo_to_bcsr, coo_to_dense, coo_to_ell, random_coo,
)


def _coo(m, n, k, seed=0):
    coo = random_coo(m, n, min(k, n), seed=seed)
    return coo, coo_to_dense(coo).astype(np.float32)


@pytest.mark.parametrize("m", [33, 100, 257])
def test_ell_spmv_m_not_block_multiple(m):
    """block_rows doesn't divide m: wrapper pads rows, output sliced back."""
    coo, d = _coo(m, 40, 3)
    ell = coo_to_ell(coo, pad_to=8)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(40), jnp.float32)
    out = ell_spmv(ell, x, block_rows=32)
    assert out.shape == (m,)
    np.testing.assert_allclose(np.asarray(out), d @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m", [1, 3, 7])
def test_ell_spmv_tiny_m(m):
    """m < 8 (one sublane tile): block_rows clamps to 8, rows pad up."""
    coo, d = _coo(m, 5, 2)
    ell = coo_to_ell(coo, pad_to=8)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(5), jnp.float32)
    out = ell_spmv(ell, x)
    assert out.shape == (m,)
    np.testing.assert_allclose(np.asarray(out), d @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)


def test_ell_spmv_zero_nnz_rows():
    """Rows with no nonzeros (ELL padding entries col=0/val=0) contribute
    exactly zero, even when x[0] != 0."""
    m, n = 24, 10
    rows = np.array([0, 0, 5, 23], np.int32)       # rows 1-4, 6-22 empty
    cols = np.array([1, 9, 4, 0], np.int32)
    vals = np.array([2.0, -1.0, 3.0, 4.0], np.float32)
    coo = COO(rows=jnp.asarray(rows), cols=jnp.asarray(cols),
              vals=jnp.asarray(vals), m=m, n=n)
    x = jnp.arange(1.0, n + 1.0, dtype=jnp.float32)   # x[0] = 1 != 0
    out = np.asarray(ell_spmv(coo_to_ell(coo, pad_to=8), x, block_rows=8))
    d = coo_to_dense(coo)
    np.testing.assert_allclose(out, d @ np.asarray(x), rtol=1e-5, atol=1e-5)
    empty = np.setdiff1d(np.arange(m), rows)
    np.testing.assert_array_equal(out[empty], np.zeros(len(empty)))


def test_banded_spmv_t_m_not_band_multiple():
    """band_size doesn't divide m: y pads to num_bands * band_size."""
    coo, d = _coo(130, 20, 3, seed=3)
    bell = coo_to_banded(coo, band_size=64, pad_to=4)
    assert bell.num_bands * bell.band_size > 130
    y = jnp.asarray(np.random.default_rng(4).standard_normal(130), jnp.float32)
    out = banded_spmv_t(bell, y, block_cols=8)
    assert out.shape == (20,)
    np.testing.assert_allclose(np.asarray(out), d.T @ np.asarray(y),
                               rtol=1e-4, atol=1e-4)


def test_banded_spmv_t_zero_nnz_cols():
    """Columns with no nonzeros return exactly zero."""
    m, n = 40, 12
    rows = np.array([0, 17, 39], np.int32)
    cols = np.array([3, 3, 11], np.int32)          # all other cols empty
    vals = np.array([1.0, 2.0, -1.0], np.float32)
    coo = COO(rows=jnp.asarray(rows), cols=jnp.asarray(cols),
              vals=jnp.asarray(vals), m=m, n=n)
    bell = coo_to_banded(coo, band_size=16, pad_to=2)
    y = jnp.ones(m, jnp.float32)
    out = np.asarray(banded_spmv_t(bell, y, block_cols=4))
    d = coo_to_dense(coo)
    np.testing.assert_allclose(out, d.T @ np.ones(m), rtol=1e-5, atol=1e-5)
    empty = np.setdiff1d(np.arange(n), cols)
    np.testing.assert_array_equal(out[empty], np.zeros(len(empty)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wrappers_preserve_dtype(dtype):
    """Outputs carry the vector dtype through all three spmv wrappers
    (accumulation is fp32 in-kernel, cast back on store)."""
    coo, d = _coo(50, 30, 4, seed=5)
    coo.vals = coo.vals.astype(dtype)
    x = jnp.asarray(np.random.default_rng(6).standard_normal(30), dtype)
    y = jnp.asarray(np.random.default_rng(7).standard_normal(50), dtype)
    out_f = ell_spmv(coo_to_ell(coo, pad_to=8), x, block_rows=16)
    out_b = banded_spmv_t(coo_to_banded(coo, band_size=16, pad_to=4), y,
                          block_cols=8)
    out_c = bcsr_spmv(coo_to_bcsr(coo, bm=8, bn=16), x, block_brows=2)
    assert out_f.dtype == dtype and out_f.shape == (50,)
    assert out_b.dtype == dtype and out_b.shape == (30,)
    assert out_c.dtype == dtype and out_c.shape == (50,)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_f, np.float32),
                               d @ np.asarray(x, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(out_c, np.float32),
                               d @ np.asarray(x, np.float32), **tol)


@pytest.mark.parametrize("nbr_block", [1, 3, 5])
def test_bcsr_spmv_blockrow_padding(nbr_block):
    """block_brows doesn't divide the block-row count: wrapper pads the
    tile stream with zero tiles and slices the result."""
    coo, d = _coo(77, 23, 3, seed=8)               # nbr = ceil(77/8) = 10
    b = coo_to_bcsr(coo, bm=8, bn=16)
    x = jnp.asarray(np.random.default_rng(9).standard_normal(23), jnp.float32)
    out = bcsr_spmv(b, x, block_brows=nbr_block)
    assert out.shape == (77,)
    np.testing.assert_allclose(np.asarray(out), d @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)

"""repro.analysis: every AST rule fires on a minimal tripping fixture,
suppressions require reasons, the repo's own src/ tree lints clean, and
the strict-mode sanitizers (CompileWatcher / transfer guard / engine tick
counters) enforce the warm-tick claims at runtime."""
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.analysis.lint import lint_paths, main as lint_main
from repro.analysis.rules import RULES, RULES_BY_ID, check_source
from repro.analysis.strict import (
    CompileWatcher, StrictViolation, expect_no_retraces, set_strict,
    strict_enabled, strict_mode,
)

REPO = Path(__file__).resolve().parent.parent


def _rules_of(source, path="src/repro/somewhere/mod.py"):
    return {v.rule for v in check_source(textwrap.dedent(source), path)}


# ---------------------------------------------------------------------------
# one tripping fixture per rule
# ---------------------------------------------------------------------------

def test_r1_literal_interpret_fires():
    src = "pl.pallas_call(kern, out_shape=o, interpret=True)(x)\n"
    assert "R1" in _rules_of(src, "src/repro/kernels/spmv.py")


def test_r1_interpret_passthrough_and_whitelist_ok():
    ok = "pl.pallas_call(kern, out_shape=o, interpret=interpret)(x)\n"
    assert "R1" not in _rules_of(ok, "src/repro/kernels/spmv.py")
    lit = "pl.pallas_call(kern, out_shape=o, interpret=False)(x)\n"
    assert "R1" not in _rules_of(lit, "src/repro/kernels/interpret.py")


def test_r2_hand_assembled_ops_fires():
    src = """
    from repro.core.solver import dense_ops
    ops = SolverOps(matvec=mv, rmatvec=rmv)
    legacy = dense_ops(a)
    """
    got = _rules_of(src, "src/repro/serve/frontend.py")
    assert "R2" in got


def test_r2_allowed_inside_core_and_operators():
    src = "ops = SolverOps(matvec=mv, rmatvec=rmv)\n"
    assert "R2" not in _rules_of(src, "src/repro/core/solver.py")
    assert "R2" not in _rules_of(src, "src/repro/operators/base.py")


def test_r3_unseeded_randomness_fires():
    assert "R3" in _rules_of("x = np.random.rand(3)\n")
    assert "R3" in _rules_of("rng = np.random.default_rng()\n")
    assert "R3" in _rules_of(
        "key = jax.random.PRNGKey(int(time.time()))\n")


def test_r3_seeded_randomness_ok():
    assert "R3" not in _rules_of("rng = np.random.default_rng(0)\n")
    assert "R3" not in _rules_of("key = jax.random.PRNGKey(seed)\n")


def test_r4_float64_outside_whitelist_fires():
    assert "R4" in _rules_of("x = np.zeros(4, np.float64)\n")
    assert "R4" in _rules_of("x = np.asarray(v, dtype='float64')\n")


def test_r4_whitelist_and_dtype_compare_ok():
    src = "x = np.zeros(4, np.float64)\n"
    assert "R4" not in _rules_of(src, "src/repro/solvers/rcd.py")
    assert "R4" not in _rules_of(src, "src/repro/core/reference.py")
    assert "R4" not in _rules_of("ok = a.dtype == np.dtype(np.float64)\n")


def test_r5_wall_clock_in_serve_fires():
    src = "t0 = time.perf_counter()\n"
    assert "R5" in _rules_of(src, "src/repro/serve/solver_engine.py")
    imp = "from time import monotonic\n"
    assert "R5" in _rules_of(imp, "src/repro/serve/frontend.py")


def test_r5_clock_py_and_non_serve_ok():
    src = "t0 = time.perf_counter()\n"
    assert "R5" not in _rules_of(src, "src/repro/serve/clock.py")
    assert "R5" not in _rules_of(src, "src/repro/roofline/measure.py")


def test_r6_reasonless_decide_fires():
    src = """
    def decide_format(coo):
        if coo.nnz > 10:
            return ("ell", f"row-regular nnz={coo.nnz}")
        return ("bcsr",)
    """
    assert "R6" in _rules_of(src)


def test_r6_reasoned_returns_ok():
    src = """
    def decide_format(coo):
        if coo.nnz > 10:
            return ("ell", f"row-regular nnz={coo.nnz}")
        reason = "fallback: " + str(coo.nnz)
        return ("bcsr", reason)
    """
    assert "R6" not in _rules_of(src)


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_reasoned_allow_suppresses():
    src = ("# repro: allow[R4] -- float64 oracle accumulator, host-side\n"
           "x = np.zeros(4, np.float64)\n")
    assert check_source(src, "src/repro/api.py") == []
    inline = ("x = np.zeros(4, np.float64)"
              "  # repro: allow[R4] -- host-side oracle\n")
    assert check_source(inline, "src/repro/api.py") == []


def test_reasonless_allow_is_r0():
    src = ("# repro: allow[R4]\n"
           "x = np.zeros(4, np.float64)\n")
    got = {v.rule for v in check_source(src, "src/repro/api.py")}
    assert got == {"R0", "R4"}   # no reason: allow is void AND flagged


def test_unknown_rule_id_is_r0():
    src = "pass  # repro: allow[R99] -- whatever\n"
    assert {v.rule for v in check_source(src, "x.py")} == {"R0"}


def test_docstring_mention_is_not_a_suppression():
    src = ('"""Write # repro: allow[R4] -- why to suppress."""\n'
           "x = np.zeros(4, np.float64)\n")
    got = {v.rule for v in check_source(src, "src/repro/api.py")}
    assert got == {"R4"}         # the docstring neither allows nor is R0


def test_allow_covers_next_line_only():
    src = ("# repro: allow[R4] -- reasoned\n"
           "x = np.zeros(4, np.float64)\n"
           "y = np.zeros(4, np.float64)\n")
    got = check_source(src, "src/repro/api.py")
    assert [v.line for v in got] == [3]


# ---------------------------------------------------------------------------
# the linter over the real tree + CLI
# ---------------------------------------------------------------------------

def test_src_tree_lints_clean():
    violations = lint_paths([str(REPO / "src")])
    assert violations == [], "\n".join(str(v) for v in violations)


def test_every_rule_has_rationale_and_json_shape():
    assert {r.id for r in RULES} == {"R1", "R2", "R3", "R4", "R5", "R6"}
    for r in RULES:
        assert r.rationale and r.title
    v = check_source("x = np.zeros(4, np.float64)\n", "src/repro/api.py")[0]
    j = v.to_json()
    assert {"rule", "file", "line", "col", "message",
            "rationale"} <= set(j)
    assert j["rationale"] == RULES_BY_ID["R4"].rationale


def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("x = np.zeros(4, np.float64)\n")
    assert lint_main([str(bad)]) == 1
    assert "R4" in capsys.readouterr().out
    good = tmp_path / "good.py"
    good.write_text("x = np.zeros(4, np.float32)\n")
    assert lint_main([str(good)]) == 0


# ---------------------------------------------------------------------------
# strict-mode runtime sanitizers
# ---------------------------------------------------------------------------

def test_compile_watcher_counts_fresh_compiles_not_cache_hits():
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x * 2.0 + 1.0)
    x = jnp.ones(7)
    f(x)                                   # compile outside the watcher
    with CompileWatcher() as w:
        f(x)                               # cache hit
    assert w.count == 0
    g = jax.jit(lambda x: x * 3.0 - 2.0)
    with CompileWatcher() as w:
        g(x)                               # fresh lowering
    assert w.count >= 1 and w.compiled


def test_expect_no_retraces_raises_on_fresh_compile():
    import jax
    import jax.numpy as jnp

    h = jax.jit(lambda x: x - 0.5)
    with pytest.raises(StrictViolation, match="recompile"):
        with expect_no_retraces("warm tick"):
            h(jnp.ones(5))


def test_strict_flag_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_STRICT", raising=False)
    set_strict(None)
    assert not strict_enabled()
    monkeypatch.setenv("REPRO_STRICT", "1")
    assert strict_enabled()
    set_strict(False)                      # explicit flag beats the env
    assert not strict_enabled()
    set_strict(None)


def test_strict_mode_sets_engine_flag_and_rank_promotion():
    import jax.numpy as jnp

    set_strict(None)
    with strict_mode() as watcher:
        assert strict_enabled()
        assert isinstance(watcher, CompileWatcher)
        with pytest.raises(Exception):     # silent broadcast now raises
            jnp.ones((3, 3)) + jnp.ones(3)
    assert not strict_enabled()


def _mk_request(i, uid):
    from repro.configs.base import PaperProblemConfig
    from repro.serve import SolveRequest
    from repro.sparse import make_lasso

    coo, b, _ = make_lasso(
        PaperProblemConfig(name="t", m=64, n=16, nnz=64 * 6, reg=0.1),
        seed=i)
    return SolveRequest(uid=uid, coo=coo, b=b, gamma0=1000.0, tol=3e-2,
                        max_iterations=4000)


def test_warm_engine_ticks_are_clean_under_sanitize():
    """THE tentpole invariant: after a cold stream, a second stream of
    same-shape requests runs with zero retraces and zero disallowed
    transfers while every tick phase executes under
    transfer_guard("disallow")."""
    from repro.serve import SolverEngine

    eng = SolverEngine(slots=2, fmt="ell", backend="jnp", check_every=16,
                       sanitize=True)
    for i in range(3):
        eng.submit(_mk_request(i, uid=i))
    eng.run()
    assert eng.tick_counters["disallowed_transfers"] == 0   # even cold
    eng.tick_counters = {k: 0 for k in eng.tick_counters}
    for i in range(3):
        eng.submit(_mk_request(i, uid=10 + i))
    done = eng.run()
    assert len(done) == 3 and all(r.done for r in done)
    assert eng.tick_counters == {"retraces": 0,
                                 "disallowed_transfers": 0}


def test_guarded_counts_and_recovers_implicit_transfer():
    """A phase that does an implicit host->device transfer under sanitize
    is counted as a red flag, then re-run with transfers allowed — the
    result is still correct."""
    import jax
    import jax.numpy as jnp
    from repro.serve import SolverEngine

    eng = SolverEngine(sanitize=True)
    host = np.ones(16, np.float32)
    out = eng._guarded(lambda: jnp.asarray(host) * 2.0)
    np.testing.assert_allclose(np.asarray(out), 2.0)
    assert eng.tick_counters["disallowed_transfers"] == 1
    # a clean phase — device-resident operand, warm jit — never trips it
    f = jax.jit(lambda x: x * 2.0)
    dev = jax.device_put(host)
    f(dev)                                 # warm the cache outside
    clean = eng._guarded(lambda: f(dev))
    np.testing.assert_allclose(np.asarray(clean), 2.0)
    assert eng.tick_counters["disallowed_transfers"] == 1   # unchanged


def test_sanitize_none_resolves_process_flag_dynamically(monkeypatch):
    from repro.analysis import strict as strict_mod
    from repro.serve import SolverEngine

    monkeypatch.delenv("REPRO_STRICT", raising=False)
    prev = strict_mod._STRICT              # may be True under the suite-
    try:                                   # wide --strict-sanitize fixture
        set_strict(False)
        eng = SolverEngine()               # constructed BEFORE the flip
        assert not eng._sanitize_now()
        set_strict(True)
        assert eng._sanitize_now()
    finally:
        set_strict(prev)
    assert not SolverEngine(sanitize=False)._sanitize_now()

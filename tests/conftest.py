"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 device by design
(the 512-device mesh belongs exclusively to launch/dryrun.py); multi-device
collective tests run in subprocesses (test_multidevice.py).

``--strict-sanitize`` runs the whole selection under the strict-mode
sanitizer matrix (repro.analysis.strict): rank promotion raises, and the
process-wide strict flag flips on, so every SolverEngine tick executes
under ``jax.transfer_guard("disallow")`` and counts retraces/implicit
transfers.  The CI ``strict`` job runs the engine/serve subset this way.
"""
import numpy as np
import pytest

import jax


def pytest_addoption(parser):
    parser.addoption(
        "--strict-sanitize", action="store_true", default=False,
        help="run tests under repro.analysis.strict: rank promotion "
             "raises, engine ticks guard transfers and count retraces")


@pytest.fixture(autouse=True)
def _strict_sanitize(request):
    if not request.config.getoption("--strict-sanitize"):
        yield
        return
    from repro.analysis.strict import set_strict

    set_strict(True)
    with jax.numpy_rank_promotion("raise"):
        yield
    set_strict(None)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)

"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 device by design
(the 512-device mesh belongs exclusively to launch/dryrun.py); multi-device
collective tests run in subprocesses (test_multidevice.py)."""
import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import (
    banded_spmv_t, bcsr_spmv, ell_spmv, fused_dual_update, prox_update,
)
from repro.kernels import ref as kref
from repro.sparse import (
    coo_to_banded, coo_to_bcsr, coo_to_dense, coo_to_ell, random_coo,
    transpose_coo,
)

DTYPES = [jnp.float32, jnp.bfloat16]
SHAPES = [(64, 16, 3), (300, 70, 5), (512, 128, 8), (1000, 333, 7)]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def _mk(m, n, k, dtype, seed=0):
    coo = random_coo(m, n, k, seed=seed)
    coo.vals = coo.vals.astype(dtype)
    return coo, coo_to_dense(coo).astype(np.float32)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("block_rows", [32, 128])
def test_ell_spmv_sweep(m, n, k, dtype, block_rows):
    coo, d = _mk(m, n, k, dtype)
    ell = coo_to_ell(coo, pad_to=8)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(n), dtype)
    out = ell_spmv(ell, x, block_rows=block_rows)
    ref = kref.ell_spmv_ref(ell.vals, ell.cols, x)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               d @ np.asarray(x, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("band_size", [64, 256])
def test_banded_spmv_t_sweep(m, n, k, dtype, band_size):
    coo, d = _mk(m, n, k, dtype, seed=2)
    bell = coo_to_banded(coo, band_size=band_size, pad_to=4)
    y = jnp.asarray(np.random.default_rng(3).standard_normal(m), dtype)
    out = banded_spmv_t(bell, y, block_cols=16)
    ref = kref.banded_spmv_t_ref(bell.vals, bell.rows,
                                 jnp.pad(y, (0, bell.num_bands *
                                             bell.band_size - m)),
                                 bell.band_size)[:n]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("bm,bn", [(8, 16), (16, 64)])
def test_bcsr_spmv_sweep(m, n, k, dtype, bm, bn):
    """Tiled-BCSR MXU kernel vs its oracle and the dense matrix, both
    orientations (rmatvec = matvec on the transpose BCSR)."""
    coo, d = _mk(m, n, k, dtype, seed=9)
    rng = np.random.default_rng(10)
    for a, dd, vlen in [(coo_to_bcsr(coo, bm=bm, bn=bn), d, n),
                        (coo_to_bcsr(transpose_coo(coo), bm=bm, bn=bn),
                         d.T, m)]:
        v = jnp.asarray(rng.standard_normal(vlen), dtype)
        out = bcsr_spmv(a, v, block_brows=4)
        pad = a.nbc * a.bn - vlen
        vt = jnp.pad(v, (0, pad)).reshape(a.nbc, a.bn)
        ref = kref.bcsr_spmv_ref(a.vals, a.bcols, vt).reshape(-1)[:a.m]
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **_tol(dtype))
        np.testing.assert_allclose(
            np.asarray(out, np.float32), dd @ np.asarray(v, np.float32),
            rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
            atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("m,n,k", SHAPES[:3])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_dual_update_sweep(m, n, k, dtype):
    coo, d = _mk(m, n, k, dtype, seed=4)
    ell = coo_to_ell(coo, pad_to=8)
    rng = np.random.default_rng(5)
    xs = jnp.asarray(rng.standard_normal(n), dtype)
    xb = jnp.asarray(rng.standard_normal(n), dtype)
    yh = jnp.asarray(rng.standard_normal(m), dtype)
    b = jnp.asarray(rng.standard_normal(m), dtype)
    out = fused_dual_update(ell, xs, xb, yh, b, 0.9, 0.05, 0.1, 0.15,
                            block_rows=64)
    coefs = jnp.asarray([0.9, 0.05, 0.1, 0.15], jnp.float32)
    ref = kref.fused_dual_update_ref(coefs, ell.vals, ell.cols, xs, xb, yh, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("n", [64, 333, 1024])
@pytest.mark.parametrize("dtype", DTYPES)
def test_prox_update_sweep(n, dtype):
    rng = np.random.default_rng(6)
    z = jnp.asarray(rng.standard_normal(n), dtype)
    xb = jnp.asarray(rng.standard_normal(n), dtype)
    xc = jnp.zeros(n, dtype)
    xs_k, xb_k = prox_update(z, xb, xc, 2.0, 0.3, 0.1, block=64)
    coefs = jnp.asarray([2.0, 0.3, 0.1], jnp.float32)
    xs_r, xb_r = kref.prox_update_ref(coefs, z, xb, xc)
    np.testing.assert_allclose(np.asarray(xs_k, np.float32),
                               np.asarray(xs_r, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(xb_k, np.float32),
                               np.asarray(xb_r, np.float32), **_tol(dtype))


def test_fused_dual_matches_unfused_composition():
    """Kernel fusion must be semantics-preserving: eq (15) composed from
    separate ops == fused kernel."""
    coo, d = _mk(256, 64, 4, jnp.float32, seed=7)
    ell = coo_to_ell(coo, pad_to=8)
    rng = np.random.default_rng(8)
    xs = jnp.asarray(rng.standard_normal(64), jnp.float32)
    xb = jnp.asarray(rng.standard_normal(64), jnp.float32)
    yh = jnp.asarray(rng.standard_normal(256), jnp.float32)
    b = jnp.asarray(rng.standard_normal(256), jnp.float32)
    fused = fused_dual_update(ell, xs, xb, yh, b, 0.7, 0.2, 0.3, 0.5)
    unfused = 0.7 * yh + ell_spmv(ell, 0.2 * xs + 0.3 * xb) - 0.5 * b
    np.testing.assert_allclose(fused, unfused, rtol=1e-5, atol=1e-5)

"""Prox library: closed-form checks + property-based prox axioms."""
import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.prox import get_prox

PROXES = ["l1", "zero", "sq_l2", "elastic_net", "nonneg", "box", "l1_box",
          "group_l1"]


def _vec(seed, n=32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(n),
                       jnp.float32)


def test_l1_soft_threshold_closed_form():
    p = get_prox("l1", reg=0.5)
    v = jnp.asarray([-2.0, -0.3, 0.0, 0.3, 2.0])
    out = p.prox(v, 1.0)
    np.testing.assert_allclose(out, [-1.5, 0.0, 0.0, 0.0, 1.5], atol=1e-7)


def test_sq_l2_closed_form():
    p = get_prox("sq_l2", reg=2.0)
    v = _vec(0)
    np.testing.assert_allclose(p.prox(v, 0.5), v / 2.0, rtol=1e-6)


def test_box_projection():
    p = get_prox("box", lo=-0.5, hi=0.25)
    out = p.prox(_vec(1), 1.0)
    assert float(out.min()) >= -0.5 and float(out.max()) <= 0.25


def test_dummy_matches_paper():
    p = get_prox("dummy")
    zhat = _vec(2)
    out = p.apply(zhat, 3.0, jnp.zeros_like(zhat))
    np.testing.assert_allclose(out, zhat + 3.0, rtol=1e-6)


def test_group_l1_zeros_small_groups():
    p = get_prox("group_l1", reg=10.0, group_size=4)
    out = p.prox(_vec(3, 16), 1.0)
    np.testing.assert_allclose(out, 0.0, atol=1e-6)


@pytest.mark.parametrize("name", PROXES)
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), t=st.floats(0.01, 10.0))
def test_prox_firm_nonexpansive(name, seed, t):
    """||prox(u) - prox(v)|| <= ||u - v|| — holds for any proper convex f."""
    p = get_prox(name)
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.standard_normal(16), jnp.float32)
    v = jnp.asarray(rng.standard_normal(16), jnp.float32)
    du = p.prox(u, t) - p.prox(v, t)
    assert float(jnp.linalg.norm(du)) <= float(jnp.linalg.norm(u - v)) + 1e-5


@pytest.mark.parametrize("name", PROXES)
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), t=st.floats(0.01, 10.0))
def test_prox_optimality(name, seed, t):
    """prox_t(v) minimizes f(x) + ||x-v||^2/(2t): value at prox <= value at
    random perturbations (first-order optimality, sampled)."""
    p = get_prox(name)
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.standard_normal(8), jnp.float32)
    x = p.prox(v, t)

    def obj(z):
        return float(p.value(z) + jnp.sum((z - v) ** 2) / (2 * t))

    base = obj(x)
    for _ in range(8):
        z = x + jnp.asarray(0.1 * rng.standard_normal(8), jnp.float32)
        if name in ("nonneg",):
            z = jnp.maximum(z, 0.0)
        if name in ("box", "l1_box"):
            z = jnp.clip(z, -1.0, 1.0)
        assert obj(z) >= base - 1e-4


def test_moreau_identity_l1():
    """prox_{tf}(v) + t*prox_{f*/t}(v/t) = v for f=|.|_1."""
    p = get_prox("l1", reg=1.0)
    v = _vec(5)
    t = 0.7
    x = p.prox(v, t)
    # conjugate of |.| is indicator of [-1,1]; prox of indicator = projection
    dual = jnp.clip(v / t, -1.0, 1.0)
    np.testing.assert_allclose(x + t * dual, v, atol=1e-6)

"""Open-loop serving as a deterministic discrete-event simulation: every
test drives ``OpenLoopFrontend`` on a ``VirtualClock`` — no wall-clock
sleeps anywhere — so deadline expiry, priority ordering, backpressure and
byte-budget rejection are exact, repeatable assertions rather than timing
races."""
import numpy as np
import pytest

from repro.configs.base import PaperProblemConfig
from repro.serve import (
    OpenLoopFrontend, SolveRequest, SolverEngine, VirtualClock, WallClock,
    poisson_arrivals, trace_arrivals,
)
from repro.sparse import make_lasso


def _req(uid, m=16, n=8, priority=0, deadline=None, max_iterations=4000):
    cfg = PaperProblemConfig(name="t", m=m, n=n, nnz=m * 4, reg=0.1)
    coo, b, _ = make_lasso(cfg, seed=500 + uid)
    return SolveRequest(uid=uid, coo=coo, b=b, gamma0=1000.0, tol=3e-2,
                        max_iterations=max_iterations, priority=priority,
                        deadline=deadline)


def _engine(**kw):
    kw.setdefault("slots", 2)
    kw.setdefault("check_every", 8)
    kw.setdefault("min_rows", 16)
    kw.setdefault("min_cols", 8)
    return SolverEngine(**kw)


# -- clocks ------------------------------------------------------------------

def test_virtual_clock_is_inert_until_advanced():
    clk = VirtualClock(t0=1.0)
    assert clk.now() == 1.0
    clk.advance(0.25)
    clk.skip_to(0.5)            # never backwards
    assert clk.now() == 1.25
    clk.skip_to(3.0)
    assert clk.now() == 3.0
    with pytest.raises(ValueError, match="backwards"):
        clk.advance(-1.0)


def test_wall_clock_skips_idle_gaps_without_sleeping():
    import time
    clk = WallClock()
    t0 = time.perf_counter()
    clk.skip_to(clk.now() + 3600.0)     # an hour of idle, instantly
    assert time.perf_counter() - t0 < 1.0
    assert clk.now() >= 3600.0


# -- arrival processes -------------------------------------------------------

def test_poisson_arrivals_are_seed_deterministic():
    def stream(seed):
        return [a.t for a in poisson_arrivals(
            [_req(i) for i in range(6)], rate=3.0, seed=seed)]
    assert stream(7) == stream(7)           # bit-identical per seed
    assert stream(7) != stream(8)
    ts = stream(7)
    assert all(b > a for a, b in zip(ts, ts[1:]))


def test_poisson_arrivals_stamp_relative_deadlines():
    arr = poisson_arrivals([_req(0), _req(1)], rate=2.0, seed=0,
                           deadline=0.5)
    for a in arr:
        assert a.request.deadline == pytest.approx(a.t + 0.5)
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals([_req(0)], rate=0.0)


def test_trace_arrivals_sort_and_validate():
    r = [_req(i) for i in range(3)]
    arr = trace_arrivals([2.0, 0.5, 1.0], r)
    assert [a.t for a in arr] == [0.5, 1.0, 2.0]
    assert [a.request.uid for a in arr] == [1, 2, 0]
    with pytest.raises(ValueError, match="arrival times"):
        trace_arrivals([0.0], r)


# -- deadline expiry ---------------------------------------------------------

def test_deadline_expiry_reclaims_inflight_slot_that_tick():
    """A 1-slot engine: request 0's deadline passes while it is mid-
    flight; the very tick the clock crosses the deadline its slot is
    reclaimed and request 1 (waiting in the queue) is admitted into that
    same slot — no idle tick in between."""
    eng = _engine(slots=1)
    r0 = _req(0, deadline=0.05, max_iterations=100_000)
    r0.tol = 1e-12                       # never converges on its own
    r1 = _req(1)
    fe = OpenLoopFrontend(eng, trace_arrivals([0.0, 0.0], [r0, r1]),
                          clock=VirtualClock(), tick_s=0.02)
    # tick at t=0 admits r0 (1 slot -> r1 waits); t crosses 0.05 after
    # 3 ticks, so the t=0.06 tick must expire r0 AND admit r1
    for _ in range(3):
        fe.step()
    assert not r0.expired and fe._inflight == {0: r0}
    fe.step()                            # now=0.06 > deadline
    assert r0.expired and not r0.done
    assert r0.timeline["t_expire"] == pytest.approx(0.06)
    assert fe._inflight.get(1) is r1     # freed slot reused that tick
    assert r1.timeline["t_admit"] == pytest.approx(0.06)
    rep = fe.run()
    assert rep["expired"] == 1 and rep["completed"] == 1
    assert r1.done and r1.x is not None
    assert eng.stats["expired"] == 1


def test_deadline_expiry_drops_queued_before_any_device_work():
    """A queued request whose deadline passes while waiting is expired
    from the wait queue — it never reaches the engine at all."""
    eng = _engine(slots=1)
    r0 = _req(0, max_iterations=100_000)
    r0.tol = 1e-12
    doomed = _req(1, deadline=0.01)
    fe = OpenLoopFrontend(eng, trace_arrivals([0.0, 0.0], [r0, doomed]),
                          clock=VirtualClock(), tick_s=0.02,
                          inflight_limit=1)
    fe.step()                            # r0 in flight, doomed waiting
    fe.step()                            # t=0.02 > 0.01: doomed expires
    assert doomed.expired and "t_admit" not in doomed.timeline
    assert doomed.timeline["queue_s"] == pytest.approx(0.02)
    assert eng.stats.get("admitted", 0) == 1


# -- priority ----------------------------------------------------------------

def test_priority_overtakes_fifo_in_wait_queue():
    """Three low-priority arrivals then one high-priority, all at t=0
    with a 1-deep admission pipe: the high-priority request is served
    first, the rest keep FIFO order."""
    eng = _engine(slots=1)
    lo = [_req(10 + i) for i in range(3)]
    hi = _req(99, priority=5)
    fe = OpenLoopFrontend(eng, trace_arrivals([0.0] * 4, lo + [hi]),
                          clock=VirtualClock(), tick_s=0.01,
                          inflight_limit=1)
    fe.run()
    assert [r.uid for r in fe.completed] == [99, 10, 11, 12]


def test_priority_pop_inside_engine_queue():
    """The engine's own bucket queues honor priority too (submit straight
    to the engine, no front-end): the high-priority request takes the
    first freed slot even though it was submitted last."""
    eng = _engine(slots=1)
    for r in [_req(0), _req(1), _req(2, priority=9)]:
        eng.submit(r)
    done = eng.run()
    assert [r.uid for r in done] == [2, 0, 1]


# -- backpressure + admission ------------------------------------------------

def test_backpressure_rejects_on_full_wait_queue():
    eng = _engine(slots=1)
    reqs = [_req(i, max_iterations=100_000) for i in range(4)]
    for r in reqs:
        r.tol = 1e-12
    fe = OpenLoopFrontend(eng, trace_arrivals([0.0] * 4, reqs),
                          clock=VirtualClock(), tick_s=0.01,
                          queue_limit=2, inflight_limit=1)
    fe.step()
    # all 4 land before admission drains the queue: 2 absorbed by the
    # queue (one of them admitted this same tick), 2 rejected on arrival
    rejected = [r for r in reqs if r.rejected]
    assert [r.uid for r in rejected] == [2, 3]
    assert all(r.reject_reason.startswith("backpressure")
               for r in rejected)
    rep = fe.report()
    assert rep["rejected_backpressure"] == 2


def test_saturated_byte_budget_rejects_with_plan_reason():
    """admission='strict' on a byte-budgeted engine: work the planner
    would only serve streamed is REJECTED, and the reject reason is the
    planner's own admission sentence (decide_admission), not a silent
    fallback.  The same request under admission='auto' is served
    streamed, with the decision stamped on its timeline."""
    from repro.plan import decide_admission

    budget = 1                           # nothing fits resident
    big = _req(7, m=64, n=64)
    eng = _engine(slots=2, device_budget=budget)
    fe = OpenLoopFrontend(eng, trace_arrivals([0.0], [big]),
                          clock=VirtualClock(), admission="strict")
    rep = fe.run()
    assert big.rejected and not big.done
    assert rep["rejected_admission"] == 1 and rep["completed"] == 0
    assert "byte budget saturated" in big.reject_reason
    # the engine's verdict IS the planner rule, with live byte numbers
    slot = eng.bucket_slot_bytes(eng.bucket_key(big))
    want, why = decide_admission(64, 64, big.coo.nnz, 1, slot_bytes=slot,
                                 budget_left=budget,
                                 allow_streaming=False)
    assert (want, why) == ("rejected", big.reject_reason)

    big2 = _req(8, m=64, n=64)
    eng2 = _engine(slots=2, device_budget=budget)
    fe2 = OpenLoopFrontend(eng2, trace_arrivals([0.0], [big2]),
                          clock=VirtualClock())
    rep2 = fe2.run()
    assert rep2["completed"] == 1 and big2.done
    assert big2.timeline["admission"] == "streamed"
    assert "budget" in big2.timeline["admission_reason"]


def test_plan_records_admission_reason():
    from repro.api import Problem

    cfg = PaperProblemConfig(name="t", m=64, n=16, nnz=256, reg=0.1)
    coo, b, _ = make_lasso(cfg, seed=0)
    pl = Problem(coo, b, prox="l1", reg=0.1).plan(tol=1e-2)
    assert "admission" in pl.reasons
    assert pl.reasons["admission"].startswith(
        ("resident", "streamed", "rejected"))


# -- latency accounting ------------------------------------------------------

def test_latency_timeline_and_phase_attribution():
    """Completed requests carry arrive/admit/done stamps on the serving
    clock plus a queue/admit/compute/harvest split; the per-request
    attribution sums back to the front-end's aggregate phase_s, which
    mirrors the engine's own tick breakdown."""
    eng = _engine()
    arr = poisson_arrivals([_req(i) for i in range(5)], rate=4.0, seed=1)
    fe = OpenLoopFrontend(eng, arr, clock=VirtualClock(), tick_s=0.01)
    rep = fe.run(slo=60.0)
    assert rep["completed"] == 5
    for r in fe.completed:
        tl = r.timeline
        assert tl["t_arrive"] <= tl["t_admit"] <= tl["t_done"]
        assert tl["latency_s"] == pytest.approx(
            tl["t_done"] - tl["t_arrive"])
        assert tl["queue_s"] == pytest.approx(tl["t_admit"] - tl["t_arrive"])
        assert tl["service_s"] == pytest.approx(tl["t_done"] - tl["t_admit"])
        for k in ("admit_s", "compute_s", "harvest_s"):
            assert tl[k] >= 0.0
    for k in ("admit_s", "compute_s", "harvest_s"):
        total = sum(r.timeline[k] for r in fe.completed)
        # aggregate also carries ticks that admitted nothing, so the
        # per-request attribution can only be <= it — never more
        assert total <= fe.phase_s[k] + 1e-9, k
        assert total >= 0.0
    # front-end mirror never loses engine time: splice+admit+compile land
    # in admit_s, dispatch in compute_s, harvest in compute_s/harvest_s
    eng_total = sum(eng.phase_s.values())
    fe_total = sum(fe.phase_s[k] for k in
                   ("admit_s", "compute_s", "harvest_s"))
    assert fe_total == pytest.approx(eng_total, rel=1e-6)
    assert rep["p50_latency_s"] <= rep["p99_latency_s"]
    assert rep["goodput_rps"] > 0 and rep["met_slo"] == 5


def test_open_loop_run_is_deterministic_on_virtual_clock():
    """Two identical simulations are bit-identical: same arrival times,
    same completion order, same latency stamps."""
    def run():
        eng = _engine()
        arr = poisson_arrivals([_req(i) for i in range(6)], rate=5.0,
                               seed=42, deadline=30.0)
        fe = OpenLoopFrontend(eng, arr, clock=VirtualClock(), tick_s=0.01)
        fe.run()
        return ([r.uid for r in fe.completed],
                [r.timeline["latency_s"] for r in fe.completed],
                [r.uid for r in fe.expired])
    assert run() == run()

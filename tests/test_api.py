"""The declarative facade: Problem -> plan -> Result.

Covers the API-layer contracts from DESIGN.md section 6: every plan the
planner can emit returns the same iterates (1e-5), plans round-trip
(repr -> override -> solve) and match the legacy entry points, Lg is never
hand-passed (Frobenius / power-iteration estimation), the serving engine
admits Problems, and deprecation shims warn exactly once.  (The legacy-
import sweep moved to AST rule R2 in repro.analysis.)
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import deprecation
from repro.api import Problem, solve_many
from repro.core.prox import get_prox
from repro.core.solver import estimate_lg, solve_tol
from repro.operators import make_operator, make_solver_ops
from repro.sparse import coo_to_bcsr, coo_to_dense, coo_to_ell, random_coo


def _lasso(m=64, n=16, k=4, seed=0):
    coo = random_coo(m, n, k, seed=seed)
    rng = np.random.default_rng(seed + 1)
    x_true = np.zeros(n, np.float32)
    x_true[rng.choice(n, 3, replace=False)] = 1.0
    d = coo_to_dense(coo)
    b = jnp.asarray(d @ x_true)
    return coo, d, b


# ---------------------------------------------------------------------------
# estimate_lg (power iteration)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,seed", [(200, 50, 0), (64, 16, 1), (300, 120, 2)])
def test_estimate_lg_matches_dense_oracle(m, n, seed):
    coo = random_coo(m, n, 6, seed=seed)
    d = coo_to_dense(coo).astype(np.float64)
    op = make_operator("dense", "jnp", jnp.asarray(d, jnp.float32))
    oracle = float(np.linalg.norm(d, 2) ** 2)
    assert abs(estimate_lg(op) - oracle) <= 1e-3 * oracle


def test_planner_power_iterates_for_matrix_free():
    """lg is never hand-passed: a matrix-free Problem gets Lg from power
    iteration (x1.05 safety), close to the dense ||A||^2 oracle."""
    coo, d, b = _lasso(seed=5)
    op = make_operator("dense", "jnp", jnp.asarray(d))
    prob = Problem(op, b, prox="l1", reg=0.1)
    pl = prob.plan(iterations=10)
    assert "power iteration" in pl.reasons["lg"]
    oracle = float(np.linalg.norm(d.astype(np.float64), 2) ** 2)
    assert abs(pl.lg / 1.05 - oracle) <= 1e-3 * oracle
    assert pl.solve().iterations == 10          # and the plan executes


def test_planner_frobenius_for_concrete_matrices():
    coo, d, b = _lasso(seed=6)
    pl = Problem(coo, b, prox="l1", reg=0.1).plan(iterations=5)
    np.testing.assert_allclose(pl.lg, float((d.astype(np.float64) ** 2).sum()),
                               rtol=1e-6)
    assert "paper init" in pl.reasons["lg"]


# ---------------------------------------------------------------------------
# Plan equivalence: every emittable plan returns the same x (1e-5)
# ---------------------------------------------------------------------------

SINGLE_VARIANTS = [("dense", "jnp"), ("ell", "jnp"), ("bcsr", "jnp"),
                   ("ell", "pallas"), ("bcsr", "pallas")]


@pytest.mark.parametrize("seed", [0, 3])
def test_every_emittable_plan_matches_reference(seed):
    """Property-style: for random COO problems, every ExecutionPlan the
    planner can emit (a1 vs a2, dense vs ELL vs BCSR, jnp vs
    pallas-interpret, 1-device strategies) returns x within 1e-5 of the
    reference solve."""
    coo, d, b = _lasso(seed=seed)
    prob = Problem(coo, b, prox="l1", reg=0.1, gamma0=100.0)
    base = prob.plan(iterations=60)
    ref = base.override(format="dense", backend="jnp").solve()
    for fmt, backend in SINGLE_VARIANTS:
        for alg in ("a1", "a2"):
            r = base.override(format=fmt, backend=backend,
                              algorithm=alg).solve()
            np.testing.assert_allclose(
                np.asarray(r.x), np.asarray(ref.x), atol=1e-5,
                err_msg=f"{fmt}/{backend}/{alg}")
    for strategy in ("replicated", "dualpart"):
        r = base.override(strategy=strategy).solve()
        np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                                   atol=1e-5, err_msg=strategy)


def test_auto_plan_solves_and_explains():
    coo, d, b = _lasso(seed=7)
    res = Problem(coo, b, prox="l1", reg=0.1, gamma0=100.0).solve(tol=1e-2)
    assert res.feasibility < 1e-2
    assert res.iterations > 0
    exp = res.plan.explain()
    for key in ("algorithm", "format", "backend", "lg", "gamma0"):
        assert key in exp
    assert res.timings["total_s"] > 0
    certs = res.certificates()
    assert set(certs) >= {"feasibility", "objective", "gap"}
    assert res.gap == certs["gap"]


# ---------------------------------------------------------------------------
# Round-trip: repr -> override -> solve, matching the legacy entry points
# ---------------------------------------------------------------------------

def test_plan_roundtrip_matches_legacy_solve_tol():
    coo, d, b = _lasso(seed=1)
    prob = Problem(coo, b, prox="l1", reg=0.1, gamma0=100.0)
    pl = prob.plan(tol=1e-3, check_every=8)
    r = repr(pl)
    assert "ExecutionPlan" in r and "format=" in r and "gamma0=" in r
    over = pl.override(format="ell", backend="jnp", algorithm="a2")
    assert over.reasons["format"] == "user override"
    res = over.solve()
    legacy = solve_tol(make_solver_ops(coo, "ell", "jnp"),
                       get_prox("l1", reg=0.1), b, over.lg, 100.0,
                       max_iterations=10_000, tol=1e-3, check_every=8)
    assert res.iterations == int(legacy.k)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(legacy.xbar),
                               atol=1e-5)


def test_distributed_plan_matches_legacy_solve_distributed():
    from jax.sharding import Mesh
    from repro.core.distributed import solve_distributed

    coo, d, b = _lasso(seed=2)
    mesh = Mesh(np.array(jax.devices()).reshape(1), ("p",))
    prob = Problem(coo, b, prox="l1", reg=0.1, gamma0=100.0)
    res = prob.solve(iterations=40, strategy="dualpart", mesh=mesh)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        xbar, _ = solve_distributed(coo, b, get_prox("l1", reg=0.1), mesh,
                                    "dualpart", gamma0=100.0, iterations=40)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(xbar),
                               atol=1e-5)


def test_proxop_instance_is_correct_on_pallas_backend():
    """A ProxOp instance carries its weight in a closure; the planner must
    not hand it to the fused prox kernel (which takes a scalar reg) — the
    pallas path has to match the named-family path exactly."""
    coo, d, b = _lasso(seed=8)
    spec = dict(iterations=60, format="ell", backend="pallas", gamma0=100.0)
    named = Problem(coo, b, prox="l1", reg=0.5).solve(**spec)
    inst = Problem(coo, b, prox=get_prox("l1", reg=0.5)).solve(**spec)
    np.testing.assert_allclose(np.asarray(inst.x), np.asarray(named.x),
                               atol=1e-6)


def test_override_mirrors_planner_validation():
    coo, d, b = _lasso(seed=9)
    op = make_operator("dense", "jnp", jnp.asarray(d))
    pl = Problem(op, b, prox="l1", reg=0.1).plan(iterations=5)
    with pytest.raises(ValueError, match="matrix-free"):
        pl.override(strategy="dualpart")
    # a mesh-only override is a distributed hint, like in plan()
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()).reshape(1), ("p",))
    pl2 = Problem(coo, b, prox="l1", reg=0.1).plan(iterations=5)
    over = pl2.override(mesh=mesh)
    assert over.execution == "distributed" and over.strategy == "dualpart"
    back = over.override(strategy=None)
    assert back.execution == "single"


def test_mixed_request_and_problem_uids_do_not_collide():
    from repro.serve import create_engine

    eng = create_engine("solver", slots=2, fmt="ell", backend="jnp",
                        check_every=16)
    coo, d, b = _lasso(seed=32)
    coo2, _, b2 = _lasso(seed=33)
    p1 = Problem(coo, b, prox="l1", reg=0.1, gamma0=100.0)
    p2 = Problem(coo2, b2, prox="l1", reg=0.1, gamma0=100.0)
    eng.submit(p1.to_request(uid=0, tol=1e-2))     # explicit uid 0
    eng.submit(p2)                                  # auto uid must skip 0
    done = eng.run()
    assert len(done) == 2
    assert len({r.uid for r in done}) == 2


def test_problem_accepts_every_matrix_container():
    """dense array, COO, ELL and BCSR inputs land on the same iterates."""
    coo, d, b = _lasso(seed=4)
    spec = dict(iterations=40, format="dense", backend="jnp", gamma0=100.0)
    ref = Problem(coo, b, prox="l1", reg=0.1).solve(**spec)
    for A in (d, coo_to_ell(coo), coo_to_bcsr(coo, bm=8, bn=16)):
        r = Problem(A, b, prox="l1", reg=0.1).solve(**spec)
        np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                                   atol=1e-5, err_msg=type(A).__name__)


# ---------------------------------------------------------------------------
# The batched path: solve_many + engine admission of Problems
# ---------------------------------------------------------------------------

def test_solve_many_engine_path_matches_standalone():
    probs = []
    for i, (m, n) in enumerate([(96, 24), (64, 16), (80, 20), (64, 16)]):
        coo, d, b = _lasso(m, n, 4, seed=10 + i)
        probs.append(Problem(coo, b, prox="l1", reg=0.1, gamma0=100.0))
    results = solve_many(probs, tol=1e-2, max_iterations=4000,
                         check_every=16, slots=2)
    assert results[0].plan.execution == "engine"
    for p, r in zip(probs, results):
        assert r.feasibility < 1e-2
        ref = p.solve(tol=1e-2, max_iterations=4000, check_every=16,
                      format="ell", backend="jnp")
        assert r.iterations == ref.iterations
        np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                                   atol=1e-5)
        with pytest.raises(ValueError, match="no solver state"):
            r.certificates()
    with pytest.raises(RuntimeError, match="solve_many"):
        results[0].plan.solve()


def test_solve_many_sequential_fallbacks():
    coo, d, b = _lasso(seed=20)
    coo2, _, b2 = _lasso(seed=21)
    # un-servable prox (ProxOp instance) -> sequential single plans
    probs = [Problem(coo, b, prox=get_prox("l1", reg=0.1), gamma0=100.0),
             Problem(coo2, b2, prox=get_prox("l1", reg=0.1), gamma0=100.0)]
    rs = solve_many(probs, tol=1e-2)
    assert all(r.plan.execution == "single" for r in rs)
    # batch="never" forces sequential even for servable fleets
    probs = [Problem(coo, b, prox="l1", reg=0.1, gamma0=100.0),
             Problem(coo2, b2, prox="l1", reg=0.1, gamma0=100.0)]
    rs = solve_many(probs, tol=1e-2, batch="never")
    assert all(r.plan.execution == "single" for r in rs)


def test_engine_admits_problems_directly():
    from repro.serve import SolverEngine, create_engine

    eng = create_engine("solver", slots=2, fmt="ell", backend="jnp",
                        check_every=16)
    assert isinstance(eng, SolverEngine)
    coo, d, b = _lasso(seed=30)
    eng.submit(Problem(coo, b, prox="l1", reg=0.1, gamma0=100.0))
    done = eng.run()
    assert len(done) == 1 and done[0].done
    with pytest.raises(TypeError, match="SolveRequest or a repro.api"):
        eng.submit(object())
    with pytest.raises(KeyError, match="unknown engine kind"):
        create_engine("tokens")


def test_unservable_problem_rejected_by_to_request():
    coo, d, b = _lasso(seed=31)
    with pytest.raises(ValueError, match="not a servable family"):
        Problem(coo, b, prox="group_l1").to_request()


# ---------------------------------------------------------------------------
# Deprecation shims: one warning per process, pointing at the facade
# ---------------------------------------------------------------------------

def test_legacy_shims_warn_once_then_stay_silent():
    from repro.core.solver import dense_ops

    deprecation.reset()
    d = jnp.eye(2)
    with pytest.warns(DeprecationWarning, match="repro.api"):
        dense_ops(d)
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # a second warning would raise
        dense_ops(d)


def test_serve_engine_alias_warns():
    deprecation.reset()
    with pytest.warns(DeprecationWarning, match="TokenEngine"):
        from repro.serve import Engine
    from repro.serve import TokenEngine
    assert Engine is TokenEngine


def test_solve_distributed_warns():
    from jax.sharding import Mesh
    from repro.core.distributed import solve_distributed

    deprecation.reset()
    coo, d, b = _lasso(seed=40)
    mesh = Mesh(np.array(jax.devices()).reshape(1), ("p",))
    with pytest.warns(DeprecationWarning, match="repro.api"):
        solve_distributed(coo, b, get_prox("l1", reg=0.1), mesh,
                          "replicated", gamma0=100.0, iterations=2)


# The PR-3 grep-style legacy-import sweep that used to live here was
# promoted to AST lint rule R2 (repro.analysis.rules; exercised by
# tests/test_analysis.py and the CI lint job).


# ---------------------------------------------------------------------------
# dtype canonicalization (explicit, warned) and the placement decision
# ---------------------------------------------------------------------------


def test_float64_operands_warn_once_and_downcast():
    """float64 inputs are canonicalized to float32 with ONE UserWarning
    (the caller's float64 tolerance semantics silently changing was the
    bug); an explicit dtype=float32 acknowledges and silences it."""
    from repro import api as api_mod

    a64 = np.diag([2.0, 4.0]).astype(np.float64)
    b64 = np.ones(2, np.float64)
    api_mod._DOWNCAST_WARNED.clear()
    with pytest.warns(UserWarning, match="float32"):
        p = Problem(a64, b64, prox="zero")
    assert p.dtype == np.float32 and p.b.dtype == jnp.float32
    assert p.dense_array().dtype == np.float32
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # second build: already warned
        Problem(a64, b64, prox="zero")
    api_mod._DOWNCAST_WARNED.clear()
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # explicit dtype: no warning
        p2 = Problem(a64, b64, prox="zero", dtype=np.float32)
    assert p2.dtype == np.float32


def test_float64_dtype_requires_x64():
    a = np.eye(2, dtype=np.float64)
    with pytest.raises(ValueError, match="x64"):
        Problem(a, np.ones(2), prox="zero", dtype=np.float64)
    with pytest.raises(ValueError, match="float32 or float64"):
        Problem(a, np.ones(2), prox="zero", dtype=np.int32)


def test_coo_float64_vals_canonicalized():
    from repro import api as api_mod
    from repro.sparse.formats import COO

    coo, d, b = _lasso(seed=50)
    coo64 = COO(rows=coo.rows, cols=coo.cols,
                vals=np.asarray(coo.vals, np.float64), m=coo.m, n=coo.n)
    api_mod._DOWNCAST_WARNED.clear()
    with pytest.warns(UserWarning, match="float32"):
        p = Problem(coo64, b, prox="l1", reg=0.1)
    assert p.coo.vals.dtype == jnp.float32
    res = p.solve(iterations=5)
    assert np.all(np.isfinite(np.asarray(res.x)))


def test_plan_records_placement_and_dtype():
    """The planner's serving-placement decision and operand dtype land in
    the plan with reasons (single process has 1 device -> "single")."""
    from repro.plan import decide_placement

    coo, d, b = _lasso(seed=51)
    pl = Problem(coo, b, prox="l1", reg=0.1).plan(iterations=5)
    assert pl.placement == "single"
    assert "placement" in pl.reasons and "dtype" in pl.reasons
    assert "placement" in pl.explain()
    # the rule itself, off-process: 1 device -> single, small problem on a
    # mesh -> replicated, big problem -> sharded, override wins
    assert decide_placement(10, 10, 50, 1, 1000)[0] == "single"
    assert decide_placement(10, 10, 50, 8, 1000)[0] == "replicated"
    assert decide_placement(10, 10, 5000, 8, 1000)[0] == "sharded"
    assert decide_placement(10, 10, 5000, 8, 1000,
                            override="single")[0] == "single"


def test_pallas_plan_records_resolved_interpret():
    coo, d, b = _lasso(seed=52)
    pl = Problem(coo, b, prox="l1", reg=0.1).plan(
        iterations=5, format="ell", backend="pallas")
    assert "interpret=True" in pl.reasons["interpret"]   # CPU container

"""Serve-mode sharding rules + MoE dispatch regime selection (§Perf)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models.api import serve_rule_overrides
from repro.models.moe import moe_forward
from repro.models.params import count_params
import repro.models.transformer as tfm


class FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)
        size = 256


def test_small_models_drop_fsdp_at_decode():
    for arch in ("qwen3-4b", "zamba2-7b", "falcon-mamba-7b", "minitron-8b"):
        over = serve_rule_overrides(get_config(arch), FakeMesh(), "decode")
        assert over.get("fsdp", "keep") is None, arch


def test_oversized_dense_keeps_fsdp():
    over = serve_rule_overrides(get_config("nemotron-4-340b"), FakeMesh(),
                                "decode")
    assert "fsdp" not in over          # 42GB/chip TP-only: must keep FSDP
    over = serve_rule_overrides(get_config("qwen1.5-110b"), FakeMesh(),
                                "decode")
    assert "fsdp" not in over


def test_deepseek_ep_widens_only_at_decode():
    cfg = get_config("deepseek-v3-671b")
    dec = serve_rule_overrides(cfg, FakeMesh(), "decode")
    assert dec.get("ep") == ("data", "model")
    assert dec.get("fsdp", "keep") is None
    pre = serve_rule_overrides(cfg, FakeMesh(), "prefill")
    assert "ep" not in pre


def test_olmoe_ep_not_divisible():
    over = serve_rule_overrides(get_config("olmoe-1b-7b"), FakeMesh(),
                                "decode")
    assert "ep" not in over            # 64 experts % 256 != 0


def test_moe_dense_path_matches_sort_path(key):
    """T<=4E dense-local-experts path must equal the sort/capacity path
    (no dropping at low load)."""
    cfg = reduced(get_config("olmoe-1b-7b"))
    tree = tfm._layer_params(cfg, "moe")["moe"]
    from repro.models.params import init_params
    p = init_params(tree, key)
    E = cfg.num_experts
    # T small -> dense path ; same tokens reshaped so T large -> sort path
    x_small = jax.random.normal(key, (1, 2 * E, cfg.d_model), jnp.float32)
    out_dense, _ = moe_forward(p, x_small, cfg)          # T = 2E <= 4E
    x_big = jnp.tile(x_small, (8, 1, 1))                 # T = 16E > 4E
    out_sort, _ = moe_forward(p, x_big, cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out_dense[0], np.float32),
                               np.asarray(out_sort[0], np.float32),
                               rtol=2e-4, atol=2e-4)


def test_moe_sort_path_drops_on_overflow(key):
    cfg = reduced(get_config("olmoe-1b-7b"))
    from repro.models.params import init_params
    p = init_params(tfm._layer_params(cfg, "moe")["moe"], key)
    x = jax.random.normal(key, (4, 16 * cfg.num_experts, cfg.d_model),
                          jnp.float32)
    out_tight, _ = moe_forward(p, x, cfg, capacity_factor=0.05)
    out_loose, _ = moe_forward(p, x, cfg, capacity_factor=8.0)
    # tight capacity must actually drop tokens (different output)
    assert float(jnp.max(jnp.abs(out_tight - out_loose))) > 1e-4
    assert bool(jnp.all(jnp.isfinite(out_tight)))

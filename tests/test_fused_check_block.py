"""Fused one-kernel check blocks == the unfused batched_step oracle.

The fused kernel (repro.kernels.fused_check_block) runs the entire
check_every inner loop — spmv forward, fused dual update, prox, per-slot
active-mask freeze — inside one batch-grid Pallas program per slot and
emits only the per-slot feasibility residual.  Every test here drives it
against N explicit ``batched_step`` calls + ``batched_feasibility`` (the
path the serving engine used before fusion) at 1e-5, over both stacked
formats, both regularizer families, ragged active masks, and mid-block
``max_iterations`` freezes.  Also: the batch-grid stacked-BCSR spmv vs
the per-slot kernel it replaced (vmap fallback), and the fused
``batched_solve_tol_fused`` driver vs ``batched_solve_tol``.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.prox import get_prox
from repro.core.solver import (
    SolverOps, batched_feasibility, batched_init, batched_solve_tol,
    batched_solve_tol_fused, batched_step,
)
from repro.kernels import FUSED_CHECK_PROXES, batched_bcsr_spmv
from repro.kernels.bcsr_spmv import bcsr_spmv_pallas
from repro.kernels.fused_check_block import fused_check_block
from repro.sparse import (
    coo_to_bcsr, coo_to_ell, random_coo, stack_bcsrs, stack_ells,
    stacked_bcsr_matvec, stacked_ell_matvec, transpose_coo,
)
from repro.sparse.formats import BCSR, ELL


def _pad_ells(ells):
    """Stack ragged-width ELLs: pad vals/cols to the common max width
    (zero val at col 0 contributes nothing)."""
    w = max(e.vals.shape[1] for e in ells)
    return [ELL(vals=np.pad(np.asarray(e.vals),
                            ((0, 0), (0, w - e.vals.shape[1]))),
                cols=np.pad(np.asarray(e.cols),
                            ((0, 0), (0, w - e.cols.shape[1]))),
                n=e.n) for e in ells]


def _pad_bcsrs(bs):
    """Stack ragged-kb BCSRs: pad with zero blocks pointing at block
    column 0."""
    kb = max(x.vals.shape[1] for x in bs)
    return [BCSR(vals=np.pad(np.asarray(x.vals),
                             ((0, 0), (0, kb - x.vals.shape[1]),
                              (0, 0), (0, 0))),
                 bcols=np.pad(np.asarray(x.bcols),
                              ((0, 0), (0, kb - x.bcols.shape[1]))),
                 m=x.m, n=x.n) for x in bs]


def _stacked(fmt, B, m, n, k, seed0=0, bm=8, bn=16):
    coos = [random_coo(m, n, k, seed=seed0 + i) for i in range(B)]
    if fmt == "ell":
        a = stack_ells(_pad_ells([coo_to_ell(c, pad_to=8) for c in coos]),
                       n=n)
        at = stack_ells(_pad_ells([coo_to_ell(transpose_coo(c), pad_to=8)
                                   for c in coos]), n=m)
        mv = stacked_ell_matvec
    else:
        a = stack_bcsrs(_pad_bcsrs([coo_to_bcsr(c, bm=bm, bn=bn)
                                    for c in coos]), m=m, n=n)
        at = stack_bcsrs(_pad_bcsrs([coo_to_bcsr(transpose_coo(c),
                                                 bm=bm, bn=bn)
                                     for c in coos]), m=n, n=m)
        mv = stacked_bcsr_matvec
    ops = SolverOps(matvec=lambda x: mv(a, x), rmatvec=lambda y: mv(at, y))
    return a, at, ops


def _oracle_block(ops, prox, b, lg, g0, state, active, maxit, steps):
    for _ in range(steps):
        state = batched_step(ops, prox, b, lg, g0, state,
                             mask=active & (state.k < maxit))
    return state, batched_feasibility(ops, b, state)


def _assert_state_close(f, o, msg=""):
    for name in ("xbar", "xstar", "yhat", "gamma"):
        np.testing.assert_allclose(
            np.asarray(getattr(f, name)), np.asarray(getattr(o, name)),
            rtol=1e-5, atol=1e-5, err_msg=f"{msg}:{name}")
    np.testing.assert_array_equal(np.asarray(f.k), np.asarray(o.k))


@pytest.mark.parametrize("fmt", ["ell", "bcsr"])
@pytest.mark.parametrize("prox_name", ["l1", "sq_l2"])
def test_fused_block_matches_step_oracle(fmt, prox_name):
    """(format) x (prox): one fused block == steps explicit batched_steps,
    from a mid-run state (k > 0) with a ragged active mask."""
    B, m, n, k, reg, steps = 3, 64, 32, 4, 0.05, 5
    a, at, ops = _stacked(fmt, B, m, n, k)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal((B, m)), jnp.float32)
    lg = jnp.full((B,), 50.0, jnp.float32)
    g0 = jnp.full((B,), 10.0, jnp.float32)
    prox = get_prox(prox_name, reg=reg)
    active = jnp.array([True, True, False])
    maxit = jnp.full((B,), 100, jnp.int32)
    # warm the state past k=0 so the eq-13 first-iteration gamma and the
    # steady-state schedule are both exercised inside the fused loop
    st = batched_init(ops, prox, b, lg, g0)
    for _ in range(4):
        st = batched_step(ops, prox, b, lg, g0, st,
                          mask=active & (st.k < maxit))
    o, feas_o = _oracle_block(ops, prox, b, lg, g0, st, active, maxit,
                              steps)
    f, feas_f = fused_check_block(a, at, b, lg, g0, reg, st, active, maxit,
                                  prox=prox_name, steps=steps,
                                  interpret=True)
    _assert_state_close(f, o, f"{fmt}/{prox_name}")
    np.testing.assert_allclose(np.asarray(feas_f), np.asarray(feas_o),
                               rtol=1e-5, atol=1e-5)
    # the always-inactive slot must never have moved
    assert int(f.k[2]) == 0


@pytest.mark.parametrize("prox_name", FUSED_CHECK_PROXES)
def test_fused_block_all_proxes_from_init(prox_name):
    """Every fused prox family, from the k=0 init state (gk_eff = lg/beta0
    branch) on the BCSR path."""
    B, m, n, k, steps = 3, 64, 32, 4, 6
    a, at, ops = _stacked("bcsr", B, m, n, k, seed0=10)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal((B, m)), jnp.float32)
    lg = jnp.full((B,), 50.0, jnp.float32)
    g0 = jnp.full((B,), 10.0, jnp.float32)
    reg = 0.05
    prox = (get_prox(prox_name, reg=reg)
            if prox_name in ("l1", "sq_l2") else get_prox(prox_name))
    active = jnp.array([True, False, True])
    maxit = jnp.full((B,), 100, jnp.int32)
    st = batched_init(ops, prox, b, lg, g0)
    o, feas_o = _oracle_block(ops, prox, b, lg, g0, st, active, maxit,
                              steps)
    f, feas_f = fused_check_block(a, at, b, lg, g0, reg, st, active, maxit,
                                  prox=prox_name, steps=steps,
                                  interpret=True)
    _assert_state_close(f, o, prox_name)
    np.testing.assert_allclose(np.asarray(feas_f), np.asarray(feas_o),
                               rtol=1e-5, atol=1e-5)


def test_fused_block_mid_block_maxit_freeze():
    """A slot whose max_iterations falls mid-block freezes at exactly that
    iteration inside the fused loop — not at the block boundary."""
    B, m, n, k, steps = 3, 64, 32, 4, 5
    a, at, ops = _stacked("ell", B, m, n, k)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal((B, m)), jnp.float32)
    lg = jnp.full((B,), 50.0, jnp.float32)
    g0 = jnp.full((B,), 10.0, jnp.float32)
    prox = get_prox("l1", reg=0.05)
    active = jnp.array([True, True, False])
    maxit = jnp.array([100, 7, 100], jnp.int32)     # slot 1 caps mid-block
    st = batched_init(ops, prox, b, lg, g0)
    for _ in range(4):                              # slot 1 enters at k=4
        st = batched_step(ops, prox, b, lg, g0, st,
                          mask=active & (st.k < maxit))
    o, _ = _oracle_block(ops, prox, b, lg, g0, st, active, maxit, steps)
    f, _ = fused_check_block(a, at, b, lg, g0, 0.05, st, active, maxit,
                             prox="l1", steps=steps, interpret=True)
    _assert_state_close(f, o, "maxit-freeze")
    assert int(f.k[1]) == 7                         # 3 of 5 steps taken


@pytest.mark.parametrize("m,n,k,bm,bn,brows",
                         [(64, 32, 4, 8, 16, 4), (300, 70, 5, 8, 16, 8),
                          (128, 128, 8, 16, 64, 3)])
def test_batched_bcsr_spmv_batch_grid(m, n, k, bm, bn, brows):
    """The batch-grid stacked-BCSR kernel == the reference stacked matvec
    AND the per-slot kernel it replaced (vmap-over-pallas_call fallback),
    including a block_brows that does not divide nbr (padding path)."""
    bs = [coo_to_bcsr(random_coo(m, n, k, seed=20 + i), bm=bm, bn=bn)
          for i in range(3)]
    a = stack_bcsrs(_pad_bcsrs(bs), m=m, n=n)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((3, n)), jnp.float32)
    y = batched_bcsr_spmv(a, x, block_brows=brows, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(stacked_bcsr_matvec(a, x)),
                               rtol=1e-5, atol=1e-5)
    pad_r = (-a.nbr) % brows

    def one_slot(v, bc, xs):
        v = jnp.pad(v, ((0, pad_r), (0, 0), (0, 0), (0, 0)))
        bc = jnp.pad(bc, ((0, pad_r), (0, 0)))
        xp = jnp.pad(xs, (0, a.nbc * a.bn - xs.shape[0]))
        y1 = bcsr_spmv_pallas(v, bc, xp.reshape(a.nbc, a.bn),
                              block_brows=brows, interpret=True)
        return y1.reshape(-1)[:a.m]

    y_vmap = jax.vmap(one_slot)(a.vals, a.bcols, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_vmap),
                               rtol=1e-5, atol=1e-5)


def test_batched_solve_tol_fused_matches_unfused():
    """The fused-body driver (block_fn owns the whole inner block) lands
    on the same iterates/iteration counts as batched_solve_tol."""
    B, m, n, k, tol, ce = 3, 64, 32, 4, 1e-2, 8
    a, at, ops = _stacked("ell", B, m, n, k, seed0=30)
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal((B, m)), jnp.float32)
    lg = jnp.asarray([float(np.sum(np.square(
        np.asarray(random_coo(m, n, k, seed=30 + i).vals))))
        for i in range(B)], jnp.float32)
    g0 = jnp.full((B,), 100.0, jnp.float32)
    reg = 0.1
    prox = get_prox("l1", reg=reg)
    ref = batched_solve_tol(ops, prox, b, lg, g0, max_iterations=500,
                            tol=tol, check_every=ce)
    active = jnp.ones((B,), bool)
    maxit = jnp.full((B,), 500, jnp.int32)

    def block_fn(state, mask):
        return fused_check_block(a, at, b, lg, g0, reg, state, mask, maxit,
                                 prox="l1", steps=ce, interpret=True)

    fused = batched_solve_tol_fused(ops, prox, b, lg, g0, block_fn,
                                    max_iterations=500, tol=tol)
    np.testing.assert_array_equal(np.asarray(fused.k), np.asarray(ref.k))
    np.testing.assert_allclose(np.asarray(fused.xbar),
                               np.asarray(ref.xbar), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(batched_feasibility(ops, b, fused)),
        np.asarray(batched_feasibility(ops, b, ref)),
        rtol=1e-5, atol=1e-5)

"""End-to-end system behaviour: the paper's pipeline (data -> partition ->
solve -> certify) on kernels, the serving engine, and the data pipeline."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec
from repro.configs.paper_problems import small_config
from repro.core.gap import certificates
from repro.core.prox import get_prox
from repro.core.solver import ell_ops, solve, solve_tol
from repro.data import SyntheticTokens
from repro.kernels import kernel_ops
from repro.models import build_model
from repro.serve import Request, TokenEngine
from repro.sparse import (
    coo_to_banded, coo_to_ell, col_partitioned_ell, ell_col_norms_sq,
    make_lasso,
)


def test_paper_pipeline_end_to_end():
    """Table-1-style generation -> Lg via column norms (paper init) ->
    A2 solve on Pallas kernel ops -> certificates healthy."""
    cfg = small_config()
    coo, b, x_true = make_lasso(cfg, seed=11)
    ellt = col_partitioned_ell(coo, parts=1)
    lg = float(jnp.sum(ell_col_norms_sq(ellt)))       # paper steps 1-2
    prox = get_prox(cfg.prox, reg=cfg.reg)
    ops = kernel_ops(coo_to_ell(coo, pad_to=8),
                     coo_to_banded(coo, band_size=512, pad_to=8),
                     prox, cfg.reg)
    state, hist = solve(ops, prox, b, lg, gamma0=1000.0, iterations=400,
                        record_every=50)
    feas = np.asarray(hist["feasibility"])
    assert feas[-1] < 0.15 * feas[0]
    cert = certificates(ops, prox, b, lg, 1000.0, state)
    assert np.isfinite(float(cert["gap"]))
    rel = float(jnp.linalg.norm(state.xbar - x_true)
                / jnp.linalg.norm(x_true))
    assert rel < 0.25


def test_solver_early_stop_kernel_path():
    cfg = small_config()
    coo, b, _ = make_lasso(cfg, seed=12)
    prox = get_prox("l1", reg=cfg.reg)
    ell, ellt = coo_to_ell(coo, pad_to=8), col_partitioned_ell(coo, parts=1)
    lg = float(jnp.sum(ell_col_norms_sq(ellt)))
    st = solve_tol(ell_ops(ell, ellt), prox, b, lg, 1000.0,
                   max_iterations=3000, tol=5e-2, check_every=32)
    assert int(st.k) < 3000


@pytest.mark.parametrize("arch", ["qwen3-4b", "falcon-mamba-7b",
                                  "musicgen-medium"])
def test_engine_serves_batched_requests(arch, key):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(key)
    eng = TokenEngine(model, slots=2, max_len=32)
    eng.init_state(params)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(4):
        shape = (3, cfg.num_codebooks) if cfg.num_codebooks else (3,)
        r = Request(uid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=shape).astype(np.int32),
            max_new_tokens=4)
        reqs.append(r)
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)


def test_engine_greedy_determinism(key):
    cfg = reduced(get_config("qwen3-4b"))
    model = build_model(cfg)
    params = model.init(key)
    outs = []
    for _ in range(2):
        eng = TokenEngine(model, slots=1, max_len=24)
        eng.init_state(params)
        r = Request(uid=0, prompt=np.array([5, 6, 7], np.int32),
                    max_new_tokens=6)
        eng.submit(r)
        eng.run()
        outs.append(tuple(r.out))
    assert outs[0] == outs[1]


def test_data_pipeline_shapes_and_determinism():
    cfg = reduced(get_config("llama-3.2-vision-11b"))
    shape = ShapeSpec("t", "train", 32, 4)
    d1 = SyntheticTokens(cfg, shape, seed=7)
    b1 = next(d1)
    d1.close()
    assert b1["tokens"].shape == (4, 32)
    assert b1["image_embeds"].shape == (4, cfg.num_image_tokens, cfg.d_model)
    assert int(b1["tokens"].max()) < cfg.vocab_size
    d2 = SyntheticTokens(cfg, shape, seed=7)
    b2 = next(d2)
    d2.close()
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_grad_compress_error_feedback_converges():
    """Compressed-gradient SGD with error feedback reaches the same loss
    neighborhood as exact SGD on a quadratic."""
    from repro.train.grad_compress import (compress_tree, decompress_tree,
                                           init_error_feedback)
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(64), jnp.float32)

    def loss(w):
        r = A @ w - b
        return 0.5 * jnp.mean(r * r)

    w_exact = {"w": jnp.zeros(16)}
    w_comp = {"w": jnp.zeros(16)}
    ef = init_error_feedback(w_comp)
    for _ in range(200):
        g1 = jax.grad(lambda w: loss(w["w"]))(w_exact)
        w_exact = jax.tree_util.tree_map(lambda w, g: w - 0.3 * g, w_exact, g1)
        g2 = jax.grad(lambda w: loss(w["w"]))(w_comp)
        q, ef = compress_tree(g2, ef, block=8)
        g2d = decompress_tree(q, w_comp)
        w_comp = jax.tree_util.tree_map(lambda w, g: w - 0.3 * g, w_comp, g2d)
    assert float(loss(w_comp["w"])) < 1.2 * float(loss(w_exact["w"])) + 1e-5

"""Checkpoint atomicity/roundtrip + fault-tolerance supervisor policies."""
import os
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncSaver, latest_step, restore, save
from repro.ft import Supervisor, SupervisorConfig, run_with_restarts


@pytest.fixture
def tree(key):
    return {"a": jax.random.normal(key, (8, 16)),
            "nested": {"b": jnp.arange(7, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def _assert_tree_equal(x, y):
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), x, y)


def test_roundtrip(tree, tmp_path):
    save(tree, str(tmp_path), step=3)
    assert latest_step(str(tmp_path)) == 3
    out = restore(tree, str(tmp_path))
    _assert_tree_equal(tree, out)


def test_latest_pointer_advances(tree, tmp_path):
    save(tree, str(tmp_path), step=1)
    t2 = jax.tree_util.tree_map(lambda x: x + 1 if x.dtype != jnp.int32 else x,
                                tree)
    save(t2, str(tmp_path), step=2)
    assert latest_step(str(tmp_path)) == 2
    _assert_tree_equal(t2, restore(tree, str(tmp_path)))
    # explicit older step still restorable
    _assert_tree_equal(tree, restore(tree, str(tmp_path), step=1))


def test_no_tmp_dir_left_behind(tree, tmp_path):
    save(tree, str(tmp_path), step=9)
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


def test_async_saver(tree, tmp_path):
    s = AsyncSaver()
    s.save(tree, str(tmp_path), step=5)
    s.wait()
    assert latest_step(str(tmp_path)) == 5


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore({"a": jnp.zeros(3)}, str(tmp_path))


# ---------------------------------------------------------------------------
# Supervisor
# ---------------------------------------------------------------------------

def test_straggler_detection():
    t = [0.0]
    sup = Supervisor(SupervisorConfig(straggler_factor=2.0),
                     clock=lambda: t[0])
    for w in ["h0", "h1", "h2", "h3"]:
        for _ in range(10):
            sup.heartbeat(w, 1.0 if w != "h2" else 4.0)
    d = sup.check()
    assert d["stragglers"] == ["h2"]
    assert d["action"] == "restart_without"
    assert sup.events, "policy decisions must be recorded"


def test_dead_worker_detection():
    t = [0.0]
    sup = Supervisor(SupervisorConfig(dead_after=5.0), clock=lambda: t[0])
    sup.heartbeat("h0")
    sup.heartbeat("h1")
    t[0] = 10.0
    sup.heartbeat("h0")
    assert sup.dead_workers() == ["h1"]


def test_no_false_positives():
    t = [0.0]
    sup = Supervisor(clock=lambda: t[0])
    for w in ["h0", "h1"]:
        for _ in range(5):
            sup.heartbeat(w, 1.0)
    assert sup.check()["action"] == "none"


def test_run_with_restarts_recovers():
    calls = {"n": 0, "restores": 0}

    def restore_fn():
        calls["restores"] += 1
        return calls["n"] * 10

    def loop(start):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise RuntimeError("boom")
        return start + 1

    final = run_with_restarts(loop, restore_fn, max_restarts=3)
    assert calls["restores"] == 3          # initial + 2 restarts
    assert final == 21


def test_run_with_restarts_gives_up():
    def loop(start):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        run_with_restarts(loop, lambda: 0, max_restarts=2)


def test_train_launcher_failure_injection(tmp_path):
    """End-to-end: the launcher survives an injected failure and reaches the
    final step via checkpoint restart."""
    from repro.launch.train import main
    rc = main(["--arch", "qwen3-4b", "--smoke", "--steps", "8",
               "--checkpoint-dir", str(tmp_path), "--checkpoint-every", "3",
               "--inject-failure", "5"])
    assert rc == 0
    assert latest_step(str(tmp_path)) is not None

"""Coordinate-descent solver family: oracle equality against a dense
float64 reference (proximal/projected gradient — deliberately no sklearn),
batched-vs-sequential exactness, the CSC operand view, the Pallas
gather-update kernel, the planner's face-off rule, and engine admission
through the same splice/freeze path as A2 requests."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import Problem, solve_many
from repro.plan import SolveSpec, decide_solver_family
from repro.sparse.formats import (
    CSC, coo_to_csc, coo_to_dense, stack_cscs, transpose_coo,
)
from repro.sparse.linalg import csc_gather_matvec, stacked_csc_gather_matvec
from repro.sparse.random import random_coo
from repro.solvers import (
    FAMILY_LOSSES, RCDState, batched_rcd_init, batched_rcd_progress,
    batched_rcd_solve_tol, batched_rcd_step, dense_reference, rcd_mask_state,
    rcd_solve_tol, reference_objective,
)


def _labels(m, seed):
    rs = np.random.default_rng(seed)
    return np.where(rs.random(m) < 0.5, -1.0, 1.0).astype(np.float32)


def _targets(m, seed):
    return np.random.default_rng(seed).standard_normal(m).astype(np.float32)


# ---------------------------------------------------------------------------
# CSC operand view
# ---------------------------------------------------------------------------

def test_csc_gather_matvec_matches_dense():
    coo = random_coo(13, 9, row_nnz=3, seed=1)
    A = np.asarray(coo_to_dense(coo), np.float64)
    x = np.random.default_rng(2).standard_normal(13).astype(np.float32)
    c = coo_to_csc(coo)                    # CSC(A): rmatvec via column major
    assert isinstance(c, CSC) and c.n == 9 and c.m == 13
    got = np.asarray(csc_gather_matvec(c, jnp.asarray(x)))
    np.testing.assert_allclose(got, A.T @ x.astype(np.float64),
                               rtol=1e-5, atol=1e-5)


def test_stacked_csc_gather_matvec_matches_per_slot():
    coos = [random_coo(12, 8, row_nnz=3, seed=s) for s in (3, 4, 5)]
    k = max(int(np.bincount(np.asarray(c.cols), minlength=8).max())
            for c in coos)
    st = stack_cscs([coo_to_csc(c, k=k) for c in coos])
    xs = np.random.default_rng(6).standard_normal((3, 12)).astype(np.float32)
    got = np.asarray(stacked_csc_gather_matvec(st, jnp.asarray(xs)))
    for i, c in enumerate(coos):
        ref = np.asarray(coo_to_dense(c), np.float64).T @ xs[i]
        np.testing.assert_allclose(got[i], ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Satellite: oracle equality vs the dense float64 reference
# ---------------------------------------------------------------------------

CASES = [("rcd_primal", "lasso", 24, 16, 0.1),
         ("rcd_primal", "logistic", 24, 16, 0.3),
         ("rcd_dual", "svm", 20, 12, 0.5),
         ("rcd_dual", "logistic", 12, 24, 0.3)]


@pytest.mark.parametrize("family,loss,m,n,reg", CASES)
def test_rcd_matches_dense_reference(family, loss, m, n, reg):
    coo = random_coo(m, n, row_nnz=4, seed=hash((family, loss)) % 977)
    b = _targets(m, 7) if loss == "lasso" else _labels(m, 7)
    x, resid, epochs = rcd_solve_tol(coo, b, reg, family=family, loss=loss,
                                     tol=1e-7, max_iterations=20_000)
    A = np.asarray(coo_to_dense(coo), np.float64)
    ref = dense_reference(A, b, reg, loss)
    np.testing.assert_allclose(np.asarray(x, np.float64), ref, atol=1e-4)
    assert abs(reference_objective(A, b, reg, loss, np.asarray(x))
               - reference_objective(A, b, reg, loss, ref)) < 1e-5


def test_family_loss_compatibility():
    assert FAMILY_LOSSES == {"rcd_primal": ("lasso", "logistic"),
                             "rcd_dual": ("svm", "logistic")}
    with pytest.raises(ValueError, match="strongly-convex dual"):
        rcd_solve_tol(random_coo(8, 4, row_nnz=2, seed=0),
                      _targets(8, 0), 0.1, family="rcd_dual", loss="lasso")
    with pytest.raises(ValueError, match="nonsmooth"):
        rcd_solve_tol(random_coo(8, 4, row_nnz=2, seed=0),
                      _labels(8, 0), 0.1, family="rcd_primal", loss="svm")


# ---------------------------------------------------------------------------
# Satellite: batched masked variants == sequential per-slot
# ---------------------------------------------------------------------------

def test_batched_rcd_matches_sequential_slots():
    coos = [random_coo(16, 12, row_nnz=3, seed=s) for s in (11, 12, 13)]
    bs = np.stack([_labels(16, 20 + s) for s in range(3)])
    k = max(int(np.bincount(np.asarray(c.cols), minlength=12).max())
            for c in coos)
    kt = max(int(np.bincount(np.asarray(c.rows), minlength=16).max())
             for c in coos)
    a = stack_cscs([coo_to_csc(c, k=k) for c in coos])
    at = stack_cscs([coo_to_csc(transpose_coo(c), k=kt) for c in coos])
    regs = jnp.asarray([0.2, 0.3, 0.4], jnp.float32)
    dim = jnp.asarray([12, 12, 12], jnp.int32)
    seeds = jnp.asarray([5, 6, 7], jnp.int32)
    state, resid = batched_rcd_solve_tol(
        a, at, jnp.asarray(bs), regs, dim, seeds, family="rcd_primal",
        loss="logistic", tol=1e-6, max_iterations=2000, check_every=4)
    for i, c in enumerate(coos):
        x1, r1, k1 = rcd_solve_tol(c, bs[i], float(regs[i]),
                                   family="rcd_primal", loss="logistic",
                                   seed=int(seeds[i]), tol=1e-6,
                                   max_iterations=2000, check_every=4)
        # identical coordinate sequence (same dims/seed); widths may pad
        # differently, so allow summation-tree rounding
        assert int(state.k[i]) == k1
        np.testing.assert_allclose(np.asarray(state.xbar[i]),
                                   np.asarray(x1), atol=1e-6)


def test_rcd_mask_state_freezes_bitwise():
    coo = random_coo(16, 12, row_nnz=3, seed=31)
    a = stack_cscs([coo_to_csc(coo)] * 2)
    at = stack_cscs([coo_to_csc(transpose_coo(coo))] * 2)
    b = jnp.asarray(np.stack([_labels(16, 1)] * 2))
    reg = jnp.asarray([0.3, 0.3], jnp.float32)
    dim = jnp.asarray([12, 12], jnp.int32)
    seed = jnp.asarray([9, 9], jnp.int32)
    s0 = batched_rcd_init(a, at, b, family="rcd_primal")
    s1 = batched_rcd_step(a, at, b, reg, dim, seed, s0,
                          family="rcd_primal", loss="logistic",
                          mask=jnp.asarray([True, False]))
    assert int(s1.k[0]) == 1 and int(s1.k[1]) == 0
    np.testing.assert_array_equal(np.asarray(s1.xbar[1]),
                                  np.asarray(s0.xbar[1]))
    assert np.any(np.asarray(s1.xbar[0]) != np.asarray(s0.xbar[0]))
    froz = rcd_mask_state(jnp.asarray([False, False]), s1, s0)
    assert froz.k.tolist() == [0, 0]


# ---------------------------------------------------------------------------
# Pallas kernel parity (interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,loss", [("rcd_primal", "lasso"),
                                         ("rcd_dual", "logistic")])
def test_pallas_kernel_parity(family, loss):
    coos = [random_coo(16, 12, row_nnz=3, seed=s) for s in (41, 42)]
    bs = np.stack([_targets(16, 1) if loss == "lasso" else _labels(16, 1),
                   _targets(16, 2) if loss == "lasso" else _labels(16, 2)])
    k = max(int(np.bincount(np.asarray(c.cols), minlength=12).max())
            for c in coos)
    kt = max(int(np.bincount(np.asarray(c.rows), minlength=16).max())
             for c in coos)
    a = stack_cscs([coo_to_csc(c, k=k) for c in coos])
    at = stack_cscs([coo_to_csc(transpose_coo(c), k=kt) for c in coos])
    b = jnp.asarray(bs)
    reg = jnp.asarray([0.2, 0.4], jnp.float32)
    dim = jnp.asarray([12, 12] if family == "rcd_primal" else [16, 16],
                      jnp.int32)
    seed = jnp.asarray([3, 4], jnp.int32)
    s0 = batched_rcd_init(a, at, b, family=family)
    ref = batched_rcd_step(a, at, b, reg, dim, seed, s0, family=family,
                           loss=loss)
    got = batched_rcd_step(a, at, b, reg, dim, seed, s0, family=family,
                           loss=loss, kernel="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(got.xbar), np.asarray(ref.xbar))
    np.testing.assert_array_equal(np.asarray(got.aux), np.asarray(ref.aux))


# ---------------------------------------------------------------------------
# Satellite: face-off rule + solver_family override round-trip
# ---------------------------------------------------------------------------

def test_face_off_picks_expected_side():
    tall = Problem(random_coo(96, 8, row_nnz=3, seed=51), _labels(96, 5),
                   reg=0.3, loss="logistic")          # n >> d: few coords
    wide = Problem(random_coo(8, 96, row_nnz=4, seed=52), _labels(8, 5),
                   reg=0.3, loss="logistic")          # d >> n: few samples
    fam_t, why_t = decide_solver_family("logistic", tall.stats)
    fam_w, why_w = decide_solver_family("logistic", wide.stats)
    assert fam_t == "rcd_primal" and "face-off" in why_t
    assert fam_w == "rcd_dual" and "face-off" in why_w
    assert tall.plan(tol=1e-3).algorithm == "rcd_primal"
    assert wide.plan(tol=1e-3).algorithm == "rcd_dual"


def test_face_off_forced_sides_and_errors():
    assert decide_solver_family("lasso")[0] == "rcd_primal"
    assert decide_solver_family("svm")[0] == "rcd_dual"
    assert decide_solver_family("")[0] == "a2"
    with pytest.raises(ValueError):
        decide_solver_family("lasso", override="rcd_dual")
    with pytest.raises(ValueError):
        decide_solver_family("svm", override="rcd_primal")
    with pytest.raises(ValueError):
        decide_solver_family("logistic", override="a2")
    with pytest.raises(ValueError):
        decide_solver_family("", override="rcd_primal")
    with pytest.raises(KeyError):
        decide_solver_family("logistic", override="nope")


def test_solver_family_override_round_trips():
    coo = random_coo(24, 16, row_nnz=4, seed=61)
    p = Problem(coo, _labels(24, 6), reg=0.3, loss="logistic")
    pl = p.plan(tol=1e-5, max_iterations=10_000)
    assert pl.algorithm == "rcd_primal" and pl.format == "csc"
    assert "rcd_primal" in repr(pl)
    pl2 = pl.override(solver_family="rcd_dual")
    assert pl2.algorithm == "rcd_dual" and pl2.format == "csc"
    assert pl2.reasons["solver_family"].endswith("user override")
    ref = dense_reference(np.asarray(coo_to_dense(coo)),
                          np.asarray(p.b), 0.3, "logistic")
    for q in (pl, pl2):
        r = q.solve()
        np.testing.assert_allclose(np.asarray(r.x, np.float64), ref,
                                   atol=1e-4)
        assert r.state is None and r.iterations > 0


def test_problem_loss_routes_automatically():
    coo = random_coo(24, 12, row_nnz=3, seed=71)
    res = Problem(coo, _targets(24, 8), reg=0.1, loss="lasso").solve(
        tol=1e-6, max_iterations=10_000)
    ref = dense_reference(np.asarray(coo_to_dense(coo)),
                          np.asarray(_targets(24, 8)), 0.1, "lasso")
    np.testing.assert_allclose(np.asarray(res.x, np.float64), ref,
                               atol=1e-4)
    assert res.plan.algorithm == "rcd_primal"
    assert res.plan.reasons["solver_family"].startswith("rcd_primal")


def test_problem_loss_validation():
    coo = random_coo(8, 4, row_nnz=2, seed=0)
    with pytest.raises(ValueError, match="unknown loss"):
        Problem(coo, _targets(8, 0), loss="huber")
    with pytest.raises(ValueError, match="composite"):
        Problem(coo, _labels(8, 0), prox="zero", loss="svm")
    # the shared stats pass is cached: same object both times
    p = Problem(coo, _targets(8, 0), reg=0.1, loss="lasso")
    assert p.stats is p.stats and p.stats.nnz == coo.nnz


# ---------------------------------------------------------------------------
# Tentpole acceptance: RCD requests bucket, splice, and freeze through
# SolverEngine.submit exactly like A2 requests
# ---------------------------------------------------------------------------

def test_engine_serves_rcd_requests():
    from repro.serve.solver_engine import SolverEngine

    # dims == the engine's bucket padding (m_pad>=64, n_pad>=16) and the
    # same check cadence -> identical coordinate sequences engine-vs-direct
    eng = SolverEngine(slots=4, check_every=4)
    cases = []
    for i, loss in enumerate(["lasso", "svm", "logistic"]):
        coo = random_coo(64, 16, row_nnz=4, seed=81 + i)
        b = _targets(64, i) if loss == "lasso" else _labels(64, i)
        p = Problem(coo, b, reg=0.2, loss=loss)
        eng.submit(p.to_request(uid=i, tol=1e-5, max_iterations=3000,
                                seed=123 + i))
        cases.append(p)
    done = {r.uid: r for r in eng.run()}
    assert len(done) == 3
    fams = {k.family for k in eng.buckets}
    assert fams == {"rcd_primal", "rcd_dual"}          # bucketed by family
    assert all(k.fmt == "csc" for k in eng.buckets)
    for i, p in enumerate(cases):
        d = done[i]
        x1, r1, k1 = rcd_solve_tol(p.coo, np.asarray(p.b), p.reg,
                                   family=d.family, loss=d.loss,
                                   seed=123 + i, tol=1e-5,
                                   max_iterations=3000, check_every=4)
        assert d.iterations == k1                      # same epoch count
        np.testing.assert_allclose(np.asarray(d.x), np.asarray(x1),
                                   atol=1e-5)
        assert d.feasibility < 1e-5 or d.iterations == 3000


def test_engine_mixes_rcd_and_a2_fleet():
    probs = []
    for i in range(2):
        probs.append(Problem(random_coo(24, 16, row_nnz=4, seed=91 + i),
                             _labels(24, i), reg=0.3, loss="logistic"))
    for i in range(2):
        probs.append(Problem(random_coo(16, 48, row_nnz=4, seed=95 + i),
                             _targets(16, i), prox="l1", reg=0.01))
    res = solve_many(probs, SolveSpec(tol=1e-3, max_iterations=20_000,
                                      slots=4))
    assert len(res) == 4
    for r in res:
        assert r.feasibility < 1e-3
    assert res[0].plan.execution == "engine"


def test_rcd_state_engine_contract():
    """The engine harvests .xbar/.k by name — RCDState must carry them."""
    assert set(RCDState._fields) >= {"xbar", "k"}
    coo = random_coo(16, 12, row_nnz=3, seed=99)
    a = stack_cscs([coo_to_csc(coo)])
    at = stack_cscs([coo_to_csc(transpose_coo(coo))])
    b = jnp.asarray(_labels(16, 9))[None, :]
    s = batched_rcd_init(a, at, b, family="rcd_dual")
    s2, resid = batched_rcd_progress(a, at, b, jnp.asarray([0.3]), s,
                                     family="rcd_dual", loss="svm")
    assert s2.xbar.shape == (1, 12) and resid.shape == (1,)

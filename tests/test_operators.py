"""The LinearOperator layer: registry dispatch, BCSR vs dense oracles,
format selection, and cross-backend solver equivalence (incl. bitwise
identity of the registry path vs the legacy constructors it replaced)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.paper_problems import small_config
from repro.core.prox import get_prox
from repro.core.solver import dense_ops, ell_ops, solve
from repro.kernels import kernel_ops
from repro.operators import (
    available, estimate_formats, from_coo, make_operator, make_solver_ops,
    select_format,
)
from repro.sparse import (
    bcsr_matvec, bcsr_to_dense, coo_to_banded, coo_to_bcsr, coo_to_dense,
    coo_to_ell, col_partitioned_ell, make_lasso, random_coo, transpose_coo,
)

CFG = small_config()


@pytest.fixture(scope="module")
def problem():
    coo, b, x_true = make_lasso(CFG, seed=3)
    d = coo_to_dense(coo).astype(np.float64)
    return coo, d, b, float((d ** 2).sum())


# ---------------------------------------------------------------------------
# Registry mechanics
# ---------------------------------------------------------------------------

def test_registry_covers_formats_and_strategies():
    have = set(available())
    for key in [("dense", "jnp"), ("coo", "jnp"), ("ell", "jnp"),
                ("bcsr", "jnp"), ("ell", "pallas"), ("bcsr", "pallas"),
                ("ell", "rowpart"), ("ell", "colpart"), ("ell", "dualpart"),
                ("ell", "block2d"), ("ell", "replicated")]:
        assert key in have, key


def test_registry_unknown_key_raises():
    with pytest.raises(KeyError, match="available"):
        make_operator("csr", "cuda")


def test_operator_metadata_and_adjoint(problem):
    coo, d, b, lg = problem
    op = from_coo(coo, "bcsr", "jnp", bm=8, bn=32)
    assert op.shape == (coo.m, coo.n)
    assert op.format == "bcsr" and op.backend == "jnp"
    assert op.stats["bm"] == 8 and op.stats["bn"] == 32
    y = jnp.ones(coo.m, jnp.float32)
    np.testing.assert_array_equal(np.asarray(op.T.matvec(y)),
                                  np.asarray(op.rmatvec(y)))
    assert op.T.shape == (coo.n, coo.m)


# ---------------------------------------------------------------------------
# BCSR vs the COO dense oracle (acceptance: 1e-5)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(64, 16, 3), (300, 70, 5), (1000, 333, 7)])
@pytest.mark.parametrize("bm,bn", [(8, 16), (8, 128), (16, 64)])
def test_bcsr_matches_dense_oracle(m, n, k, bm, bn):
    coo = random_coo(m, n, min(k, n), seed=1)
    d = coo_to_dense(coo).astype(np.float32)
    a = coo_to_bcsr(coo, bm=bm, bn=bn)
    at = coo_to_bcsr(transpose_coo(coo), bm=bm, bn=bn)
    np.testing.assert_allclose(bcsr_to_dense(a), d, atol=1e-6)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    y = jnp.asarray(rng.standard_normal(m), jnp.float32)
    np.testing.assert_allclose(np.asarray(bcsr_matvec(a, x)), d @ np.asarray(x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(bcsr_matvec(at, y)),
                               d.T @ np.asarray(y), rtol=1e-5, atol=1e-5)


def test_bcsr_accumulates_duplicate_entries():
    from repro.sparse import COO
    coo = COO(rows=jnp.asarray([0, 0, 1], jnp.int32),
              cols=jnp.asarray([1, 1, 0], jnp.int32),
              vals=jnp.asarray([2.0, 3.0, 1.0], jnp.float32), m=2, n=2)
    d = bcsr_to_dense(coo_to_bcsr(coo, bm=2, bn=2))
    np.testing.assert_allclose(d, [[0.0, 5.0], [1.0, 0.0]])


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bcsr_operator_matches_dense(problem, backend):
    coo, d, b, lg = problem
    op = from_coo(coo, "bcsr", backend, bm=8, bn=32)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(coo.n), jnp.float32)
    y = jnp.asarray(rng.standard_normal(coo.m), jnp.float32)
    d32 = d.astype(np.float32)
    np.testing.assert_allclose(np.asarray(op.matvec(x)), d32 @ np.asarray(x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(op.rmatvec(y)), d32.T @ np.asarray(y),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Format selector
# ---------------------------------------------------------------------------

def test_selector_prefers_ell_for_scattered_rows():
    coo = random_coo(4000, 500, 4, seed=0)          # uniform scatter
    plan = select_format(coo)
    assert plan.format == "ell"
    assert set(plan.estimates) == {"ell", "banded_ell", "bcsr"}
    assert all(v["s"] > 0 for v in plan.estimates.values())


def test_selector_prefers_bcsr_for_clustered_blocks():
    """Block-diagonal-ish matrix: dense 8x128 tiles -> MXU wins the model."""
    rng = np.random.default_rng(0)
    rows, cols, vals = [], [], []
    for blk in range(16):                            # 16 dense 8x128 blocks
        r0, c0 = blk * 8, (blk % 4) * 128
        r, c = np.meshgrid(np.arange(8), np.arange(128), indexing="ij")
        rows.append((r0 + r).reshape(-1))
        cols.append((c0 + c).reshape(-1))
        vals.append(rng.standard_normal(8 * 128))
    from repro.sparse import COO
    coo = COO(rows=jnp.asarray(np.concatenate(rows), jnp.int32),
              cols=jnp.asarray(np.concatenate(cols), jnp.int32),
              vals=jnp.asarray(np.concatenate(vals), jnp.float32),
              m=128, n=512)
    plan = select_format(coo)
    assert plan.format == "bcsr"
    assert plan.params["bn"] == 128
    assert plan.estimates["bcsr"]["occupancy"] > 0.9


def test_selector_forces_banded_when_y_exceeds_vmem():
    coo = random_coo(2000, 100, 3, seed=1)
    plan = select_format(coo, y_vmem_budget=1000)    # pretend tiny VMEM
    assert plan.format == "ell"                      # ELL/pallas bundle...
    assert "band_size" in plan.params                # ...with banded backward


def test_estimates_scale_with_padding_waste():
    est_uniform = estimate_formats(random_coo(1000, 200, 4, seed=2))
    assert est_uniform["ell"]["pad_ratio"] >= 1.0
    assert est_uniform["bcsr"]["occupancy"] <= 1.0


# ---------------------------------------------------------------------------
# Cross-backend solver equivalence through the registry
# ---------------------------------------------------------------------------

def _solve(ops, prox, b, lg, alg):
    s, _ = solve(ops, prox, b, lg, 100.0, iterations=60, algorithm=alg)
    return s


@pytest.mark.parametrize("alg", ["a1", "a2"])
def test_registry_path_bitwise_equals_legacy_constructors(problem, alg):
    """The legacy constructors (dense_ops/ell_ops/kernel_ops) are thin
    registry adapters: iterates must be bitwise-identical to operators
    obtained directly from the registry."""
    coo, d, b, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    d32 = jnp.asarray(d, jnp.float32)

    ell, ellt = coo_to_ell(coo), col_partitioned_ell(coo, parts=1)
    ell8 = coo_to_ell(coo, pad_to=8)
    bell = coo_to_banded(coo, band_size=512, pad_to=8)
    pairs = [
        (dense_ops(d32), make_operator("dense", "jnp", d32).solver_ops()),
        (ell_ops(ell, ellt),
         make_operator("ell", "jnp", ell, ellt).solver_ops()),
        (kernel_ops(ell8, bell, prox, CFG.reg),
         make_operator("ell", "pallas", ell8, bell, prox,
                       CFG.reg).solver_ops()),
    ]
    for legacy, registry in pairs:
        s_l = _solve(legacy, prox, b, lg, alg)
        s_r = _solve(registry, prox, b, lg, alg)
        np.testing.assert_array_equal(np.asarray(s_l.xbar),
                                      np.asarray(s_r.xbar))
        np.testing.assert_array_equal(np.asarray(s_l.xstar),
                                      np.asarray(s_r.xstar))
        np.testing.assert_array_equal(np.asarray(s_l.yhat),
                                      np.asarray(s_r.yhat))


@pytest.mark.parametrize("alg", ["a1", "a2"])
def test_all_backends_agree_on_iterates(problem, alg):
    """jnp / kernel / BCSR / distributed backends from the registry land on
    the same A1/A2 iterates (float tolerance across accumulation orders)."""
    from jax.sharding import Mesh
    from repro.core.distributed import solve_distributed

    coo, d, b, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    ref = _solve(make_solver_ops(coo, "dense", "jnp"), prox, b, lg, alg)

    for ops in [make_solver_ops(coo, "ell", "jnp"),
                make_solver_ops(coo, "ell", "pallas", prox=prox, reg=CFG.reg,
                                band_size=512, block_rows=256,
                                block_cols=128),
                make_solver_ops(coo, "bcsr", "jnp", bm=8, bn=32),
                make_solver_ops(coo, "bcsr", "pallas", prox=prox,
                                reg=CFG.reg, bm=8, bn=32, block_brows=4)]:
        s = _solve(ops, prox, b, lg, alg)
        np.testing.assert_allclose(np.asarray(s.xbar), np.asarray(ref.xbar),
                                   atol=1e-4)

    mesh = Mesh(np.array(jax.devices()).reshape(1), ("p",))
    for strategy in ("replicated", "dualpart"):
        xbar, _ = solve_distributed(coo, b, prox, mesh, strategy,
                                    gamma0=100.0, iterations=60,
                                    algorithm=alg)
        np.testing.assert_allclose(np.asarray(xbar), np.asarray(ref.xbar),
                                   atol=1e-4)


def test_auto_format_produces_working_solver(problem):
    coo, d, b, lg = problem
    prox = get_prox("l1", reg=CFG.reg)
    op = from_coo(coo, "auto", "pallas", prox=prox, reg=CFG.reg)
    assert op.format in ("ell", "bcsr")
    s = _solve(op.solver_ops(), prox, b, lg, "a2")
    ref = _solve(make_solver_ops(coo, "dense", "jnp"), prox, b, lg, "a2")
    np.testing.assert_allclose(np.asarray(s.xbar), np.asarray(ref.xbar),
                               atol=1e-4)

"""Measured autotune tables round-trip through the format selector.

Reads the table named by env ``REPRO_AUTOTUNE_TABLE`` (the CI smoke points
this at a fresh ``benchmarks/autotune.py --quick`` run) or, unset, the
committed ``experiments/bench/autotune.json``.  Each spmv cell records the
exact matrix recipe (m, n, row_nnz, seed), so the tests rebuild the
operand and assert ``operators/select.py`` (1) prefers the measured cell
over the analytic roofline, (2) reproduces the cell's seconds at the
cell's own work, and (3) predicts a *different*-size matrix's measured
seconds within 2x via the linear-in-work scaling — prediction quality
against real measurements, no timing in the test itself.
"""
import json
import os

import pytest

from repro.operators.select import (
    estimate_formats, load_measured_table, select_format,
)
from repro.sparse import random_coo

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_DEFAULT = os.path.join(_REPO, "experiments", "bench", "autotune.json")


def _table_path():
    return os.environ.get("REPRO_AUTOTUNE_TABLE") or _DEFAULT


@pytest.fixture(scope="module")
def cells():
    path = _table_path()
    if not os.path.exists(path):
        pytest.skip(f"no autotune table at {path} "
                    "(run benchmarks/autotune.py)")
    got = load_measured_table(path)
    assert got, f"table at {path} loaded empty"
    return got


def _spmv_cells(cells, fmt=None):
    out = [c for c in cells if c.get("kind") == "spmv"]
    if fmt:
        out = [c for c in out if c["format"] == fmt]
    return out


def _estimate_cell(cell, table):
    coo = random_coo(cell["m"], cell["n"], cell["row_nnz"],
                     seed=cell["seed"])
    if cell["format"] == "bcsr":
        est = estimate_formats(
            coo, bm_bn_candidates=((cell["bm"], cell["bn"]),),
            table=table, backend=cell["backend"])
    else:
        est = estimate_formats(coo, table=table, backend=cell["backend"])
    return est[cell["format"]]


def test_measured_cells_override_analytic(cells):
    """Every spmv cell's own matrix prices as source=measured, within 2x
    of the cell's recorded seconds (exact up to nearest-cell ties)."""
    spmv = _spmv_cells(cells)
    assert spmv, "table has no spmv cells"
    for cell in spmv:
        entry = _estimate_cell(cell, cells)
        assert entry["source"] == "measured", cell
        assert "analytic_s" in entry
        ratio = entry["s"] / cell["measured_s"]
        assert 0.5 <= ratio <= 2.0, (cell, entry["s"])


def test_without_table_stays_analytic(cells):
    cell = _spmv_cells(cells)[0]
    entry = _estimate_cell(cell, None)
    assert entry["source"] == "analytic"
    assert "analytic_s" not in entry


def test_cross_size_prediction_within_2x(cells):
    """Predicting a matrix NOT in the table (its cell withheld) from the
    remaining cells lands within 2x of that cell's measurement — the
    linear-in-work interpolation acceptance bound."""
    by_size = {}
    for c in _spmv_cells(cells, "ell"):
        by_size.setdefault((c["m"], c["n"], c["backend"]), c)
    sizes = sorted(by_size)
    if len({(m, n) for m, n, _ in sizes}) < 2:
        pytest.skip("table has one spmv size only (quick table)")
    target = by_size[sizes[-1]]
    held_out = [c for c in cells
                if not (c.get("kind") == "spmv" and c["format"] == "ell"
                        and c["m"] == target["m"])]
    entry = _estimate_cell(target, held_out)
    assert entry["source"] == "measured"
    ratio = entry["s"] / target["measured_s"]
    assert 0.5 <= ratio <= 2.0, (entry["s"], target["measured_s"])


def test_select_format_consults_env_table(cells, monkeypatch):
    """select_format with the env var set routes through the measured
    table (every candidate the table covers reports source=measured)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", _table_path())
    cell = _spmv_cells(cells)[0]
    coo = random_coo(cell["m"], cell["n"], cell["row_nnz"],
                     seed=cell["seed"])
    plan = select_format(coo, backend=cell["backend"])
    sources = {f: e["source"] for f, e in plan.estimates.items()}
    assert sources[cell["format"]] == "measured", sources


def test_malformed_table_falls_back_to_analytic(tmp_path, monkeypatch):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(bad))
    assert load_measured_table() is None
    coo = random_coo(64, 32, 4, seed=0)
    est = estimate_formats(coo, table=load_measured_table())
    assert all(e["source"] == "analytic" for e in est.values())


def test_check_block_cells_have_sweep_axes(cells):
    """The fused check-block sweep covers slot-width and check_every axes
    (the data the planner's cadence/bucket decisions cite)."""
    cb = [c for c in cells if c.get("kind") == "check_block"]
    if not cb:
        pytest.skip("table has no check_block cells")
    for c in cb:
        assert c["slots"] >= 1 and c["check_every"] >= 1
        assert c["measured_s"] > 0
        assert c["per_slot_iter_s"] == pytest.approx(
            c["measured_s"] / (c["slots"] * c["check_every"]))

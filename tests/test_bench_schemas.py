"""Committed bench artifacts keep their documented schema: the JSON files
under experiments/bench/ are read by benchmarks/README.md consumers (and
by later PRs building on their numbers), so key drift or nonsense values
(negative phase times, p50 > p99) should fail in CI, not in a reader's
notebook.  Each test skips if its artifact has not been generated —
running the bench is not a test prerequisite — but the repo commits all
three, so in CI they all run."""
import json
import os

import pytest

BENCH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "bench")

PHASES = ("admit_s", "splice_s", "dispatch_s", "harvest_s", "compile_s")
#: strict-mode tick counters (PR 9) riding along in tick_breakdown
COUNTERS = ("retraces", "disallowed_transfers")


def _load(name):
    path = os.path.join(BENCH, name)
    if not os.path.exists(path):
        pytest.skip(f"{name} not generated (run benchmarks/run.py)")
    with open(path) as f:
        return json.load(f)


def _check_phase_s(phase, wall, what):
    """phase_s contract: every entry non-negative, the ``*_s`` times sum
    within the wall time they decompose (phases are disjoint slices of
    the tick loop; non-``_s`` keys are counters, not seconds)."""
    for k, v in phase.items():
        assert v >= 0.0, f"{what}: negative phase {k}={v}"
    total = sum(v for k, v in phase.items() if k.endswith("_s"))
    assert total <= wall * 1.01 + 1e-6, \
        f"{what}: phases sum to {total:.4f}s > wall {wall:.4f}s"


def test_solver_serving_schema():
    rec = _load("solver_serving.json")
    for key in ("requests", "slots", "tol", "seed", "check_every",
                "buckets", "engine_s", "sequential_s", "sequential_jit_s",
                "rps_engine", "rps_sequential", "rps_sequential_jit",
                "speedup_vs_sequential", "speedup_vs_sequential_jit",
                "iterations", "steps", "tick_breakdown",
                "tick_breakdown_warm"):
        assert key in rec, key
    assert set(rec["tick_breakdown"]) == set(PHASES) | set(COUNTERS)
    assert set(rec["tick_breakdown_warm"]) == set(PHASES) | set(COUNTERS)
    _check_phase_s(rec["tick_breakdown"], rec["engine_s"],
                   "solver_serving measured window")
    # the strict-mode claim as committed data: a warm engine re-admits and
    # serves a whole stream with zero recompiles and zero implicit
    # transfers, every tick under transfer_guard("disallow")
    for counter in COUNTERS:
        assert rec["tick_breakdown"][counter] == 0, counter
    assert rec["rps_engine"] > 0 and rec["engine_s"] > 0


def test_sharded_serving_schema():
    rec = _load("sharded_serving.json")
    for key in ("requests", "slots", "big_shape", "shard_above",
                "formats", "by_devices", "speedup_8v1", "by_grid",
                "grid_format"):
        assert key in rec, key
    for fmt, frec in rec["formats"].items():
        assert "by_devices" in frec and "speedup_8v1" in frec, fmt
        for dev, point in frec["by_devices"].items():
            for key in ("dt", "rps", "devices", "buckets",
                        "sharded_admitted"):
                assert key in point, (fmt, dev, key)
            assert point["rps"] > 0 and point["dt"] > 0
    # the gridpart sub-mesh axis: each point names its (rows, cols)
    # shape and carries the planner's ring wire-byte numbers + reason
    assert rec["by_grid"], "need >= 1 gridpart factorization point"
    for gname, point in rec["by_grid"].items():
        r, c = (int(v) for v in gname.split("x"))
        assert point["grid_shape"] == [r, c], (gname, point["grid_shape"])
        assert point["rps"] > 0 and point["dt"] > 0, gname
        assert point["sharded_admitted"] >= 1, gname
        assert "gridpart" in point["bucket_body"], (gname,
                                                    point["bucket_body"])
        wire = point["wire_bytes"]
        assert set(wire) == {"fwd", "bwd", "total"}, (gname, wire)
        assert wire["fwd"] >= 0 and wire["bwd"] >= 0, (gname, wire)
        assert wire["total"] == wire["fwd"] + wire["bwd"], (gname, wire)
        assert point["wire_reason"].startswith(str(int(wire["total"]))), \
            (gname, point["wire_reason"])
        assert "ring model" in point["wire_reason"], gname


def test_sharded_serving_quick_grid_smoke(tmp_path):
    """``benchmarks/run.py sharded_serving --quick --grid 2x4`` end to
    end: the sweep emits a grid point carrying grid_shape and the
    wire-byte reason, written to a scratch dir via REPRO_BENCH_OUT so
    the committed artifact is never touched."""
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["REPRO_BENCH_OUT"] = str(tmp_path)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    p = subprocess.run(
        [sys.executable, os.path.join(repo, "benchmarks", "run.py"),
         "sharded_serving", "--quick", "--format", "ell",
         "--grid", "2x4"],
        env=env, capture_output=True, text=True, timeout=900)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    with open(os.path.join(tmp_path, "sharded_serving.json")) as f:
        rec = json.load(f)
    assert rec["quick"] and rec["grid_format"] == "ell"
    assert set(rec["by_grid"]) == {"2x4"}
    point = rec["by_grid"]["2x4"]
    assert point["grid_shape"] == [2, 4]
    assert point["sharded_admitted"] >= 1
    assert "gridpart" in point["bucket_body"]
    assert "ring model" in point["wire_reason"]
    assert point["wire_bytes"]["total"] == (point["wire_bytes"]["fwd"]
                                            + point["wire_bytes"]["bwd"])


def test_rcd_serving_schema():
    rec = _load("rcd_serving.json")
    for key in ("requests", "slots", "tol", "max_iterations", "seed",
                "loss", "solver_family_flag", "points"):
        assert key in rec, key
    assert len(rec["points"]) >= 3, "need >= 3 n/d aspect-ratio points"
    aspects = set()
    for point in rec["points"]:
        for key in ("m", "n", "aspect_m_over_n", "solver_family",
                    "reason", "arms"):
            assert key in point, (point.get("m"), key)
        assert point["solver_family"] in ("rcd_primal", "rcd_dual")
        assert point["reason"], "face-off decision must carry a reason"
        aspects.add(round(point["aspect_m_over_n"], 6))
        for arm in ("auto", "rcd_primal", "rcd_dual", "a2"):
            assert arm in point["arms"], (point["m"], arm)
            r = point["arms"][arm]
            for key in ("rps", "wall_s", "tol", "mean_iterations",
                        "max_iterations_seen", "converged", "family",
                        "buckets"):
                assert key in r, (point["m"], arm, key)
            assert r["rps"] > 0 and r["wall_s"] > 0
            assert 0 <= r["converged"] <= rec["requests"]
            assert r["mean_iterations"] <= rec["max_iterations"]
        # the forced arms really ran the family they claim
        assert point["arms"]["rcd_primal"]["family"] == ["rcd_primal"]
        assert point["arms"]["rcd_dual"]["family"] == ["rcd_dual"]
        assert point["arms"]["a2"]["family"] == ["a2"]
        assert point["arms"]["auto"]["family"] == [point["solver_family"]]
    assert len(aspects) >= 3, "aspect ratios must differ"


def test_open_loop_serving_schema():
    rec = _load("open_loop_serving.json")
    for key in ("requests", "slots", "tol", "seed", "slo_s", "arrival",
                "rates", "loads"):
        assert key in rec, key
    assert len(rec["loads"]) >= 3, "need >= 3 offered-load points"
    for load in rec["loads"]:
        for key in ("offered", "completed", "expired",
                    "rejected_backpressure", "rejected_admission",
                    "elapsed_s", "ticks", "p50_latency_s",
                    "p99_latency_s", "slo_s", "met_slo", "goodput_rps",
                    "offered_rate", "phase_s"):
            assert key in load, (load.get("offered_rate"), key)
        served = (load["completed"] + load["expired"]
                  + load["rejected_backpressure"]
                  + load["rejected_admission"])
        assert served == load["offered"], "requests lost by the loop"
        # percentiles monotone whenever anything completed
        if load["completed"]:
            assert load["p50_latency_s"] <= load["p99_latency_s"]
            assert load["p50_latency_s"] >= 0.0
        assert 0 <= load["met_slo"] <= load["completed"]
        assert load["goodput_rps"] >= 0.0
        # the front-end books engine tick time into admit/compute/harvest
        # (queue_s is wait time, not wall work — it may overlap ticks)
        work = {k: v for k, v in load["phase_s"].items() if k != "queue_s"}
        _check_phase_s(work, load["elapsed_s"],
                       f"open_loop rate={load['offered_rate']}")

"""Documented examples can't rot: every ```python block in README.md must
execute, and the solver module's doctests are collected by the CI docs job
(pytest --doctest-modules src/repro/core/solver.py)."""
import re
from pathlib import Path

import pytest

README = Path(__file__).resolve().parent.parent / "README.md"


def _python_blocks():
    text = README.read_text()
    return re.findall(r"```python\n(.*?)```", text, flags=re.S)


def test_readme_has_python_snippets():
    assert len(_python_blocks()) >= 2, "README lost its quickstart snippets"


@pytest.mark.parametrize("idx", range(len(_python_blocks())))
def test_readme_snippet_runs(idx):
    """Each fenced python block is self-contained and executes cleanly
    (asserts inside the snippets check the numerics)."""
    code = _python_blocks()[idx]
    exec(compile(code, f"README.md:python[{idx}]", "exec"), {})


def test_readme_mentions_tier1_command():
    text = README.read_text()
    assert "python -m pytest -x -q" in text
    assert "pip install -e ." in text


def test_serving_module_doctests():
    """The bucket-lifecycle doctests (admit -> place -> advance ->
    freeze) in the serving engine and the distributed drivers execute —
    the CI docs job also collects them via --doctest-modules over
    serve/ and core/distributed.py."""
    import doctest

    import repro.core.distributed
    import repro.serve.solver_engine

    for mod in (repro.serve.solver_engine, repro.core.distributed):
        res = doctest.testmod(mod, verbose=False)
        assert res.attempted > 0, f"{mod.__name__} lost its doctests"
        assert res.failed == 0, (mod.__name__, res)

"""Per-arch smoke tests (reduced configs): one train step, prefill, decode;
shape checks, finiteness, decode<->forward consistency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced
from repro.configs.base import ShapeSpec
from repro.distributed import null_shardings
from repro.models import build_model
from repro.models.params import count_params
from repro.train import OptConfig, make_train_step
from repro.train import optimizer as opt_mod


def _batch(cfg, key, B=2, S=16):
    tok = jax.random.randint(
        key, (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S),
        0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, key):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(key)
    shape = ShapeSpec("s", "train", 16, 2)
    step, _, _ = make_train_step(model, shape, null_shardings(),
                                 OptConfig(lr=1e-3), donate=False)
    opt = opt_mod.init(params, OptConfig())
    batch = _batch(cfg, key)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        max, jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch, key):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    lg, cache = model.prefill(params, batch["tokens"], extras=extras or None)
    want = (B, 1, cfg.num_codebooks, cfg.vocab_size) if cfg.num_codebooks \
        else (B, 1, cfg.vocab_size)
    assert lg.shape == want
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))

    dc = model.init_cache(B, S + 8, dtype=jnp.float32)
    lg2, dc2 = model.decode(params, dc, batch["tokens"][:, :1],
                            jnp.zeros(B, jnp.int32))
    assert lg2.shape == want
    assert np.all(np.isfinite(np.asarray(lg2, np.float32)))


@pytest.mark.parametrize("arch", ["qwen3-4b", "falcon-mamba-7b", "zamba2-7b",
                                  "olmoe-1b-7b", "deepseek-v3-671b"])
def test_decode_matches_forward(arch, key):
    """Feeding tokens one-by-one through decode must reproduce the full
    forward's final logits (cache correctness)."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(key)
    B, S = 1, 8
    batch = _batch(cfg, key, B, S)
    tok = batch["tokens"]
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    lg_full, _ = model.prefill(params, tok, extras=extras or None)

    cache = model.init_cache(B, S + 2, dtype=jnp.float32)
    for t in range(S):
        lg_step, cache = model.decode(params, cache, tok[:, t:t + 1],
                                      jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(lg_step, np.float32).reshape(-1),
        np.asarray(lg_full, np.float32).reshape(-1), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_counts(arch):
    """Full (non-reduced) configs build a param TREE whose count is in the
    right ballpark for the named model (no allocation — PSpec math only)."""
    import repro.models.transformer as tfm
    cfg = get_config(arch)
    n = count_params(tfm.param_tree(cfg))
    expected = {
        "minitron-8b": 8e9, "nemotron-4-340b": 340e9, "qwen1.5-110b": 110e9,
        "qwen3-4b": 4e9, "llama-3.2-vision-11b": 10e9, "zamba2-7b": 7e9,
        "deepseek-v3-671b": 671e9, "olmoe-1b-7b": 7e9,
        "falcon-mamba-7b": 7e9, "musicgen-medium": 1.5e9,
    }[arch]
    assert 0.55 * expected < n < 1.6 * expected, (arch, n, expected)


def test_musicgen_multihead_loss(key):
    cfg = reduced(get_config("musicgen-medium"))
    model = build_model(cfg)
    params = model.init(key)
    loss = model.loss(params, _batch(cfg, key))
    assert np.isfinite(float(loss))


def test_vlm_image_embeds_affect_output(key):
    cfg = reduced(get_config("llama-3.2-vision-11b"))
    model = build_model(cfg)
    params = model.init(key)
    # cross-attn gates init at 0 (llama-3.2 recipe) -> open them for the test
    params["cross"]["xattn"]["gate"] = jnp.ones_like(
        params["cross"]["xattn"]["gate"])
    batch = _batch(cfg, key)
    l1 = model.loss(params, batch)
    batch2 = dict(batch, image_embeds=batch["image_embeds"] * 100.0)
    l2 = model.loss(params, batch2)
    assert float(l1) != float(l2)

"""Multi-device tests run in SUBPROCESSES (the main pytest process must keep
1 device: jax locks device count at first init; only dryrun.py gets 512).

Covers: the 5 distributed solver strategies vs the dense reference on 8
devices, A1==A2 distributed, consensus training convergence, compressed/
bucketed collectives, elastic checkpoint restore 8 -> 4 devices, gridpart
mesh-factorization equivalence (property-based where hypothesis is
installed; REPRO_TEST_GRID=RxC pins the factorization for CI matrix legs),
and the planner's wire-byte model vs the HLO collective counter.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:                      # CI pins hypothesis; local runs skip
    HAVE_HYPOTHESIS = False

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


STRATEGY_BODY = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.sparse import make_lasso, coo_to_dense
from repro.core.solver import dense_ops, solve
from repro.core.prox import get_prox
from repro.core.distributed import solve_distributed
from repro.configs.paper_problems import small_config

cfg = small_config()
coo, b, xt = make_lasso(cfg, seed=3)
d = coo_to_dense(coo)
lg = float((d**2).sum())
prox = get_prox("l1", reg=cfg.reg)
ref, _ = solve(dense_ops(jnp.asarray(d)), prox, b, lg, 100.0, iterations=60)
devs = jax.devices()
mesh1 = Mesh(np.array(devs).reshape(8), ("p",))
mesh2 = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
for strategy, mesh in [("replicated", mesh1), ("rowpart", mesh1),
                       ("colpart", mesh1), ("dualpart", mesh1),
                       ("block2d", mesh2)]:
    for alg in ("a1", "a2"):
        xbar, _ = solve_distributed(coo, b, prox, mesh, strategy,
                                    gamma0=100.0, iterations=60,
                                    algorithm=alg)
        err = float(jnp.max(jnp.abs(xbar - ref.xbar)))
        assert err < 5e-4, (strategy, alg, err)
        print(strategy, alg, "ok", err)
print("PASS")
"""


def test_distributed_strategies_8dev():
    out = run_sub(STRATEGY_BODY)
    assert "PASS" in out


SOLVE_TOL_CLAMP_BODY = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.sparse import make_lasso
from repro.core.prox import get_prox
from repro.core.distributed import build_problem, make_solve_tol_fn, _pad_to
from repro.configs.paper_problems import small_config

cfg = small_config()
coo, b, _ = make_lasso(cfg, seed=3)
prox = get_prox("l1", reg=cfg.reg)
mesh = Mesh(np.array(jax.devices()).reshape(8), ("p",))
problem = build_problem(coo, mesh, "dualpart")
bp = _pad_to(b, problem.m_pad)
# max_iterations OFF the check_every grid: the clamped inner block must
# stop at exactly the budget (regression: used to overrun by up to
# check_every - 1 steps)
for maxit, ce in ((10, 8), (21, 8), (5, 16)):
    fn = make_solve_tol_fn(problem, prox, 1000.0, tol=1e-12,
                           max_iterations=maxit, check_every=ce)
    state = jax.block_until_ready(fn(problem.operands, bp))
    assert int(state.k) == maxit, (maxit, ce, int(state.k))
print("PASS clamp")
"""


def test_solve_tol_clamp_shard_map_8dev():
    """The shard_map solve_tol variant never overruns max_iterations."""
    out = run_sub(SOLVE_TOL_CLAMP_BODY)
    assert "PASS" in out


ENGINE_MIX_BODY = """
import json
import numpy as np, jax
from repro.launch.solver_serve import make_problems
from repro.serve import SolverEngine, ShardedBucketKey

# ragged mix + 2 oversized requests (nnz = 512*8 > shard_above) -- on 8
# devices they planner-route to a mesh-wide sharded bucket, on 1 device
# to a streamed single-device bucket; iterates must agree either way
probs = make_problems(10, seed=7, big_every=5, big_shape=(512, 64),
                      shapes=[(96, 24), (64, 16)])
reqs = [p.to_request(uid=i, tol=3e-2, max_iterations=4000)
        for i, p in enumerate(probs)]
eng = SolverEngine(slots=2, check_every=16, shard_above=2048)
keys = [eng.submit(r) for r in reqs]
if jax.device_count() > 1:
    assert any(isinstance(k, ShardedBucketKey) for k in keys), keys
done = eng.run()
assert len(done) == len(reqs)
out = {r.uid: {"k": r.iterations, "x": np.asarray(r.x).tolist()}
       for r in done}
print("RESULT " + json.dumps(out))
"""


def test_sharded_engine_matches_single_device_engine():
    """The same ragged request mix (including sharded-routed oversized
    problems) served through a 1-device and an 8-fake-device engine must
    report identical per-request iteration counts with iterates within
    1e-5."""
    import json

    outs = {}
    for devices in (1, 8):
        out = run_sub(ENGINE_MIX_BODY, devices=devices)
        line = next(l for l in out.splitlines() if l.startswith("RESULT "))
        outs[devices] = json.loads(line[len("RESULT "):])
    assert outs[1].keys() == outs[8].keys()
    for uid in outs[1]:
        assert outs[1][uid]["k"] == outs[8][uid]["k"], uid
        np.testing.assert_allclose(outs[1][uid]["x"], outs[8][uid]["x"],
                                   atol=1e-5, err_msg=f"uid {uid}")


SHARDED_BODY_MIX = """
import json
import numpy as np, jax
from repro.launch.solver_serve import make_problems
from repro.serve import ShardedBucketKey, SolverEngine

fmt, strategy, backend = %CFG%
# ragged mix + oversized requests (nnz = 512*8 > shard_above): on 8
# devices they route to a mesh-wide sharded bucket whose BODY is the
# requested (fmt, strategy, backend) cell of DESIGN.md section 5's table
probs = make_problems(8, seed=7, big_every=4, big_shape=(512, 64),
                      shapes=[(96, 24), (64, 16)])
reqs = [p.to_request(uid=i, tol=3e-2, max_iterations=4000)
        for i, p in enumerate(probs)]
eng = SolverEngine(slots=2, check_every=16, shard_above=2048, fmt=fmt,
                   backend=backend, sharded_strategy=strategy)
keys = [eng.submit(r) for r in reqs]
if jax.device_count() > 1:
    sk = [k for k in keys if isinstance(k, ShardedBucketKey)]
    assert sk and all(k.fmt == fmt for k in sk), keys
    if strategy is not None:
        assert all(k.strategy == strategy for k in sk), sk
done = eng.run()
assert len(done) == len(reqs)
out = {r.uid: {"k": r.iterations, "x": np.asarray(r.x).tolist()}
       for r in done}
print("RESULT " + json.dumps(out))
"""


def _run_body_mix(devices, fmt, strategy, backend):
    import json

    body = SHARDED_BODY_MIX.replace("%CFG%",
                                    repr((fmt, strategy, backend)))
    out = run_sub(body, devices=devices)
    line = next(l for l in out.splitlines() if l.startswith("RESULT "))
    return json.loads(line[len("RESULT "):])


def test_sharded_bucket_bodies_match_single_device_8dev():
    """Every mesh-wide bucket body — BCSR/Pallas(interpret) rowpart and
    dualpart, ELL dualpart — serves the same ragged mix as a 1-device
    engine with identical per-request iteration counts and iterates
    within 1e-5 (the MXU path and the mesh composing, ISSUE 5's
    acceptance bar)."""
    ref = _run_body_mix(1, "ell", None, "jnp")
    for fmt, strategy, backend in [("bcsr", "rowpart", "pallas"),
                                   ("bcsr", "dualpart", "pallas"),
                                   ("ell", "dualpart", "jnp")]:
        got = _run_body_mix(8, fmt, strategy, backend)
        assert ref.keys() == got.keys()
        for uid in ref:
            assert ref[uid]["k"] == got[uid]["k"], (fmt, strategy, uid)
            np.testing.assert_allclose(
                ref[uid]["x"], got[uid]["x"], atol=1e-5,
                err_msg=f"{fmt}/{strategy} uid {uid}")


SHARDED_BYTE_CLAMP_BODY = """
import numpy as np, jax
from repro.launch.solver_serve import make_problems
from repro.serve import ShardedBucketKey, SolverEngine

probs = make_problems(4, seed=3, big_every=1, big_shape=(512, 64),
                      shapes=[(96, 24)])
reqs = [p.to_request(uid=i, tol=3e-2, max_iterations=4000)
        for i, p in enumerate(probs)]
free = SolverEngine(slots=4, check_every=16, shard_above=2048)
key = free.submit(reqs[0])
assert isinstance(key, ShardedBucketKey), key
per_slot = free.bucket_slot_bytes(key)
# budget holds exactly ONE slot of the sharded bucket per shard device:
# creation must clamp the slot width to 1 (not depth=4) and the queue
# drains over extra admission generations
eng = SolverEngine(slots=4, check_every=16, shard_above=2048,
                   device_budget=per_slot)
for r in reqs:
    eng.submit(r)
done = eng.run()
assert len(done) == 4 and all(r.feasibility < r.tol for r in done)
bkt = next(b for k, b in eng.buckets.items()
           if isinstance(k, ShardedBucketKey))
assert bkt.slots == 1, bkt.slots
print("PASS sharded byte clamp")
"""


def test_sharded_bucket_byte_budget_clamps_slots_8dev():
    """Mesh-wide bucket creation admits against the byte budget too: a
    device_budget of one sharded slot clamps the bucket to 1 slot even
    with a 4-deep queue, and the stream still drains correctly."""
    out = run_sub(SHARDED_BYTE_CLAMP_BODY)
    assert "PASS" in out


CONSENSUS_BODY = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed import shard_map
from repro.core.consensus import (ConsensusConfig, consensus_init,
                                  consensus_step, consensus_gap)
rng = np.random.default_rng(0)
Xs = rng.standard_normal((4, 64, 8)).astype(np.float32)
w_true = rng.standard_normal(8).astype(np.float32)
ys = Xs @ w_true + 0.01*rng.standard_normal((4, 64)).astype(np.float32)
def loss_fn(params, batch):
    X, y = batch
    r = X @ params["w"] - y
    return 0.5*jnp.mean(r*r)
mesh = Mesh(np.array(jax.devices())[:4].reshape(4), ("data",))
cfg = ConsensusConfig(gamma0=1.0, inner_steps=4, inner_lr=0.1)
def run(X, y):
    params = {"w": jnp.zeros(8)}
    state, lg = consensus_init(loss_fn, params, (X[0], y[0]), cfg, 4)
    def body(s, _):
        s = consensus_step(loss_fn, s, (X[0], y[0]), cfg, lg)
        return s, consensus_gap(s)
    state, gaps = jax.lax.scan(body, state, jnp.arange(150))
    return state.z_bar["w"], gaps
f = jax.jit(shard_map(run, mesh=mesh, in_specs=(P("data"), P("data")),
                          out_specs=(P(), P())))
w, gaps = f(jnp.asarray(Xs), jnp.asarray(ys))
assert float(gaps[-1]) < 2e-6, float(gaps[-1])   # fp32-on-CPU margin
assert float(jnp.linalg.norm(w - w_true)) < 0.1
print("PASS consensus gap", float(gaps[-1]))
"""


def test_consensus_training_4dev():
    out = run_sub(CONSENSUS_BODY, devices=4)
    assert "PASS" in out


COLLECTIVES_BODY = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed import shard_map
from repro.distributed.collectives import (bucketed_allreduce,
                                           psum_compressed, ring_allreduce)
mesh = Mesh(np.array(jax.devices()).reshape(8), ("p",))
x = np.random.default_rng(0).standard_normal((8, 1000)).astype(np.float32)

def f(xs):
    return ring_allreduce(xs, "p")
out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("p", None),
                            out_specs=P("p", None)))(jnp.asarray(x))
# each shard's output row must equal the global sum (replicated result)
out = np.asarray(out)
np.testing.assert_allclose(out, np.tile(x.sum(0), (8, 1)), rtol=1e-5)

def g(xs):
    return psum_compressed(xs, "p")
outc = jax.jit(shard_map(g, mesh=mesh, in_specs=P("p", None),
                             out_specs=P("p", None)))(jnp.asarray(x))
outc = np.asarray(outc)
ref = np.tile(x.sum(0), (8, 1))
rel = np.abs(outc - ref).max() / np.abs(ref).max()
assert rel < 0.02, rel   # int8 block quantization error bound

tree = {"a": jnp.asarray(x), "b": jnp.asarray(x[0])}
def h(t):
    return bucketed_allreduce(t, "p", bucket_bytes=1024)
# check_vma=False: all-gathered reductions are replicated in value but the
# vma tracker cannot downcast varying->invariant (see collectives.py note)
outt = jax.jit(shard_map(h, mesh=mesh,
                             in_specs=({"a": P("p", None), "b": P(None)},),
                             out_specs={"a": P("p", None), "b": P(None)},
                             check_vma=False))(tree)
np.testing.assert_allclose(np.asarray(outt["b"]), x[0] * 8, rtol=1e-5)
print("PASS collectives")
"""


def test_collectives_8dev():
    out = run_sub(COLLECTIVES_BODY)
    assert "PASS" in out


ELASTIC_BODY = """
import numpy as np, jax, jax.numpy as jnp, tempfile, os
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import save, restore
d = tempfile.mkdtemp()
devs = jax.devices()
mesh8 = Mesh(np.array(devs).reshape(8), ("model",))
mesh4 = Mesh(np.array(devs[:4]).reshape(4), ("model",))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
sharded8 = jax.device_put(x, NamedSharding(mesh8, P("model", None)))
save({"w": sharded8}, d, step=1)
# restore onto the SMALLER mesh (elastic shrink 8 -> 4)
out = restore({"w": x}, d, shardings={"w": NamedSharding(mesh4, P("model", None))})
np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(x))
assert len(out["w"].sharding.device_set) == 4
print("PASS elastic")
"""


def test_elastic_restore_8_to_4():
    out = run_sub(ELASTIC_BODY)
    assert "PASS" in out


TRAIN_SHARDED_BODY = """
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec
from repro.models import build_model
from repro.distributed import make_shardings
from repro.launch.mesh import make_mesh
from repro.train import make_train_step, OptConfig
from repro.train import optimizer as opt_mod
from repro.data import SyntheticTokens

mesh = make_mesh((2, 2), ("data", "model"))
sh = make_shardings(mesh)
cfg = reduced(get_config("olmoe-1b-7b"))
shape = ShapeSpec("t", "train", 16, 4)
model = build_model(cfg)
step, in_sh, _ = make_train_step(model, shape, sh, OptConfig(lr=1e-3),
                                 donate=False)
params = jax.device_put(model.init(jax.random.PRNGKey(0)), in_sh[0])
opt = jax.device_put(opt_mod.init(params, OptConfig()), in_sh[1])
data = SyntheticTokens(cfg, shape, seed=0, shardings=in_sh[2])
losses = []
for _ in range(8):
    params, opt, m = step(params, opt, next(data))
    losses.append(float(m["loss"]))
data.close()
assert losses[-1] < losses[0], losses
print("PASS sharded train", losses[0], "->", losses[-1])
"""


def test_sharded_train_2x2():
    out = run_sub(TRAIN_SHARDED_BODY, devices=4)
    assert "PASS" in out


GRID_EQUIV_BODY = """
import numpy as np, jax
from repro.launch.solver_serve import make_problems
from repro.serve import ShardedBucketKey, SolverEngine

num, seed, big_every, shapes = %MIX%
arms = %ARMS%
probs = make_problems(num, seed=seed, big_every=big_every,
                      big_shape=(512, 64), shapes=shapes)


def serve(**kw):
    eng = SolverEngine(slots=2, check_every=16, shard_above=2048, **kw)
    keys = [eng.submit(p.to_request(uid=i, tol=3e-2, max_iterations=4000))
            for i, p in enumerate(probs)]
    done = eng.run()
    assert len(done) == num, (kw, len(done))
    sk = [k for k in keys if isinstance(k, ShardedBucketKey)]
    return {r.uid: (r.iterations, np.asarray(r.x)) for r in done}, sk


# devices=1 inside the same 8-fake-device process: identical math, no mesh
ref, _ = serve(devices=1)
for arm in arms:
    kw = dict(devices=8)
    if isinstance(arm, (list, tuple)):
        kw["grid"] = tuple(arm)
    else:
        kw["sharded_strategy"] = arm
    got, sk = serve(**kw)
    assert sk, arm                     # the big requests really went mesh-wide
    if "grid" in kw:
        assert all(k.strategy == "gridpart" and k.grid == tuple(arm)
                   for k in sk), (arm, sk)
    for uid in ref:
        k0, x0 = ref[uid]
        k1, x1 = got[uid]
        assert k0 == k1, (arm, uid, k0, k1)
        err = float(np.abs(x0 - x1).max())
        assert err <= 1e-5, (arm, uid, err)
    print("OK", arm)
print("PASS grid equivalence")
"""


def _grid_arms():
    """All (rows, cols) factorizations of 8, or just the one the CI matrix
    pinned via REPRO_TEST_GRID=RxC."""
    pin = os.environ.get("REPRO_TEST_GRID", "").strip()
    if pin:
        r, _, c = pin.lower().partition("x")
        return [(int(r), int(c))]
    return [(1, 8), (2, 4), (4, 2), (8, 1)]


def _check_grid_mix(arms, seed=7, big_every=4,
                    shapes=((96, 24), (64, 16)), num=8):
    body = (GRID_EQUIV_BODY
            .replace("%MIX%", repr((num, seed, big_every,
                                    [tuple(s) for s in shapes])))
            .replace("%ARMS%", repr(list(arms))))
    out = run_sub(body, timeout=900)
    assert "PASS" in out


def test_gridpart_factorizations_match_single_device_8dev():
    """Every (rows, cols) factorization of the 8-device mesh serves the
    same ragged mix (oversized + small requests) with iteration counts
    identical to — and iterates within 1e-5 of — a 1-device engine."""
    _check_grid_mix(_grid_arms())


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(arm=hyp_st.sampled_from(_grid_arms() + ["rowpart", "dualpart"]),
           seed=hyp_st.integers(min_value=0, max_value=3),
           big_every=hyp_st.sampled_from([3, 4]),
           shapes=hyp_st.sampled_from([((96, 24), (64, 16)),
                                       ((64, 16), (48, 48)),
                                       ((48, 48), (96, 24), (64, 16))]))
    def test_sharded_strategy_property_matches_single_device_8dev(
            arm, seed, big_every, shapes):
        """Property: over mesh factorizations AND the 1-D strategies,
        any ragged mix solves identically to the 1-device engine."""
        _check_grid_mix([arm], seed=seed, big_every=big_every,
                        shapes=shapes)
else:
    @pytest.mark.skip(reason="hypothesis not installed (CI pins it)")
    def test_sharded_strategy_property_matches_single_device_8dev():
        pass


WIRE_BYTES_BODY = """
import os, re
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

os.environ["REPRO_SHARD_ABOVE_NNZ"] = "500"

from repro.distributed.sharding import shard_map
from repro.operators.registry import make_operator
from repro.plan import sharded_wire_bytes
from repro.roofline.analysis import collective_stats
from repro.sparse.formats import COO, StackedELL, coo_to_ell
from repro.sparse.partition import (block_partitioned_ell,
                                    blockgrid_ell_width,
                                    blockgrid_transpose_ell,
                                    blockgrid_transpose_ell_width)

S, m_pad, n_pad, ndev = 2, 128, 128, 8
rng = np.random.default_rng(0)
coos = []
for s in range(S):
    d = (rng.random((m_pad, n_pad)) * (rng.random((m_pad, n_pad)) < 0.1))
    r, c = np.nonzero(d)
    coos.append(COO(rows=r, cols=c, vals=d[r, c].astype(np.float32),
                    m=m_pad, n=n_pad))
x = rng.random((S, n_pad)).astype(np.float32)
y = rng.random((S, m_pad)).astype(np.float32)


def measured(fn, mesh, in_specs, out_specs, args):
    hlo = (jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs))
           .lower(*args).compile().as_text())
    return collective_stats(hlo, default_group=ndev).by_op


# ---- dualpart: the model IS the lowered HLO's collectives ----
mesh = Mesh(np.array(jax.devices()[:ndev]), ("p",))
w = max(int(np.bincount(c0.rows, minlength=m_pad).max()) for c0 in coos)
av = np.stack([np.asarray(coo_to_ell(c0, k=w).vals) for c0 in coos])
ac = np.stack([np.asarray(coo_to_ell(c0, k=w).cols) for c0 in coos])


def fwd_dual(av, ac, x_loc):
    a = StackedELL(vals=av, cols=ac, n=n_pad)
    return make_operator("stacked_ell", "dualpart", a, "p").matvec(x_loc)


def bwd_dual(av, ac, y_loc):
    a = StackedELL(vals=av, cols=ac, n=n_pad)
    return make_operator("stacked_ell", "dualpart", a, "p").rmatvec(y_loc)


ell3 = P(None, "p", None)
model = sharded_wire_bytes("dualpart", S, m_pad, n_pad, ndev)
got_f = measured(fwd_dual, mesh, (ell3, ell3, P(None, "p")),
                 P(None, "p"), (av, ac, x))
got_b = measured(bwd_dual, mesh, (ell3, ell3, P(None, "p")),
                 P(None, "p"), (av, ac, y))
assert round(got_f.get("all-gather", 0)) == model["fwd"], (got_f, model)
assert round(got_b.get("reduce-scatter", 0)) == model["bwd"], (got_b, model)
# ... and NOTHING else moves: the counter sees only the modeled collectives
assert round(sum(got_f.values())) == model["fwd"], got_f
assert round(sum(got_b.values())) == model["bwd"], got_b

# the retired backward all_gathered the full residual (m) AND the full
# gradient (n) every iteration; shard-resident x must at least halve that
old_bwd = (ndev - 1) * S * (m_pad + n_pad) * 4 // ndev
assert sum(got_b.values()) <= 0.55 * old_bwd, (got_b, old_bwd)
print("dualpart fwd/bwd wire", model["fwd"], model["bwd"],
      "old bwd", old_bwd)

# ---- gridpart: per-axis terms, every factorization ----
for (R, C) in [(1, 8), (2, 4), (4, 2), (8, 1)]:
    mesh2 = Mesh(np.array(jax.devices()[:ndev]).reshape(R, C), ("r", "c"))
    wg = max(blockgrid_ell_width(c0, R, C) for c0 in coos)
    wt = max(blockgrid_transpose_ell_width(c0, R, C) for c0 in coos)
    gav = np.stack([np.asarray(block_partitioned_ell(c0, R, C, k=wg)[0])
                    for c0 in coos], axis=2)
    gac = np.stack([np.asarray(block_partitioned_ell(c0, R, C, k=wg)[1])
                    for c0 in coos], axis=2)
    tav = np.stack([np.asarray(blockgrid_transpose_ell(c0, R, C, k=wt)[0])
                    for c0 in coos], axis=2)
    tac = np.stack([np.asarray(blockgrid_transpose_ell(c0, R, C, k=wt)[1])
                    for c0 in coos], axis=2)

    def fwd_grid(gav, gac, tav, tac, x_loc):
        a = StackedELL(vals=gav[0, 0], cols=gac[0, 0], n=n_pad // C)
        at = StackedELL(vals=tav[0, 0], cols=tac[0, 0], n=gav.shape[3])
        op = make_operator("stacked_ell", "gridpart", a, ("r", "c"), at)
        return op.matvec(x_loc)

    def bwd_grid(gav, gac, tav, tac, y_loc):
        a = StackedELL(vals=gav[0, 0], cols=gac[0, 0], n=n_pad // C)
        at = StackedELL(vals=tav[0, 0], cols=tac[0, 0], n=gav.shape[3])
        op = make_operator("stacked_ell", "gridpart", a, ("r", "c"), at)
        return op.rmatvec(y_loc)

    g5 = P("r", "c", None, None, None)
    model = sharded_wire_bytes("gridpart", S, m_pad, n_pad, ndev,
                               grid=(R, C))
    got_f = measured(fwd_grid, mesh2,
                     (g5, g5, g5, g5, P(None, ("c", "r"))),
                     P(None, "r"), (gav, gac, tav, tac, x))
    got_b = measured(bwd_grid, mesh2,
                     (g5, g5, g5, g5, P(None, "r")),
                     P(None, ("c", "r")), (gav, gac, tav, tac, y))
    assert round(sum(got_f.values())) == model["fwd"], (R, C, got_f, model)
    assert round(sum(got_b.values())) == model["bwd"], (R, C, got_b, model)
    print(f"gridpart {R}x{C} wire ok", model)

# ---- the recorded plan reasons carry the same numbers ----
from repro.api import Problem
from repro.plan import (decide_bucket_body, grid_shapes, sharding_ndev)
from repro.serve.solver_engine import (sharded_bucket_dims,
                                       sharded_bucket_widths,
                                       sharded_grid_widths)

coo = coos[0]
b = rng.random(m_pad).astype(np.float32)
pl = Problem(coo, b, prox="l1", reg=0.1).plan(tol=1e-2)
mm = re.match(r"(\\d+) collective wire bytes/device per iteration per "
              r"slot \\(fwd (\\d+) \\+ bwd (\\d+), ring model",
              pl.reasons["wire_bytes"])
assert mm, pl.reasons["wire_bytes"]
total, fwd, bwd = map(int, mm.groups())
mb = re.match(r"stacked_ell/(\\w+)( (\\d+)x(\\d+))? mesh-wide",
              pl.reasons["bucket_body"])
assert mb, pl.reasons["bucket_body"]
strategy = mb.group(1)
grid = (int(mb.group(3)), int(mb.group(4))) if mb.group(2) else None
ndev_pl = sharding_ndev(coo.nnz, jax.device_count(), 500)
mp, npd = sharded_bucket_dims(coo.m, coo.n, ndev_pl)
mdl = sharded_wire_bytes(strategy, 1, mp, npd, ndev_pl, grid=grid)
assert (total, fwd, bwd) == (mdl["total"], mdl["fwd"], mdl["bwd"]), (
    (total, fwd, bwd), mdl)
w_, wtr, wtd = sharded_bucket_widths(coo, mp, npd, ndev_pl, "ell")
gw = {g: sharded_grid_widths(coo, mp, npd, g, "ell")
      for g in grid_shapes(ndev_pl)}
s2, g2, per_dev2, _ = decide_bucket_body("ell", mp, npd, w_, wtr, wtd,
                                         ndev_pl, grid_widths=gw)
assert (s2, g2) == (strategy, grid), ((s2, g2), (strategy, grid))
assert int(pl.reasons["operand_bytes"].split()[0]) == per_dev2
print("PASS wire bytes")
"""


def test_wire_byte_model_matches_hlo_counter_8dev():
    """The planner's ring wire-byte model equals the collective bytes
    ``roofline.collective_stats`` counts in the lowered HLO — for the
    shard-resident dualpart pair and every gridpart factorization — the
    shard-resident backward moves <= 0.55x the retired two-all_gather
    path, and the plan's recorded wire/operand-byte reasons carry exactly
    the model's numbers."""
    out = run_sub(WIRE_BYTES_BODY)
    assert "PASS" in out

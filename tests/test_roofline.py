"""Roofline machinery: HLO collective parser, scan-correction, hw model."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.roofline import analysis, hw
from repro.roofline.analysis import collective_stats, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert shape_bytes("pred[7]") == 7


def test_collective_parse_synthetic():
    hlo = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[2048]{0} all-gather(%y), replica_groups=[2,8]<=[16] ..., dimensions={0}
  %rs = f32[256]{0} reduce-scatter(%z), replica_groups={{0,1}}, to_apply=%add
  %cp = f32[100]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    st = collective_stats(hlo)
    assert st.count == 4
    np.testing.assert_allclose(st.by_op["all-reduce"],
                               2 * 4096 * 3 / 4)
    np.testing.assert_allclose(st.by_op["all-gather"], 4096 * 7 / 8)
    np.testing.assert_allclose(st.by_op["reduce-scatter"], 1024 * 1)
    np.testing.assert_allclose(st.by_op["collective-permute"], 400)


def test_scan_body_counted_once_and_corrected():
    """The motivating bug: scan flops undercounted; units fix via trip count."""
    def f_scan(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f_scan).lower(x, w).compile()
    flops, _ = analysis.cost_of(c)
    one_iter = 2 * 64 * 128 * 128
    assert flops < 2 * one_iter          # counted once (the bug)
    assert abs(flops * 10 - 10 * one_iter) / (10 * one_iter) < 0.2


def test_two_point_seq_correction():
    """units.measure_units linearization recovers scan-body cost x S."""
    from repro.roofline.units import Unit, _SEQ_OF, measure_units

    D = 64
    S = 32

    def g(x):  # matmul outside scan (linear in S) + elementwise scan body
        def body(c, xt):
            return c * 0.9 + jnp.tanh(xt), None
        y = x @ jnp.ones((D, D), jnp.float32)
        c, _ = jax.lax.scan(body, jnp.zeros((D,), jnp.float32), y)
        return c

    u = Unit("t", g, (jax.ShapeDtypeStruct((S, D), jnp.float32),), None, 1.0,
             seq_scan=True,
             half_args=(jax.ShapeDtypeStruct((S // 2, D), jnp.float32),))
    _SEQ_OF[id(u)] = S
    [cost] = measure_units([u])
    expected_matmul = 2 * S * D * D
    expected_scan = S * (D * 3)          # ~3 flops/elem/step
    assert cost.flops > expected_matmul + expected_scan * 0.3
    assert cost.flops < expected_matmul * 2 + expected_scan * 10


def test_terms_and_dominant():
    t = analysis.terms(flops=197e12, bytes_hbm=819e9 * 2, wire_bytes=0.0,
                       model_flops=197e12 / 2)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.dominant == "memory"
    assert t.roofline_fraction == pytest.approx(0.25)


def test_analytic_bytes_sane():
    from repro.configs import SHAPES, get_config
    from repro.distributed.sharding import Shardings
    from repro.roofline.units import analytic_bytes

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
            size = 256

    sh = Shardings(mesh=FakeMesh(), rules={"tp": "model", "fsdp": "data",
                                           "dp": "data", "seq": "model",
                                           "ep": "model"})
    cfg = get_config("minitron-8b")
    b_train = analytic_bytes(cfg, SHAPES["train_4k"], sh)
    b_dec = analytic_bytes(cfg, SHAPES["decode_32k"], sh)
    # train must at least cover optimizer io; decode at least the cache read
    assert b_train > 8e9 * 12 / 256
    cache = 32 * 128 * 32768 * 8 * 128 * 2 * 2 / 256
    assert b_dec > cache * 0.9

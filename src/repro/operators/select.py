"""Heuristic format selection from matrix statistics.

Reuses the roofline machinery (repro.roofline.hw peaks + the 3-term time
model of repro.roofline.analysis) to estimate the per-apply time of each
candidate format and picks the cheapest:

  ELL        — VPU gather path; bytes grow with the padded width
               k_max = max row nnz, so row imbalance inflates it.
  BandedELL  — same VPU path for A^T y, but y is staged per band; required
               (not just preferred) once y no longer fits VMEM.
  BCSR       — dense (bm, bn) tiles contracted on the MXU; pays for
               zero-fill inside tiles (occupancy), wins when nonzeros
               cluster so tiles are dense enough that the MXU's ~50x flop
               advantage over the VPU covers the fill.

The estimates are arithmetic-intensity arguments, not measurements — the
same modeling the dry-run roofline uses for collectives — and are recorded
in the returned plan so benchmarks can compare prediction vs measurement.
Because the analytic model can be orders of magnitude off for kernels the
machine actually runs (interpret-mode Pallas on CPU most of all), a
MEASURED table from the sweep harness (``benchmarks/autotune.py`` ->
``experiments/bench/autotune.json``) is consulted first when provided:
pass ``table=`` explicitly or point env ``REPRO_AUTOTUNE_TABLE`` at the
json; with neither, behavior is purely analytic as before.  A measured
cell's per-apply seconds are scaled linearly in stored work (padded
entries) to the matrix at hand — nearest-cell-in-work interpolation, the
dace ``FlopCount`` roofline's measured-table fix rather than a better
formula.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os

import numpy as np

from repro.roofline import hw

#: env var naming an autotune.json whose measured cells override the
#: analytic roofline in ``select_format`` / ``estimate_formats``.
AUTOTUNE_TABLE_ENV = "REPRO_AUTOTUNE_TABLE"

# VPU fp32 peak (v5e: 4 MXU-adjacent vector units, 8x128 lanes, ~940 MHz,
# 2 flops/lane/cycle) — the gather-path ceiling. The MXU peak is hw's bf16
# number; fp32 tiles run at half.
PEAK_FLOPS_VPU = 3.9e12
PEAK_FLOPS_MXU_F32 = hw.PEAK_FLOPS_BF16 / 2.0
VMEM_BYTES = 16 * 2 ** 20          # v5e per-core VMEM
_IDX = 4                           # int32 index bytes
_VAL = 4                           # fp32 value bytes


@dataclasses.dataclass(frozen=True)
class FormatPlan:
    format: str                    # "ell" | "banded_ell" | "bcsr"
    backend: str
    params: dict                   # converter kwargs (band_size, bm, bn, ...)
    estimates: dict                # per-candidate modeled seconds + notes


@dataclasses.dataclass(frozen=True)
class MatrixStats:
    """ONE statistics pass over a COO matrix, shared by every consumer.

    The paper computes these with MapReduce counters during the read
    stage; this record is the single-pass analogue.  Computed once at
    ``Problem`` ingest (``Problem.stats``) and handed to the roofline
    format selector (row/col padded widths), the planner's Frobenius
    ``Lg`` estimate (``frob_sq`` — paper init steps 1-2), the serving
    cost model, and the coordinate-descent face-off rule
    (``repro.plan.decide_solver_family`` — n-vs-d plus the nnz moments,
    Csiba & Richtárik).  Before this record each consumer re-ran its own
    bincount/`` vals**2`` pass over the same matrix.
    """

    m: int
    n: int
    nnz: int
    density: float
    row_nnz_mean: float
    row_nnz_max: int
    col_nnz_mean: float
    col_nnz_max: int
    frob_sq: float                 # sum_i ||A_i||^2 = ||A||_F^2

    @classmethod
    def from_coo(cls, coo) -> "MatrixStats":
        rc = np.bincount(np.asarray(coo.rows), minlength=coo.m)
        cc = np.bincount(np.asarray(coo.cols), minlength=coo.n)
        vals = np.asarray(coo.vals)
        return cls(
            m=int(coo.m), n=int(coo.n), nnz=int(coo.nnz),
            density=float(coo.nnz) / float(max(1, coo.m * coo.n)),
            row_nnz_mean=float(rc.mean()) if rc.size else 0.0,
            row_nnz_max=int(rc.max(initial=0)),
            col_nnz_mean=float(cc.mean()) if cc.size else 0.0,
            col_nnz_max=int(cc.max(initial=0)),
            # float64 avoids catastrophic cancellation on large-nnz sums
            # and is reduced to a python float immediately
            # repro: allow[R4] -- host-side planner stat, not an operand
            frob_sq=float(np.sum(np.square(vals, dtype=np.float64))),
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def matrix_stats(coo) -> dict:
    """Cheap global statistics as a plain dict (legacy shape of
    ``MatrixStats.from_coo`` — kept because operator ``stats`` metadata
    and bench json records store dicts)."""
    return MatrixStats.from_coo(coo).as_dict()


def _roofline_s(flops: float, bytes_hbm: float, peak_flops: float) -> float:
    return max(flops / peak_flops, bytes_hbm / hw.HBM_BW)


def ell_bytes(rows: int, k: int) -> int:
    """Stored bytes of ONE row-ELL orientation: fp32 vals + int32 cols at
    the padded width ``k``.  The shared operand-byte primitive: the
    roofline estimates below and the serving engine's byte-based
    ``device_budget`` admission (repro.plan.bucket_operand_bytes /
    sharded_bucket_bytes) price storage through this same formula."""
    return int(rows) * int(k) * (_VAL + _IDX)


def bcsr_bytes(nbr: int, kb: int, bm: int, bn: int) -> int:
    """Stored bytes of ONE tiled-BCSR orientation: ``nbr * kb`` dense
    fp32 (bm, bn) tiles + one int32 block-column index per tile.  Tile
    zero-fill is real storage (and real HBM traffic), which is why BCSR
    and ELL buckets price very differently per stored nonzero."""
    return int(nbr) * int(kb) * (int(bm) * int(bn) * _VAL + _IDX)


def load_measured_table(path: str | None = None):
    """The ``cells`` list of an autotune table, or None.

    Resolution: explicit ``path`` > env ``REPRO_AUTOTUNE_TABLE`` > None.
    Unreadable / malformed / empty tables resolve to None (the selector
    then falls back to the analytic roofline), so a stale env var can
    never break a solve."""
    if path is None:
        path = os.environ.get(AUTOTUNE_TABLE_ENV)
    if not path:
        return None
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return None
    cells = data.get("cells") if isinstance(data, dict) else data
    return list(cells) if cells else None


def _measured_s(cells, fmt: str, backend: str, params: dict,
                work: float) -> float | None:
    """Measured per-apply seconds for (fmt, backend, params) scaled to
    ``work`` stored entries, from the nearest cell in log-work; None when
    no cell matches."""
    if not cells or work <= 0:
        return None
    best = None
    for cell in cells:
        if cell.get("kind", "spmv") != "spmv":
            continue
        if cell.get("format") != fmt or cell.get("backend") != backend:
            continue
        if fmt == "bcsr" and (cell.get("bm") != params.get("bm")
                              or cell.get("bn") != params.get("bn")):
            continue
        cw, cs = float(cell.get("work", 0)), float(cell.get("measured_s", 0))
        if cw <= 0 or cs <= 0:
            continue
        dist = abs(math.log(work / cw))
        if best is None or dist < best[0]:
            best = (dist, cs * work / cw)
    return None if best is None else best[1]


def _bcsr_block_count(coo, bm: int, bn: int) -> int:
    nbc = max(1, -(-coo.n // bn))
    bi = np.asarray(coo.rows) // bm
    bj = np.asarray(coo.cols) // bn
    return int(np.unique(bi.astype(np.int64) * nbc + bj).size)


def _apply_measured(entry: dict, cells, fmt: str, backend: str,
                    work: float) -> dict:
    """Override an analytic entry's ``s`` with the measured-table estimate
    when one matches; the analytic figure survives as ``analytic_s`` and
    ``source`` records which model priced the entry."""
    meas = _measured_s(cells, fmt, backend, entry["params"], work)
    entry["work"] = work
    if meas is None:
        entry["source"] = "analytic"
    else:
        entry["analytic_s"] = entry["s"]
        entry["s"] = meas
        entry["source"] = "measured"
    return entry


def estimate_formats(coo, bm_bn_candidates=((8, 128), (16, 128), (32, 128),
                                            (8, 256)), table=None,
                     backend: str = "pallas", stats=None) -> dict:
    """Modeled per-apply seconds for each candidate (format, params).

    With ``table`` (an autotune ``cells`` list), matching measured cells
    override the analytic roofline — each entry says which in ``source``.
    ``stats``: a precomputed ``MatrixStats`` (one ingest-time pass shared
    with the planner); recomputed here only when absent.
    """
    st = stats if stats is not None else MatrixStats.from_coo(coo)
    if not isinstance(st, dict):
        st = st.as_dict()
    m, n, nnz = st["m"], st["n"], st["nnz"]
    vec_bytes = (m + n) * _VAL
    out = {}

    # ELL: m * k_max stored entries (vals + idx), 2 flops each, VPU.
    k = max(1, st["row_nnz_max"])
    ell_bytes_ = ell_bytes(m, k) + vec_bytes
    out["ell"] = _apply_measured(dict(
        s=_roofline_s(2.0 * m * k, ell_bytes_, PEAK_FLOPS_VPU),
        bytes=ell_bytes_, pad_ratio=m * k / max(1, nnz),
        params=dict()), table, "ell", backend, float(m) * k)

    # BandedELL (backward pass layout): same stored volume keyed by columns,
    # k_max over columns; viable at any m (y staged per band), mandatory
    # once y exceeds VMEM.
    kc = max(1, st["col_nnz_max"])
    band_bytes = ell_bytes(n, kc) + vec_bytes
    out["banded_ell"] = _apply_measured(dict(
        s=_roofline_s(2.0 * n * kc, band_bytes, PEAK_FLOPS_VPU),
        bytes=band_bytes, pad_ratio=n * kc / max(1, nnz),
        params=dict(band_size=max(8, min(4096, VMEM_BYTES // (8 * _VAL))))),
        table, "banded_ell", backend, float(n) * kc)

    # BCSR: dense tiles on the MXU; zero-fill costs bytes AND flops but at
    # the ~50x higher MXU ceiling.  Tile candidates priced by the measured
    # table compete only with each other: an analytic candidate is
    # optimistic by orders of magnitude next to a measured one, so mixing
    # sources in the min() would always bury the measurements.
    best = None
    for bm, bn in bm_bn_candidates:
        nblocks = _bcsr_block_count(coo, bm, bn)
        tile_entries = nblocks * bm * bn
        bytes_ = bcsr_bytes(nblocks, 1, bm, bn) + vec_bytes
        cand = _apply_measured(dict(
            s=_roofline_s(2.0 * tile_entries, bytes_, PEAK_FLOPS_MXU_F32),
            bytes=bytes_, occupancy=nnz / max(1, tile_entries),
            params=dict(bm=bm, bn=bn)),
            table, "bcsr", backend, float(tile_entries))
        rank = (cand["source"] != "measured", cand["s"])
        if best is None or rank < (best["source"] != "measured", best["s"]):
            best = cand
    out["bcsr"] = best
    return out


def select_format(coo, backend: str = "pallas",
                  y_vmem_budget: int = VMEM_BYTES,
                  table=None, stats=None) -> FormatPlan:
    """Pick the cheapest modeled format; force the banded backward layout
    when y cannot be VMEM-resident (the flat gather is then impossible on
    a real TPU regardless of modeled time).

    ``table``: autotune ``cells`` (see ``load_measured_table``) whose
    measured timings trump the analytic model; None consults env
    ``REPRO_AUTOTUNE_TABLE`` (and stays fully analytic when unset)."""
    if table is None:
        table = load_measured_table()
    est = estimate_formats(coo, table=table, backend=backend, stats=stats)
    y_bytes = coo.m * _VAL
    if y_bytes > y_vmem_budget:
        choice = "banded_ell"
    else:
        choice = min(("ell", "bcsr"), key=lambda f: est[f]["s"])
        # tiny/irregular matrices: an almost-empty tiling wastes MXU work
        if choice == "bcsr" and est["bcsr"]["occupancy"] < 0.02:
            choice = "ell"
    params = dict(est[choice]["params"])
    fmt = "ell" if choice == "banded_ell" else choice
    if choice == "banded_ell":
        # the ELL/pallas bundle already uses the banded layout backward
        params = dict(band_size=params["band_size"])
    return FormatPlan(format=fmt, backend=backend, params=params,
                      estimates=est)

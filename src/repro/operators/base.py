"""The LinearOperator protocol: one interface over formats x backends.

Every execution substrate the solver can run on — jnp reference, Pallas
kernels, shard_map'd distributed strategies — is expressed as a
``LinearOperator``: matvec/rmatvec plus optional fused passes and metadata.
The solver itself consumes the narrower ``SolverOps`` bundle
(repro.core.solver); ``LinearOperator.solver_ops()`` is the ONLY place in
the codebase that constructs one, so every solver is provably built through
this layer (grep for ``SolverOps(``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.core.solver import SolverOps


@dataclasses.dataclass(frozen=True)
class LinearOperator:
    """A (possibly sharded) linear map A with its adjoint.

    matvec:  x -> A x
    rmatvec: y -> A^T y
    fused_dual(yhat, xstar, xbar, b, c0, c1, c2, c3)
        = c0*yhat + A(c1*xstar + c2*xbar) - c3*b    (eq. 15, one A pass)
    prox_update(prox, zhat, gamma, tau, xbar, xc) -> (xstar_new, xbar_new)
        fused prox + heavy-ball averaging (paper step 14 inner block).
    shape:   logical (m, n) of the global matrix (None entries if unknown,
             e.g. matrix-free operators).
    nnz:     stored nonzeros (None if unknown).
    format/backend: the registry key this operator was built under.
    stats:   free-form metadata (padding ratios, tile occupancy, estimated
             arithmetic intensity, ...) — feeds the format selector and the
             benchmark tables.
    """

    matvec: Callable
    rmatvec: Callable
    shape: tuple[Optional[int], Optional[int]] = (None, None)
    format: str = "custom"
    backend: str = "custom"
    nnz: Optional[int] = None
    fused_dual: Optional[Callable] = None
    prox_update: Optional[Callable] = None
    stats: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __call__(self, x):
        return self.matvec(x)

    @property
    def T(self) -> "LinearOperator":
        """The adjoint operator (fused passes do not transpose)."""
        m, n = self.shape
        return dataclasses.replace(
            self, matvec=self.rmatvec, rmatvec=self.matvec, shape=(n, m),
            fused_dual=None, prox_update=None)

    def solver_ops(self) -> SolverOps:
        """Adapt to the solver's operator bundle.

        This is the sole ``SolverOps`` construction site in the repo — all
        backends (jnp / Pallas / distributed strategies) flow through here.
        """
        return SolverOps(matvec=self.matvec, rmatvec=self.rmatvec,
                         fused_dual=self.fused_dual,
                         prox_update=self.prox_update)

"""Strategy-local LinearOperator builders (run INSIDE shard_map).

One builder per distributed strategy of repro.core.distributed, registered
under (format="ell", backend=<strategy>): each receives the DistProblem
metadata plus the device-local operand shards and returns the local
operator whose collective signature realizes that strategy's paper design
(rowpart ~ MR1/MR3, colpart ~ MR2, dualpart ~ Spark dual-RDD,
block2d ~ the 2-D generalization; see DESIGN.md).

These builders are pure closures over jnp + lax collectives, so they are
traceable inside shard_map exactly like the hand-assembled bundles they
replaced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.operators.base import LinearOperator
from repro.operators.registry import make_operator, register


def _scatter_rmatvec(vals, cols, y_loc, n):
    """z = A_loc^T y_loc from a row-ELL block with column indices into [0, n).
    Accumulates in y's dtype (fp32) so bf16-compressed operands stay exact."""
    contrib = vals.astype(y_loc.dtype) * y_loc[:, None]
    return jnp.zeros((n,), y_loc.dtype).at[cols.reshape(-1)].add(
        contrib.reshape(-1))


def _scatter_matvec(vals_t, rows, x_loc, m):
    """y = A_loc x_loc from a col-ELL block (ELL of A^T) with row indices."""
    contrib = vals_t.astype(x_loc.dtype) * x_loc[:, None]
    return jnp.zeros((m,), x_loc.dtype).at[rows.reshape(-1)].add(
        contrib.reshape(-1))


def _gather_matvec(vals, cols, x):
    return jnp.sum(vals * jnp.take(x, cols, axis=0), axis=1)


def _shape(problem):
    return (problem.m, problem.n)


@register("ell", "replicated")
def replicated_operator(problem, operands) -> LinearOperator:
    av, ac = operands["a"]
    atv, atc = operands["at"]
    return LinearOperator(
        matvec=lambda x: _gather_matvec(av, ac, x),
        rmatvec=lambda y: _gather_matvec(atv, atc, y),
        shape=_shape(problem), format="ell", backend="replicated")


@register("ell", "rowpart")
def rowpart_operator(problem, operands) -> LinearOperator:
    av, ac = operands["a"]              # local (mb, k), global cols
    ax = problem.axes[0]
    return LinearOperator(
        matvec=lambda x: _gather_matvec(av, ac, x),
        rmatvec=lambda y: jax.lax.psum(
            _scatter_rmatvec(av, ac, y, problem.n_pad), ax),
        shape=_shape(problem), format="ell", backend="rowpart")


@register("ell", "colpart")
def colpart_operator(problem, operands) -> LinearOperator:
    atv, atc = operands["at"]           # local (nb, kc), global rows
    ax = problem.axes[0]
    return LinearOperator(
        matvec=lambda x: jax.lax.psum(
            _scatter_matvec(atv, atc, x, problem.m_pad), ax),
        rmatvec=lambda y: _gather_matvec(atv, atc, y),
        shape=_shape(problem), format="ell", backend="colpart")


@register("ell", "dualpart")
def dualpart_operator(problem, operands) -> LinearOperator:
    av, ac = operands["a"]              # row block, global cols
    atv, atc = operands["at"]           # col block (ELL of A^T), global rows
    ax = problem.axes[0]

    def matvec(x_loc):                  # partial over my columns -> RS to rows
        y_part = _scatter_matvec(atv, atc, x_loc, problem.m_pad)
        return jax.lax.psum_scatter(y_part, ax, scatter_dimension=0,
                                    tiled=True)

    def rmatvec(y_loc):                 # partial over my rows -> RS to cols
        z_part = _scatter_rmatvec(av, ac, y_loc, problem.n_pad)
        return jax.lax.psum_scatter(z_part, ax, scatter_dimension=0,
                                    tiled=True)

    return LinearOperator(matvec=matvec, rmatvec=rmatvec,
                          shape=_shape(problem), format="ell",
                          backend="dualpart")


@register("ell", "block2d")
def block2d_operator(problem, operands) -> LinearOperator:
    # operands carry a leading (1, 1) block index -> squeeze
    ra, ca = problem.axes
    av, ac = (o[0, 0] for o in operands["a"])

    def matvec(x_loc):                  # (nb,) -> (mb,): gather + psum(model)
        return jax.lax.psum(_gather_matvec(av, ac, x_loc), ca)

    if problem.dual_copy:
        atv, atc = (o[0, 0] for o in operands["at"])

        def rmatvec(y_loc):             # gather-only backward (kernel-friendly)
            return jax.lax.psum(_gather_matvec(atv, atc, y_loc), ra)
    else:
        def rmatvec(y_loc):             # scatter-add backward
            nb = problem.n_pad // problem.mesh.devices.shape[
                problem.mesh.axis_names.index(ca)]
            return jax.lax.psum(_scatter_rmatvec(av, ac, y_loc, nb), ra)

    return LinearOperator(matvec=matvec, rmatvec=rmatvec,
                          shape=_shape(problem), format="ell",
                          backend="block2d")


@register("stacked_ell", "rowpart")
def stacked_rowpart_operator(a, axis: str, at_vals=None,
                             at_rows=None) -> LinearOperator:
    """Slot-batched row-partitioned local operator (runs INSIDE shard_map) —
    the serving engine's mesh-wide buckets (core.distributed
    .make_sharded_bucket_fns).

    ``a`` is the device-local shard of a StackedELL: vals/cols (S, m_loc, k)
    with GLOBAL column indices into [0, n).  x (S, n) is replicated, y
    (S, m_loc) row-sharded — the batched rowpart signature: fwd local
    gather, bwd partial A^T y + psum(n) ~ MR1/MR3 per slot.  The gathers
    are flattened with slot offsets (one flat gather for the whole slot
    batch, like sparse.linalg.stacked_ell_matvec).

    ``at_vals``/``at_rows`` (S, n, k_t), when given, are this shard's
    TRANSPOSE blocks (``sparse.partition.rowshard_transpose_ell``, row
    indices local to the shard's y slice) — the dual-copy memory-for-
    gather trade applied per row shard, so the backward is gather-only
    instead of scatter-add.  Without them the backward falls back to a
    flat scatter-add.
    """
    from repro.sparse.linalg import stacked_ell_matvec

    n = a.n

    def rmatvec_scatter(y):              # (S, m_loc) -> (S, n) partial
        off = (jnp.arange(a.batch, dtype=a.cols.dtype) * n)[:, None, None]
        contrib = a.vals.astype(y.dtype) * y[:, :, None]
        z = jnp.zeros((a.batch * n,), y.dtype).at[
            (a.cols + off).reshape(-1)].add(contrib.reshape(-1))
        return jax.lax.psum(z.reshape(a.batch, n), axis)

    def rmatvec_gather(y):               # (S, m_loc) -> (S, n) partial
        m_loc = y.shape[1]
        off = (jnp.arange(a.batch, dtype=at_rows.dtype)
               * m_loc)[:, None, None]
        g = jnp.take(y.reshape(-1), at_rows + off, axis=0)  # (S, n, k_t)
        return jax.lax.psum(jnp.sum(at_vals * g, axis=2), axis)

    return LinearOperator(
        matvec=lambda x: stacked_ell_matvec(a, x),
        rmatvec=rmatvec_scatter if at_vals is None else rmatvec_gather,
        shape=(a.m, n), format="stacked_ell", backend="rowpart",
        stats=dict(batch=a.batch, k=a.k,
                   dual_copy=at_vals is not None))


@register("stacked_bcsr", "rowpart")
def stacked_bcsr_rowpart_operator(a, axis: str, at, *,
                                  kernel_backend: str = "jnp",
                                  interpret=None) -> LinearOperator:
    """Slot-batched row-partitioned TILED local operator (runs INSIDE
    shard_map) — the MXU-path body of the serving engine's mesh-wide
    buckets.

    ``a`` is the device-local shard of a StackedBCSR: vals
    (S, nbr_loc, kb, bm, bn) dense tiles with GLOBAL block-column indices
    into [0, n/bn), so the replicated x feeds each tile's ``dot_general``
    directly.  ``at`` is this shard's TRANSPOSE tile block
    (``sparse.partition.rowshard_transpose_bcsr``: the BCSR of
    ``A_shard^T``, block-columns local to the shard's y slice) — the
    dual-copy trade in tiles, so the backward is also gather + dot_general
    (never scatter), psum'd over shards ~ MR1/MR3 per slot.

    ``kernel_backend="pallas"`` contracts tiles through the Pallas MXU kernel
    (``kernels.bcsr_spmv`` via the vmap-over-pallas_call batch wrapper);
    ``"jnp"`` uses the reference ``stacked_bcsr_matvec``.
    """
    mv = _stacked_bcsr_mv(kernel_backend, interpret)
    return LinearOperator(
        matvec=lambda x: mv(a, x),
        rmatvec=lambda y: jax.lax.psum(mv(at, y), axis),
        shape=(a.m, a.n), format="stacked_bcsr", backend="rowpart",
        stats=dict(batch=a.batch, kb=a.kb, kb_t=at.kb,
                   body_backend=kernel_backend, dual_copy=True))


@register("stacked_ell", "dualpart")
def stacked_ell_dualpart_operator(a, axis: str, at=None) -> LinearOperator:
    """Slot-batched dual-partitioned local operator (runs INSIDE
    shard_map): each shard holds its row block of A (vals/cols
    (S, m_loc, k), GLOBAL columns) — and, with x SHARD-RESIDENT
    ((S, n/ndev) per shard, the engine's x-space layout), no transpose
    copy at all.

    The forward reassembles x with ONE tiled all_gather(n) and gathers
    locally; the backward scatter-adds the partial ``A_loc^T y_loc`` over
    the full n and reduces it straight back to the x shard with ONE tiled
    psum_scatter(n).  Against the old replicated-x body (all_gather(m) +
    all_gather(n) per backward) the pair moves (n) + (n) instead of
    (m + n) + (n)-forward-free — HALVING backward wire bytes whenever
    m >= n — and drops the transpose operand entirely (the byte axis
    ``repro.plan.sharded_bucket_bytes`` prices at 0 for dualpart).  The
    harvest-side all_gather happens for free when the engine device_gets
    the sharded xbar.  ``at`` is accepted for call-signature parity and
    ignored (callers pass a zero-width stand-in).
    """
    from repro.sparse.linalg import stacked_ell_matvec

    n = a.n

    def matvec(x_loc):                   # (S, n_loc) -> (S, m_loc)
        xg = jax.lax.all_gather(x_loc, axis, axis=1, tiled=True)
        return stacked_ell_matvec(a, xg)

    def rmatvec(y):                      # (S, m_loc) -> (S, n_loc)
        off = (jnp.arange(a.batch, dtype=a.cols.dtype) * n)[:, None, None]
        contrib = a.vals.astype(y.dtype) * y[:, :, None]
        z = jnp.zeros((a.batch * n,), y.dtype).at[
            (a.cols + off).reshape(-1)].add(contrib.reshape(-1))
        return jax.lax.psum_scatter(z.reshape(a.batch, n), axis,
                                    scatter_dimension=1, tiled=True)

    return LinearOperator(
        matvec=matvec, rmatvec=rmatvec,
        shape=(a.m, n), format="stacked_ell", backend="dualpart",
        stats=dict(batch=a.batch, k=a.k, dual_copy=False))


@register("stacked_bcsr", "dualpart")
def stacked_bcsr_dualpart_operator(a, axis: str, at=None, *,
                                   kernel_backend: str = "jnp",
                                   interpret=None) -> LinearOperator:
    """Dual-partitioned MXU-path body with SHARD-RESIDENT x: the tiled
    analogue of ``("stacked_ell", "dualpart")`` — all_gather(n) + tile
    contraction forward (Pallas when ``kernel_backend="pallas"``),
    per-tile partial products scatter-added over the full n and
    psum_scatter'd back to the x shard backward.  ``at`` is accepted for
    call-signature parity and ignored (zero-width stand-in).
    """
    mv = _stacked_bcsr_mv(kernel_backend, interpret)

    def matvec(x_loc):                   # (S, n_loc) -> (S, m_loc)
        xg = jax.lax.all_gather(x_loc, axis, axis=1, tiled=True)
        return mv(a, xg)

    def rmatvec(y):                      # (S, m_loc) -> (S, n_loc)
        S, nbr, kb, bm, bn = a.vals.shape
        n_full = a.nbc * bn              # tile-padded n (>= a.n)
        yt = y.reshape(S, nbr, bm)
        contrib = jnp.einsum("sikmn,sim->sikn",
                             a.vals.astype(y.dtype), yt)
        off = (jnp.arange(S, dtype=a.bcols.dtype)
               * n_full)[:, None, None, None]
        idx = (a.bcols[..., None] * bn
               + jnp.arange(bn, dtype=a.bcols.dtype) + off)
        z = jnp.zeros((S * n_full,), y.dtype).at[
            idx.reshape(-1)].add(contrib.reshape(-1))
        z = z.reshape(S, n_full)[:, :a.n]   # tile pad columns are zero
        return jax.lax.psum_scatter(z, axis, scatter_dimension=1,
                                    tiled=True)

    return LinearOperator(
        matvec=matvec, rmatvec=rmatvec,
        shape=(a.m, a.n), format="stacked_bcsr", backend="dualpart",
        stats=dict(batch=a.batch, kb=a.kb,
                   body_backend=kernel_backend, dual_copy=False))


@register("stacked_ell", "gridpart")
def stacked_ell_gridpart_operator(a, axes, at) -> LinearOperator:
    """Slot-batched 2-D grid-partitioned local operator (runs INSIDE a
    shard_map over a (row_axis, col_axis) sub-mesh): device (i, j) holds
    block (i, j) of every slot's A — ``a`` vals/cols (S, mb, k) with
    block-LOCAL columns into [0, n/C) — plus the block's transpose tile
    ``at`` (S, nb, k_t) with block-LOCAL rows into [0, m/R)
    (``sparse.partition.blockgrid_transpose_ell``).

    y (S, m/R) is sharded over the row axis (replicated along columns);
    x (S, n/(C*R)) is sharded over BOTH axes (column block j, row tile i).
    The forward all_gathers x over the row axis (reassembling the block's
    column slice inside each column group), gathers locally, and psums
    the partial y along the COLUMN axis; the backward is a gather-only
    tile product psum_scatter'd along the ROW axis — per-device wire
    bytes shrink with BOTH mesh axes (the Nathan & Klabjan 2-D unlock).
    """
    from repro.sparse.linalg import stacked_ell_matvec

    ra, ca = axes

    def matvec(x_loc):                   # (S, n/(C*R)) -> (S, m/R)
        xg = jax.lax.all_gather(x_loc, ra, axis=1, tiled=True)  # (S, n/C)
        return jax.lax.psum(stacked_ell_matvec(a, xg), ca)

    def rmatvec(y_loc):                  # (S, m/R) -> (S, n/(C*R))
        z_part = stacked_ell_matvec(at, y_loc)                  # (S, n/C)
        return jax.lax.psum_scatter(z_part, ra, scatter_dimension=1,
                                    tiled=True)

    return LinearOperator(
        matvec=matvec, rmatvec=rmatvec,
        shape=(a.m, a.n), format="stacked_ell", backend="gridpart",
        stats=dict(batch=a.batch, k=a.k, k_t=at.k, dual_copy=True))


@register("stacked_bcsr", "gridpart")
def stacked_bcsr_gridpart_operator(a, axes, at, *,
                                   kernel_backend: str = "jnp",
                                   interpret=None) -> LinearOperator:
    """2-D grid-partitioned MXU-path body: the tiled analogue of
    ``("stacked_ell", "gridpart")`` — block BCSR tiles forward
    (all_gather(row axis) -> tile contraction -> psum(col axis)), the
    block's transpose BCSR tiles backward (gather + dot_general ->
    psum_scatter(row axis)), contraction on the Pallas kernel when
    ``kernel_backend="pallas"``.
    """
    mv = _stacked_bcsr_mv(kernel_backend, interpret)
    ra, ca = axes

    def matvec(x_loc):                   # (S, n/(C*R)) -> (S, m/R)
        xg = jax.lax.all_gather(x_loc, ra, axis=1, tiled=True)
        return jax.lax.psum(mv(a, xg), ca)

    def rmatvec(y_loc):                  # (S, m/R) -> (S, n/(C*R))
        return jax.lax.psum_scatter(mv(at, y_loc), ra,
                                    scatter_dimension=1, tiled=True)

    return LinearOperator(
        matvec=matvec, rmatvec=rmatvec,
        shape=(a.m, a.n), format="stacked_bcsr", backend="gridpart",
        stats=dict(batch=a.batch, kb=a.kb, kb_t=at.kb,
                   body_backend=kernel_backend, dual_copy=True))


def _stacked_bcsr_mv(backend: str, interpret):
    """The per-shard stacked-BCSR apply: Pallas MXU tiles or jnp oracle."""
    if backend == "pallas":
        from repro.kernels.ops import batched_bcsr_spmv

        return lambda s, v: batched_bcsr_spmv(s, v, interpret=interpret)
    from repro.sparse.linalg import stacked_bcsr_matvec

    return stacked_bcsr_matvec


def local_operator(problem, operands) -> LinearOperator:
    """Dispatch a DistProblem's local shard through the registry."""
    return make_operator("ell", problem.strategy, problem, operands)

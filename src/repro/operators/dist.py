"""Strategy-local LinearOperator builders (run INSIDE shard_map).

One builder per distributed strategy of repro.core.distributed, registered
under (format="ell", backend=<strategy>): each receives the DistProblem
metadata plus the device-local operand shards and returns the local
operator whose collective signature realizes that strategy's paper design
(rowpart ~ MR1/MR3, colpart ~ MR2, dualpart ~ Spark dual-RDD,
block2d ~ the 2-D generalization; see DESIGN.md).

These builders are pure closures over jnp + lax collectives, so they are
traceable inside shard_map exactly like the hand-assembled bundles they
replaced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.operators.base import LinearOperator
from repro.operators.registry import make_operator, register


def _scatter_rmatvec(vals, cols, y_loc, n):
    """z = A_loc^T y_loc from a row-ELL block with column indices into [0, n).
    Accumulates in y's dtype (fp32) so bf16-compressed operands stay exact."""
    contrib = vals.astype(y_loc.dtype) * y_loc[:, None]
    return jnp.zeros((n,), y_loc.dtype).at[cols.reshape(-1)].add(
        contrib.reshape(-1))


def _scatter_matvec(vals_t, rows, x_loc, m):
    """y = A_loc x_loc from a col-ELL block (ELL of A^T) with row indices."""
    contrib = vals_t.astype(x_loc.dtype) * x_loc[:, None]
    return jnp.zeros((m,), x_loc.dtype).at[rows.reshape(-1)].add(
        contrib.reshape(-1))


def _gather_matvec(vals, cols, x):
    return jnp.sum(vals * jnp.take(x, cols, axis=0), axis=1)


def _shape(problem):
    return (problem.m, problem.n)


@register("ell", "replicated")
def replicated_operator(problem, operands) -> LinearOperator:
    av, ac = operands["a"]
    atv, atc = operands["at"]
    return LinearOperator(
        matvec=lambda x: _gather_matvec(av, ac, x),
        rmatvec=lambda y: _gather_matvec(atv, atc, y),
        shape=_shape(problem), format="ell", backend="replicated")


@register("ell", "rowpart")
def rowpart_operator(problem, operands) -> LinearOperator:
    av, ac = operands["a"]              # local (mb, k), global cols
    ax = problem.axes[0]
    return LinearOperator(
        matvec=lambda x: _gather_matvec(av, ac, x),
        rmatvec=lambda y: jax.lax.psum(
            _scatter_rmatvec(av, ac, y, problem.n_pad), ax),
        shape=_shape(problem), format="ell", backend="rowpart")


@register("ell", "colpart")
def colpart_operator(problem, operands) -> LinearOperator:
    atv, atc = operands["at"]           # local (nb, kc), global rows
    ax = problem.axes[0]
    return LinearOperator(
        matvec=lambda x: jax.lax.psum(
            _scatter_matvec(atv, atc, x, problem.m_pad), ax),
        rmatvec=lambda y: _gather_matvec(atv, atc, y),
        shape=_shape(problem), format="ell", backend="colpart")


@register("ell", "dualpart")
def dualpart_operator(problem, operands) -> LinearOperator:
    av, ac = operands["a"]              # row block, global cols
    atv, atc = operands["at"]           # col block (ELL of A^T), global rows
    ax = problem.axes[0]

    def matvec(x_loc):                  # partial over my columns -> RS to rows
        y_part = _scatter_matvec(atv, atc, x_loc, problem.m_pad)
        return jax.lax.psum_scatter(y_part, ax, scatter_dimension=0,
                                    tiled=True)

    def rmatvec(y_loc):                 # partial over my rows -> RS to cols
        z_part = _scatter_rmatvec(av, ac, y_loc, problem.n_pad)
        return jax.lax.psum_scatter(z_part, ax, scatter_dimension=0,
                                    tiled=True)

    return LinearOperator(matvec=matvec, rmatvec=rmatvec,
                          shape=_shape(problem), format="ell",
                          backend="dualpart")


@register("ell", "block2d")
def block2d_operator(problem, operands) -> LinearOperator:
    # operands carry a leading (1, 1) block index -> squeeze
    ra, ca = problem.axes
    av, ac = (o[0, 0] for o in operands["a"])

    def matvec(x_loc):                  # (nb,) -> (mb,): gather + psum(model)
        return jax.lax.psum(_gather_matvec(av, ac, x_loc), ca)

    if problem.dual_copy:
        atv, atc = (o[0, 0] for o in operands["at"])

        def rmatvec(y_loc):             # gather-only backward (kernel-friendly)
            return jax.lax.psum(_gather_matvec(atv, atc, y_loc), ra)
    else:
        def rmatvec(y_loc):             # scatter-add backward
            nb = problem.n_pad // problem.mesh.devices.shape[
                problem.mesh.axis_names.index(ca)]
            return jax.lax.psum(_scatter_rmatvec(av, ac, y_loc, nb), ra)

    return LinearOperator(matvec=matvec, rmatvec=rmatvec,
                          shape=_shape(problem), format="ell",
                          backend="block2d")


@register("stacked_ell", "rowpart")
def stacked_rowpart_operator(a, axis: str, at_vals=None,
                             at_rows=None) -> LinearOperator:
    """Slot-batched row-partitioned local operator (runs INSIDE shard_map) —
    the serving engine's mesh-wide buckets (core.distributed
    .make_sharded_bucket_fns).

    ``a`` is the device-local shard of a StackedELL: vals/cols (S, m_loc, k)
    with GLOBAL column indices into [0, n).  x (S, n) is replicated, y
    (S, m_loc) row-sharded — the batched rowpart signature: fwd local
    gather, bwd partial A^T y + psum(n) ~ MR1/MR3 per slot.  The gathers
    are flattened with slot offsets (one flat gather for the whole slot
    batch, like sparse.linalg.stacked_ell_matvec).

    ``at_vals``/``at_rows`` (S, n, k_t), when given, are this shard's
    TRANSPOSE blocks (``sparse.partition.rowshard_transpose_ell``, row
    indices local to the shard's y slice) — the dual-copy memory-for-
    gather trade applied per row shard, so the backward is gather-only
    instead of scatter-add.  Without them the backward falls back to a
    flat scatter-add.
    """
    from repro.sparse.linalg import stacked_ell_matvec

    n = a.n

    def rmatvec_scatter(y):              # (S, m_loc) -> (S, n) partial
        off = (jnp.arange(a.batch, dtype=a.cols.dtype) * n)[:, None, None]
        contrib = a.vals.astype(y.dtype) * y[:, :, None]
        z = jnp.zeros((a.batch * n,), y.dtype).at[
            (a.cols + off).reshape(-1)].add(contrib.reshape(-1))
        return jax.lax.psum(z.reshape(a.batch, n), axis)

    def rmatvec_gather(y):               # (S, m_loc) -> (S, n) partial
        m_loc = y.shape[1]
        off = (jnp.arange(a.batch, dtype=at_rows.dtype)
               * m_loc)[:, None, None]
        g = jnp.take(y.reshape(-1), at_rows + off, axis=0)  # (S, n, k_t)
        return jax.lax.psum(jnp.sum(at_vals * g, axis=2), axis)

    return LinearOperator(
        matvec=lambda x: stacked_ell_matvec(a, x),
        rmatvec=rmatvec_scatter if at_vals is None else rmatvec_gather,
        shape=(a.m, n), format="stacked_ell", backend="rowpart",
        stats=dict(batch=a.batch, k=a.k,
                   dual_copy=at_vals is not None))


@register("stacked_bcsr", "rowpart")
def stacked_bcsr_rowpart_operator(a, axis: str, at, *,
                                  kernel_backend: str = "jnp",
                                  interpret=None) -> LinearOperator:
    """Slot-batched row-partitioned TILED local operator (runs INSIDE
    shard_map) — the MXU-path body of the serving engine's mesh-wide
    buckets.

    ``a`` is the device-local shard of a StackedBCSR: vals
    (S, nbr_loc, kb, bm, bn) dense tiles with GLOBAL block-column indices
    into [0, n/bn), so the replicated x feeds each tile's ``dot_general``
    directly.  ``at`` is this shard's TRANSPOSE tile block
    (``sparse.partition.rowshard_transpose_bcsr``: the BCSR of
    ``A_shard^T``, block-columns local to the shard's y slice) — the
    dual-copy trade in tiles, so the backward is also gather + dot_general
    (never scatter), psum'd over shards ~ MR1/MR3 per slot.

    ``kernel_backend="pallas"`` contracts tiles through the Pallas MXU kernel
    (``kernels.bcsr_spmv`` via the vmap-over-pallas_call batch wrapper);
    ``"jnp"`` uses the reference ``stacked_bcsr_matvec``.
    """
    mv = _stacked_bcsr_mv(kernel_backend, interpret)
    return LinearOperator(
        matvec=lambda x: mv(a, x),
        rmatvec=lambda y: jax.lax.psum(mv(at, y), axis),
        shape=(a.m, a.n), format="stacked_bcsr", backend="rowpart",
        stats=dict(batch=a.batch, kb=a.kb, kb_t=at.kb,
                   body_backend=kernel_backend, dual_copy=True))


@register("stacked_ell", "dualpart")
def stacked_ell_dualpart_operator(a, axis: str, at) -> LinearOperator:
    """Slot-batched dual-partitioned local operator (runs INSIDE
    shard_map): each shard caches BOTH orientations — its row block of A
    (vals/cols (S, m_loc, k), GLOBAL columns) AND its slice of the plain
    transpose (``at``: (S, n_loc, k_t) rows of A^T = columns of A, GLOBAL
    row indices) — the Spark dual-RDD cache per slot.

    x is replicated, y row-sharded: the forward is a local gather
    (collective-free); the backward reassembles y with a tiled all_gather,
    gathers each shard's OWN primal coordinates from its transpose slice,
    and all_gathers the result back to the replicated x space.  Against
    ``rowpart`` this trades the psum(n) backward for two all_gathers
    (m + n bytes) and stores the transpose ONCE across the mesh instead of
    one full-n block per shard — ndev x less transpose memory, the axis
    the byte cost model prices (repro.plan.sharded_bucket_bytes).
    """
    from repro.sparse.linalg import stacked_ell_matvec

    def rmatvec(y):                      # (S, m_loc) -> (S, n) replicated
        yg = jax.lax.all_gather(y, axis, axis=1, tiled=True)
        z_loc = stacked_ell_matvec(at, yg)           # my columns only
        return jax.lax.all_gather(z_loc, axis, axis=1, tiled=True)

    return LinearOperator(
        matvec=lambda x: stacked_ell_matvec(a, x),
        rmatvec=rmatvec,
        shape=(a.m, a.n), format="stacked_ell", backend="dualpart",
        stats=dict(batch=a.batch, k=a.k, k_t=at.k, dual_copy=True))


@register("stacked_bcsr", "dualpart")
def stacked_bcsr_dualpart_operator(a, axis: str, at, *,
                                   kernel_backend: str = "jnp",
                                   interpret=None) -> LinearOperator:
    """Dual-partitioned MXU-path body: the tiled analogue of
    ``("stacked_ell", "dualpart")`` — row-block tiles forward
    (collective-free), each shard's slice of the plain transpose BCSR
    backward (all_gather y -> tile contraction -> all_gather z), with the
    per-tile contraction on the Pallas kernel when ``kernel_backend="pallas"``.
    """
    mv = _stacked_bcsr_mv(kernel_backend, interpret)

    def rmatvec(y):                      # (S, m_loc) -> (S, n) replicated
        yg = jax.lax.all_gather(y, axis, axis=1, tiled=True)
        return jax.lax.all_gather(mv(at, yg), axis, axis=1, tiled=True)

    return LinearOperator(
        matvec=lambda x: mv(a, x),
        rmatvec=rmatvec,
        shape=(a.m, a.n), format="stacked_bcsr", backend="dualpart",
        stats=dict(batch=a.batch, kb=a.kb, kb_t=at.kb,
                   body_backend=kernel_backend, dual_copy=True))


def _stacked_bcsr_mv(backend: str, interpret):
    """The per-shard stacked-BCSR apply: Pallas MXU tiles or jnp oracle."""
    if backend == "pallas":
        from repro.kernels.ops import batched_bcsr_spmv

        return lambda s, v: batched_bcsr_spmv(s, v, interpret=interpret)
    from repro.sparse.linalg import stacked_bcsr_matvec

    return stacked_bcsr_matvec


def local_operator(problem, operands) -> LinearOperator:
    """Dispatch a DistProblem's local shard through the registry."""
    return make_operator("ell", problem.strategy, problem, operands)

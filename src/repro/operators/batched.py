"""Batched LinearOperator builders: B independent problems, one operator.

The serving engine (repro.serve.solver_engine) buckets concurrent
``min f(x) s.t. Ax = b`` requests by padded shape and runs one vmapped A2
step per bucket.  The operator side of that is here: stacked formats
(``StackedELL`` / ``StackedBCSR`` / a plain (B, m, n) dense stack) whose
matvec/rmatvec/fused_dual carry a leading batch axis, registered under the
same (format, backend) table as the single-problem builders, so the batched
path is reachable from every call site (``make_operator("stacked_ell",
"pallas", ...)``) and inherits the registry's discoverability.

Backend notes:
  jnp    — vmapped reference matvecs (repro.sparse.linalg.stacked_*).
  pallas — stacked-ELL and stacked-BCSR both run real batch-grid kernels
           (the grid gains the slot dimension: kernels/batched_ell_spmv.py,
           kernels/bcsr_spmv.py's batched_bcsr_spmv_pallas, and the batched
           fused dual update).

All builders take BOTH orientations (A, A^T) pre-stacked — the batched path
keeps the repo's memory-for-gather trade: the backward pass is a gather
over the transpose stack, never a scatter.
"""
from __future__ import annotations

from functools import partial

import jax.numpy as jnp

from repro.operators.base import LinearOperator
from repro.operators.registry import register
from repro.sparse.formats import (
    COO, StackedBCSR, StackedCSC, StackedELL, coo_bcsr_width, coo_to_bcsr,
    coo_to_csc, coo_to_ell, pad_coo, stack_bcsrs, stack_cscs, stack_ells,
    transpose_coo,
)
from repro.sparse.linalg import (
    stacked_bcsr_matvec, stacked_csc_gather_matvec, stacked_ell_matvec,
)


@register("stacked_dense", "jnp")
def stacked_dense_operator(d) -> LinearOperator:
    """d: (B, m, n) — B independent dense matrices (batched matmul path)."""
    return LinearOperator(
        matvec=lambda x: jnp.einsum("bmn,bn->bm", d, x),
        rmatvec=lambda y: jnp.einsum("bmn,bm->bn", d, y),
        shape=(int(d.shape[1]), int(d.shape[2])), format="stacked_dense",
        backend="jnp", nnz=int(d.shape[0] * d.shape[1] * d.shape[2]),
        stats=dict(batch=int(d.shape[0])))


@register("stacked_ell", "jnp")
def stacked_ell_operator(a: StackedELL, at: StackedELL) -> LinearOperator:
    """(stacked ELL of A, stacked ELL of A^T), vmapped gather reference."""
    return LinearOperator(
        matvec=partial(stacked_ell_matvec, a),
        rmatvec=partial(stacked_ell_matvec, at),
        shape=(a.m, at.m), format="stacked_ell", backend="jnp",
        stats=dict(batch=a.batch, k=a.k, k_t=at.k))


@register("stacked_ell", "pallas")
def stacked_ell_pallas_operator(a: StackedELL, at: StackedELL, prox=None,
                                reg=0.0, *, block_rows: int = 512,
                                interpret: bool | None = None
                                ) -> LinearOperator:
    """Batch-grid kernels: grid (B, m/block_rows); per-slot fused dual (the
    (B, 4) coefficient rows carry each slot's own schedule position)."""
    from repro.kernels.ops import batched_ell_spmv, batched_fused_dual_update

    def fused(yhat, xstar, xbar, b, c0, c1, c2, c3):
        coefs = jnp.concatenate(
            [jnp.broadcast_to(jnp.asarray(c, jnp.float32),
                              (yhat.shape[0], 1)) for c in (c0, c1, c2, c3)],
            axis=1)
        return batched_fused_dual_update(a, xstar, xbar, yhat, b, coefs,
                                         block_rows=block_rows,
                                         interpret=interpret)

    return LinearOperator(
        matvec=lambda x: batched_ell_spmv(a, x, block_rows=block_rows,
                                          interpret=interpret),
        rmatvec=lambda y: batched_ell_spmv(at, y, block_rows=block_rows,
                                           interpret=interpret),
        fused_dual=fused,
        shape=(a.m, at.m), format="stacked_ell", backend="pallas",
        stats=dict(batch=a.batch, k=a.k, k_t=at.k))


@register("stacked_csc", "jnp")
def stacked_csc_operator(a: StackedCSC, at: StackedCSC) -> LinearOperator:
    """(stacked CSC of A, stacked CSC of A^T) — the batched column-major
    pair the RCD serving buckets hold; matvec/rmatvec are the flat-gather
    reductions the residual refresh uses."""
    return LinearOperator(
        matvec=partial(stacked_csc_gather_matvec, at),
        rmatvec=partial(stacked_csc_gather_matvec, a),
        shape=(a.m, a.n), format="stacked_csc", backend="jnp",
        stats=dict(batch=a.batch, k=a.k, k_t=at.k))


@register("stacked_csc", "pallas")
def stacked_csc_pallas_operator(a: StackedCSC, at: StackedCSC, prox=None,
                                reg=0.0, *, block_rows: int = 512,
                                interpret: bool | None = None
                                ) -> LinearOperator:
    """Stacked CSC through the batch-grid ELL kernel on the transpose view
    (a stacked CSC of A^T IS a stacked ELL of A); per-coordinate updates go
    through repro.kernels.rcd_update from the solver side."""
    from repro.kernels.ops import batched_ell_spmv

    def view(c: StackedCSC) -> StackedELL:
        return StackedELL(vals=c.vals, cols=c.rows, n=c.m)

    return LinearOperator(
        matvec=lambda x: batched_ell_spmv(view(at), x, block_rows=block_rows,
                                          interpret=interpret),
        rmatvec=lambda y: batched_ell_spmv(view(a), y, block_rows=block_rows,
                                           interpret=interpret),
        shape=(a.m, a.n), format="stacked_csc", backend="pallas",
        stats=dict(batch=a.batch, k=a.k, k_t=at.k))


@register("stacked_bcsr", "jnp")
def stacked_bcsr_operator(a: StackedBCSR, at: StackedBCSR) -> LinearOperator:
    return LinearOperator(
        matvec=partial(stacked_bcsr_matvec, a),
        rmatvec=partial(stacked_bcsr_matvec, at),
        shape=(a.m, a.n), format="stacked_bcsr", backend="jnp",
        stats=dict(batch=a.batch, blocks=a.nbr * a.kb, bm=a.bm, bn=a.bn))


@register("stacked_bcsr", "pallas")
def stacked_bcsr_pallas_operator(a: StackedBCSR, at: StackedBCSR, prox=None,
                                 reg=0.0, *, block_brows: int = 8,
                                 interpret: bool | None = None
                                 ) -> LinearOperator:
    from repro.kernels.ops import batched_bcsr_spmv

    return LinearOperator(
        matvec=lambda x: batched_bcsr_spmv(a, x, block_brows=block_brows,
                                           interpret=interpret),
        rmatvec=lambda y: batched_bcsr_spmv(at, y, block_brows=block_brows,
                                            interpret=interpret),
        shape=(a.m, a.n), format="stacked_bcsr", backend="pallas",
        stats=dict(batch=a.batch, blocks=a.nbr * a.kb, bm=a.bm, bn=a.bn))


# --------------------------------------------------------------------------
# Host-side bucket assembly
# --------------------------------------------------------------------------

def stack_coos(coos: list[COO], fmt: str, m_pad: int, n_pad: int, *,
               k: int | None = None, k_t: int | None = None, bm: int = 8,
               bn: int = 128, kb: int | None = None, kb_t: int | None = None,
               pad_to: int = 8):
    """Pad each COO to (m_pad, n_pad), convert to ``fmt``, stack both
    orientations.  Returns (stacked_A, stacked_AT) ready for
    ``make_operator("stacked_<fmt>", backend, a, at)``.

    k/k_t (ELL widths) and kb/kb_t (BCSR blocks per block-row) set the
    bucket-wide padded widths; callers pass the bucket maxima so every
    problem in the bucket stacks to the same shape.
    """
    padded = [pad_coo(c, m_pad, n_pad) for c in coos]
    if fmt == "ell":
        k = k or max(1, *(int(jnp.max(jnp.bincount(
            c.rows, length=m_pad))) for c in padded))
        k_t = k_t or max(1, *(int(jnp.max(jnp.bincount(
            c.cols, length=n_pad))) for c in padded))
        a = stack_ells([coo_to_ell(c, k=k, pad_to=pad_to) for c in padded])
        at = stack_ells([coo_to_ell(transpose_coo(c), k=k_t, pad_to=pad_to)
                         for c in padded])
        return a, at
    if fmt == "bcsr":
        # size the bucket widths without materializing tiles, then convert
        # each problem exactly once at the common widths
        kb = kb or max(coo_bcsr_width(c, bm=bm, bn=bn) for c in padded)
        kb_t = kb_t or max(coo_bcsr_width(transpose_coo(c), bm=bm, bn=bn)
                           for c in padded)
        fwd = [coo_to_bcsr(c, bm=bm, bn=bn, kb=kb, pad_to=1) for c in padded]
        bwd = [coo_to_bcsr(transpose_coo(c), bm=bm, bn=bn, kb=kb_t, pad_to=1)
               for c in padded]
        return stack_bcsrs(fwd), stack_bcsrs(bwd)
    if fmt == "csc":
        # column widths: max per-column nnz (and per-row for the transpose)
        k = k or max(1, *(int(jnp.max(jnp.bincount(
            c.cols, length=n_pad))) for c in padded))
        k_t = k_t or max(1, *(int(jnp.max(jnp.bincount(
            c.rows, length=m_pad))) for c in padded))
        a = stack_cscs([coo_to_csc(c, k=k, pad_to=pad_to) for c in padded],
                       m=m_pad)
        at = stack_cscs([coo_to_csc(transpose_coo(c), k=k_t, pad_to=pad_to)
                         for c in padded], m=n_pad)
        return a, at
    raise KeyError(f"unknown stacked format {fmt!r} (ell | bcsr | csc)")

"""Single-device LinearOperator builders: {dense, coo, ell, bcsr} x
{jnp, pallas}.

Each builder takes pre-converted format arrays (so callers that already
hold an ELL/BCSR pay no conversion); ``build_from_coo`` is the conversion
front-end used by ``repro.operators.registry.from_coo``.

Backend notes:
  jnp    — the reference path (repro.sparse.linalg); also the oracle the
           Pallas kernels are tested against.
  pallas — the fused-kernel path (repro.kernels.ops): ELL forward +
           BandedELL backward with the fused dual/prox passes, or BCSR in
           both orientations with MXU tile contraction. Off-TPU the same
           calls run in interpret mode.
"""
from __future__ import annotations

from functools import partial

import jax.numpy as jnp

from repro.operators.base import LinearOperator
from repro.operators.registry import get_builder, register
from repro.sparse.formats import (
    BCSR, COO, CSC, ELL, BandedELL, coo_to_banded, coo_to_bcsr, coo_to_csc,
    coo_to_ell, transpose_coo,
)
from repro.sparse.linalg import (
    bcsr_matvec, coo_matvec, coo_rmatvec, csc_gather_matvec, ell_matvec,
)


def _csc_ell_view(c: CSC) -> ELL:
    """A CSC of A is bit-for-bit an ELL of A^T, so the ELL kernels apply."""
    return ELL(vals=c.vals, cols=c.rows, n=c.m)


def _ell_nnz_stats(a: ELL) -> dict:
    return dict(padded_entries=int(a.m * a.k),
                k=int(a.k))


@register("dense", "jnp")
def dense_operator(d) -> LinearOperator:
    return LinearOperator(
        matvec=lambda x: d @ x, rmatvec=lambda y: d.T @ y,
        shape=tuple(d.shape), format="dense", backend="jnp",
        nnz=int(d.shape[0] * d.shape[1]))


@register("coo", "jnp")
def coo_operator(a: COO) -> LinearOperator:
    return LinearOperator(
        matvec=partial(coo_matvec, a), rmatvec=partial(coo_rmatvec, a),
        shape=(a.m, a.n), format="coo", backend="jnp", nnz=int(a.nnz))


@register("ell", "jnp")
def ell_operator(a: ELL, at: ELL) -> LinearOperator:
    """(ELL of A, ELL of A^T) — both orientations stored, gather-only."""
    return LinearOperator(
        matvec=partial(ell_matvec, a), rmatvec=partial(ell_matvec, at),
        shape=(a.m, at.m), format="ell", backend="jnp",
        stats=dict(fwd=_ell_nnz_stats(a), bwd=_ell_nnz_stats(at)))


@register("bcsr", "jnp")
def bcsr_operator(a: BCSR, at: BCSR) -> LinearOperator:
    return LinearOperator(
        matvec=partial(bcsr_matvec, a), rmatvec=partial(bcsr_matvec, at),
        shape=(a.m, a.n), format="bcsr", backend="jnp",
        stats=dict(blocks=a.nnz_blocks, bm=a.bm, bn=a.bn,
                   blocks_t=at.nnz_blocks))


@register("csc", "jnp")
def csc_operator(a: CSC, at: CSC) -> LinearOperator:
    """(CSC of A, CSC of A^T) — the column-major pair for coordinate descent.

    The RCD bodies (repro.solvers.rcd) slice single columns out of these
    arrays; the whole-matrix matvec/rmatvec here are the gather reductions
    the stopping residuals and oracles use."""
    return LinearOperator(
        matvec=partial(csc_gather_matvec, at),
        rmatvec=partial(csc_gather_matvec, a),
        shape=(a.m, a.n), format="csc", backend="jnp",
        stats=dict(k=a.k, k_t=at.k))


@register("csc", "pallas")
def csc_pallas_operator(a: CSC, at: CSC, prox=None, reg: float = 0.0, *,
                        block_rows: int = 512,
                        interpret: bool | None = None) -> LinearOperator:
    """CSC served by the ELL kernels through the transpose view (a CSC of
    A^T IS an ELL of A); the per-coordinate gather-update kernel lives in
    repro.kernels.rcd_update and is invoked by the solver, not here."""
    from repro.kernels.ops import ell_spmv

    return LinearOperator(
        matvec=lambda x: ell_spmv(_csc_ell_view(at), x,
                                  block_rows=block_rows, interpret=interpret),
        rmatvec=lambda y: ell_spmv(_csc_ell_view(a), y,
                                   block_rows=block_rows, interpret=interpret),
        shape=(a.m, a.n), format="csc", backend="pallas",
        stats=dict(k=a.k, k_t=at.k))


def _fused_l1_prox(prox, reg, interpret):
    """The fused prox kernel implements l1 only; other proxes fall back to
    the composed jnp primal step (SolverOps.primal default)."""
    if prox is None or prox.name != "l1":
        return None
    from repro.kernels.ops import prox_update

    def fused(p, zhat, gamma, tau, xbar, xc):
        return prox_update(zhat, xbar, xc, gamma, tau, reg,
                           interpret=interpret)
    return fused


@register("ell", "pallas")
def ell_pallas_operator(a: ELL, at: BandedELL, prox=None, reg: float = 0.0,
                        *, block_rows: int = 512, block_cols: int = 512,
                        interpret: bool | None = None) -> LinearOperator:
    """The full fused-kernel bundle: ELL forward, BandedELL backward,
    one-pass dual update (eq. 15) and, for l1, the fused prox."""
    from repro.kernels.ops import banded_spmv_t, ell_spmv, fused_dual_update

    return LinearOperator(
        matvec=lambda x: ell_spmv(a, x, block_rows=block_rows,
                                  interpret=interpret),
        rmatvec=lambda y: banded_spmv_t(at, y, block_cols=block_cols,
                                        interpret=interpret),
        fused_dual=lambda yhat, xstar, xbar, b, c0, c1, c2, c3:
            fused_dual_update(a, xstar, xbar, yhat, b, c0, c1, c2, c3,
                              block_rows=block_rows, interpret=interpret),
        prox_update=_fused_l1_prox(prox, reg, interpret),
        shape=(a.m, at.n), format="ell", backend="pallas",
        stats=dict(fwd=_ell_nnz_stats(a),
                   bwd=dict(bands=at.num_bands, kb=at.kb)))


@register("bcsr", "pallas")
def bcsr_pallas_operator(a: BCSR, at: BCSR, prox=None, reg: float = 0.0,
                         *, block_brows: int = 8,
                         interpret: bool | None = None) -> LinearOperator:
    """MXU-path bundle: tiled BCSR in both orientations. The dual update
    composes from matvec (SolverOps.dual default — still one A pass); the
    l1 prox reuses the elementwise fused prox kernel."""
    from repro.kernels.ops import bcsr_spmv

    return LinearOperator(
        matvec=lambda x: bcsr_spmv(a, x, block_brows=block_brows,
                                   interpret=interpret),
        rmatvec=lambda y: bcsr_spmv(at, y, block_brows=block_brows,
                                    interpret=interpret),
        prox_update=_fused_l1_prox(prox, reg, interpret),
        shape=(a.m, a.n), format="bcsr", backend="pallas",
        stats=dict(blocks=a.nnz_blocks, bm=a.bm, bn=a.bn,
                   blocks_t=at.nnz_blocks))


def build_from_coo(coo: COO, fmt: str, backend: str, *, prox=None,
                   reg: float = 0.0, **opts) -> LinearOperator:
    """Convert a COO matrix to ``fmt`` and build on ``backend``.

    opts (all optional): pad_to, band_size, bm, bn, bm_t, bn_t, block_rows,
    block_cols, block_brows, interpret. Converter options irrelevant to the
    requested format are ignored, so one call site can serve all formats.
    Unknown (fmt, backend) pairs raise the registry's KeyError.
    """
    pad_to = opts.pop("pad_to", None)               # default differs per fmt
    band_size = opts.pop("band_size", 512)
    bm, bn = opts.pop("bm", 8), opts.pop("bn", 128)
    bm_t, bn_t = opts.pop("bm_t", bm), opts.pop("bn_t", bn)
    builder = get_builder(fmt, backend)             # validate the pair first
    if fmt == "dense":
        from repro.sparse.formats import coo_to_dense
        return builder(jnp.asarray(coo_to_dense(coo)))
    if fmt == "coo":
        return builder(coo)
    if fmt == "ell":
        a = coo_to_ell(coo, pad_to=pad_to or 8)
        if backend == "pallas":
            at = coo_to_banded(coo, band_size=band_size, pad_to=pad_to or 8)
            return builder(a, at, prox, reg, **opts)
        at = coo_to_ell(transpose_coo(coo), pad_to=pad_to or 8)
        return builder(a, at)
    if fmt == "csc":
        a = coo_to_csc(coo, pad_to=pad_to or 1)
        at = coo_to_csc(transpose_coo(coo), pad_to=pad_to or 1)
        if backend == "pallas":
            return builder(a, at, prox, reg, **opts)
        return builder(a, at)
    if fmt == "bcsr":
        a = coo_to_bcsr(coo, bm=bm, bn=bn, pad_to=pad_to or 1)
        at = coo_to_bcsr(transpose_coo(coo), bm=bm_t, bn=bn_t,
                         pad_to=pad_to or 1)
        if backend == "pallas":
            return builder(a, at, prox, reg, **opts)
        return builder(a, at)
    raise KeyError(f"unknown format {fmt!r} for build_from_coo")

# The unified LinearOperator layer: one protocol + a (format, backend)
# registry over which every solver in the repo is constructed — jnp
# reference ops, Pallas kernel bundles (ELL and tiled-BCSR/MXU), the
# shard_map-local operators of each distributed strategy, and the stacked
# batched operators of the solver serving engine. See DESIGN.md sections
# 3 and 5.
from repro.operators.base import LinearOperator
from repro.operators.registry import (
    available, from_coo, get_builder, make_operator, make_solver_ops,
    register,
)
from repro.operators import builders as _builders          # noqa: F401
from repro.operators import batched as _batched            # noqa: F401
from repro.operators import dist as _dist                  # noqa: F401
from repro.operators.batched import stack_coos
from repro.operators.dist import local_operator
from repro.operators.select import (
    FormatPlan, MatrixStats, estimate_formats, matrix_stats, select_format,
)

__all__ = [
    "LinearOperator", "FormatPlan", "MatrixStats", "available",
    "estimate_formats", "from_coo", "get_builder", "local_operator",
    "make_operator", "make_solver_ops", "matrix_stats", "register",
    "select_format", "stack_coos",
]

"""Registry of LinearOperator builders keyed by (format, backend).

Formats:  "dense", "coo", "ell", "bcsr" (single device) — plus the
          strategy-local shards registered by repro.operators.dist.
Backends: "jnp" (reference), "pallas" (TPU kernels, interpret off-TPU),
          and one backend per distributed strategy ("rowpart", "colpart",
          "dualpart", "block2d", "replicated").

``make_operator`` dispatches to the registered builder; ``from_coo`` is the
high-level entry point that also performs the host-side format conversion
(and, with format="auto", runs the roofline-driven selector). New formats
or backends plug in with @register and become visible to every call site —
solver tests, benchmarks, launch cells — without touching them.
"""
from __future__ import annotations

from typing import Callable

from repro.operators.base import LinearOperator

_REGISTRY: dict[tuple[str, str], Callable[..., LinearOperator]] = {}


def register(fmt: str, backend: str):
    """Decorator: register a builder under (format, backend)."""
    def deco(fn: Callable[..., LinearOperator]):
        _REGISTRY[(fmt, backend)] = fn
        return fn
    return deco


def get_builder(fmt: str, backend: str) -> Callable[..., LinearOperator]:
    try:
        return _REGISTRY[(fmt, backend)]
    except KeyError:
        avail = ", ".join(f"{f}/{b}" for f, b in sorted(_REGISTRY))
        raise KeyError(
            f"no operator builder for format={fmt!r} backend={backend!r}; "
            f"available: {avail}") from None


def available() -> list[tuple[str, str]]:
    return sorted(_REGISTRY)


def make_operator(fmt: str, backend: str, *args, **kwargs) -> LinearOperator:
    """Build a LinearOperator from pre-converted format arrays."""
    return get_builder(fmt, backend)(*args, **kwargs)


def from_coo(coo, fmt: str = "auto", backend: str = "jnp", *,
             prox=None, reg: float = 0.0, measured_table=None,
             **opts) -> LinearOperator:
    """COO -> LinearOperator, converting to ``fmt`` on the host.

    fmt="auto" picks the format and block sizes from matrix statistics via
    the roofline selector (repro.operators.select); ``measured_table``
    (autotune cells, see ``select.load_measured_table``) makes that pick
    use measured timings instead of the analytic model.  ``opts`` are
    forwarded to the converter/builder (band_size, bm, bn, pad_to,
    block_rows, ...).
    """
    from repro.operators import builders

    if fmt == "auto":
        from repro.operators.select import select_format
        plan = select_format(coo, backend=backend, table=measured_table)
        fmt = plan.format
        opts = {**plan.params, **opts}
    return builders.build_from_coo(coo, fmt, backend, prox=prox, reg=reg,
                                   **opts)


def make_solver_ops(coo, fmt: str = "auto", backend: str = "jnp", *,
                    prox=None, reg: float = 0.0, **opts):
    """One-call convenience: COO -> SolverOps through the registry."""
    return from_coo(coo, fmt, backend, prox=prox, reg=reg, **opts).solver_ops()

"""TPU v5e hardware model (the dry-run target; this container is CPU-only).

Collective wire model: per-device bytes for ring algorithms over one torus
axis; each axis of the 2D ICI torus gives a bidirectional ring = 2 usable
links per collective. These constants feed the three roofline terms
(EXPERIMENTS.md section Roofline)."""

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link (one direction)
LINKS_PER_AXIS = 2              # bidirectional ring on one torus axis
COLLECTIVE_BW = ICI_LINK_BW * LINKS_PER_AXIS
HBM_PER_CHIP = 16 * 1024 ** 3   # 16 GiB

CHIPS_PER_POD = 256             # 16 x 16
PODS = 2


def mfu(model_flops_per_device: float, seconds: float) -> float:
    return model_flops_per_device / (seconds * PEAK_FLOPS_BF16)

"""Refresh the §Dry-run and §Roofline tables in EXPERIMENTS.md in place
(all other sections — Validation, Paper-tables, Perf — are hand-written
narrative around checked-in measurements and stay untouched)."""
from __future__ import annotations

import re
import sys

from repro.roofline.report import dryrun_table, load_cells, roofline_table


def replace_table(text: str, section: str, new_table: str) -> str:
    """Replace the first markdown table found after `section` heading."""
    idx = text.index(section)
    tbl_start = text.index("\n| ", idx) + 1
    end = tbl_start
    for line in text[tbl_start:].splitlines(keepends=True):
        if not line.startswith("|"):
            break
        end += len(line)
    return text[:tbl_start] + new_table + "\n" + text[end:]


def main(path: str = "EXPERIMENTS.md"):
    cells = load_cells()
    text = open(path).read()
    text = replace_table(text, "## §Dry-run", dryrun_table(cells))
    text = replace_table(text, "## §Roofline", roofline_table(cells))
    open(path, "w").write(text)
    print("refreshed", path)


if __name__ == "__main__":
    main(*sys.argv[1:])

"""Cost units: trip-count-correct FLOP/byte/wire accounting per cell.

XLA's cost analysis counts scan bodies once (see roofline/analysis.py), so
each cell decomposes into UNITS — the scanned bodies and the un-scanned
remainder — lowered standalone on the same mesh/shardings and multiplied by
their static trip counts:

  train:   grad(layer-block) x L x microbatches  (+ per-stack for moe/vlm/
           hybrid) + grad(embed+head+CE) x microbatches + optimizer x 1
  prefill: layer-forward x L + head x 1
  decode:  layer-decode x L + head x 1

Units whose body contains an interior SEQUENCE scan (Mamba) are lowered at
S and S/2; f(S) = a*S + b gives the corrected cost (a + b/S_unit)*S ~= aS+b
with the body's once-counted cost b re-scaled linearly — implemented as
cost(S) := 2*f(S) - f_half*2 ... concretely: a = (f(S)-f(S/2))/(S/2), and
true(S) = a*S + b*S/S = a*S + (f(S) - a*S) * S  -- NO: b is counted once
but is incurred S times, so true(S) = a*S + (f(S) - a*S)*S. Since
everything else in the block scales linearly with S, b isolates the scan
body. (Verified against analytic recurrence FLOPs in tests.)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import Shardings
from repro.models import transformer as tfm
from repro.models.api import build_model
from repro.models.layers import cross_entropy, embed, logits, rms_norm
from repro.models.params import partition_specs, sds_params
from repro.roofline import analysis
from repro.train import OptConfig
from repro.train import optimizer as opt_mod

tmap = jax.tree_util.tree_map
F32 = jnp.float32


@dataclasses.dataclass
class Unit:
    name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    mult: float
    seq_scan: bool = False       # two-point correction over the seq axis
    half_args: tuple | None = None


@dataclasses.dataclass
class UnitCost:
    name: str
    flops: float
    bytes_hbm: float
    wire: float
    mult: float


def _lower(unit_fn, args, in_shardings):
    jitted = jax.jit(unit_fn, in_shardings=in_shardings) \
        if in_shardings is not None else jax.jit(unit_fn)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    flops, bytes_hbm = analysis.cost_of(compiled)
    w_out, w_in = analysis.collective_stats_split(compiled.as_text())
    return flops, bytes_hbm, w_out.wire_bytes, w_in.wire_bytes


def measure_units(units: list[Unit]) -> list[UnitCost]:
    out = []
    for u in units:
        f, b, w_out, w_in = _lower(u.fn, u.args, u.in_shardings)
        w = w_out + w_in
        if u.seq_scan and u.half_args is not None:
            # flops/bytes: f(S) = a*S + body_once (everything outside the
            # seq scan is ~linear in S; the scan body is counted once).
            # a*S = 2(f(S)-f(S/2)); true cost = a*S + body*S.
            fh, bh, *_ = _lower(u.fn, u.half_args, u.in_shardings)
            S = _SEQ_OF[id(u)]

            def corrected(full, half):
                a_S = 2.0 * (full - half)
                body = max(full - a_S, 0.0)
                return a_S + body * S

            f = corrected(f, fh)
            b = corrected(b, bh)
            # wire: while-body collectives recur per step; the rest (FSDP
            # param gathers, grad reduces) are S-constant — measured
            # directly from the HLO computation structure, NOT two-point.
            w = w_out + w_in * S
        out.append(UnitCost(u.name, f * u.mult, b * u.mult, w * u.mult,
                            u.mult))
    return out


_SEQ_OF: dict[int, int] = {}


def _named(sh: Shardings, spec_tree):
    if sh.mesh is None:
        return None
    return tmap(lambda s: NamedSharding(sh.mesh, s), spec_tree)


def _dp(shape: ShapeSpec, sh: Shardings):
    from repro.models.api import _dp_axis
    return _dp_axis(shape, sh)


# ---------------------------------------------------------------------------
# Unit builders
# ---------------------------------------------------------------------------

def _layer_sds(cfg: ModelConfig, ffn: str, sh: Shardings):
    tree = tfm._layer_params(cfg, ffn)
    return (sds_params(tree, jnp.dtype(cfg.dtype)),
            _named(sh, partition_specs(tree, sh.rules)))


def _mamba_sds(cfg: ModelConfig, sh: Shardings):
    from repro.models import ssm as ssm_mod
    from repro.models.layers import rms_norm_params
    tree = {"ln1": rms_norm_params(cfg.d_model),
            "mamba": (ssm_mod.mamba1_params(cfg) if cfg.ssm_type == "mamba1"
                      else ssm_mod.mamba2_params(cfg))}
    return (sds_params(tree, jnp.dtype(cfg.dtype)),
            _named(sh, partition_specs(tree, sh.rules)))


def _x_sds(cfg, b, s):
    return jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))


def _grad_block(block, remat: bool):
    f = jax.checkpoint(block) if remat else block

    def loss(lp, x):
        y, aux = f(lp, x)
        # 0.5*||y||^2, NOT sum(y): a constant cotangent lets XLA's algebraic
        # simplifier turn the backward matmuls into plain reductions and the
        # unit undercounts the backward pass by ~3x (verified empirically).
        yf = y.astype(F32)
        return 0.5 * jnp.sum(yf * yf) + aux

    return jax.grad(loss, argnums=(0, 1))


def train_units(cfg: ModelConfig, shape: ShapeSpec, sh: Shardings,
                unroll_attn: bool = True) -> list[Unit]:
    mb = cfg.microbatches_train
    b_mb = shape.global_batch // mb
    S = shape.seq_len
    dp = _dp(shape, sh)
    x_sh = NamedSharding(sh.mesh, P(dp, None, None)) if sh.mesh else None
    units: list[Unit] = []

    def attn_block(ffn, use_mla):
        def block(lp, x):
            y, aux, _ = tfm._attn_ffn_fwd(lp, x, cfg, sh, use_mla=use_mla,
                                          ffn=ffn, chunk=512,
                                          unroll=unroll_attn)
            return y, aux
        return block

    def mamba_block(lp, x):
        y, _ = tfm._mamba_fwd(lp, x, cfg, sh, None)
        return y, jnp.zeros((), F32)

    def add_layer_unit(name, block, ptree, psh, mult, seq_scan=False):
        fn = _grad_block(block, cfg.remat)
        args = (ptree, _x_sds(cfg, b_mb, S))
        u = Unit(name, fn, args, (psh, x_sh) if sh.mesh else None, mult,
                 seq_scan=seq_scan,
                 half_args=(ptree, _x_sds(cfg, b_mb, S // 2))
                 if seq_scan else None)
        if seq_scan:
            _SEQ_OF[id(u)] = S
        units.append(u)

    fam = cfg.family
    if fam in ("dense", "audio"):
        pt, psh = _layer_sds(cfg, "mlp", sh)
        add_layer_unit("layer", attn_block("mlp", False), pt, psh,
                       cfg.num_layers * mb)
    elif fam == "moe":
        if cfg.first_dense_layers:
            pt, psh = _layer_sds(cfg, "mlp", sh)
            add_layer_unit("dense_layer", attn_block("mlp", cfg.use_mla),
                           pt, psh, cfg.first_dense_layers * mb)
        pt, psh = _layer_sds(cfg, "moe", sh)
        n_moe = cfg.num_layers - cfg.first_dense_layers
        add_layer_unit("moe_layer", attn_block("moe", cfg.use_mla), pt, psh,
                       (n_moe + cfg.mtp_depth) * mb)
    elif fam == "vlm":
        pt, psh = _layer_sds(cfg, "mlp", sh)
        add_layer_unit("layer", attn_block("mlp", False), pt, psh,
                       cfg.num_layers * mb)
        n_groups = cfg.num_layers // cfg.cross_attn_every
        from repro.models import attention as attn_mod
        from repro.models.layers import mlp, mlp_params, rms_norm_params
        ctree = {"ln": tfm.rms_norm_params(cfg.d_model),
                 "xattn": attn_mod.cross_attn_params(cfg),
                 "ln2": tfm.rms_norm_params(cfg.d_model),
                 "mlp": mlp_params(cfg)}
        csds = sds_params(ctree, jnp.dtype(cfg.dtype))
        cpsh = _named(sh, partition_specs(ctree, sh.rules))
        img_sds = jax.ShapeDtypeStruct(
            (b_mb, cfg.num_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))

        def cross_block(cp, x, img):
            h = attn_mod.cross_attn_forward(cp["xattn"],
                                            rms_norm(cp["ln"], x), img, cfg)
            x = x + h
            return x + mlp(cp["mlp"], rms_norm(cp["ln2"], x), cfg), \
                jnp.zeros((), F32)

        def loss(cp, x, img):
            f = jax.checkpoint(cross_block) if cfg.remat else cross_block
            y, aux = f(cp, x, img)
            yf = y.astype(F32)
            return 0.5 * jnp.sum(yf * yf) + aux   # see _grad_block note

        fn = jax.grad(loss, argnums=(0, 1, 2))
        units.append(Unit("cross_block", fn,
                          (csds, _x_sds(cfg, b_mb, S), img_sds),
                          (cpsh, x_sh,
                           NamedSharding(sh.mesh, P(dp, None, None)))
                          if sh.mesh else None,
                          n_groups * mb))
    elif fam == "ssm":
        pt, psh = _mamba_sds(cfg, sh)
        add_layer_unit("mamba_layer", mamba_block, pt, psh,
                       cfg.num_layers * mb, seq_scan=True)
    elif fam == "hybrid":
        pt, psh = _mamba_sds(cfg, sh)
        add_layer_unit("mamba_layer", mamba_block, pt, psh,
                       cfg.num_layers * mb, seq_scan=True)
        at, ash = _layer_sds(cfg, "mlp", sh)
        n_groups = cfg.num_layers // cfg.attn_every
        add_layer_unit("shared_attn", attn_block("mlp", False), at, ash,
                       n_groups * mb)

    # embed + head + CE (grad), once per microbatch
    etree = {"embed": tfm.embed_params(cfg),
             "final_ln": tfm.rms_norm_params(cfg.d_model)}
    esds = sds_params(etree, jnp.dtype(cfg.dtype))
    esh = _named(sh, partition_specs(etree, sh.rules))
    tok_sds = jax.ShapeDtypeStruct(
        (b_mb, S, cfg.num_codebooks) if cfg.num_codebooks else (b_mb, S),
        jnp.int32)
    tok_sh = NamedSharding(sh.mesh, P(dp, None, None)
                           if cfg.num_codebooks else P(dp, None)) \
        if sh.mesh else None

    def eh_loss(ep, tokens):
        x = embed(ep["embed"], tokens, cfg)
        h = rms_norm(ep["final_ln"], x)
        lg = logits(ep["embed"], h[:, :-1], cfg)
        return cross_entropy(lg, tokens[:, 1:])

    units.append(Unit("embed_head", jax.grad(eh_loss), (esds, tok_sds),
                      (esh, tok_sh) if sh.mesh else None, mb))

    # optimizer update, once
    model = build_model(cfg)
    params_sds = model.sds()
    psh_full = _named(sh, model.pspecs(sh.rules))
    ocfg = OptConfig(state_dtype=cfg.opt_state_dtype)
    opt_sds = jax.eval_shape(lambda p: opt_mod.init(p, ocfg), params_sds)
    grads_sds = tmap(lambda p: jax.ShapeDtypeStruct(p.shape, F32), params_sds)

    def opt_fn(g, s, p):
        np_, ns, _ = opt_mod.update(g, s, p, ocfg)
        return np_, ns

    opt_in_sh = ((psh_full,
                  opt_mod.OptState(
                      step=NamedSharding(sh.mesh, P()), m=psh_full,
                      v=psh_full),
                  psh_full) if sh.mesh else None)
    units.append(Unit("optimizer", opt_fn, (grads_sds, opt_sds, params_sds),
                      opt_in_sh, 1.0))
    return units


def serve_units(cfg: ModelConfig, shape: ShapeSpec, sh: Shardings,
                unroll_attn: bool = True) -> list[Unit]:
    """Units for prefill (full-seq forward) or decode (1 token vs cache)."""
    from repro.models.api import cache_shardings, cache_sds

    B, S = shape.global_batch, shape.seq_len
    dp = _dp(shape, sh)
    units: list[Unit] = []
    decode = shape.kind == "decode"
    x_s = _x_sds(cfg, B, 1 if decode else S)
    x_sh = NamedSharding(sh.mesh, P(dp, None, None)) if sh.mesh else None
    cur_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    cur_sh = NamedSharding(sh.mesh, P(dp)) if sh.mesh else None

    full_cache = cache_sds(cfg, shape)
    full_csh = cache_shardings(cfg, shape, sh)

    def slice_cache(tree, spec_tree, strip: int):
        sds = tmap(lambda a: jax.ShapeDtypeStruct(a.shape[strip:], a.dtype),
                   tree)
        nsh = tmap(lambda s: NamedSharding(sh.mesh, P(*s[strip:])),
                   spec_tree) if sh.mesh else None
        return sds, nsh

    def add(name, fn, args, in_sh, mult, seq_scan=False, half_args=None):
        u = Unit(name, fn, args, in_sh if sh.mesh else None, mult,
                 seq_scan=seq_scan, half_args=half_args)
        if seq_scan:
            _SEQ_OF[id(u)] = S
        units.append(u)

    fam = cfg.family

    def attn_stack_unit(stack_key, ffn, use_mla, mult):
        pt, psh = _layer_sds(cfg, ffn, sh)
        if decode:
            csds, csh = slice_cache(full_cache[stack_key],
                                    full_csh[stack_key], 1)

            def fn(lp, lc, x, cur):
                return tfm._attn_ffn_decode(lp, x, cfg, lc, cur,
                                            use_mla=use_mla, ffn=ffn, sh=sh)

            add(f"{stack_key}_decode", fn, (pt, csds, x_s, cur_sds),
                (psh, csh, x_sh, cur_sh), mult)
        else:
            def fn(lp, x):
                y, aux, kv = tfm._attn_ffn_fwd(lp, x, cfg, sh,
                                               use_mla=use_mla, ffn=ffn,
                                               chunk=512, unroll=unroll_attn,
                                               collect_kv=True)
                return y, kv

            add(f"{stack_key}_fwd", fn, (pt, x_s), (psh, x_sh), mult)

    if fam in ("dense", "audio"):
        attn_stack_unit("layers", "mlp", False, cfg.num_layers)
    elif fam == "moe":
        if cfg.first_dense_layers:
            attn_stack_unit("dense_layers", "mlp", cfg.use_mla,
                            cfg.first_dense_layers)
        attn_stack_unit("moe_layers", "moe", cfg.use_mla,
                        cfg.num_layers - cfg.first_dense_layers)
    elif fam == "vlm":
        attn_stack_unit("layers", "mlp", False, cfg.num_layers)
        # cross blocks: decode reads cached cross kv; prefill computes it.
        # counted inside the full-step remainder for simplicity (8 small
        # blocks; <2% of cell flops) — noted in EXPERIMENTS.md.
    elif fam in ("ssm", "hybrid"):
        key = "ssm" if fam == "ssm" else "ssm_groups"
        pt, psh = _mamba_sds(cfg, sh)
        mult = cfg.num_layers
        if decode:
            if fam == "ssm":
                csds, csh = slice_cache(full_cache["ssm"], full_csh["ssm"], 1)
            else:
                csds, csh = slice_cache(full_cache["ssm_groups"],
                                        full_csh["ssm_groups"], 2)

            def fn(lp, lc, x):
                y, nc = tfm._mamba_fwd(lp, x, cfg, sh, lc)
                return y, nc

            add("mamba_decode", fn, (pt, csds, x_s), (psh, csh, x_sh), mult)
        else:
            def fn(lp, x):
                y, _ = tfm._mamba_fwd(lp, x, cfg, sh, None)
                return y

            half = (pt, _x_sds(cfg, B, S // 2))
            add("mamba_fwd", fn, (pt, x_s), (psh, x_sh), mult,
                seq_scan=True, half_args=half)
        if fam == "hybrid":
            attn_stack_unit("attn_kv", "mlp", False,
                            cfg.num_layers // cfg.attn_every)

    # head: final norm + last-position logits (prefill) or 1-token logits
    etree = {"embed": tfm.embed_params(cfg),
             "final_ln": tfm.rms_norm_params(cfg.d_model)}
    esds = sds_params(etree, jnp.dtype(cfg.dtype))
    esh = _named(sh, partition_specs(etree, sh.rules))

    def head_fn(ep, x):
        h = rms_norm(ep["final_ln"], x[:, -1:])
        return logits(ep["embed"], h, cfg)

    add("head", head_fn, (esds, x_s), (esh, x_sh), 1.0)
    return units


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS
# ---------------------------------------------------------------------------

def active_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total_params, active_params_per_token)."""
    from repro.models.params import count_params
    tree = tfm.param_tree(cfg)
    total = count_params(tree)
    if cfg.family != "moe":
        return total, total
    # replace expert count by (shared + topk) experts' worth
    from repro.models.params import PSpec
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=lambda x: isinstance(x, PSpec))
    active = 0
    for l in leaves:
        n = math.prod(l.shape)
        if len(l.shape) >= 3 and l.shape[-3] == cfg.num_experts and \
                l.axes[-3] == "ep":
            n = n // cfg.num_experts * cfg.num_experts_per_token
        active += n
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeSpec, chips: int) -> float:
    """6*N*D (train) / 2*N*D (forward-only), N = active params, per device."""
    total, active = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    factor = 6.0 if shape.kind == "train" else 2.0
    if cfg.family == "moe" and cfg.mtp_depth and shape.kind == "train":
        factor *= (cfg.num_layers + cfg.mtp_depth) / cfg.num_layers
    return factor * active * tokens / chips


# ---------------------------------------------------------------------------
# Analytic HBM byte model (TPU-achievable bound)
# ---------------------------------------------------------------------------
# The HLO "bytes accessed" from this container's CPU-compiled modules counts
# every unfused elementwise producer/consumer round trip; a TPU fuses those
# into VMEM. This model counts only traffic that MUST hit HBM on a TPU:
#   * parameter reads (x3 for train: fwd + remat recompute + bwd; x1 serve)
#   * gradient accumulate read/write (fp32) per microbatch + optimizer io
#   * one activation checkpoint write+read per layer boundary (remat policy)
#     plus a C_ACT x d_model per-token working-set spill allowance
#   * logits/embedding io, KV-cache read (+1-token write) for decode
# Coefficients are deliberately explicit & conservative; EXPERIMENTS.md cites
# this docstring as the memory-term methodology.

C_ACT_TRAIN = 12.0     # bytes/token/layer multiplier on d_model (bf16 rw x3 passes)
C_ACT_FWD = 6.0        # forward-only working set


def _mesh_factors(sh: Shardings):
    if sh.mesh is None:
        return 1, 1, 1
    sizes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))
    tp = sizes.get("model", 1)
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    return dp, tp, dp * tp


def _expert_bytes(cfg: ModelConfig) -> float:
    if not cfg.num_experts:
        return 0.0
    wi_cols = 2 * cfg.moe_d_ff if cfg.activation == "swiglu" else cfg.moe_d_ff
    per_expert = cfg.d_model * (wi_cols + cfg.moe_d_ff) * 2.0
    n_moe = cfg.num_layers - cfg.first_dense_layers + cfg.mtp_depth
    return per_expert * cfg.num_experts * n_moe


def _weights_bytes_per_dev(cfg: ModelConfig, sh: Shardings,
                           active_only: bool) -> float:
    """Parameter bytes resident/read per device under the ACTIVE rules
    (fsdp may be dropped and ep widened for serving — §Perf)."""
    dp, tp, chips = _mesh_factors(sh)
    total, active = active_params(cfg)
    pb = 2.0
    exp_total = _expert_bytes(cfg)
    exp_active = exp_total / max(cfg.num_experts, 1) * \
        (cfg.num_experts_per_token + cfg.num_shared_experts) \
        if cfg.num_experts else 0.0
    dense = total * pb - exp_total
    dense_div = tp * (dp if sh.rules.get("fsdp") is not None else 1)
    ep = sh.rules.get("ep")
    ep_axes = ep if isinstance(ep, tuple) else (ep,) if ep else ()
    sizes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape)) \
        if sh.mesh else {}
    ep_div = 1
    for a in ep_axes:
        ep_div *= sizes.get(a, 1)
    # decode reads every expert resident on the device (B*topk >> E/dev);
    # training/prefill touch active experts' worth of flops but all weights
    exp_term = exp_total / max(ep_div, 1)
    if active_only and cfg.num_experts:
        exp_term = min(exp_term, exp_active)
    return dense / dense_div + exp_term


def analytic_bytes(cfg: ModelConfig, shape: ShapeSpec, sh: Shardings) -> float:
    """Per-device HBM bytes for one step (see module comment)."""
    from repro.models.api import cache_sds

    dp, tp, chips = _mesh_factors(sh)
    total, active = active_params(cfg)
    pbytes = 2.0
    p_dev = total * pbytes / chips
    tokens = shape.global_batch * shape.seq_len
    tokens_dp = tokens / dp
    d = cfg.d_model
    v_tp = cfg.vocab_size / tp * max(cfg.num_codebooks, 1)
    L = cfg.num_layers

    if shape.kind == "train":
        mb = cfg.microbatches_train
        sbytes = 4.0 if cfg.opt_state_dtype == "float32" else 2.0
        weights = 3.0 * mb * p_dev
        grads = 2.0 * mb * total * 4.0 / chips
        optim = total / chips * (6.0 * sbytes + 2.0 * pbytes + 4.0)
        acts = tokens_dp * d * C_ACT_TRAIN * L / 1.0
        logits_io = tokens_dp * v_tp * 2.0 * 3.0
        embed_io = tokens_dp * d * 2.0 * 2.0
        return weights + grads + optim + acts + logits_io + embed_io

    if shape.kind == "prefill":
        weights = _weights_bytes_per_dev(cfg, sh, active_only=False)
        acts = tokens_dp * d * C_ACT_FWD * L
        cache = sum(x.size * x.dtype.itemsize
                    for x in jax.tree_util.tree_leaves(cache_sds(cfg, shape)))
        return weights + acts + cache / chips + tokens_dp * d * 2.0

    # decode: weights once + full cache read (+tiny write) + head
    weights = _weights_bytes_per_dev(cfg, sh, active_only=False)
    cache = sum(x.size * x.dtype.itemsize
                for x in jax.tree_util.tree_leaves(cache_sds(cfg, shape)))
    head = shape.global_batch / dp * v_tp * 2.0
    return weights + cache / chips + head

"""Roofline machinery: HLO collective parsing + 3-term derivation.

Methodology notes (validated empirically in this repo):

  * `compiled.cost_analysis()` on an SPMD-partitioned module reports
    PER-PARTITION (= per-device) flops/bytes — post-partitioning shapes.
  * XLA's HloCostAnalysis counts a while/scan BODY ONCE regardless of trip
    count. All repro models scan over layers/microbatches/kv-chunks, so raw
    full-step numbers undercount by ~the layer count. The fix implemented
    here (roofline/units.py): lower each scanned UNIT standalone and
    multiply by its static trip count; units containing an interior
    sequence scan (Mamba) use a two-point linearization — lower at S and
    S/2, where f(S) = a*S + b has b ~= (scan-body-counted-once), so the
    corrected cost is (a + b) * S.
  * Collective wire bytes are not in cost_analysis: we parse the post-SPMD
    HLO text and apply ring cost factors per op (all-reduce 2(g-1)/g x out,
    all-gather (g-1)/g x out, reduce-scatter (g-1) x out, all-to-all
    (g-1)/g x out, collective-permute 1 x out), with g parsed from
    replica_groups (explicit or iota form).
"""
from __future__ import annotations

import dataclasses
import re

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return int(m.group(2))                      # [G, S]<=[N]: groups of S
    m = _GROUPS_LIST_RE.search(line)
    if m:
        body = m.group(1).strip()
        return len(body.split(",")) if body else 1
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                          # per-device, ring model
    by_op: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, op: str, wire: float):
        self.wire_bytes += wire
        self.by_op[op] = self.by_op.get(op, 0.0) + wire
        self.count += 1


_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")


def _wire_of(line: str, default_group: int):
    m = _COLL_RE.search(line)
    if not m:
        return None
    type_str, op, _ = m.groups()
    b = shape_bytes(type_str)
    g = _group_size(line, default_group)
    if g <= 1:
        return op, 0.0
    if op == "all-reduce":
        wire = 2.0 * b * (g - 1) / g
    elif op == "all-gather":
        wire = b * (g - 1) / g
    elif op == "reduce-scatter":
        wire = b * (g - 1)                           # out is the shard
    elif op == "all-to-all":
        wire = b * (g - 1) / g
    else:                                            # collective-permute
        wire = float(b)
    return op, wire


def collective_stats(hlo_text: str, default_group: int = 16) -> CollectiveStats:
    """Per-device wire bytes from post-SPMD HLO (while/scan bodies counted
    once — callers multiply by trip counts; see collective_stats_split)."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        ow = _wire_of(line, default_group)
        if ow:
            st.add(*ow)
    return st


def collective_stats_split(hlo_text: str, default_group: int = 16):
    """(outside, inside_while) collective stats. Collectives that live in a
    while-body computation recur once per trip; everything else is once per
    call. Needed because scan-body wire must be scaled by the trip count
    while S-constant traffic (FSDP param gathers) must NOT be."""
    bodies = set(re.findall(r"body=%?([\w.\-]+)", hlo_text))
    outside, inside = CollectiveStats(), CollectiveStats()
    cur = None
    for line in hlo_text.splitlines():
        if line.rstrip().endswith("{"):
            m = _COMP_START_RE.match(line.strip())
            if m:
                cur = m.group(1)
        ow = _wire_of(line, default_group)
        if ow:
            (inside if cur in bodies else outside).add(*ow)
    return outside, inside


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float             # from the ANALYTIC byte model (TPU-achievable)
    collective_s: float
    flops: float                # per device
    bytes_hbm: float            # analytic bytes, per device
    wire_bytes: float           # per device
    model_flops: float = 0.0    # 6ND-style analytic, per device
    memory_hlo_s: float = 0.0   # pessimistic bound from CPU-backend HLO
    bytes_hlo: float = 0.0      # (CPU fuses far less than TPU; see DESIGN)

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """How close the *useful* model flops are to the peak achievable on
        the dominant resource: model_flops/peak divided by the bound time."""
        if self.bound_s <= 0:
            return 0.0
        ideal = self.model_flops / hw.PEAK_FLOPS_BF16
        return ideal / self.bound_s

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0


def terms(flops: float, bytes_hbm: float, wire_bytes: float,
          model_flops: float = 0.0, bytes_hlo: float = 0.0) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / hw.PEAK_FLOPS_BF16,
        memory_s=bytes_hbm / hw.HBM_BW,
        collective_s=wire_bytes / hw.COLLECTIVE_BW,
        flops=flops, bytes_hbm=bytes_hbm, wire_bytes=wire_bytes,
        model_flops=model_flops,
        memory_hlo_s=bytes_hlo / hw.HBM_BW, bytes_hlo=bytes_hlo)


def cost_of(compiled) -> tuple[float, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def memory_of(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = getattr(ma, k, None)
    return out

"""Assemble EXPERIMENTS.md tables from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os


def load_cells(d: str = "experiments/dryrun"):
    cells = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        rec = json.load(open(f))
        key = (rec.get("arch"), rec.get("shape"), rec.get("mesh"))
        cells[key] = rec
    return cells


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def dryrun_table(cells) -> str:
    rows = ["| arch | shape | mesh | compile | args/dev | temp/dev | "
            "collectives (once) | wire/dev (once) |",
            "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if not r.get("ok"):
            rows.append(f"| {arch} | {shape} | {mesh} | **FAIL** | | | | |")
            continue
        mem = r.get("memory", {})
        fo = r.get("full_step_once", {})
        rows.append(
            f"| {arch} | {shape} | {mesh} | {r.get('compile_s')}s "
            f"| {fmt_bytes(mem.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(mem.get('temp_size_in_bytes'))} "
            f"| {fo.get('collective_count', '-')} "
            f"| {fmt_bytes(fo.get('wire_bytes'))} |")
    return "\n".join(rows)


def roofline_table(cells) -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "bound | MODEL_FLOPs/dev | useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if mesh != "16x16" or "roofline" not in r or not r.get("ok"):
            continue
        t = r["roofline"]
        rows.append(
            f"| {arch} | {shape} | {fmt_s(t['compute_s'])} "
            f"| {fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} "
            f"| **{t['dominant']}** | {fmt_s(t['bound_s'])} "
            f"| {t['model_flops']:.3e} | {t['useful_ratio']:.2f} "
            f"| {t['roofline_fraction']*100:.1f}% |")
    return "\n".join(rows)


def pick_hillclimb(cells):
    """worst roofline fraction, most collective-bound, paper-representative."""
    lm = {k: v for k, v in cells.items()
          if k[2] == "16x16" and v.get("ok") and "roofline" in v
          and not k[0].startswith("paper-")}
    worst = min(lm, key=lambda k: lm[k]["roofline"]["roofline_fraction"])
    coll = max(lm, key=lambda k: (lm[k]["roofline"]["collective_s"]
                                  / max(lm[k]["roofline"]["bound_s"], 1e-12)))
    return worst, coll


if __name__ == "__main__":
    cells = load_cells()
    print(dryrun_table(cells))
    print()
    print(roofline_table(cells))
    print()
    print("hillclimb picks:", pick_hillclimb(cells))

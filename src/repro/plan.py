"""The planner layer: ``SolveSpec`` (intent) -> ``ExecutionPlan`` (decisions)
-> ``Result`` (iterate + certificates + timings).

The paper's pitch is that the *system* picks the execution design: the user
states ``min f(x) s.t. Ax = b`` and the platform chooses the storage format,
kernels, and distribution strategy (MR1-MR4 / the Spark dual-RDD trick) for
them.  This module is that planner for the repo:

  * it reuses the roofline format selector (``repro.operators.select``) to
    pick ELL vs tiled BCSR vs dense from matrix statistics,
  * it estimates the Lipschitz constant when the caller has none — the
    paper's exact ``Lg = sum_i ||A_i||^2`` when values are available, power
    iteration (``repro.core.solver.estimate_lg``) for matrix-free operators,
  * and it compiles the choice down to the kernel-layer drivers it leaves
    untouched: ``core.solver.solve/solve_tol`` (single device),
    ``core.distributed.make_solve_fn/make_solve_tol_fn`` (shard_map
    strategies), and the batched serving engine (via ``repro.api.solve_many``).

Every decision lands in an inspectable ``ExecutionPlan`` with a one-line
reason per choice; ``plan.override(...)`` swaps any decision and re-solves,
which is how the equivalence tests pin every emittable plan to the same
iterates.  The reason contract is enforced by lint rule R6
(``repro.analysis.rules``): every ``return`` in a ``decide_*`` function
must be a tuple ending in a reason string, so no decision path goes dark.

>>> import numpy as np
>>> from repro.api import Problem
>>> p = Problem(np.diag([2.0, 2.0, 2.0]).astype(np.float32),
...             np.ones(3, np.float32), prox="zero")
>>> pl = p.plan(iterations=300, gamma0=1.0)
>>> (pl.algorithm, pl.format, pl.backend, pl.execution)
('a2', 'dense', 'jnp', 'single')
>>> [round(float(v), 2) for v in pl.solve().x]   # min 0 s.t. 2x = 1
[0.5, 0.5, 0.5]
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np

__all__ = ["ExecutionPlan", "Result", "SolveSpec", "bucket_operand_bytes",
           "decide_admission", "decide_bucket_body", "decide_check_every",
           "decide_placement", "decide_solver_family", "grid_shapes", "plan",
           "sharded_bucket_bytes", "sharded_wire_bytes", "sharding_ndev"]


@dataclasses.dataclass(frozen=True)
class SolveSpec:
    """Caller intent — *what* to solve for, not *how*.

    Every field has a planner default; anything set explicitly is honored
    and recorded as a user override in the plan's reasons.

    tol=None means a fixed ``iterations`` budget (``core.solver.solve``
    semantics); tol set means early exit on relative feasibility
    (``solve_tol`` semantics, capped at ``max_iterations``).
    """

    algorithm: str = "auto"              # "a1" | "a2" | "auto"
    solver_family: str = "auto"          # "a1"|"a2"|"rcd_primal"|"rcd_dual"
    #                                      |"auto" (face-off rule decides)
    tol: Optional[float] = None
    iterations: int = 300
    max_iterations: int = 10_000
    check_every: Optional[int] = None    # None -> planner default
    format: str = "auto"                 # "dense"|"coo"|"ell"|"bcsr"|"auto"
    backend: str = "auto"                # "jnp"|"pallas"|"auto"
    strategy: Optional[str] = None       # distributed strategy name
    mesh: Any = None                     # jax Mesh (hint for strategies)
    gamma0: Optional[float] = None
    c: float = 3.0
    lg: Optional[float] = None
    lg_method: str = "auto"              # "auto"|"frobenius"|"power"
    record_every: int = 0
    batch: str = "auto"                  # "auto"|"never" (solve_many policy)
    slots: int = 8                       # engine slot count (solve_many)
    interpret: Optional[bool] = None     # Pallas interpret-mode override
    placement: str = "auto"              # "auto"|"single"|"replicated"|"sharded"
    devices: Any = None                  # engine devices (count or list)
    shard_above: Optional[int] = None    # per-device nnz capacity override
    format_params: dict = dataclasses.field(default_factory=dict)


def resolve_spec(spec: SolveSpec | None, overrides: dict) -> SolveSpec:
    """spec + keyword overrides -> one SolveSpec (overrides win)."""
    if spec is None:
        return SolveSpec(**overrides)
    return dataclasses.replace(spec, **overrides) if overrides else spec


@dataclasses.dataclass
class Result:
    """What a solve hands back: the iterate plus its evidence.

    x            primal iterate (xbar), trimmed to the problem's n.
    iterations   iterations executed (the solver's k).
    feasibility  relative feasibility ||A x - b|| / max(1, ||b||) — the
                 paper's stopping criterion, evaluated host-side.
    objective    f(x) from the prox's value function.
    timings      dict(build_s, solve_s, total_s) wall-clock seconds; the
                 first solve of a shape includes compile time in solve_s.
    state        final PDState (None for engine-batched results).
    history      per-record feasibility/objective when record_every was set.
    plan         the ExecutionPlan that produced this result.
    """

    x: Any
    plan: "ExecutionPlan"
    iterations: int
    feasibility: float
    objective: float
    timings: dict
    state: Any = None
    history: Optional[dict] = None
    _certs: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)

    def certificates(self) -> dict:
        """Convergence certificates from ``repro.core.gap`` (smoothed gap,
        absolute feasibility, objective) for the final state; computed
        lazily on the jnp reference operator and cached."""
        if self._certs is None:
            if self.state is None:
                raise ValueError(
                    "no solver state attached (engine-batched results carry "
                    "only the iterate); re-solve via Problem.solve for "
                    "certificates")
            from repro.core.gap import certificates as _certificates

            prob, p = self.plan.problem, self.plan
            ops = prob.reference_ops()
            out = _certificates(ops, prob.prox, prob.b, p.lg, p.gamma0,
                                self.state, c=p.spec.c,
                                algorithm=p.algorithm)
            self._certs = {k: float(v) for k, v in out.items()}
        return self._certs

    @property
    def gap(self) -> Optional[float]:
        """Smoothed-gap certificate G_{gamma,beta} (None when no state)."""
        return None if self.state is None else self.certificates()["gap"]


@dataclasses.dataclass
class ExecutionPlan:
    """The planner's decisions, inspectable and overridable.

    ``execution`` is "single" (registry operator + core.solver drivers),
    "distributed" (shard_map strategy via core.distributed), or "engine"
    (slot-batched serving via repro.api.solve_many — such plans describe a
    shared engine run and are not individually solvable).

    ``placement`` is the serving-placement decision (how this problem
    should land on a device mesh): "single" (whole problem on one device),
    "replicated" (whole problem per device — engine buckets are pinned
    round-robin so independent buckets advance concurrently), or "sharded"
    (operands partitioned mesh-wide; the engine admits such requests into
    shard_map'd buckets, and a direct ``.solve()`` runs a distributed
    strategy).  ``reasons`` maps each decision to a one-line why;
    ``estimates`` carries the roofline selector's modeled per-apply seconds
    when it ran.
    """

    problem: Any
    spec: SolveSpec
    execution: str
    algorithm: str
    format: str
    backend: str
    strategy: Optional[str]
    mesh: Any
    lg: float
    gamma0: float
    params: dict
    reasons: dict
    estimates: Optional[dict] = None
    placement: str = "single"
    check_every: int = 16
    _op: Any = dataclasses.field(default=None, repr=False, compare=False)

    def __repr__(self):
        shape = ("?" if self.problem is None
                 else f"{self.problem.m}x{self.problem.n}")
        mode = self.execution if self.strategy is None \
            else f"{self.execution}:{self.strategy}"
        return (f"ExecutionPlan({mode}, problem={shape}, "
                f"algorithm={self.algorithm!r}, format={self.format!r}, "
                f"backend={self.backend!r}, lg={self.lg:.6g}, "
                f"gamma0={self.gamma0:.6g}, params={self.params!r})")

    def explain(self) -> str:
        """Human-readable decision table (one line per choice + reason)."""
        rows = [("execution", self.execution), ("placement", self.placement),
                ("algorithm", self.algorithm),
                ("format", self.format), ("backend", self.backend),
                ("strategy", self.strategy), ("lg", f"{self.lg:.6g}"),
                ("gamma0", f"{self.gamma0:.6g}"),
                ("check_every", self.check_every)]
        lines = []
        for key, choice in rows:
            why = self.reasons.get(key, "")
            lines.append(f"{key:10s} = {str(choice):14s} {why}")
        if self.estimates:
            modeled = "  ".join(f"{k}={v['s']:.3g}s"
                                for k, v in self.estimates.items())
            lines.append(f"{'modeled':10s} = {modeled}")
        return "\n".join(lines)

    def override(self, **changes) -> "ExecutionPlan":
        """A new plan with some decisions (or spec fields) replaced; all
        other choices are kept, so overridden plans stay comparable to the
        planner's pick.  Setting/clearing ``strategy`` flips between the
        distributed and single-device executions."""
        plan_fields = {f.name for f in dataclasses.fields(ExecutionPlan)
                       if f.init and f.name not in ("problem", "spec")}
        spec_fields = {f.name for f in dataclasses.fields(SolveSpec)}
        pc = {k: v for k, v in changes.items() if k in plan_fields}
        sc = {k: v for k, v in changes.items() if k not in plan_fields}
        unknown = [k for k in sc if k not in spec_fields]
        if unknown:
            raise TypeError(f"unknown plan/spec fields: {unknown}")
        spec = dataclasses.replace(self.spec, **sc) if sc else self.spec
        if "solver_family" in sc:
            # the family pins algorithm/format/placement together — re-run
            # the planner at the new spec so they stay consistent (plan()
            # records the explicit family as a user override)
            replanned = plan(self.problem, spec)
            return dataclasses.replace(replanned, **pc) if pc else replanned
        new = dataclasses.replace(self, spec=spec, **pc, _op=None,
                                  reasons={**self.reasons,
                                           **{k: "user override"
                                              for k in changes}})
        if "strategy" in pc or "mesh" in pc:
            # mirror plan()'s semantics: a mesh is a distributed hint
            # (defaulting to dualpart), and strategies need matrix values;
            # an explicit strategy in this call (including None) wins
            if "strategy" not in pc and new.mesh is not None \
                    and new.strategy is None:
                new.strategy = "dualpart"
            new.execution = "distributed" if new.strategy else "single"
            new.placement = "sharded" if new.strategy else "single"
            if new.execution == "distributed" and new.problem.coo is None:
                raise ValueError(
                    "distributed strategies need a concrete matrix "
                    "(COO/dense), not a matrix-free operator")
        return new

    # -- execution ---------------------------------------------------------

    def operator(self):
        """Build (and cache) the LinearOperator this plan runs on."""
        if self._op is None:
            prob = self.problem
            if prob.operator is not None:
                self._op = prob.operator
            elif self.format == "dense":
                import jax.numpy as jnp

                from repro.operators import make_operator
                self._op = make_operator(
                    "dense", "jnp", jnp.asarray(prob.dense_array()))
            else:
                from repro.operators import from_coo
                opts = dict(self.params)
                if self.backend == "pallas" and self.spec.interpret is not None:
                    opts["interpret"] = self.spec.interpret
                # fused prox kernels take a scalar reg; when the Problem's
                # weight is unknown (reg=None: a ProxOp instance with its
                # own closure), withhold the prox so the builder composes
                # the always-correct ProxOp.apply path instead
                kprox, kreg = prob.prox, prob.reg
                if kreg is None:
                    kprox, kreg = None, 0.0
                self._op = from_coo(prob.coo, self.format, self.backend,
                                    prox=kprox, reg=kreg, **opts)
        return self._op

    def solve(self) -> Result:
        """Execute the plan through the kernel layer it compiled to."""
        if self.execution == "engine":
            raise RuntimeError(
                "engine plans describe a shared batched run; execute them "
                "through repro.api.solve_many")
        if self.algorithm in ("rcd_primal", "rcd_dual"):
            return self._solve_rcd()
        import jax

        from repro.core import solver as _solver

        prob, spec = self.problem, self.spec
        t0 = time.perf_counter()
        history = None
        if self.execution == "distributed":
            state, build_s, t1 = self._solve_distributed()
        else:
            ops = self.operator().solver_ops()
            build_s = time.perf_counter() - t0
            t1 = time.perf_counter()
            if spec.tol is None:
                state, history = _solver.solve(
                    ops, prob.prox, prob.b, self.lg, self.gamma0,
                    iterations=spec.iterations, algorithm=self.algorithm,
                    c=spec.c, record_every=spec.record_every)
            else:
                state = _solver.solve_tol(
                    ops, prob.prox, prob.b, self.lg, self.gamma0,
                    max_iterations=spec.max_iterations, tol=spec.tol,
                    algorithm=self.algorithm, c=spec.c,
                    check_every=self.check_every)
            state = jax.block_until_ready(state)
        solve_s = time.perf_counter() - t1
        x = state.xbar
        feas = prob.relative_feasibility(np.asarray(x))
        objective = float(prob.prox.value(x))
        timings = dict(build_s=build_s, solve_s=solve_s,
                       total_s=time.perf_counter() - t0)
        return Result(x=x, plan=self, iterations=int(state.k),
                      feasibility=feas, objective=objective,
                      timings=timings, state=state, history=history)

    def _solve_rcd(self) -> Result:
        """Coordinate-descent execution (``repro.solvers.rcd_solve_tol``
        over the CSC operand pair).  ``iterations`` counts EPOCHS;
        ``feasibility`` reports the family's relative fixed-point residual
        (zero exactly at optimality) rather than ||Ax - b|| — ERM losses
        have no linear constraint to be feasible against.  ``objective``
        is the float64 primal objective at the returned iterate."""
        from repro.solvers import rcd_solve_tol, reference_objective

        prob, spec = self.problem, self.spec
        t0 = time.perf_counter()
        coo = prob.coo                       # lazy dense->COO conversion
        build_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        if spec.tol is None:                 # fixed epoch budget
            tol, maxit = 0.0, spec.iterations
        else:
            tol, maxit = float(spec.tol), spec.max_iterations
        x, resid, epochs = rcd_solve_tol(
            coo, prob.b, prob.reg, family=self.algorithm, loss=prob.loss,
            tol=tol, max_iterations=maxit,
            check_every=min(self.check_every, max(1, maxit)),
            kernel="pallas" if self.backend == "pallas" else None,
            interpret=spec.interpret)
        solve_s = time.perf_counter() - t1
        objective = reference_objective(prob.dense_array(),
                                        np.asarray(prob.b), prob.reg,
                                        prob.loss, np.asarray(x))
        timings = dict(build_s=build_s, solve_s=solve_s,
                       total_s=time.perf_counter() - t0)
        return Result(x=x, plan=self, iterations=epochs, feasibility=resid,
                      objective=objective, timings=timings, state=None)

    def _solve_distributed(self):
        import jax
        import jax.numpy as jnp

        from repro.core import distributed as D
        from repro.core.solver import PDState

        prob, spec = self.problem, self.spec
        t0 = time.perf_counter()
        mesh = self.mesh if self.mesh is not None else _default_mesh(
            self.strategy)
        dp = D.build_problem(prob.coo, mesh, self.strategy)
        dp.lg = self.lg                     # honor the plan's (overridable) Lg
        bp = D._pad_to(jnp.asarray(prob.b), dp.m_pad)
        if spec.tol is None:
            fn = D.make_solve_fn(dp, prob.prox, self.gamma0,
                                 spec.iterations, self.algorithm, spec.c)
        else:
            fn = D.make_solve_tol_fn(dp, prob.prox, self.gamma0,
                                     tol=spec.tol,
                                     max_iterations=spec.max_iterations,
                                     algorithm=self.algorithm, c=spec.c,
                                     check_every=self.check_every)
        build_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        state = jax.block_until_ready(fn(dp.operands, bp))
        # trim the partition padding back to the logical problem
        state = PDState(xbar=state.xbar[:prob.n], xstar=state.xstar[:prob.n],
                        yhat=state.yhat[:prob.m], gamma=state.gamma,
                        k=state.k)
        return state, build_s, t1


def _default_mesh(strategy: str):
    import jax
    import numpy as _np
    from jax.sharding import Mesh

    devs = _np.array(jax.devices())
    if strategy == "block2d":
        return Mesh(devs.reshape(1, -1), ("data", "model"))
    return Mesh(devs.reshape(-1), ("p",))


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------

_DENSE_DENSITY = 0.25     # above this, padded sparse formats store >= dense

#: stored entries a single device is assumed to serve comfortably; problems
#: above it are placed "sharded" (mesh-wide operands) when devices exist.
#: Deliberately conservative for real accelerators; tests/benchmarks shrink
#: it (spec.shard_above / the engine's shard_above / env) to exercise the
#: sharded path on fake CPU devices.
_SHARD_ABOVE_NNZ = 4_000_000


def _shard_threshold(shard_above: Optional[int] = None) -> int:
    if shard_above is not None:
        return int(shard_above)
    import os
    env = os.environ.get("REPRO_SHARD_ABOVE_NNZ")
    return int(env) if env else _SHARD_ABOVE_NNZ


def bucket_operand_bytes(fmt: str, slots: int, m_pad: int, n_pad: int,
                         width: int, width_t: int) -> int:
    """Resident operand bytes of ONE single-device serving bucket: both
    orientations at the padded widths, plus b — the unit the engine's
    byte-based ``device_budget`` admits in.

    ell   slots x (m_pad*width + n_pad*width_t) stored entries, 8 B each
          (fp32 val + int32 index) — the row-ELL + transpose-ELL pair.
    csc   the same 8 B/entry arithmetic over the column-major pair the
          coordinate-descent families gather from: CSC(A) is
          (n_pad, width) at width = padded max COLUMN nnz, CSC(A^T) is
          (m_pad, width_t) at the padded max row nnz.
    bcsr  slots x dense (8, min(128, dim)) tiles per orientation
          (``operators.select.bcsr_bytes``): tile zero-fill is real
          storage, so a BCSR bucket can cost many times its ELL twin for
          the same nonzeros — the gap slot-count accounting cannot see.
    """
    from repro.operators.select import _VAL, bcsr_bytes, ell_bytes

    b_bytes = m_pad * _VAL
    if fmt == "ell":
        per_slot = ell_bytes(m_pad, width) + ell_bytes(n_pad, width_t)
    elif fmt == "csc":
        per_slot = ell_bytes(n_pad, width) + ell_bytes(m_pad, width_t)
    elif fmt == "bcsr":
        bm, bn, bn_t = 8, min(128, n_pad), min(128, m_pad)
        per_slot = (bcsr_bytes(-(-m_pad // bm), width, bm, bn)
                    + bcsr_bytes(-(-n_pad // bm), width_t, bm, bn_t))
    else:                                   # dense and friends: the array
        per_slot = 2 * m_pad * n_pad * _VAL
    return slots * (per_slot + b_bytes)


def grid_shapes(ndev: int) -> list[tuple[int, int]]:
    """Every (rows, cols) factorization of ``ndev`` — the gridpart
    candidate set ``decide_bucket_body`` scores (1xN ~ colpart-like,
    Nx1 ~ dualpart-like, the interior points the genuinely 2-D ones)."""
    return [(r, ndev // r) for r in range(1, ndev + 1) if ndev % r == 0]


def sharded_wire_bytes(strategy: str, slots: int, m_pad: int, n_pad: int,
                       ndev: int, grid: tuple[int, int] | None = None
                       ) -> dict:
    """PER-DEVICE collective wire bytes of ONE iteration (forward +
    backward) of a mesh-wide bucket, ring-algorithm model — the same
    per-op factors ``repro.roofline.analysis.collective_stats`` charges
    when it reads the lowered HLO, so the model and the counter agree:

      all-reduce (psum)             2(g-1)/g x full bytes
      all-gather (tiled)             (g-1)/g x full bytes
      reduce-scatter (psum_scatter)  (g-1)/g x full bytes

    rowpart   fwd 0 (x replicated); bwd psum over (S, n).
    dualpart  fwd all_gather(x) over (S, n); bwd psum_scatter over
              (S, n) — the shard-resident-x pair, n + n bytes where the
              old two-all_gather backward moved (m + n) + n.
    gridpart  (R, C) grid: fwd all_gather(x block) over the row axis
              ((S, n/C) at group R) + psum(y) over the column axis
              ((S, m/R) at group C); bwd psum_scatter over the row axis
              ((S, n/C) at group R) — both terms shrink with BOTH axes.

    Returns {"fwd": _, "bwd": _, "total": _} so the planner can price
    and the wire-byte reason can name each direction.
    """
    from repro.operators.select import _VAL

    def _ag(group: int, elems: int) -> int:      # all-gather / RS (tiled)
        return (group - 1) * elems * _VAL // group

    def _ar(group: int, elems: int) -> int:      # all-reduce (psum)
        return 2 * (group - 1) * elems * _VAL // group

    g = ndev
    if strategy == "rowpart":
        fwd, bwd = 0, _ar(g, slots * n_pad)
    elif strategy == "dualpart":
        fwd, bwd = _ag(g, slots * n_pad), _ag(g, slots * n_pad)
    elif strategy == "gridpart":
        R, C = grid
        fwd = _ag(R, slots * (n_pad // C)) + _ar(C, slots * (m_pad // R))
        bwd = _ag(R, slots * (n_pad // C))
    else:
        raise KeyError(f"unknown sharded-bucket strategy {strategy!r}")
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}


def sharded_bucket_bytes(fmt: str, strategy: str, slots: int, m_pad: int,
                         n_pad: int, width: int, width_t: int,
                         ndev: int, grid: tuple[int, int] | None = None
                         ) -> int:
    """PER-DEVICE resident operand bytes of one mesh-wide sharded bucket
    (the geometry ``core.distributed.make_sharded_bucket_fns`` lays out).

    The forward operand is always 1/ndev of the row(-tile) stack.  The
    strategies differ exactly where the byte model can see it:

    rowpart   each shard stores a FULL-n transpose block of its own rows
              (``rowshard_transpose_ell/_bcsr``) — n_pad * width_t per
              shard, i.e. the transpose axis is replicated ndev times
              mesh-wide, in exchange for a psum(n)-only backward.
    dualpart  x is shard-resident and the backward is a scatter +
              psum_scatter, so NO transpose is stored at all — callers
              pass ``width_t=0`` and the at term prices to 0 (the
              zero-width stand-in the engine allocates).
    gridpart  device (i, j) of the (R, C) ``grid`` stores block (i, j)
              ((m/R, n/C) at ``width``) plus its transpose tile
              ((n/C, m/R) at ``width_t``) — both operands shrink with
              both mesh axes; wire cost is priced separately by
              ``sharded_wire_bytes``.
    """
    from repro.operators.select import _VAL, bcsr_bytes, ell_bytes

    if strategy == "gridpart":
        R, C = grid
        mb, nb = m_pad // R, n_pad // C
        b_bytes = mb * _VAL
        if fmt == "ell":
            a = ell_bytes(mb, width)
            at = ell_bytes(nb, width_t)
        else:
            bm = 8
            a = bcsr_bytes(mb // bm, width, bm, min(128, nb))
            at = bcsr_bytes(-(-nb // bm), width_t, bm, min(128, mb))
        return slots * (a + at + b_bytes)
    b_bytes = (m_pad // ndev) * _VAL
    if fmt == "ell":
        a = ell_bytes(m_pad // ndev, width)
        at = (ell_bytes(n_pad, width_t) if strategy == "rowpart"
              else ell_bytes(-(-n_pad // ndev), width_t))
    else:
        bm, bn = 8, min(128, n_pad)
        a = bcsr_bytes(m_pad // (bm * ndev), width, bm, bn)
        nbt = -(-n_pad // bm)
        if strategy == "rowpart":
            at = bcsr_bytes(nbt, width_t, bm, min(128, m_pad // ndev))
        else:
            at = bcsr_bytes(-(-nbt // ndev), width_t, bm, min(128, m_pad))
    return slots * (a + at + b_bytes)


def _bucket_body_score(fmt: str, strategy: str, m_pad: int, n_pad: int,
                       w: int, wt: int, ndev: int, check_every: int,
                       grid: Optional[tuple[int, int]] = None):
    """(resident_bytes, wire_dict, total_score) of one bucket-body
    candidate — resident operand bytes plus ``check_every`` iterations of
    collective wire bytes, the unit ``decide_bucket_body`` minimizes."""
    resident = sharded_bucket_bytes(fmt, strategy, 1, m_pad, n_pad, w, wt,
                                    ndev, grid=grid)
    wire = sharded_wire_bytes(strategy, 1, m_pad, n_pad, ndev, grid=grid)
    return resident, wire, resident + check_every * wire["total"]


def decide_bucket_body(fmt: str, m_pad: int, n_pad: int, width: int,
                       width_t_rowpart: int, width_t_dualpart: int,
                       ndev: int, override: Optional[str] = None,
                       grid_widths: Optional[dict] = None,
                       ) -> tuple[str, Optional[tuple[int, int]], int, str]:
    """The sharded-bucket body decision: (strategy, grid,
    bytes_per_device, reason); ``grid`` is the chosen (rows, cols)
    sub-mesh shape for gridpart and None for the 1-D strategies.  Shared
    between ``plan()`` (which records it as the ``bucket_body`` reason)
    and ``SolverEngine.sharded_bucket_key`` (which builds the bucket it
    names), so the engine executes the same rule the plan explains
    instead of silently rewriting it.

    The score is byte-priced end to end: per-slot resident operand bytes
    (``sharded_bucket_bytes``) plus the per-axis WIRE bytes of one
    check block (``sharded_wire_bytes`` x ``DEFAULT_CHECK_EVERY``
    iterations, HBM byte ~ wire byte) — so a 1-D layout that stores
    little but psums a huge axis every iteration loses to a grid whose
    collectives shrink with both mesh dims, and vice versa.  Ties go to
    dualpart (no transpose copy, the planner's default for direct
    distributed solves).

    ``grid_widths`` maps candidate (rows, cols) factorizations to their
    (width, width_t) storage widths (the engine computes them with
    ``sharded_grid_widths``); without it only the 1-D strategies
    compete — callers on a hot admission path may also pass placeholder
    widths for any strategy an ``override`` rules out (the engine skips
    computing them entirely).  ``override="gridpart"`` picks the best
    candidate in ``grid_widths`` (which must then be non-empty)."""
    from repro.core.solver import DEFAULT_CHECK_EVERY

    if override is not None and override not in ("rowpart", "dualpart",
                                                 "gridpart"):
        raise KeyError(f"unknown sharded-bucket strategy override "
                       f"{override!r} (rowpart | dualpart | gridpart | "
                       f"None)")

    if override == "gridpart" and not grid_widths:
        raise ValueError("override='gridpart' needs grid_widths (candidate "
                         "(rows, cols) -> (width, width_t))")
    candidates: dict = {}
    if override in (None, "rowpart"):
        candidates[("rowpart", None)] = _bucket_body_score(
            fmt, "rowpart", m_pad, n_pad, width, width_t_rowpart, ndev,
            DEFAULT_CHECK_EVERY)
    if override in (None, "dualpart"):
        candidates[("dualpart", None)] = _bucket_body_score(
            fmt, "dualpart", m_pad, n_pad, width, width_t_dualpart, ndev,
            DEFAULT_CHECK_EVERY)
    if override in (None, "gridpart"):
        for g, (w_g, wt_g) in (grid_widths or {}).items():
            candidates[("gridpart", tuple(g))] = _bucket_body_score(
                fmt, "gridpart", m_pad, n_pad, w_g, wt_g, ndev,
                DEFAULT_CHECK_EVERY, grid=tuple(g))
    # smallest total; ties go to dualpart, then the declaration order above
    (strategy, grid), (resident, wire, total) = min(
        candidates.items(),
        key=lambda kv: (kv[1][2], kv[0][0] != "dualpart"))
    why = (f"byte-priced body model over {ndev} devices: " +
           "; ".join(
               f"{s}{'x'.join(map(str, g)) if g else ''} "
               f"{c[0]}B resident + {c[1]['total']}B wire/iter"
               for (s, g), c in candidates.items()) +
           f" -> {strategy}{'x'.join(map(str, grid)) if grid else ''} "
           f"(score = resident + {DEFAULT_CHECK_EVERY} x wire, "
           f"fwd {wire['fwd']}B + bwd {wire['bwd']}B wire/iter/device "
           f"per slot)")
    if override is not None:
        why = f"user override {override}; {why}"
    return strategy, grid, resident, why


def decide_check_every(override: Optional[int] = None) -> tuple[int, str]:
    """The feasibility-check cadence decision: (check_every, reason).

    One rule for every entry point — ``plan()`` records it in the plan's
    reasons, and the serving engine / benchmark / launch CLIs resolve their
    ``check_every=None`` defaults through it, so the historical 8-vs-16
    split between ``core.solver`` and the engine cannot reappear.  The
    default is ``core.solver.DEFAULT_CHECK_EVERY``: large enough that the
    O(nnz) feasibility spmv is amortized to a few percent of block cost,
    small enough that a converged slot wastes at most one block.
    """
    if override is not None:
        if override < 1:
            raise ValueError(f"check_every must be >= 1, got {override}")
        return int(override), "user override"
    from repro.core.solver import DEFAULT_CHECK_EVERY

    return DEFAULT_CHECK_EVERY, (
        f"planner default ({DEFAULT_CHECK_EVERY}): feasibility spmv "
        f"amortized over the block, at most one wasted block per slot")


def decide_solver_family(loss: str, stats=None,
                         override: str = "auto") -> tuple[str, str]:
    """The solver-family face-off: (family, reason).

    a1/a2 serve plain prox + linear-constraint saddle problems (no loss
    term); the coordinate families serve the ERM losses over the
    column-major CSC operand view (``repro.solvers.rcd``):

    lasso     -> rcd_primal FORCED: the l1 composite is not strongly
                 convex, so there is no smooth dual coordinate
                 subproblem — while the primal coordinate step is an
                 exact 1-D soft-threshold.
    svm       -> rcd_dual FORCED: the hinge has no primal coordinate
                 curvature (nonsmooth), while its dual is a box QP with
                 a closed-form 1-D update (SDCA).
    logistic  -> both sides are valid: face off on modeled epoch cost x
                 degree imbalance from the shared ``MatrixStats``.  An
                 epoch visits every coordinate once and the widest
                 coordinate bounds the padded gather width, so the side
                 with fewer, more balanced coordinates wins — the
                 size/imbalance shape of Csiba & Richtarik's
                 importance-sampling analysis, applied as a routing
                 rule.

    Shared between ``plan()`` (records it as the plan's
    ``solver_family`` reason) and ``Problem.to_request`` (stamps the
    family on the engine request), so direct solves and engine admission
    route by the same rule.  ``override`` must name a registered family
    compatible with the loss.
    """
    from repro.solvers import FAMILY_NAMES
    from repro.solvers.rcd import LOSSES, check_family_loss

    if override != "auto":
        if override not in FAMILY_NAMES:
            raise KeyError(f"unknown solver family {override!r} "
                           f"(choose from {FAMILY_NAMES} | 'auto')")
        if override in ("rcd_primal", "rcd_dual"):
            if not loss:
                raise ValueError(
                    f"{override} needs a loss term: construct the Problem "
                    f"with loss='lasso'|'svm'|'logistic'")
            check_family_loss(override, loss)
        elif loss:
            raise ValueError(
                f"solver_family {override!r} does not serve loss={loss!r}: "
                "the a1/a2 smoothing bodies solve min f(x) s.t. Ax = b, "
                "not ERM losses (pick rcd_primal/rcd_dual or 'auto')")
        return override, "user override"
    if not loss:
        return "a2", ("no loss= term: the primal-dual smoothing family "
                      "serves prox + linear-constraint problems")
    if loss == "lasso":
        return "rcd_primal", (
            "forced: the l1 composite is not strongly convex (no smooth "
            "dual coordinate subproblem); primal RCD takes exact 1-D "
            "soft-threshold steps")
    if loss == "svm":
        return "rcd_dual", (
            "forced: the hinge has no primal coordinate curvature "
            "(nonsmooth); SDCA's dual box QP has a closed-form 1-D update")
    if loss != "logistic":
        raise ValueError(f"unknown loss {loss!r} (choose from {LOSSES})")
    if stats is None:
        raise ValueError("the logistic face-off needs MatrixStats — a "
                         "concrete matrix, not a matrix-free operator")
    imb_p = stats.col_nnz_max / max(1.0, stats.col_nnz_mean)
    imb_d = stats.row_nnz_max / max(1.0, stats.row_nnz_mean)
    score_p = stats.n * (1.0 + imb_p)
    score_d = stats.m * (1.0 + imb_d)
    family = "rcd_primal" if score_p <= score_d else "rcd_dual"
    return family, (
        f"face-off on epoch cost x imbalance (Csiba & Richtarik): primal "
        f"{stats.n} coords x (1 + {imb_p:.2g}) = {score_p:.4g} vs dual "
        f"{stats.m} samples x (1 + {imb_d:.2g}) = {score_d:.4g} "
        f"-> {family}")


def sharding_ndev(nnz: int, n_devices: int,
                  shard_above: Optional[int] = None) -> int:
    """Capacity-sized sub-mesh for one sharded problem: the fewest devices
    whose combined per-device capacity (the ``decide_placement`` threshold)
    holds the operands — collectives should span the shards, not the
    world.  Shared by the engine's sharded-bucket sizing and the planner's
    bucket-body reason, so both price the same mesh."""
    cap = _shard_threshold(shard_above)
    need = -(-int(nnz) // max(1, cap))
    return max(2, min(n_devices, need))


#: above this nnz, _cost_reasons estimates widths from mean degrees
#: instead of exact host passes — the reason string is advisory, and an
#: O(nnz log nnz) scan per plan() would dwarf the planner itself.
_EXACT_WIDTHS_NNZ = 1_000_000


def _next_pow2(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


def _cost_reasons(problem, fmt: str, placement: str, n_devices: int,
                  shard_above: Optional[int]) -> dict:
    """The ``bucket_body`` / ``operand_bytes`` reasons: which serving body
    this problem's placement maps to and what its operands cost resident,
    from the same byte model the engine's admission charges against.

    Dims and widths come from the engine's own helpers
    (``SolverEngine.sharded_bucket_dims/sharded_bucket_widths`` /
    ``bucket_key``'s padded tiling, at the default 64/16 floors), so the
    recorded body matches the bucket a default-configured engine builds;
    an engine with a different ``fmt`` / ``min_rows`` / forced
    ``sharded_strategy`` re-evaluates the same rule at its own config.
    Above ``_EXACT_WIDTHS_NNZ`` stored entries the widths are estimated
    from mean degrees (labeled in the reason) instead of exact O(nnz)
    host passes — the engine still computes exact widths at admission.
    """
    coo = problem.coo
    fmt_b = fmt if fmt in ("ell", "bcsr", "csc") else "ell"
    exact = coo.nnz <= _EXACT_WIDTHS_NNZ
    est = "" if exact else " (widths estimated from mean degrees)"
    floor = 1 if fmt_b == "bcsr" else 8
    pow2 = lambda v: _next_pow2(max(floor, v))
    mean_w = pow2(-(-coo.nnz // max(1, coo.m)))
    mean_wt = pow2(-(-coo.nnz // max(1, coo.n)))
    if placement == "sharded" and n_devices > 1:
        from repro.serve.solver_engine import (
            sharded_bucket_dims, sharded_bucket_widths, sharded_grid_widths,
        )
        ndev = sharding_ndev(coo.nnz, n_devices, shard_above)
        m_pad, n_pad = sharded_bucket_dims(coo.m, coo.n, ndev)
        if exact:     # the engine's own padded-width computation, shared
            w, wt_row, wt_dual = sharded_bucket_widths(
                coo, m_pad, n_pad, ndev, fmt_b)
            gw = {g: sharded_grid_widths(coo, m_pad, n_pad, g, fmt_b)
                  for g in grid_shapes(ndev)}
        else:
            w, wt_row, wt_dual = mean_w, mean_wt, 0
            gw = {(r, c): (pow2(-(-coo.nnz // max(1, coo.m * c))),
                           pow2(-(-coo.nnz // max(1, coo.n * r))))
                  for r, c in grid_shapes(ndev)}
        strategy, grid, per_dev, why = decide_bucket_body(
            fmt_b, m_pad, n_pad, w, wt_row, wt_dual, ndev, grid_widths=gw)
        wire = sharded_wire_bytes(strategy, 1, m_pad, n_pad, ndev, grid=grid)
        body = f"stacked_{fmt_b}/{strategy}" + (
            f" {grid[0]}x{grid[1]}" if grid else "")
        return {
            "bucket_body": (f"{body} mesh-wide bucket "
                            f"over {ndev} devices ({why}){est}"),
            "operand_bytes": (f"{per_dev} resident operand bytes/device "
                              f"per slot — the unit the engine's "
                              f"byte-based device_budget admits in{est}"),
            "wire_bytes": (f"{wire['total']} collective wire bytes/device "
                           f"per iteration per slot (fwd {wire['fwd']} + "
                           f"bwd {wire['bwd']}, ring model — the factors "
                           f"roofline.collective_stats charges){est}"),
        }
    m_pad = max(64, _next_pow2(coo.m))
    n_pad = max(16, _next_pow2(coo.n))
    if not exact:
        w, wt = (mean_wt, mean_w) if fmt_b == "csc" else (mean_w, mean_wt)
    elif fmt_b == "bcsr":   # mirror SolverEngine.bucket_key's padded tiling
        from repro.sparse.formats import coo_bcsr_width, pad_coo, transpose_coo
        c = pad_coo(coo, m_pad, n_pad)
        w = pow2(coo_bcsr_width(c, bm=8, bn=min(128, n_pad)))
        wt = pow2(coo_bcsr_width(transpose_coo(c), bm=8,
                                 bn=min(128, m_pad)))
    else:
        # row/col degree maxima from the shared single-pass MatrixStats
        # (the redundant bincount pass this reason used to re-run)
        stats = getattr(problem, "stats", None)
        if stats is not None:
            rmax, cmax = stats.row_nnz_max, stats.col_nnz_max
        else:
            rows = np.asarray(coo.rows)
            cols = np.asarray(coo.cols)
            rmax = int(np.bincount(rows, minlength=coo.m).max()) \
                if rows.size else 1
            cmax = int(np.bincount(cols, minlength=coo.n).max()) \
                if cols.size else 1
        if fmt_b == "csc":      # CSC pair: width = col max, width_t = row max
            w, wt = pow2(max(1, cmax)), pow2(max(1, rmax))
        else:
            w, wt = pow2(max(1, rmax)), pow2(max(1, cmax))
    bytes_ = bucket_operand_bytes(fmt_b, 1, m_pad, n_pad, w, wt)
    return {
        "bucket_body": (f"stacked_{fmt_b} single-device bucket body "
                        f"(placement={placement})"),
        "operand_bytes": (f"{bytes_} resident operand bytes per slot at "
                          f"the engine's default bucket padding "
                          f"({m_pad}x{n_pad}, widths {w}/{wt}; both "
                          f"orientations + b){est}"),
    }


def decide_placement(m: int, n: int, nnz: Optional[int], n_devices: int,
                     shard_above: Optional[int] = None,
                     override: str = "auto") -> tuple[str, str]:
    """The serving-placement decision: (placement, reason).

    single     whole problem on one device (the only choice at 1 device).
    replicated whole problem per device; many such problems are spread
               round-robin over devices (the engine's bucket placement).
    sharded    operands exceed the per-device threshold: partition them
               mesh-wide (distributed strategy / shard_map'd bucket).

    ``nnz`` falls back to the dense m*n when unknown; the threshold is
    ``shard_above`` > env REPRO_SHARD_ABOVE_NNZ > ``_SHARD_ABOVE_NNZ``.
    Shared by ``plan()`` and the serving engine's admission
    (repro.serve.solver_engine), so both route by the same rule.
    """
    if override != "auto":
        return override, "user override"
    size = int(nnz) if nnz is not None else int(m) * int(n)
    limit = _shard_threshold(shard_above)
    if n_devices <= 1:
        if size >= limit:
            return "single", (
                f"one device visible, but {size} stored entries exceed its "
                f"{limit} capacity: operands cannot stay resident (the "
                "serving engine streams them per step block)")
        return "single", "one device visible: nothing to place"
    if size >= limit:
        return "sharded", (
            f"{size} stored entries >= per-device threshold {limit}: "
            f"partition operands over {n_devices} devices")
    return "replicated", (
        f"{size} stored entries < per-device threshold {limit}: "
        f"problem fits one device; independent problems spread "
        f"round-robin over {n_devices} devices")


def decide_admission(m: int, n: int, nnz: Optional[int], n_devices: int,
                     slot_bytes: Optional[int] = None,
                     budget_left: Optional[int] = None,
                     shard_above: Optional[int] = None,
                     allow_streaming: bool = True) -> tuple[str, str]:
    """The serving-admission decision: (admission, reason) with admission
    in {"resident", "streamed", "rejected"}.

    Where ``decide_placement`` answers *where* a problem lands on the
    mesh, this answers *whether taking it is a good idea* — the verdict
    the open-loop front-end enforces before a request ever reaches the
    engine, and the reason every rejection carries.  Historically the
    engine silently spilled over-budget work to streamed (per-tick
    re-uploaded) operands; this rule makes that an explicit, reasoned
    decision with a refusal path:

    resident   operands stay device-resident across ticks (fits one
               device, or shards over a mesh whose floor-1 fairness
               always finds it a slot).
    streamed   the work can only be served out-of-core — over the
               per-device stored-entry capacity on a single device, or a
               byte budget (``slot_bytes`` vs ``budget_left``, the
               engine's live numbers) too saturated to hold one slot —
               and ``allow_streaming`` permits paying per-tick re-upload
               traffic for it.
    rejected   the same conditions with ``allow_streaming=False``: the
               caller would rather shed load (backpressure) than degrade
               every tenant with streamed-operand ticks.

    Shared between ``plan()`` (recorded as the ``admission`` reason, with
    budget numbers unknown) and ``SolverEngine.admission_for`` (which
    supplies its live ``slot_bytes``/``budget_left``), so the front-end
    enforces exactly the rule the plan explains.
    """
    size = int(nnz) if nnz is not None else int(m) * int(n)
    limit = _shard_threshold(shard_above)
    if n_devices > 1 and size >= limit:
        return "resident", (
            f"{size} stored entries >= per-device threshold {limit}: "
            f"mesh-wide sharded bucket, shards stay device-resident "
            f"(floor-1 slot fairness always admits)")
    if n_devices <= 1 and size >= limit:
        if allow_streaming:
            return "streamed", (
                f"{size} stored entries exceed the single device's "
                f"{limit} capacity: operands re-upload per check block")
        return "rejected", (
            f"{size} stored entries exceed the single device's {limit} "
            f"capacity and streaming is disallowed: admitting it would "
            f"pay per-tick operand re-uploads")
    if slot_bytes is not None and budget_left is not None \
            and slot_bytes > budget_left:
        if allow_streaming:
            return "streamed", (
                f"byte budget saturated: one slot costs {slot_bytes} "
                f"resident operand bytes but only {max(0, budget_left)} "
                f"remain — served with per-tick re-uploads")
        return "rejected", (
            f"byte budget saturated: one slot costs {slot_bytes} resident "
            f"operand bytes but only {max(0, budget_left)} remain, and "
            f"streaming is disallowed")
    return "resident", (
        f"{size} stored entries fit one device's {limit} capacity"
        + ("" if slot_bytes is None else
           f"; {slot_bytes} slot bytes within the remaining "
           f"{budget_left} byte budget"))


def plan(problem, spec: SolveSpec | None = None, **overrides) -> ExecutionPlan:
    """Resolve caller intent into an ExecutionPlan (no device work yet
    beyond Lg estimation when values are unavailable)."""
    spec = resolve_spec(spec, overrides)
    reasons: dict[str, str] = {}
    estimates = None

    # algorithm / solver family --------------------------------------------
    loss = getattr(problem, "loss", "") or ""
    fam_override = spec.solver_family
    if fam_override == "auto" and spec.algorithm != "auto":
        fam_override = spec.algorithm
    algorithm, why_f = decide_solver_family(
        loss, getattr(problem, "stats", None), fam_override)
    rcd = algorithm in ("rcd_primal", "rcd_dual")
    reasons["solver_family"] = f"{algorithm}: {why_f}"
    if rcd:
        reasons["algorithm"] = (f"solver_family face-off -> {algorithm} "
                                "(see solver_family)")
    elif spec.algorithm != "auto" or spec.solver_family != "auto":
        reasons["algorithm"] = "user override"
    else:
        reasons["algorithm"] = ("fused schedule: identical iterates to A1 "
                                "with 1 fwd + 1 bwd pass, 2 sync points "
                                "(paper Alg. 2)")

    # gamma0 ---------------------------------------------------------------
    if spec.gamma0 is not None:
        gamma0, reasons["gamma0"] = float(spec.gamma0), "user override"
    elif getattr(problem, "gamma0", None) is not None:
        gamma0, reasons["gamma0"] = float(problem.gamma0), "problem default"
    else:
        gamma0, reasons["gamma0"] = 100.0, "planner default (paper Sec. 5)"

    # execution / strategy / placement -------------------------------------
    distributed = spec.strategy is not None or spec.mesh is not None
    if distributed and problem.coo is None:
        raise ValueError("distributed strategies need a concrete matrix "
                         "(COO/dense), not a matrix-free operator")
    if rcd:
        if distributed:
            raise ValueError(
                "coordinate-descent families have no distributed strategy: "
                "one update scatters into arbitrary rows of its cached "
                "vector, which has no row-partitioned form")
        if problem.coo is None:
            raise ValueError("coordinate-descent families need a concrete "
                             "matrix (the CSC coordinate view), not a "
                             "matrix-free operator")
        if spec.format not in ("auto", "csc"):
            raise ValueError(
                f"format {spec.format!r} cannot serve coordinate descent: "
                "per-coordinate access needs the column-major csc view")
        strategy, execution, placement = None, "single", "single"
        reasons["strategy"] = "coordinate families run single-device"
        reasons["placement"] = (
            "rcd buckets are single-device: the scattered per-coordinate "
            "cache update has no row-partitioned form (oversized problems "
            "fall back to streamed operands at serve time)")
        fmt = "csc"
        reasons["format"] = ("coordinate access is column-major: CSC(A) / "
                             "CSC(A^T) flat-gather pair (forced for rcd)")
        if spec.backend != "auto":
            backend, reasons["backend"] = spec.backend, "user override"
        else:
            import jax
            on_tpu = jax.default_backend() == "tpu"
            backend = "pallas" if on_tpu else "jnp"
            reasons["backend"] = (
                "TPU: per-coordinate Pallas gather-update kernel" if on_tpu
                else f"{jax.default_backend()}: jnp reference ops "
                     "(Pallas would run in interpret mode)")
        params, estimates = {}, None
    elif not distributed:
        # serving placement: does this problem fit one device, and should a
        # too-large one be auto-upgraded to a mesh-wide (sharded) solve?
        import jax
        placement, why_p = decide_placement(
            problem.m, problem.n, problem.nnz, len(jax.devices()),
            spec.shard_above, spec.placement)
        if placement == "sharded":
            if problem.coo is not None:
                distributed = True
                reasons["strategy"] = (
                    "placement=sharded: auto-upgraded to a distributed "
                    "strategy (dualpart caches both orientations)")
            else:
                placement = "single"
                why_p += ("; matrix-free operators cannot be partitioned — "
                          "kept single")
        reasons["placement"] = why_p
    else:
        placement = "sharded"
        reasons["placement"] = ("strategy/mesh given: operands partitioned "
                                "mesh-wide")
    if rcd:
        pass                      # execution/format/backend decided above
    elif distributed:
        strategy = spec.strategy or "dualpart"
        reasons.setdefault("strategy", (
            "user override" if spec.strategy else
            "mesh given: dualpart caches both orientations (Spark "
            "dual-RDD), reduce-scatter on both passes"))
        execution = "distributed"
        fmt, backend = "ell", "jnp"
        reasons["format"] = ("strategies partition ELL in both orientations "
                             "(repro.sparse.partition)")
        reasons["backend"] = "shard_map-local jnp operators"
        params: dict = {}
    else:
        strategy = None
        reasons["strategy"] = ("single device (pass strategy=/mesh= or use "
                               "repro.api.solve_many for fleets)")
        execution = "single"
        fmt, backend, params, estimates, why = _choose_format(problem, spec)
        reasons.update(why)
    if backend == "pallas":
        import jax

        from repro.kernels import default_interpret
        resolved = default_interpret(spec.interpret)
        reasons["interpret"] = (
            f"pallas interpret={resolved} "
            f"(kernels.default_interpret: explicit flag > env "
            f"REPRO_PALLAS_INTERPRET > backend={jax.default_backend()!r})")
    if getattr(problem, "dtype", None) is not None:
        reasons["dtype"] = (f"operands canonicalized to "
                            f"{np.dtype(problem.dtype).name} "
                            f"(repro.api.Problem; dtype= overrides)")

    # check cadence --------------------------------------------------------
    if rcd and spec.check_every is None:
        from repro.solvers.rcd import DEFAULT_RCD_CHECK_EVERY
        check_every = DEFAULT_RCD_CHECK_EVERY
        reasons["check_every"] = (
            f"rcd default ({DEFAULT_RCD_CHECK_EVERY}): each residual check "
            "re-runs both matvecs (~one epoch of work), so it amortizes "
            "over a handful of epochs")
    else:
        check_every, reasons["check_every"] = \
            decide_check_every(spec.check_every)

    # lg -------------------------------------------------------------------
    lg, reasons["lg"] = _choose_lg(problem, spec)

    # serving cost model: bucket body + operand bytes + admission ------------
    if problem.coo is not None:
        import jax
        reasons.update(_cost_reasons(problem, fmt, placement,
                                     len(jax.devices()), spec.shard_above))
        adm, why_a = decide_admission(problem.m, problem.n, problem.nnz,
                                      len(jax.devices()),
                                      shard_above=spec.shard_above)
        reasons["admission"] = (
            f"{adm}: {why_a} (byte-budget admission is re-checked at "
            f"serve time against the engine's live device_budget — "
            f"SolverEngine.admission_for)")

    return ExecutionPlan(problem=problem, spec=spec, execution=execution,
                         algorithm=algorithm, format=fmt, backend=backend,
                         strategy=strategy, mesh=spec.mesh, lg=lg,
                         gamma0=gamma0, params=params, reasons=reasons,
                         estimates=estimates, placement=placement,
                         check_every=check_every)


def _choose_format(problem, spec: SolveSpec):
    """(format, backend, params, estimates, reasons) for a single-device
    solve — the roofline selector extended with dense/matrix-free cases."""
    reasons: dict[str, str] = {}
    estimates = None
    if problem.operator is not None:
        reasons["format"] = reasons["backend"] = \
            "caller-provided LinearOperator (matrix-free)"
        return (problem.operator.format, problem.operator.backend,
                dict(spec.format_params), None, reasons)

    if spec.backend != "auto":
        backend, reasons["backend"] = spec.backend, "user override"
    else:
        import jax
        on_tpu = jax.default_backend() == "tpu"
        backend = "pallas" if on_tpu else "jnp"
        reasons["backend"] = ("TPU: fused Pallas kernels" if on_tpu else
                              f"{jax.default_backend()}: jnp reference ops "
                              "(Pallas would run in interpret mode)")

    if spec.format != "auto":
        fmt, reasons["format"] = spec.format, "user override"
        params = dict(spec.format_params)
    else:
        density = problem.density
        if density >= _DENSE_DENSITY:
            fmt = "dense"
            reasons["format"] = (f"density {density:.2f} >= "
                                 f"{_DENSE_DENSITY}: padded sparse formats "
                                 "would store at least the dense array")
            params = {}
        else:
            from repro.operators.select import select_format
            fp = select_format(problem.coo, backend=backend,
                               stats=getattr(problem, "stats", None))
            fmt, params, estimates = fp.format, dict(fp.params), fp.estimates
            reasons["format"] = ("roofline selector: cheapest modeled "
                                 "per-apply time over {ell, banded_ell, "
                                 "bcsr} (repro.operators.select)")
            params.update(spec.format_params)
    if fmt in ("dense", "coo") and backend != "jnp":
        backend = "jnp"
        reasons["backend"] = f"{fmt} format is registered for jnp only"
    return fmt, backend, params, estimates, reasons


def _choose_lg(problem, spec: SolveSpec):
    """Lg resolution: explicit > problem > Frobenius (paper init steps 1-2,
    exact when values are host-available) > power iteration (matrix-free)."""
    if spec.lg is not None:
        return float(spec.lg), "user override"
    if getattr(problem, "lg", None) is not None:
        return float(problem.lg), "problem-supplied"
    method = spec.lg_method
    if method == "auto":
        method = "frobenius" if problem.coo is not None else "power"
    if method == "frobenius":
        if problem.coo is None:
            raise ValueError("lg_method='frobenius' needs matrix values; "
                             "use 'power' for matrix-free operators")
        stats = getattr(problem, "stats", None)
        if stats is not None:       # the shared single-pass MatrixStats
            lg = float(stats.frob_sq)
        else:
            lg = float(np.sum(np.square(np.asarray(problem.coo.vals))))
        return lg, ("Lg = sum_i ||A_i||^2 (paper init steps 1-2; exact "
                    "upper bound on ||A||^2; from the shared MatrixStats "
                    "pass)")
    from repro.core.solver import estimate_lg

    op = problem.operator if problem.operator is not None \
        else problem.reference_operator()
    lg = 1.05 * estimate_lg(op, n=problem.n)
    return lg, ("power iteration on A^T A (core.solver.estimate_lg) "
                "x 1.05 safety margin")

"""Cell builders: (arch x shape x mesh) -> lowered-ready step functions.

A "cell" is one dry-run unit: a jit'd step with ShapeDtypeStruct arguments
and explicit in_shardings. Three kinds for LM archs (train / prefill /
decode) plus the paper's own solver cells (one A2 iteration, block2d).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, PaperProblemConfig, ShapeSpec
from repro.core.prox import get_prox
from repro.core.solver import PDState
from repro.distributed.sharding import Shardings, make_shardings
from repro.models.api import (
    batch_shardings, batch_specs, build_model, cache_sds, cache_shardings,
)
from repro.train import OptConfig
from repro.train import optimizer as opt_mod
from repro.train.train_loop import make_train_step

tmap = jax.tree_util.tree_map


@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable                 # jit-able python callable
    args: tuple                  # SDS pytrees
    in_shardings: Any            # NamedSharding pytrees (or None)
    meta: dict


def _named(sh: Shardings, spec_tree):
    return tmap(lambda s: NamedSharding(sh.mesh, s), spec_tree)


def make_lm_cell(arch: str, shape_name: str, mesh) -> Cell:
    from repro.models.api import serve_rule_overrides

    cfg: ModelConfig = get_config(arch)
    shape = SHAPES[shape_name]
    # DECODE cells use inference sharding rules (TP-only params where they
    # fit, cluster-wide EP) — §Perf hillclimb. Prefill keeps the training
    # rules: measured across all 10 archs, dropping fsdp at prefill lets
    # GSPMD pick strictly worse layouts (e.g. olmoe 5.2s -> 41.5s wire).
    overrides = serve_rule_overrides(cfg, mesh, "decode") \
        if SHAPES[shape_name].kind == "decode" else None
    sh = make_shardings(mesh, overrides)
    model = build_model(cfg)
    params_sds = model.sds()
    param_sh = _named(sh, model.pspecs(sh.rules))
    bsp = batch_specs(cfg, shape)
    bsh = _named(sh, batch_shardings(cfg, shape, sh))

    if shape.kind == "train":
        step, in_sh, _ = make_train_step(model, shape, sh, donate=False)
        ocfg = OptConfig(state_dtype=cfg.opt_state_dtype)
        opt_sds = jax.eval_shape(lambda p: opt_mod.init(p, ocfg), params_sds)
        # make_train_step returns a jit'd fn with shardings baked in
        return Cell(name=f"{arch}:{shape_name}", fn=step,
                    args=(params_sds, opt_sds, bsp),
                    in_shardings=None,          # baked into the jit
                    meta=dict(cfg=cfg, shape=shape, sh=sh, kind="train",
                              model=model))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            tokens = batch["tokens"]
            extras = {k: v for k, v in batch.items() if k != "tokens"}
            return model.prefill(params, tokens, sh, extras or None)

        fn = jax.jit(prefill_step, in_shardings=(param_sh, bsh))
        return Cell(name=f"{arch}:{shape_name}", fn=fn,
                    args=(params_sds, bsp), in_shardings=None,
                    meta=dict(cfg=cfg, shape=shape, sh=sh, kind="prefill",
                              model=model))

    # decode
    csds = cache_sds(cfg, shape)
    csh = _named(sh, cache_shardings(cfg, shape, sh))

    def decode(params, cache, batch):
        return model.decode(params, cache, batch["tokens"],
                            batch["cur_index"], sh)

    # cache is donated: the updated cache aliases the input buffer (in-place
    # token append on TPU — no full-cache copy per step)
    fn = jax.jit(decode, in_shardings=(param_sh, csh, bsh),
                 donate_argnums=(1,))
    return Cell(name=f"{arch}:{shape_name}", fn=fn,
                args=(params_sds, csds, bsp), in_shardings=None,
                meta=dict(cfg=cfg, shape=shape, sh=sh, kind="decode",
                          model=model))


# ---------------------------------------------------------------------------
# Paper solver cells (allocation-free dry-run of one A2 iteration, block2d)
# ---------------------------------------------------------------------------

def make_paper_cell(arch: str, mesh, strategy: str = "block2d",
                    algorithm: str = "a2", operand_dtype=jnp.float32,
                    index_dtype=jnp.int32) -> Cell:
    """One A2 (or A1) iteration of the block2d-distributed solver.

    The device-local operators are built through the operator registry
    (repro.operators: (format="ell", backend="block2d")) by make_step_fn's
    make_local_ops — this cell only assembles the sharded operand specs.

    `operand_dtype=bf16` + `index_dtype=int16` is the §Perf compressed-ELL
    variant: 4 bytes/nnz instead of 8 (values in bf16, block-LOCAL column
    indices < n/C = 3125 for D6 fit int16); the iteration math stays fp32
    (gathers/accumulations promote).
    """
    from repro.core.distributed import DistProblem, make_step_fn
    from repro.sparse.partition import _ceil_to

    pcfg: PaperProblemConfig = get_config(arch)
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    ca = names[-1]
    if "pod" in names:                  # fold pod into the row (data) axis
        ra: Any = ("pod", names[-2])
        R = sizes["pod"] * sizes[names[-2]]
    else:
        ra = names[-2]
        R = sizes[ra]
    C = sizes[ca]
    m_pad, n_pad = _ceil_to(pcfg.m, R), _ceil_to(pcfg.n, C)
    mb = m_pad // R
    k = _ceil_to(max(1, round(pcfg.nnz / pcfg.m / C)) + 8, 8)
    if index_dtype == jnp.int16 and n_pad // C >= 2 ** 15:
        raise ValueError("block width too large for int16 indices")
    grid_spec = P(ra, ca, None, None)
    vals = jax.ShapeDtypeStruct((R, C, mb, k), operand_dtype)
    cols = jax.ShapeDtypeStruct((R, C, mb, k), index_dtype)
    problem = DistProblem(
        strategy="block2d", mesh=mesh, axes=(ra, ca),
        operands=dict(a=(vals, cols)),
        operand_specs=dict(a=(grid_spec, grid_spec)),
        x_spec=P(ca), y_spec=P(ra),
        m=pcfg.m, n=pcfg.n, m_pad=m_pad, n_pad=n_pad, lg=float(pcfg.m),
        dual_copy=False)
    prox = get_prox(pcfg.prox, reg=pcfg.reg)
    step = make_step_fn(problem, prox, pcfg.gamma0, algorithm=algorithm)
    b_sds = jax.ShapeDtypeStruct((m_pad,), jnp.float32)
    xs = jax.ShapeDtypeStruct((n_pad,), jnp.float32)
    ys = jax.ShapeDtypeStruct((m_pad,), jnp.float32)
    state = PDState(xbar=xs, xstar=xs, yhat=ys,
                    gamma=jax.ShapeDtypeStruct((), jnp.float32),
                    k=jax.ShapeDtypeStruct((), jnp.int32))
    return Cell(name=f"{arch}:step", fn=step,
                args=(problem.operands, b_sds, state), in_shardings=None,
                meta=dict(cfg=pcfg, kind="solver", problem=problem))

"""Training launcher.

Real-hardware entry point AND the CPU-runnable driver for reduced configs:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 50 --checkpoint-dir /tmp/ckpt --inject-failure 17

Features wired here: data pipeline -> jit'd microbatched train step ->
periodic async checkpoints -> supervisor-managed restart (simulated failure
injection proves the restart path) -> elastic restore (the checkpoint loads
onto whatever mesh the relaunch builds).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, reduced
from repro.configs.base import ShapeSpec
from repro.checkpoint import AsyncSaver, latest_step, restore
from repro.data import SyntheticTokens
from repro.distributed import make_shardings, null_shardings
from repro.ft import Supervisor, run_with_restarts
from repro.models import build_model
from repro.train import OptConfig, make_train_step
from repro.train import optimizer as opt_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="raise at this step once (tests restart path)")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x2:data,model (default: single device)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
        shape = ShapeSpec("train_smoke", "train", 64, 8)
    else:
        shape = SHAPES[args.shape]

    if args.mesh:
        dims, names = args.mesh.split(":")
        shp = tuple(int(d) for d in dims.split("x"))
        from repro.launch.mesh import make_mesh
        sh = make_shardings(make_mesh(shp, tuple(names.split(","))))
    else:
        sh = null_shardings()

    model = build_model(cfg)
    ocfg = OptConfig(lr=args.lr, state_dtype=cfg.opt_state_dtype,
                     warmup_steps=max(2, args.steps // 10))
    step_fn, _, _ = make_train_step(model, shape, sh, ocfg, donate=False)
    data = SyntheticTokens(cfg, shape, seed=0)
    saver = AsyncSaver()
    sup = Supervisor()
    injected = {"done": False}

    state = {}

    def restore_or_init() -> int:
        if args.checkpoint_dir and latest_step(args.checkpoint_dir) is not None:
            tgt = {"params": model.sds(dtype=jnp.dtype(cfg.dtype)),
                   "opt": jax.eval_shape(
                       lambda p: opt_mod.init(p, ocfg),
                       model.sds(dtype=jnp.dtype(cfg.dtype)))}
            loaded = restore(tgt, args.checkpoint_dir)
            state["params"], state["opt"] = loaded["params"], loaded["opt"]
            start = latest_step(args.checkpoint_dir)
            print(f"[train] restored step {start} from {args.checkpoint_dir}")
            return start
        state["params"] = model.init(jax.random.PRNGKey(0))
        state["opt"] = opt_mod.init(state["params"], ocfg)
        return 0

    def loop(start: int) -> int:
        for step in range(start, args.steps):
            if step == args.inject_failure and not injected["done"]:
                injected["done"] = True
                raise RuntimeError("injected node failure")
            t0 = time.time()
            batch = next(data)
            state["params"], state["opt"], metrics = step_fn(
                state["params"], state["opt"], batch)
            dt = time.time() - t0
            sup.heartbeat("host0", dt)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if args.checkpoint_dir and (step + 1) % args.checkpoint_every == 0:
                saver.save({"params": state["params"], "opt": state["opt"]},
                           args.checkpoint_dir, step + 1)
        saver.wait()
        return args.steps

    final = run_with_restarts(
        loop, restore_or_init, max_restarts=3,
        on_restart=lambda n: print(f"[train] RESTART #{n} from checkpoint"))
    data.close()
    print(f"[train] done at step {final}; supervisor events: {sup.events}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

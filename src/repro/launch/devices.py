"""Device-count bootstrap for CLIs: one place for the XLA_FLAGS dance.

The host platform's device count locks at jax initialisation, so a
``--devices N`` knob must append ``--xla_force_host_platform_device_count``
to ``XLA_FLAGS`` *before* anything imports jax.  Importing this module is
safe pre-jax (``import repro`` is lazy and pulls no jax).
"""
from __future__ import annotations

import os
import sys


def force_host_devices(n: int | None) -> bool:
    """Request ``n`` forced host devices; returns whether the flag was set.

    A no-op (returning False) when ``n`` is falsy or jax is already
    imported — in the latter case the flag would be silently ignored, so
    the caller's engine just takes the first ``n`` existing devices.
    """
    if not n or "jax" in sys.modules:
        return False
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(n)}")
    return True

"""Solver-serving launcher: continuous-batching engine over solve requests.

Generates a ragged stream of paper-style LASSO instances as declarative
``repro.api.Problem``s (mixed shapes and regularizers — the multi-tenant
traffic the serving engine buckets), drains it through the solver engine
(``repro.serve.create_engine("solver")``), and reports requests/sec.  With
``--compare-sequential`` the same stream is also solved one-by-one through
single-problem facade plans for the throughput ratio the batching exists
for.

``--devices N`` serves on a mesh of N devices (forced host devices when the
platform has fewer — the CPU-bringup path): buckets are pinned round-robin
and requests above ``--shard-above`` stored entries are admitted into
mesh-wide sharded buckets.  Device count locks at jax initialisation, so
the flag must be handled before anything imports jax — which is why this
module's repro imports live inside the functions.

With ``--arrival-rate`` the stream runs OPEN-LOOP instead of being
submitted all at once: seeded Poisson arrivals drive the engine through
``repro.serve.OpenLoopFrontend`` (bounded wait queue, priority admission,
planner-reasoned backpressure), ``--deadline`` gives every request a
relative latency bound past which its slot is reclaimed, and ``--slo``
sets the goodput threshold of the final report.

  PYTHONPATH=src python -m repro.launch.solver_serve --requests 16 \
      --slots 8 --fmt ell --backend jnp --tol 1e-2 --compare-sequential \
      --devices 4 --shard-above 2000
  PYTHONPATH=src python -m repro.launch.solver_serve --requests 32 \
      --arrival-rate 100 --deadline 2.0 --slo 0.25 --seed 7
"""
from __future__ import annotations

import argparse
import os
import time


def make_problems(num: int, seed: int = 0, gamma0: float = 1000.0,
                  big_every: int = 0, big_shape=(1024, 128), shapes=None):
    """Ragged problem stream: 3 shape families x 2 regularizers; with
    ``big_every`` > 0 every big_every-th request is an oversized instance
    (``big_shape``) — the traffic that exercises sharded placement."""
    import numpy as np

    from repro.api import Problem
    from repro.configs.base import PaperProblemConfig
    from repro.sparse import make_lasso

    rng = np.random.default_rng(seed)
    shapes = shapes or [(192, 48), (128, 32), (256, 64)]
    probs = []
    for i in range(num):
        if big_every and i % big_every == big_every - 1:
            m, n = big_shape
        else:
            m, n = shapes[i % len(shapes)]
        cfg = PaperProblemConfig(name=f"req{i}", m=m, n=n, nnz=m * 8,
                                 reg=0.1)
        coo, b, _ = make_lasso(cfg, seed=int(rng.integers(1 << 30)))
        probs.append(Problem(coo, b, prox="l1",
                             reg=float([0.1, 0.05][i % 2]), gamma0=gamma0))
    return probs


def solve_sequentially(probs, tol: float = 1e-2,
                       check_every: int | None = None,
                       max_iterations: int = 4000):
    """The baseline the engine replaces: one single-problem facade plan per
    request (same format/backend/stopping rule the engine applies per
    slot)."""
    return [p.solve(tol=tol, max_iterations=max_iterations,
                    check_every=check_every, format="ell", backend="jnp")
            for p in probs]


def _serve_open_loop(eng, reqs, args):
    """Open-loop mode: drain a seeded Poisson arrival stream through the
    front-end on a WallClock (real latencies, idle gaps skipped) and
    print the per-request timeline plus the p50/p99 + goodput report."""
    from repro.serve import OpenLoopFrontend, WallClock, poisson_arrivals

    arrivals = poisson_arrivals(reqs, rate=args.arrival_rate,
                                seed=args.seed, deadline=args.deadline)
    fe = OpenLoopFrontend(eng, arrivals, clock=WallClock(),
                          queue_limit=args.queue_limit,
                          admission=("strict" if args.strict_admission
                                     else "auto"))
    rep = fe.run(slo=args.slo)
    for r in sorted(fe.completed, key=lambda r: r.uid):
        tl = r.timeline
        print(f"[solver-serve] req {r.uid}: k={r.iterations} "
              f"queue={tl['queue_s']*1e3:.1f}ms "
              f"latency={tl['latency_s']*1e3:.1f}ms ({tl['admission']})")
    for r in sorted(fe.expired, key=lambda r: r.uid):
        print(f"[solver-serve] req {r.uid}: EXPIRED after "
              f"{r.timeline['latency_s']*1e3:.1f}ms")
    for r in sorted(fe.rejected, key=lambda r: r.uid):
        print(f"[solver-serve] req {r.uid}: REJECTED ({r.reject_reason})")
    p50 = rep["p50_latency_s"]
    p99 = rep["p99_latency_s"]
    print(f"[solver-serve] open-loop @{args.arrival_rate:g} req/s: "
          f"{rep['completed']}/{rep['offered']} completed, "
          f"{rep['expired']} expired, "
          f"{rep['rejected_backpressure'] + rep['rejected_admission']} "
          f"rejected in {rep['elapsed_s']:.2f}s; "
          f"p50={(p50 or 0)*1e3:.1f}ms p99={(p99 or 0)*1e3:.1f}ms "
          f"goodput={rep['goodput_rps']:.1f} req/s"
          + (f" (SLO {args.slo:g}s: {rep['met_slo']} met)"
             if args.slo is not None else ""))
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--fmt", default="ell", choices=("ell", "bcsr"))
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--tol", type=float, default=1e-2)
    ap.add_argument("--check-every", type=int, default=None,
                    help="feasibility-check cadence (default: the "
                         "planner's repro.plan.decide_check_every)")
    ap.add_argument("--fused", action="store_true", default=None,
                    help="force one-kernel fused check blocks (default: "
                         "auto — fused whenever backend=pallas)")
    ap.add_argument("--compare-sequential", action="store_true")
    ap.add_argument("--devices", type=int, default=None,
                    help="serve on a mesh of N devices (forces host "
                         "devices when the platform has fewer; must run "
                         "before jax initialises)")
    ap.add_argument("--shard-above", type=int, default=None,
                    help="per-device stored-entry capacity for the "
                         "sharded-placement rule (default: planner's)")
    ap.add_argument("--big-every", type=int, default=0,
                    help="make every N-th request oversized (routes to a "
                         "sharded bucket when above --shard-above)")
    ap.add_argument("--sharded-strategy", default=None,
                    choices=("rowpart", "dualpart", "gridpart"),
                    help="force the mesh-wide bucket body layout "
                         "(default: the planner's byte-priced rule, "
                         "repro.plan.decide_bucket_body)")
    ap.add_argument("--grid", default=None, metavar="RxC",
                    help="force the gridpart (rows, cols) sub-mesh "
                         "shape, e.g. 2x4 (implies "
                         "--sharded-strategy gridpart; default: the "
                         "planner scores every factorization)")
    ap.add_argument("--device-budget", type=int, default=None,
                    help="resident operand-byte capacity per device "
                         "(bytes; buckets admit against it via the "
                         "planner's cost model)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    metavar="RPS",
                    help="serve OPEN-LOOP: seeded Poisson arrivals at "
                         "this offered rate instead of submitting the "
                         "whole stream up front")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="open-loop relative deadline per request "
                         "(seconds after arrival; overdue requests are "
                         "expired and their slots reclaimed)")
    ap.add_argument("--slo", type=float, default=None, metavar="S",
                    help="open-loop latency SLO in seconds for the "
                         "goodput summary (default: no SLO — every "
                         "completion counts)")
    ap.add_argument("--queue-limit", type=int, default=64,
                    help="open-loop wait-queue capacity; arrivals "
                         "beyond it are rejected (backpressure)")
    ap.add_argument("--strict-admission", action="store_true",
                    help="open-loop: reject work the planner would only "
                         "serve streamed instead of admitting it")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed for the request mix and the arrival "
                         "stream (bit-reproducible runs)")
    args = ap.parse_args(argv)

    from repro.launch.devices import force_host_devices
    force_host_devices(args.devices)

    from repro.serve import create_engine

    grid = None
    if args.grid:
        r, _, c = args.grid.lower().partition("x")
        if not (r.isdigit() and c.isdigit()):
            raise SystemExit(f"--grid takes RxC (e.g. 2x4), got "
                             f"{args.grid!r}")
        grid = (int(r), int(c))
    probs = make_problems(args.requests, seed=args.seed,
                          big_every=args.big_every)
    eng = create_engine("solver", slots=args.slots, fmt=args.fmt,
                        backend=args.backend, check_every=args.check_every,
                        devices=args.devices, shard_above=args.shard_above,
                        sharded_strategy=args.sharded_strategy, grid=grid,
                        device_budget=args.device_budget, fused=args.fused)
    reqs = [p.to_request(uid=i, tol=args.tol, max_iterations=4000)
            for i, p in enumerate(probs)]
    if args.arrival_rate is not None:
        return _serve_open_loop(eng, reqs, args)
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    for r in sorted(done, key=lambda r: r.uid):
        print(f"[solver-serve] req {r.uid}: m={r.coo.m} n={r.coo.n} "
              f"k={r.iterations} feas={r.feasibility:.4f}")
    rps = len(done) / max(dt, 1e-9)
    print(f"[solver-serve] {len(done)} requests in {dt:.2f}s "
          f"({rps:.1f} req/s; {len(eng.buckets)} buckets x {args.slots} "
          f"slots, {eng.stats['iterations']} slot-iterations, "
          f"{len(eng.devices)} devices, "
          f"{eng.stats['sharded_admitted']} sharded admissions)")
    if args.compare_sequential:
        t0 = time.time()
        solve_sequentially(probs, tol=args.tol,
                           check_every=args.check_every)
        dts = time.time() - t0
        print(f"[solver-serve] sequential loop: {dts:.2f}s "
              f"({len(probs)/max(dts,1e-9):.1f} req/s) -> "
              f"batched speedup {dts/max(dt,1e-9):.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

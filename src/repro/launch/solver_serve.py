"""Solver-serving launcher: continuous-batching engine over solve requests.

Generates a ragged stream of paper-style LASSO instances (mixed shapes and
regularizers — the multi-tenant traffic the serving engine buckets), drains
it through ``repro.serve.SolverEngine``, and reports requests/sec.  With
``--compare-sequential`` the same stream is also solved one-by-one through
``solve_tol`` for the throughput ratio the batching exists for.

  PYTHONPATH=src python -m repro.launch.solver_serve --requests 16 \
      --slots 8 --fmt ell --backend jnp --tol 1e-2 --compare-sequential
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.base import PaperProblemConfig
from repro.serve import SolveRequest, SolverEngine
from repro.sparse import make_lasso


def make_requests(num: int, seed: int = 0, tol: float = 1e-2,
                  gamma0: float = 1000.0) -> list[SolveRequest]:
    """Ragged request stream: 3 shape families x 2 regularizers."""
    rng = np.random.default_rng(seed)
    shapes = [(192, 48), (128, 32), (256, 64)]
    reqs = []
    for i in range(num):
        m, n = shapes[i % len(shapes)]
        cfg = PaperProblemConfig(name=f"req{i}", m=m, n=n, nnz=m * 8,
                                 reg=0.1)
        coo, b, _ = make_lasso(cfg, seed=int(rng.integers(1 << 30)))
        reqs.append(SolveRequest(
            uid=i, coo=coo, b=b, prox="l1", reg=float([0.1, 0.05][i % 2]),
            gamma0=gamma0, tol=tol, max_iterations=4000))
    return reqs


def solve_sequentially(reqs: list[SolveRequest], check_every: int = 16):
    """The baseline the engine replaces: one solve_tol call per request,
    honoring each request's own tol/max_iterations (the same work the
    engine does per slot)."""
    import jax

    from repro.core.prox import get_prox
    from repro.core.solver import solve_tol
    from repro.operators import make_solver_ops

    out = []
    for r in reqs:
        ops = make_solver_ops(r.coo, "ell", "jnp")
        prox = get_prox(r.prox, reg=r.reg)
        s = solve_tol(ops, prox, r.b, r.lg, r.gamma0,
                      max_iterations=r.max_iterations, tol=r.tol,
                      check_every=check_every)
        out.append(jax.block_until_ready(s))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--fmt", default="ell", choices=("ell", "bcsr"))
    ap.add_argument("--backend", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--tol", type=float, default=1e-2)
    ap.add_argument("--check-every", type=int, default=16)
    ap.add_argument("--compare-sequential", action="store_true")
    args = ap.parse_args(argv)

    reqs = make_requests(args.requests, tol=args.tol)
    eng = SolverEngine(slots=args.slots, fmt=args.fmt, backend=args.backend,
                       check_every=args.check_every)
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    for r in sorted(done, key=lambda r: r.uid):
        print(f"[solver-serve] req {r.uid}: m={r.coo.m} n={r.coo.n} "
              f"k={r.iterations} feas={r.feasibility:.4f}")
    rps = len(done) / max(dt, 1e-9)
    print(f"[solver-serve] {len(done)} requests in {dt:.2f}s "
          f"({rps:.1f} req/s; {len(eng.buckets)} buckets x {args.slots} "
          f"slots, {eng.stats['iterations']} slot-iterations)")
    if args.compare_sequential:
        t0 = time.time()
        solve_sequentially(reqs, args.check_every)
        dts = time.time() - t0
        print(f"[solver-serve] sequential loop: {dts:.2f}s "
              f"({len(reqs)/max(dts,1e-9):.1f} req/s) -> "
              f"batched speedup {dts/max(dt,1e-9):.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

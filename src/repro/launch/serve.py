"""Serving launcher: continuous-batching engine over a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import Request, TokenEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = TokenEngine(model, slots=args.slots, max_len=args.max_len)
    eng.init_state(params)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(2, 8))
        shape = (plen, cfg.num_codebooks) if cfg.num_codebooks else (plen,)
        prompt = rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32)
        r = Request(uid=i, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(r)
        eng.submit(r)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    total_toks = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"[serve] req {r.uid}: {len(r.out)} tokens -> {r.out[:6]}...")
    print(f"[serve] {total_toks} tokens in {dt:.2f}s "
          f"({total_toks/max(dt,1e-9):.1f} tok/s, continuous batching over "
          f"{args.slots} slots)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (device count is locked at first backend init, and only
dryrun.py sets the 512-placeholder-device XLA flag).
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — run under "
            "launch/dryrun.py (which forces 512 host devices) or a real pod")
    return Mesh(np.asarray(devs[:need]).reshape(shape), axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(f"need {need} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:need]).reshape(shape), axes)

"""Multi-pod dry-run driver (THE compile-proof + roofline data source).

MUST be run as a module main: `PYTHONPATH=src python -m repro.launch.dryrun
--arch minitron-8b --shape train_4k --mesh both --units`.

The first two lines force 512 placeholder host devices BEFORE any jax
import; never set this globally (tests/benches must see 1 device).
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402

from repro.configs import ARCH_IDS, PAPER_IDS, SHAPES, applicable, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh                             # noqa: E402
from repro.launch.steps import make_lm_cell, make_paper_cell                   # noqa: E402
from repro.roofline import analysis, hw, units as units_mod                    # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             do_units: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips}
    t0 = time.time()
    if arch.startswith("paper-"):
        cell = make_paper_cell(arch, mesh)
    else:
        cell = make_lm_cell(arch, shape_name, mesh)
    lowered = (cell.fn.lower(*cell.args) if hasattr(cell.fn, "lower")
               else jax.jit(cell.fn).lower(*cell.args))
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["memory"] = analysis.memory_of(compiled)
    flops_once, bytes_once = analysis.cost_of(compiled)
    coll = analysis.collective_stats(compiled.as_text())
    rec["full_step_once"] = {
        "flops": flops_once, "bytes": bytes_once,
        "wire_bytes": coll.wire_bytes, "collectives_by_op": coll.by_op,
        "collective_count": coll.count,
        "note": "scan bodies counted once; see units for corrected totals"}

    if do_units and not arch.startswith("paper-"):
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        sh = cell.meta["sh"]
        ulist = (units_mod.train_units(cfg, shape, sh)
                 if shape.kind == "train"
                 else units_mod.serve_units(cfg, shape, sh))
        costs = units_mod.measure_units(ulist)
        rec["units"] = [vars(c) for c in costs]
        flops = sum(c.flops for c in costs)
        bts_hlo = sum(c.bytes_hbm for c in costs)
        wire = sum(c.wire for c in costs)
        mf = units_mod.model_flops(cfg, shape, chips)
        bts = units_mod.analytic_bytes(cfg, shape, sh)
        t = analysis.terms(flops, bts, wire, mf, bytes_hlo=bts_hlo)
        rec["roofline"] = {
            "compute_s": t.compute_s, "memory_s": t.memory_s,
            "memory_hlo_s": t.memory_hlo_s,
            "collective_s": t.collective_s, "dominant": t.dominant,
            "bound_s": t.bound_s, "model_flops": mf,
            "useful_ratio": t.useful_ratio,
            "roofline_fraction": t.roofline_fraction,
            "flops": flops, "bytes": bts, "bytes_hlo": bts_hlo,
            "wire_bytes": wire,
        }
    elif arch.startswith("paper-"):
        # solver cell: no interior scans in one iteration — full numbers are
        # trip-count-exact already.
        pcfg = get_config(arch)
        mf = 4.0 * pcfg.nnz / chips          # fwd+bwd sparse ops, 2 flops/nnz
        t = analysis.terms(flops_once, bytes_once, coll.wire_bytes, mf)
        rec["roofline"] = {
            "compute_s": t.compute_s, "memory_s": t.memory_s,
            "collective_s": t.collective_s, "dominant": t.dominant,
            "bound_s": t.bound_s, "model_flops": mf,
            "useful_ratio": t.useful_ratio,
            "roofline_fraction": t.roofline_fraction,
            "flops": flops_once, "bytes": bytes_once,
            "wire_bytes": coll.wire_bytes,
        }
    return rec


def cells(args):
    archs = args.arch.split(",") if args.arch else list(ARCH_IDS)
    shapes = args.shape.split(",") if args.shape else list(SHAPES)
    for arch in archs:
        if arch.startswith("paper-"):
            yield arch, "step"
            continue
        cfg = get_config(arch)
        for sname in shapes:
            if applicable(cfg, SHAPES[sname]):
                yield arch, sname


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="comma list; default: all 10 + use paper-lasso-dN "
                         "for solver cells")
    ap.add_argument("--shape", default=None, help="comma list of shapes")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--units", action="store_true", default=True)
    ap.add_argument("--no-units", dest="units", action="store_false")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_fail = 0
    for arch, sname in cells(args):
        for mp in meshes:
            tag = f"{arch}_{sname}_{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            try:
                rec = run_cell(arch, sname, mp, do_units=args.units and not mp)
                rec["ok"] = True
                n_ok += 1
                print(f"OK   {tag}  compile={rec['compile_s']}s "
                      f"dominant={rec.get('roofline', {}).get('dominant')}")
            except Exception as e:
                rec = {"arch": arch, "shape": sname, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()}
                n_fail += 1
                print(f"FAIL {tag}  {type(e).__name__}: {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
    print(f"\n{n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""repro — a JAX/Pallas reproduction of "A scalable system for primal-dual
optimization", grown into a serving-oriented solver platform.

The top-level namespace is the declarative facade (loaded lazily so
``import repro`` stays cheap):

    import repro as pd
    result = pd.Problem(A, b, prox="l1", reg=0.1).solve(tol=1e-4)

Everything else lives in the subpackages (repro.core, repro.operators,
repro.sparse, repro.kernels, repro.serve, ...) — see README.md's repo map.
"""
_FACADE = ("ExecutionPlan", "Problem", "Result", "SolveSpec", "plan",
           "solve", "solve_many")

__all__ = list(_FACADE)


def __getattr__(name):
    if name in _FACADE:
        from repro import api
        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_FACADE))

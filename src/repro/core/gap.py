"""Convergence certificates: smoothed gap, feasibility, objective residual.

G_{gamma,beta}(w) = f_beta(xbar) - g_gamma(ybar):
  f_beta(x) = f(x) + ||Ax-b||^2/(2 beta)           (max_y <Ax-b,y> - beta/2||y||^2)
  g_gamma(y) = min_x f(x)+<Ax-b,y>+gamma/2||x-xc||^2  (evaluated via the prox)

The paper's accelerated schedule guarantees G = O(1/k^2); tests fit the decay
exponent on the recorded history.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.prox import ProxOp
from repro.core.solver import PDState, SolverOps, beta_j, gamma_j


def dual_point(ops: SolverOps, b, lg, state: PDState,
               algorithm: str = "a2"):
    """The ybar iterate. A1 carries ybar directly in the yhat slot; A2
    carries yhat^{k}, from which ybar^{k+1} = yhat + (gamma/Lg)(A x* - b)
    (paper step 13)."""
    if algorithm == "a1":
        return state.yhat
    return state.yhat + (state.gamma / lg) * (ops.matvec(state.xstar) - b)


def certificates(ops: SolverOps, prox: ProxOp, b, lg, gamma0: float,
                 state: PDState, c: float = 3.0, xc=None,
                 algorithm: str = "a2"):
    """Returns dict(feasibility, objective, gap) for the current iterate."""
    k = state.k.astype(b.dtype)
    gamma = state.gamma
    beta = beta_j(k, gamma0, lg, c)
    ybar = dual_point(ops, b, lg, state, algorithm)
    r = ops.matvec(state.xbar) - b
    f_beta = prox.value(state.xbar) + jnp.vdot(r, r) / (2.0 * beta)
    z = ops.rmatvec(ybar)
    xc = jnp.zeros_like(z) if xc is None else xc
    xg = prox.apply(z, gamma, xc)
    g_gamma = (prox.value(xg) + jnp.vdot(ops.matvec(xg) - b, ybar)
               + 0.5 * gamma * jnp.vdot(xg - xc, xg - xc))
    return {
        "feasibility": jnp.linalg.norm(r),
        "objective": prox.value(state.xbar),
        "gap": f_beta - g_gamma,
        "gamma": gamma,
        "beta": beta,
    }

"""Proximal operators for the p-decomposable primal subproblem.

The solver's primal step is (paper step 12 / A2 step 14):

    x* = argmin_{x in X} f(x) + <zhat, x> + (gamma/2) ||x - xc||^2
       = prox_{f/gamma}( xc - zhat/gamma )

Every ``ProxOp`` exposes:
  * ``apply(zhat, gamma, xc)``  — the solver-facing form above (elementwise,
    fully parallel over the p blocks — the paper's "Do 1<=i<=p in parallel").
  * ``prox(v, t)``              — plain prox_{t f}(v) (tested for the Moreau
    identity / firm-nonexpansiveness properties).
  * ``value(x)``                — f(x) (for gap certificates).

``dummy`` reproduces the paper's scalability-test prox (Section 5):
argmin{...} := zhat + gamma — dependence on the dual variable and gamma kept,
cost of a real prox removed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ProxOp:
    name: str
    prox: Callable                       # (v, t) -> x
    value: Callable                      # (x,)  -> f(x)
    apply_fn: Callable | None = None     # override for non-potential proxes

    def apply(self, zhat, gamma, xc):
        if self.apply_fn is not None:
            return self.apply_fn(zhat, gamma, xc)
        return self.prox(xc - zhat / gamma, 1.0 / gamma)


def _soft(v, thr):
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)


def l1(reg: float = 1.0) -> ProxOp:
    return ProxOp(
        "l1",
        prox=lambda v, t: _soft(v, reg * t),
        value=lambda x: reg * jnp.sum(jnp.abs(x)),
    )


def zero() -> ProxOp:
    return ProxOp("zero", prox=lambda v, t: v, value=lambda x: jnp.zeros((), x.dtype))


def sq_l2(reg: float = 1.0) -> ProxOp:
    return ProxOp(
        "sq_l2",
        prox=lambda v, t: v / (1.0 + reg * t),
        value=lambda x: 0.5 * reg * jnp.sum(x * x),
    )


def elastic_net(reg: float = 1.0, reg2: float = 1.0) -> ProxOp:
    return ProxOp(
        "elastic_net",
        prox=lambda v, t: _soft(v, reg * t) / (1.0 + reg2 * t),
        value=lambda x: reg * jnp.sum(jnp.abs(x)) + 0.5 * reg2 * jnp.sum(x * x),
    )


def nonneg() -> ProxOp:
    return ProxOp("nonneg", prox=lambda v, t: jnp.maximum(v, 0.0),
                  value=lambda x: jnp.zeros((), x.dtype))


def box(lo: float = -1.0, hi: float = 1.0) -> ProxOp:
    return ProxOp("box", prox=lambda v, t: jnp.clip(v, lo, hi),
                  value=lambda x: jnp.zeros((), x.dtype))


def l1_box(reg: float = 1.0, lo: float = -1.0, hi: float = 1.0) -> ProxOp:
    """f = reg*||x||_1 over X = [lo, hi]^n (prox of l1 then project: valid for
    separable box since soft-threshold then clip solves the 1-d problem)."""
    return ProxOp(
        "l1_box",
        prox=lambda v, t: jnp.clip(_soft(v, reg * t), lo, hi),
        value=lambda x: reg * jnp.sum(jnp.abs(x)),
    )


def group_l1(reg: float = 1.0, group_size: int = 4) -> ProxOp:
    def prox(v, t):
        g = v.reshape(-1, group_size)
        nrm = jnp.linalg.norm(g, axis=1, keepdims=True)
        scale = jnp.maximum(1.0 - reg * t / jnp.maximum(nrm, 1e-30), 0.0)
        return (g * scale).reshape(v.shape)

    def value(x):
        return reg * jnp.sum(jnp.linalg.norm(x.reshape(-1, group_size), axis=1))

    return ProxOp("group_l1", prox=prox, value=value)


def dummy() -> ProxOp:
    """Paper Section 5 throughput prox: x* := zhat + gamma."""
    return ProxOp("dummy", prox=lambda v, t: v,
                  value=lambda x: jnp.zeros((), x.dtype),
                  apply_fn=lambda zhat, gamma, xc: zhat + gamma)


_REGISTRY = {
    "l1": l1, "zero": zero, "sq_l2": sq_l2, "elastic_net": elastic_net,
    "nonneg": nonneg, "box": box, "l1_box": l1_box, "group_l1": group_l1,
    "dummy": dummy,
}


def get_prox(name: str, **kw) -> ProxOp:
    if name not in _REGISTRY:
        raise KeyError(f"unknown prox {name!r}; known: {tuple(_REGISTRY)}")
    return _REGISTRY[name](**kw)

# The paper's primary contribution: the smoothed accelerated primal-dual
# solver (A1 faithful / A2 fused schedules), its prox library, convergence
# certificates, and the distributed execution strategies that map the
# paper's Hadoop/Spark data-movement designs onto a JAX device mesh.
from repro.core.gap import certificates
from repro.core.prox import ProxOp, get_prox
from repro.core.solver import (
    PDState, SolverOps, a1_init, a1_step, a2_init, a2_step, beta_j,
    dense_ops, ell_ops, estimate_lg, gamma_j, solve, solve_tol, tau_k,
)

__all__ = [n for n in dir() if not n.startswith("_")]

"""Consensus-constrained training with the A2 primal-dual schedule.

The paper lists *consensus optimization* among the motivating applications of
(1). Here the constraint set is

    min  sum_i f_i(theta_i)   s.t.  theta_i = z   for i = 1..P,

written as Ax = b with x = (theta_1..theta_P, z), b = 0, and A the incidence
operator (theta_i - z). Per coordinate, A^T A has eigenvalues {1, P+1}, so we
use the exact Lg = ||A||^2 = P + 1 instead of the paper's loose column-sum
(both are valid upper bounds; the exact one is free here — recorded in
DESIGN.md as an adaptation).

Everything in A2 is elementwise per parameter except:
  * matvec      r_i = theta_i - z            (local on each data shard)
  * rmatvec     (y_i, -psum_i y_i)           (ONE psum per iteration — the
                                              2-barrier structure survives)
  * the f_i prox — no closed form for a neural loss, so the primal
    subproblem argmin f_i(t) + <zhat_i, t> + gamma/2 ||t - c||^2 is solved
    INEXACTLY with a few SGD steps (warm-started at the previous theta*_i).

This is the bridge that makes the paper's solver a first-class *trainer*
feature: each data-parallel shard trains its own replica; the dual variables
enforce consensus asymptotically — an alternative to lockstep gradient
all-reduce whose per-iteration wire cost is ONE psum of the parameters
regardless of how many local prox steps are taken (vs. one all-reduce per
SGD step for DDP): the paper's "reduce synchronization points" idea applied
to distributed training.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.solver import beta_j, gamma_j, tau_k

tmap = jax.tree_util.tree_map


class ConsensusState(NamedTuple):
    theta_bar: dict      # xbar, replica block (per-shard)
    theta_star: dict     # xstar, replica block
    z_bar: dict          # xbar, consensus block (replicated)
    z_star: dict         # xstar, consensus block
    yhat: dict           # dual (per-shard, theta-shaped)
    k: jax.Array


@dataclasses.dataclass(frozen=True)
class ConsensusConfig:
    gamma0: float = 1.0
    c: float = 3.0
    inner_steps: int = 4         # inexact-prox SGD steps
    inner_lr: float = 0.1
    axis: str = "data"           # replica axis name inside shard_map


def _inexact_prox(loss_fn, batch, zhat, gamma, center, init, cfg):
    """~argmin f(t; batch) + <zhat, t> + gamma/2 ||t - center||^2 via SGD."""

    def phi_grad(t):
        g = jax.grad(loss_fn)(t, batch)
        return tmap(lambda gi, zi, ti, ci: gi + zi + gamma * (ti - ci),
                    g, zhat, t, center)

    def body(_, t):
        g = phi_grad(t)
        return tmap(lambda ti, gi: ti - cfg.inner_lr / (1.0 + gamma) * gi, t, g)

    return jax.lax.fori_loop(0, cfg.inner_steps, body, init)


def consensus_init(loss_fn: Callable, params, batch, cfg: ConsensusConfig,
                   num_replicas: int):
    """A2 init (steps 7-9): tau_{-1}=1, yhat^{-1}=0; one primal block."""
    lg = float(num_replicas + 1)
    gamma0 = jnp.asarray(cfg.gamma0, jnp.float32)
    zeros = tmap(jnp.zeros_like, params)
    # zhat = A^T yhat^{-1} = 0; center = current params (warm center)
    theta_star = _inexact_prox(loss_fn, batch, zeros, gamma0, params, params,
                               cfg)
    z_star = tmap(lambda u: jax.lax.pmean(u, cfg.axis), theta_star)
    return ConsensusState(theta_bar=theta_star, theta_star=theta_star,
                          z_bar=z_star, z_star=z_star,
                          yhat=tmap(jnp.zeros_like, params),
                          k=jnp.zeros((), jnp.int32)), lg


def consensus_step(loss_fn: Callable, state: ConsensusState, batch,
                   cfg: ConsensusConfig, lg: float) -> ConsensusState:
    """One A2 iteration on the consensus problem (runs inside shard_map)."""
    c = cfg.c
    k = state.k.astype(jnp.float32)
    tk = tau_k(k, c)
    bk = beta_j(k, cfg.gamma0, lg, c)
    gk = gamma_j(k, cfg.gamma0, c)
    gk_eff = jnp.where(state.k == 0, lg / beta_j(0, cfg.gamma0, lg, c), gk)
    c0 = 1.0 - tk
    c1 = (1.0 - tk) * gk_eff / lg
    c2 = tk / bk
    # eq (15) specialization: A(c1 x* + c2 xbar) = (c1 th*_i + c2 thbar_i)
    #                                            - (c1 z*   + c2 zbar), b = 0
    yhat = tmap(
        lambda yh, ts, tb, zs, zb:
            c0 * yh + (c1 * ts + c2 * tb) - (c1 * zs + c2 * zb),
        state.yhat, state.theta_star, state.theta_bar, state.z_star,
        state.z_bar)
    # backward: zhat_theta_i = yhat_i ; zhat_z = -sum_i yhat_i   [barrier]
    zhat_z = tmap(lambda u: -jax.lax.psum(u, cfg.axis), yhat)
    gk1 = gamma_j(k + 1.0, cfg.gamma0, c)
    # primal blocks: inexact prox for theta_i (center = consensus z_bar);
    # exact prox for z (f_z = 0): z* = center - zhat/gamma
    theta_star = _inexact_prox(loss_fn, batch, yhat, gk1, state.z_bar,
                               state.theta_star, cfg)
    z_star = tmap(lambda zb, zz: zb - zz / gk1, state.z_bar, zhat_z)
    theta_bar = tmap(lambda b_, s: (1.0 - tk) * b_ + tk * s,
                     state.theta_bar, theta_star)
    z_bar = tmap(lambda b_, s: (1.0 - tk) * b_ + tk * s, state.z_bar, z_star)
    return ConsensusState(theta_bar=theta_bar, theta_star=theta_star,
                          z_bar=z_bar, z_star=z_star, yhat=yhat,
                          k=state.k + 1)


def consensus_gap(state: ConsensusState, axis: str = "data") -> jax.Array:
    """||A xbar||^2 = sum_i ||theta_bar_i - z_bar||^2 (psum'd feasibility)."""
    sq = tmap(lambda t, z: jnp.sum((t - z) ** 2), state.theta_bar, state.z_bar)
    total = jax.tree_util.tree_reduce(jnp.add, sq)
    return jax.lax.psum(total, axis)

"""Pure-numpy A1 oracle — the paper's "Matlab reference" role.

Deliberately written as a line-by-line transcription of pseudocode A1 (no
closed-form schedule reuse, explicit beta recurrence) so the JAX solvers are
checked against an *independent* implementation.
"""
from __future__ import annotations

import numpy as np


def soft(v, thr):
    return np.sign(v) * np.maximum(np.abs(v) - thr, 0.0)


def a1_reference(a: np.ndarray, b: np.ndarray, reg: float, gamma0: float,
                 iterations: int, c_bar: float = 1.0,
                 record: bool = False):
    """A1 with f = reg*||x||_1, X = R^n, zero center points."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    m, n = a.shape
    # Init (steps 1-7)
    lg_i = (a * a).sum(axis=0)                # ||A_i||_2^2 per column
    lg = lg_i.sum()
    c = max(3.0, c_bar)
    tau = c / (c + 2.0)
    beta = 3.0 * c * c * lg / ((c + 2.0) ** 2 * gamma0)
    # eq (3): xbar0 = argmin f + <A^T yc, x> + gamma0/2 ||x||^2, yc = 0
    xbar = soft(np.zeros(n), reg / gamma0)
    ybar = (a @ xbar - b) / beta              # eq (4)
    xstar = xbar.copy()
    hist = []
    for k in range(iterations):
        tau = c / (k + c + 2.0)               # eq (5)
        gamma_next = gamma0 * (c + 2.0) / (k + c + 3.0)
        ystar = (a @ xbar - b) / beta         # eq (6)
        yhat = (1.0 - tau) * ybar + tau * ystar
        zhat = a.T @ yhat                     # eq (7)
        xstar = soft(-zhat / gamma_next, reg / gamma_next)   # eq (8), xc = 0
        xbar = (1.0 - tau) * xbar + tau * xstar
        ybar = yhat + (gamma_next / lg) * (a @ xstar - b)    # eq (9)
        beta = lg * c * c * (k + c + 4.0) / (
            gamma0 * (c + 2.0) * (k + c + 3.0) * (k + 3.0))  # eq (10)
        if record:
            hist.append(dict(k=k + 1,
                             feasibility=float(np.linalg.norm(a @ xbar - b)),
                             objective=float(reg * np.abs(xbar).sum()),
                             gap=smoothed_gap(a, b, reg, xbar, ybar,
                                              gamma_next, beta)))
    return dict(xbar=xbar, xstar=xstar, ybar=ybar, lg=lg, history=hist)


def smoothed_gap(a, b, reg, xbar, ybar, gamma, beta) -> float:
    """G_{gamma,beta}(w) = f_beta(xbar) - g_gamma(ybar)  (Section 1)."""
    r = a @ xbar - b
    f_beta = reg * np.abs(xbar).sum() + (r @ r) / (2.0 * beta)
    z = a.T @ ybar
    xg = soft(-z / gamma, reg / gamma)
    g_gamma = (reg * np.abs(xg).sum() + (a @ xg - b) @ ybar
               + 0.5 * gamma * (xg @ xg))
    return float(f_beta - g_gamma)

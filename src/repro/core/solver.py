"""The paper's primal-dual algorithms A1 (faithful) and A2 (fused).

Problem:  min f(x)  s.t.  Ax = b, x in X,   f/X p-decomposable,
smoothed with d_S(x, xc) = 1/2||x - xc||^2 and b_y(y) = 1/2||y||^2.

Parameter schedules (closed forms; c = max(3, c_bar) = 3):
    tau_k   = c / (k + c + 2)
    gamma_j = gamma0 (c+2) / (j + c + 2)                      (j >= 0)
    beta_j  = Lg c^2 (j+c+3) / (gamma0 (c+2)(j+c+2)(j+2))     (j >= 0)
(gamma_0 = gamma0 and beta_0 = 3 c^2 Lg /((c+2)^2 gamma0) fall out of the
closed forms — the paper's init steps 5-6.)

A1 per iteration: 2 forward + 1 backward applications, >=4 sync points.
A2 per iteration: 1 forward (on the linearity-combined vector) + 1 backward,
2 sync points — the paper's system contribution. Both produce *identical*
iterates (verified in tests, mirroring the paper's Matlab check).

The operator bundle ``SolverOps`` abstracts the execution substrate: plain
jnp (reference), Pallas kernels (fused HBM-pass versions), shard_map'ped
distributed operators (repro.core.distributed), or the stacked batched
operators of the serving engine — the solver body is reused verbatim on all
of them, since everything but the operators is elementwise.  Bundles are
constructed exclusively through the (format, backend) registry in
``repro.operators`` (``LinearOperator.solver_ops()`` is the one
construction site); ``dense_ops``/``ell_ops`` below are thin adapters over
that registry kept for legacy callers — do not build ``SolverOps`` by hand.

Two families of drivers:

* single problem — ``solve`` (fixed iterations, lax.scan) and ``solve_tol``
  (early exit on the relative-feasibility criterion, checked every
  ``check_every`` iterations).
* batched — ``batched_init`` / ``batched_step`` / ``batched_solve`` /
  ``batched_solve_tol`` run B independent problems (stacked operands with a
  leading batch axis, per-slot ``lg``/``gamma0``/``k`` schedules) through
  the same A1/A2 bodies.  ``batched_step`` takes a per-slot boolean
  ``mask``: finished slots are frozen (their state re-emitted unchanged),
  which is what lets the serving engine (repro.serve.solver_engine) retire
  problems independently while the bucket keeps stepping.

Schedule helpers double as the numeric reference (c = 3):

>>> tau_k(0.0), tau_k(1.0)
(0.6, 0.5)
>>> gamma_j(0, 2.0), gamma_j(3, 2.0)
(2.0, 1.25)
>>> beta_j(0, 1.0, 1.0)
1.08
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prox import ProxOp
from repro.deprecation import warn_once


# One default for the feasibility-check cadence, everywhere.  Every driver
# (solve_tol, batched_solve_tol, the serving engine, the distributed bodies,
# the benchmark CLIs) resolves check_every=None to this value — historically
# the solver used 8 while the engine/benchmarks used 16, so "the default"
# depended on the entry point.  The planner records the resolution in plan
# reasons (repro.plan.decide_check_every).
DEFAULT_CHECK_EVERY = 16


# --------------------------------------------------------------------------
# Parameter schedules
# --------------------------------------------------------------------------

def tau_k(k, c: float = 3.0):
    return c / (k + c + 2.0)


def gamma_j(j, gamma0: float, c: float = 3.0):
    return gamma0 * (c + 2.0) / (j + c + 2.0)


def beta_j(j, gamma0: float, lg, c: float = 3.0):
    return lg * c * c * (j + c + 3.0) / (gamma0 * (c + 2.0) * (j + c + 2.0) * (j + 2.0))


# --------------------------------------------------------------------------
# Lipschitz-constant estimation
# --------------------------------------------------------------------------

def estimate_lg(op, n: int | None = None, max_iters: int = 500,
                tol: float = 1e-6, seed: int = 0) -> float:
    """Estimate ``Lg = ||A||_2^2`` (the top eigenvalue of A^T A) by power
    iteration using only ``matvec``/``rmatvec`` — so the planner never needs
    the caller to hand-pass ``lg``, even for matrix-free operators.

    ``op`` is anything exposing ``matvec`` and ``rmatvec`` (a
    ``LinearOperator`` or a ``SolverOps``); ``n`` is the primal dimension,
    inferred from ``op.shape`` when available.  The start vector is
    deterministic (``seed``), iteration stops once the eigenvalue estimate
    is ``tol``-relatively converged.

    Note the distinction from the paper's init step 1: the paper uses
    ``sum_i ||A_i||^2 = ||A||_F^2`` (exact, host-side, needs the values);
    this helper returns the tight constant ``||A||_2^2 <= ||A||_F^2`` and
    is the fallback when only the operator's action is available.

    >>> import jax.numpy as jnp
    >>> d = jnp.diag(jnp.asarray([3.0, 1.0, 0.5]))
    >>> ops = SolverOps(matvec=lambda x: d @ x, rmatvec=lambda y: d.T @ y)
    >>> round(estimate_lg(ops, n=3), 4)   # ||A||_2^2 = 9
    9.0
    """
    if n is None:
        shape = getattr(op, "shape", None)
        if shape is not None and shape[1] is not None:
            n = shape[1]
        else:
            raise ValueError("estimate_lg needs n when op has no shape")
    rng = np.random.default_rng(seed)
    v0 = rng.standard_normal(n).astype(np.float32)
    v = jnp.asarray(v0 / np.linalg.norm(v0))
    lam = 0.0
    for _ in range(max_iters):
        w = op.rmatvec(op.matvec(v))
        new = float(jnp.linalg.norm(w))
        if new == 0.0:                       # A == 0
            return 0.0
        v = w / new
        if abs(new - lam) <= tol * max(new, 1.0):
            return new
        lam = new
    return lam


# --------------------------------------------------------------------------
# Operator bundle
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolverOps:
    """matvec: x -> Ax;  rmatvec: y -> A^T y.

    fused_dual(yhat, xstar, xbar, b, c0, c1, c2, c3)
        = c0*yhat + A(c1*xstar + c2*xbar) - c3*b     (eq. 15, one A pass)
    prox_update(prox, zhat, gamma, tau, xbar, xc) -> (xstar_new, xbar_new)
        fused prox + heavy-ball averaging (paper step 14 inner block).
    Defaults compose from matvec; kernel/distributed backends override.
    """

    matvec: Callable
    rmatvec: Callable
    fused_dual: Optional[Callable] = None
    prox_update: Optional[Callable] = None

    def dual(self, yhat, xstar, xbar, b, c0, c1, c2, c3):
        if self.fused_dual is not None:
            return self.fused_dual(yhat, xstar, xbar, b, c0, c1, c2, c3)
        u = c1 * xstar + c2 * xbar
        return c0 * yhat + self.matvec(u) - c3 * b

    def primal(self, prox: ProxOp, zhat, gamma, tau, xbar, xc):
        if self.prox_update is not None:
            return self.prox_update(prox, zhat, gamma, tau, xbar, xc)
        xstar = prox.apply(zhat, gamma, xc)
        return xstar, (1.0 - tau) * xbar + tau * xstar


class PDState(NamedTuple):
    """A2 carry. For A1, ybar additionally carried (yhat reused as scratch)."""
    xbar: jax.Array
    xstar: jax.Array
    yhat: jax.Array      # A2: yhat^{k-1};  A1: ybar^k
    gamma: jax.Array     # gamma used to produce current xstar
    k: jax.Array


# --------------------------------------------------------------------------
# A1 — faithful pseudocode
# --------------------------------------------------------------------------

def a1_init(ops: SolverOps, prox: ProxOp, b, lg, gamma0: float, c: float = 3.0,
            xc=None, yc=None, n: int | None = None):
    n = n if n is not None else ops.rmatvec(jnp.zeros_like(b)).shape[0]
    xc = jnp.zeros(n, b.dtype) if xc is None else xc
    yc = jnp.zeros_like(b) if yc is None else yc
    beta0 = beta_j(0, gamma0, lg, c)
    zc = ops.rmatvec(yc)
    xbar0 = prox.apply(zc, jnp.asarray(gamma0, b.dtype), xc)      # eq (3)
    ybar0 = (ops.matvec(xbar0) - b) / beta0                        # eq (4)
    return PDState(xbar=xbar0, xstar=xbar0, yhat=ybar0,
                   gamma=jnp.asarray(gamma0, b.dtype),
                   k=jnp.zeros((), jnp.int32))


def a1_step(ops: SolverOps, prox: ProxOp, b, lg, gamma0: float,
            state: PDState, c: float = 3.0, xc=None) -> PDState:
    k = state.k.astype(b.dtype)
    tk = tau_k(k, c)
    gk1 = gamma_j(k + 1.0, gamma0, c)
    bk = beta_j(k, gamma0, lg, c)
    # step 10: yhat = (1-t) ybar + t * (A xbar - b)/beta_k      [2 syncs: matvec]
    ystar = (ops.matvec(state.xbar) - b) / bk
    yhat = (1.0 - tk) * state.yhat + tk * ystar
    # steps 11-12: zhat = A^T yhat ; prox ; averaging
    zhat = ops.rmatvec(yhat)
    xc = jnp.zeros_like(zhat) if xc is None else xc
    xstar, xbar = ops.primal(prox, zhat, gk1, tk, state.xbar, xc)
    # step 13: ybar^{k+1} = yhat + (gamma_{k+1}/Lg)(A xstar - b)  [2nd forward]
    ybar = yhat + (gk1 / lg) * (ops.matvec(xstar) - b)
    return PDState(xbar=xbar, xstar=xstar, yhat=ybar, gamma=gk1,
                   k=state.k + 1)


# --------------------------------------------------------------------------
# A2 — optimized parallel execution (the paper's contribution)
# --------------------------------------------------------------------------

def a2_init(ops: SolverOps, prox: ProxOp, b, lg, gamma0: float, c: float = 3.0,
            xc=None, yc=None, n: int | None = None):
    """Steps 7-9: k=-1, tau_{-1}=1, yhat^{-1}=yc; one primal block; yhat:=0."""
    n = n if n is not None else ops.rmatvec(jnp.zeros_like(b)).shape[0]
    xc = jnp.zeros(n, b.dtype) if xc is None else xc
    yc = jnp.zeros_like(b) if yc is None else yc
    zc = ops.rmatvec(yc)
    gamma0_ = jnp.asarray(gamma0, b.dtype)
    xstar, _ = ops.primal(prox, zc, gamma0_, jnp.asarray(1.0, b.dtype),
                          jnp.zeros(n, b.dtype), xc)
    # tau_{-1} = 1  =>  xbar^0 = xstar
    return PDState(xbar=xstar, xstar=xstar, yhat=jnp.zeros_like(b),
                   gamma=gamma0_, k=jnp.zeros((), jnp.int32))


def a2_step(ops: SolverOps, prox: ProxOp, b, lg, gamma0: float,
            state: PDState, c: float = 3.0, xc=None) -> PDState:
    """One fused iteration: 1 forward + 1 backward + 1 prox, 2 sync barriers."""
    k = state.k.astype(b.dtype)
    tk = tau_k(k, c)
    bk = beta_j(k, gamma0, lg, c)
    # eq (13): for k=0 the gamma in eq (15) is Lg/beta_0, not the input gamma0
    gk_eff = jnp.where(state.k == 0, lg / beta_j(0, gamma0, lg, c), state.gamma)
    # eq (15): ONE forward application on the combined vector  [barrier 1]
    c0 = 1.0 - tk
    c1 = (1.0 - tk) * gk_eff / lg
    c2 = tk / bk
    c3 = c1 + c2
    yhat = ops.dual(state.yhat, state.xstar, state.xbar, b, c0, c1, c2, c3)
    # step 14: backward + prox + averaging                      [barrier 2]
    gk1 = gamma_j(k + 1.0, gamma0, c)
    zhat = ops.rmatvec(yhat)
    xc = jnp.zeros_like(zhat) if xc is None else xc
    xstar, xbar = ops.primal(prox, zhat, gk1, tk, state.xbar, xc)
    return PDState(xbar=xbar, xstar=xstar, yhat=yhat, gamma=gk1,
                   k=state.k + 1)


# --------------------------------------------------------------------------
# Drivers
# --------------------------------------------------------------------------

def solve(ops: SolverOps, prox: ProxOp, b, lg, gamma0: float = 1.0,
          iterations: int = 100, algorithm: str = "a2", c: float = 3.0,
          xc=None, yc=None, n: int | None = None, record_every: int = 0,
          unroll: int = 1):
    """Fixed-iteration solve via lax.scan. Returns (state, history|None).

    history (when record_every>0): dict of per-record feasibility ||A xbar - b||,
    objective f(xbar), and the iterate snapshots' k.

    >>> import jax.numpy as jnp
    >>> from repro.core.prox import get_prox
    >>> from repro.operators import make_operator
    >>> ops = make_operator("dense", "jnp", 2.0 * jnp.eye(2)).solver_ops()
    >>> st, _ = solve(ops, get_prox("zero"), jnp.ones(2), lg=8.0,
    ...               gamma0=1.0, iterations=300)
    >>> round(float(st.xbar[0]), 2)   # min 0 s.t. 2x = 1
    0.5
    """
    init = (a2_init if algorithm == "a2" else a1_init)(
        ops, prox, b, lg, gamma0, c, xc=xc, yc=yc, n=n)
    step = a2_step if algorithm == "a2" else a1_step

    def body(state, _):
        new = step(ops, prox, b, lg, gamma0, state, c)
        rec = ()
        if record_every:
            feas = jnp.linalg.norm(ops.matvec(new.xbar) - b)
            rec = (new.k, feas, prox.value(new.xbar))
        return new, rec

    final, recs = jax.lax.scan(body, init, None, length=iterations,
                               unroll=unroll)
    if record_every:
        ks, feas, obj = recs
        sel = slice(record_every - 1, None, record_every)
        history = {"k": ks[sel], "feasibility": feas[sel], "objective": obj[sel]}
        return final, history
    return final, None


def solve_tol(ops: SolverOps, prox: ProxOp, b, lg, gamma0: float = 1.0,
              max_iterations: int = 10_000, tol: float = 1e-6,
              algorithm: str = "a2", c: float = 3.0,
              check_every: int | None = None):
    """Early-stopping solve (paper step 8/10 stopping_criterion):
    relative feasibility ||A xbar - b|| / max(1, ||b||) < tol.

    ``max_iterations`` is a hard cap: the inner block is clamped to
    ``min(check_every, max_iterations - k)`` so the final partial block
    never oversteps the budget (feasibility is still only *checked* on the
    ``check_every`` grid and once at the cap).  ``check_every=None``
    resolves to ``DEFAULT_CHECK_EVERY``."""
    check_every = DEFAULT_CHECK_EVERY if check_every is None else check_every
    init = (a2_init if algorithm == "a2" else a1_init)(ops, prox, b, lg, gamma0, c)
    step = a2_step if algorithm == "a2" else a1_step
    bnorm = jnp.maximum(jnp.linalg.norm(b), 1.0)

    def cond(state):
        feas = jnp.linalg.norm(ops.matvec(state.xbar) - b) / bnorm
        return jnp.logical_and(state.k < max_iterations, feas >= tol)

    def body(state):  # <= check_every inner steps per feasibility check
        return jax.lax.fori_loop(
            0, jnp.minimum(check_every, max_iterations - state.k),
            lambda _, s: step(ops, prox, b, lg, gamma0, s, c), state)

    return jax.lax.while_loop(cond, body, init)


# --------------------------------------------------------------------------
# Batched drivers — B independent problems, one vmapped A2 body
# --------------------------------------------------------------------------
#
# Operands carry a leading batch axis: b (B, m), lg (B,), gamma0 (B,),
# every PDState leaf (B, ...) — gamma and k are per-slot, so each problem
# runs its own schedule (tau_k/gamma_j/beta_j broadcast elementwise over
# the slot axis).  These bodies deliberately mirror a1_step/a2_step above
# term for term (incl. the eq-13 k==0 gk_eff case): any numeric change
# there must be made here too — the batched-vs-sequential equality tests
# in tests/test_solver_engine.py enforce the pairing.  ``ops`` must be a *batched* SolverOps whose
# matvec/rmatvec/fused_dual map (B, n) -> (B, m): build one through the
# stacked formats in the registry (``make_operator("stacked_ell", ...)``).
# Padding inside a bucket is exact, not approximate: padded rows are
# all-zero with b=0 (dual coordinate stays 0), padded columns are all-zero
# with the prox centered at 0 (primal coordinate stays 0), so a problem's
# iterates in a padded slot match its standalone solve to float tolerance.


def mask_state(mask: jax.Array, new: PDState, old: PDState) -> PDState:
    """Per-slot freeze: keep ``new`` where mask is True, ``old`` elsewhere."""
    m2 = mask[:, None]
    return PDState(xbar=jnp.where(m2, new.xbar, old.xbar),
                   xstar=jnp.where(m2, new.xstar, old.xstar),
                   yhat=jnp.where(m2, new.yhat, old.yhat),
                   gamma=jnp.where(mask, new.gamma, old.gamma),
                   k=jnp.where(mask, new.k, old.k))


def batched_init(ops: SolverOps, prox: ProxOp, b, lg, gamma0,
                 algorithm: str = "a2", c: float = 3.0,
                 n: int | None = None) -> PDState:
    """Batched a1/a2 init: b (B, m), lg (B,), gamma0 (B,) -> PDState (B, ...)."""
    bsz = b.shape[0]
    lg = jnp.asarray(lg, b.dtype)
    g0 = jnp.asarray(gamma0, b.dtype)
    n = n if n is not None else ops.rmatvec(jnp.zeros_like(b)).shape[-1]
    xc = jnp.zeros((bsz, n), b.dtype)
    zc = ops.rmatvec(jnp.zeros_like(b))
    if algorithm == "a2":
        # steps 7-9: one primal block with tau_{-1} = 1, then yhat := 0
        xstar, _ = ops.primal(prox, zc, g0[:, None],
                              jnp.ones((bsz, 1), b.dtype), xc, xc)
        return PDState(xbar=xstar, xstar=xstar, yhat=jnp.zeros_like(b),
                       gamma=g0, k=jnp.zeros((bsz,), jnp.int32))
    beta0 = beta_j(0.0, g0, lg, c)
    xbar0 = prox.apply(zc, g0[:, None], xc)
    ybar0 = (ops.matvec(xbar0) - b) / beta0[:, None]
    return PDState(xbar=xbar0, xstar=xbar0, yhat=ybar0, gamma=g0,
                   k=jnp.zeros((bsz,), jnp.int32))


def batched_step(ops: SolverOps, prox: ProxOp, b, lg, gamma0, state: PDState,
                 algorithm: str = "a2", c: float = 3.0,
                 mask: jax.Array | None = None) -> PDState:
    """One masked batched iteration; slots where ``mask`` is False are frozen.

    The compute still runs for frozen slots (SIMD batch), but their state is
    re-emitted unchanged — k does not advance, iterates do not move — so a
    retired problem's result is immutable while its bucket keeps stepping.
    """
    lg = jnp.asarray(lg, b.dtype)
    g0 = jnp.asarray(gamma0, b.dtype)
    k = state.k.astype(b.dtype)
    tk = tau_k(k, c)                                   # (B,)
    gk1 = gamma_j(k + 1.0, g0, c)
    xc = None
    if algorithm == "a2":
        bk = beta_j(k, g0, lg, c)
        gk_eff = jnp.where(state.k == 0, lg / beta_j(0.0, g0, lg, c),
                           state.gamma)
        c0 = 1.0 - tk
        c1 = (1.0 - tk) * gk_eff / lg
        c2 = tk / bk
        c3 = c1 + c2
        yhat = ops.dual(state.yhat, state.xstar, state.xbar, b, c0[:, None],
                        c1[:, None], c2[:, None], c3[:, None])
        zhat = ops.rmatvec(yhat)
        xc = jnp.zeros_like(zhat)
        xstar, xbar = ops.primal(prox, zhat, gk1[:, None], tk[:, None],
                                 state.xbar, xc)
        new = PDState(xbar=xbar, xstar=xstar, yhat=yhat, gamma=gk1,
                      k=state.k + 1)
    else:
        bk = beta_j(k, g0, lg, c)
        ystar = (ops.matvec(state.xbar) - b) / bk[:, None]
        yhat = (1.0 - tk)[:, None] * state.yhat + tk[:, None] * ystar
        zhat = ops.rmatvec(yhat)
        xc = jnp.zeros_like(zhat)
        xstar, xbar = ops.primal(prox, zhat, gk1[:, None], tk[:, None],
                                 state.xbar, xc)
        ybar = yhat + (gk1 / lg)[:, None] * (ops.matvec(xstar) - b)
        new = PDState(xbar=xbar, xstar=xstar, yhat=ybar, gamma=gk1,
                      k=state.k + 1)
    if mask is None:
        return new
    return mask_state(mask, new, state)


def batched_feasibility(ops: SolverOps, b, state: PDState) -> jax.Array:
    """Per-slot relative feasibility ||A xbar - b|| / max(1, ||b||) -> (B,)."""
    r = ops.matvec(state.xbar) - b
    return (jnp.linalg.norm(r, axis=-1)
            / jnp.maximum(jnp.linalg.norm(b, axis=-1), 1.0))


def batched_solve(ops: SolverOps, prox: ProxOp, b, lg, gamma0,
                  iterations: int = 100, algorithm: str = "a2",
                  c: float = 3.0, unroll: int = 1) -> PDState:
    """Fixed-iteration batched solve (no masking — all slots step together).

    >>> import jax.numpy as jnp
    >>> from repro.core.prox import get_prox
    >>> from repro.operators import make_operator
    >>> d = jnp.stack([2.0 * jnp.eye(2), 4.0 * jnp.eye(2)])
    >>> ops = make_operator("stacked_dense", "jnp", d).solver_ops()
    >>> st = batched_solve(ops, get_prox("zero"), jnp.ones((2, 2)),
    ...                    lg=jnp.array([8.0, 32.0]),
    ...                    gamma0=jnp.array([1.0, 1.0]), iterations=300)
    >>> [round(float(v), 2) for v in st.xbar[:, 0]]   # solves Ax = 1 per slot
    [0.5, 0.25]
    """
    init = batched_init(ops, prox, b, lg, gamma0, algorithm, c)

    def body(state, _):
        return batched_step(ops, prox, b, lg, gamma0, state, algorithm, c), ()

    final, _ = jax.lax.scan(body, init, None, length=iterations,
                            unroll=unroll)
    return final


def batched_solve_tol(ops: SolverOps, prox: ProxOp, b, lg, gamma0,
                      max_iterations=10_000, tol=1e-6,
                      algorithm: str = "a2", c: float = 3.0,
                      check_every: int | None = None,
                      active: jax.Array | None = None) -> PDState:
    """Batched early-exit solve: per-slot ``solve_tol`` semantics.

    tol / max_iterations may be scalars or (B,) arrays.  Each slot stops
    (is mask-frozen) once its relative feasibility drops below its tol or
    its k reaches its max_iterations, checked every ``check_every``
    iterations — the same cadence as ``solve_tol``, so a slot's final state
    matches the standalone call.  Like ``solve_tol``, max_iterations is a
    hard per-slot cap: inside a check block each slot additionally freezes
    at ``k == max_iterations``, so ragged budgets never overrun by a
    partial block.  ``active`` pre-masks slots so a partially
    filled batch never steps its empty slots.  (The serving engine
    implements the same semantics with its own jit'd bodies —
    repro.serve.solver_engine — because it also needs mid-stream admission;
    this driver is the one-shot batch API.)
    """
    check_every = DEFAULT_CHECK_EVERY if check_every is None else check_every
    bsz = b.shape[0]
    tol = jnp.broadcast_to(jnp.asarray(tol, b.dtype), (bsz,))
    maxit = jnp.broadcast_to(jnp.asarray(max_iterations, jnp.int32), (bsz,))
    state = batched_init(ops, prox, b, lg, gamma0, algorithm, c)
    act = jnp.ones((bsz,), bool) if active is None else active
    act = act & (batched_feasibility(ops, b, state) >= tol) & (state.k < maxit)

    def cond(carry):
        return jnp.any(carry[1])

    def body(carry):
        state, act = carry
        state = jax.lax.fori_loop(
            0, check_every,
            lambda _, s: batched_step(ops, prox, b, lg, gamma0, s, algorithm,
                                      c, mask=act & (s.k < maxit)),
            state)
        feas = batched_feasibility(ops, b, state)
        return state, act & (feas >= tol) & (state.k < maxit)

    state, _ = jax.lax.while_loop(cond, body, (state, act))
    return state


def batched_solve_tol_fused(ops: SolverOps, prox: ProxOp, b, lg, gamma0,
                            block_fn, max_iterations=10_000, tol=1e-6,
                            algorithm: str = "a2", c: float = 3.0,
                            active: jax.Array | None = None) -> PDState:
    """``batched_solve_tol`` with the check block delegated to ``block_fn``.

    ``block_fn(state, mask) -> (state, feas)`` owns the entire inner block —
    ``check_every`` masked steps plus the feasibility recheck — so a fused
    one-kernel implementation (``repro.kernels.fused_check_block``, with the
    per-slot ``max_iterations`` freeze baked into the kernel) slots in
    without this driver knowing the format.  ``ops`` is only used for init
    and the pre-loop feasibility check; the state/feas contract of
    ``block_fn`` must match ``check_every`` applications of
    ``batched_step`` + ``batched_feasibility`` (tests enforce parity with
    ``batched_solve_tol`` at 1e-5).
    """
    bsz = b.shape[0]
    tol = jnp.broadcast_to(jnp.asarray(tol, b.dtype), (bsz,))
    maxit = jnp.broadcast_to(jnp.asarray(max_iterations, jnp.int32), (bsz,))
    state = batched_init(ops, prox, b, lg, gamma0, algorithm, c)
    act = jnp.ones((bsz,), bool) if active is None else active
    act = act & (batched_feasibility(ops, b, state) >= tol) & (state.k < maxit)

    def cond(carry):
        return jnp.any(carry[1])

    def body(carry):
        state, act = carry
        state, feas = block_fn(state, act)
        return state, act & (feas >= tol) & (state.k < maxit)

    state, _ = jax.lax.while_loop(cond, body, (state, act))
    return state


def dense_ops(a: jax.Array) -> SolverOps:
    """Deprecated shim over the (dense, jnp) registry operator — state the
    problem through the facade (``repro.api.Problem``) or build operators
    via ``repro.operators.make_operator`` instead."""
    from repro.operators import make_operator

    warn_once("repro.core.solver.dense_ops",
              "repro.api.Problem(...).solve() or "
              "make_operator('dense', 'jnp', a).solver_ops()")
    return make_operator("dense", "jnp", a).solver_ops()


def ell_ops(ell_a, ell_at) -> SolverOps:
    """Deprecated shim: (ELL of A, ELL of A^T) -> SolverOps via registry —
    use the facade or ``make_operator('ell', 'jnp', ...)`` instead."""
    from repro.operators import make_operator

    warn_once("repro.core.solver.ell_ops",
              "repro.api.Problem(...).solve() or "
              "make_operator('ell', 'jnp', a, at).solver_ops()")
    return make_operator("ell", "jnp", ell_a, ell_at).solver_ops()

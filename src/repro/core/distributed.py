"""Distributed execution strategies for the primal-dual solver.

Each strategy is a shard_map'd iteration whose collective signature mirrors
one of the paper's Hadoop/Spark designs (DESIGN.md section 2):

  rowpart   A row-sharded, x replicated, y row-sharded.
            fwd: local;            bwd: psum(n)            ~ MR1/MR3
  colpart   A^T row-sharded (column blocks of A), x col-sharded, y replicated.
            fwd: psum(m);          bwd: local              ~ MR2 (transposed)
  dualpart  BOTH copies cached (the Spark dual-RDD trick), x col-, y row-sharded.
            fwd: reduce-scatter(m) bwd: reduce-scatter(n)  ~ Spark + MR4 combiner
  block2d   A in a 2-D (data x model) block grid; x sharded over `model`,
            y over `data`.  fwd: psum(m/R) over model; bwd: psum(n/C) over data.
            The 1000+-node generalization (per-device wire bytes shrink with
            BOTH mesh axes). `dual_copy=True` additionally stores each block's
            transpose so the backward is gather-only (kernel-friendly) instead
            of scatter-add — the paper's memory-for-network trade, per block.

The solver body (repro.core.solver a1_step/a2_step) is reused verbatim inside
shard_map: everything except the operators is elementwise, and the schedule
scalars are computed redundantly per device — the "embarrassingly parallel
except 2 barriers" structure of pseudocode A2.

The per-strategy local operators themselves live in repro.operators.dist
(one LinearOperator builder per strategy, registered under
(format="ell", backend=<strategy>)); this module owns the partitioning,
the shard_map plumbing, and the drivers.

Besides the direct drivers (``make_solve_fn`` / ``make_solve_tol_fn``),
this module builds the SERVING-bucket bodies
(``make_sharded_bucket_fns``): the solve_tol loop body wrapped in the
engine's masked-slot machinery, with the kernel and layout picked per
(fmt, strategy, backend) — row-ELL gathers or tiled-BCSR MXU
contractions, rowpart or dualpart sharding (DESIGN.md section 5's
table).  In the engine's bucket lifecycle (repro.serve.solver_engine:
admit -> place -> advance -> freeze), these are the "advance" — every
tick runs one check_every block via ``advance_fn`` and the engine
freezes/harvests slots whose psum'd verdict flipped.

The direct drivers compose the same way end to end — partition, solve
inside one shard_map, trim the padding (works on a 1-device mesh too,
the degenerate case):

>>> import numpy as np, jax, jax.numpy as jnp
>>> from jax.sharding import Mesh
>>> from repro.core.prox import get_prox
>>> from repro.sparse.formats import COO
>>> eye = COO(rows=jnp.arange(4), cols=jnp.arange(4),
...           vals=jnp.ones(4), m=4, n=4)
>>> mesh = Mesh(np.array(jax.devices()[:1]), ("p",))
>>> dp = build_problem(eye, mesh, "dualpart")   # both orientations cached
>>> fn = make_solve_tol_fn(dp, get_prox("zero"), gamma0=10.0, tol=1e-5)
>>> st = fn(dp.operands, _pad_to(2.0 * jnp.ones(4), dp.m_pad))
>>> [round(float(v), 3) for v in st.xbar[:2]]   # min 0 s.t. I x = 2
[2.0, 2.0]
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.prox import ProxOp
from repro.core.solver import PDState, SolverOps, a1_init, a1_step, a2_init, a2_step
from repro.sparse.formats import COO

from repro.distributed.sharding import shard_map as _shard_map
from repro.sparse.partition import (
    _ceil_to, block_partitioned_ell, col_partitioned_ell, row_partitioned_ell,
)

STRATEGIES = ("rowpart", "colpart", "dualpart", "block2d", "replicated")


@dataclasses.dataclass
class DistProblem:
    """Sharded operand bundle + the specs that drive shard_map."""

    strategy: str
    mesh: Mesh
    axes: tuple[str, ...]            # 1 axis name, or (row_axis, col_axis)
    operands: Any                    # pytree of global arrays (or SDS)
    operand_specs: Any               # matching PartitionSpec pytree
    x_spec: P
    y_spec: P
    m: int                           # unpadded sizes
    n: int
    m_pad: int
    n_pad: int
    lg: float | jax.Array
    dual_copy: bool = False

    @property
    def state_specs(self) -> PDState:
        return PDState(xbar=self.x_spec, xstar=self.x_spec, yhat=self.y_spec,
                       gamma=P(), k=P())


# ---------------------------------------------------------------------------
# Operand construction (host side, real arrays)
# ---------------------------------------------------------------------------

def build_problem(coo: COO, mesh: Mesh, strategy: str = "dualpart",
                  axes: tuple[str, ...] | None = None,
                  dual_copy: bool = True) -> DistProblem:
    """Partition a concrete COO matrix for `strategy` on `mesh`."""
    if strategy not in STRATEGIES:
        raise KeyError(f"unknown strategy {strategy!r}")
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axes is None:
        axes = tuple(mesh.axis_names[-2:]) if strategy == "block2d" \
            else (mesh.axis_names[-1],)

    lg = float(np.sum(np.asarray(coo.vals) ** 2))  # sum_i ||A_i||^2 (paper 1-2)

    if strategy == "replicated":
        ell = row_partitioned_ell(coo, 1)
        ellt = col_partitioned_ell(coo, 1)
        return DistProblem(strategy, mesh, axes,
                           operands=dict(a=(ell.vals, ell.cols),
                                         at=(ellt.vals, ellt.cols)),
                           operand_specs=dict(a=(P(), P()), at=(P(), P())),
                           x_spec=P(), y_spec=P(), m=coo.m, n=coo.n,
                           m_pad=ell.vals.shape[0], n_pad=ellt.vals.shape[0],
                           lg=lg)

    if strategy == "rowpart":
        p = axis_sizes[axes[0]]
        ell = row_partitioned_ell(coo, p)
        return DistProblem(strategy, mesh, axes,
                           operands=dict(a=(ell.vals, ell.cols)),
                           operand_specs=dict(a=(P(axes[0]), P(axes[0]))),
                           x_spec=P(), y_spec=P(axes[0]), m=coo.m, n=coo.n,
                           m_pad=ell.vals.shape[0], n_pad=coo.n, lg=lg)

    if strategy == "colpart":
        p = axis_sizes[axes[0]]
        ellt = col_partitioned_ell(coo, p)
        return DistProblem(strategy, mesh, axes,
                           operands=dict(at=(ellt.vals, ellt.cols)),
                           operand_specs=dict(at=(P(axes[0]), P(axes[0]))),
                           x_spec=P(axes[0]), y_spec=P(), m=coo.m, n=coo.n,
                           m_pad=coo.m, n_pad=ellt.vals.shape[0], lg=lg)

    if strategy == "dualpart":
        p = axis_sizes[axes[0]]
        ell = row_partitioned_ell(coo, p)
        ellt = col_partitioned_ell(coo, p)
        m_pad = _ceil_to(ell.vals.shape[0], p)
        n_pad = _ceil_to(ellt.vals.shape[0], p)
        return DistProblem(strategy, mesh, axes,
                           operands=dict(a=(ell.vals, ell.cols),
                                         at=(ellt.vals, ellt.cols)),
                           operand_specs=dict(a=(P(axes[0]), P(axes[0])),
                                              at=(P(axes[0]), P(axes[0]))),
                           x_spec=P(axes[0]), y_spec=P(axes[0]),
                           m=coo.m, n=coo.n, m_pad=m_pad, n_pad=n_pad, lg=lg)

    # block2d
    ra, ca = axes
    R, C = axis_sizes[ra], axis_sizes[ca]
    vals, cols, m_pad, n_pad = block_partitioned_ell(coo, R, C)
    operands = dict(a=(vals, cols))
    specs = dict(a=(P(ra, ca), P(ra, ca)))
    if dual_copy:
        # per-block transpose: ELL of block^T with block-local row indices
        vt, ct, _, _ = block_partitioned_ell(
            COO(rows=coo.cols, cols=coo.rows, vals=coo.vals,
                m=n_pad, n=m_pad), C, R)
        # grid of A^T is (C, R); transpose grid dims so device (i,j) holds
        # block^T of its own block
        operands["at"] = (jnp.swapaxes(vt, 0, 1), jnp.swapaxes(ct, 0, 1))
        specs["at"] = (P(ra, ca), P(ra, ca))
    return DistProblem(strategy, mesh, axes, operands=operands,
                       operand_specs=specs, x_spec=P(ca), y_spec=P(ra),
                       m=coo.m, n=coo.n, m_pad=m_pad, n_pad=n_pad, lg=lg,
                       dual_copy=dual_copy)


# ---------------------------------------------------------------------------
# Local operator bundles (run INSIDE shard_map)
# ---------------------------------------------------------------------------

def make_local_ops(problem: DistProblem, operands) -> SolverOps:
    """Device-local SolverOps for `problem.strategy`, via the operator
    registry (repro.operators.dist registers one LinearOperator builder per
    strategy; this is a thin adapter kept for existing call sites)."""
    from repro.operators.dist import local_operator

    return local_operator(problem, operands).solver_ops()


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def _pad_to(v, size):
    return jnp.pad(v, (0, size - v.shape[0])) if size > v.shape[0] else v


def _algo_fns(algorithm: str):
    """(init_fn, step_fn) for the requested schedule."""
    if algorithm == "a2":
        return a2_init, a2_step
    return a1_init, a1_step


def _local_n(problem: DistProblem) -> int:
    """Per-device primal dimension: n_pad divided over the x-sharded axes."""
    nloc = problem.n_pad
    for ax in (problem.x_spec or ()):
        if ax is not None:
            nloc //= problem.mesh.devices.shape[problem.mesh.axis_names.index(ax)]
    return nloc


def make_solve_fn(problem: DistProblem, prox: ProxOp, gamma0: float,
                  iterations: int, algorithm: str = "a2", c: float = 3.0):
    """Returns jit(shard_map(full solve)): (operands, b_padded) -> PDState.

    The whole iteration loop lives inside one shard_map so operands stay
    device-resident across iterations — the RDD-persistence analogue."""
    init_fn, step_fn = _algo_fns(algorithm)
    nloc = _local_n(problem)

    def local_solve(operands, b):
        ops = make_local_ops(problem, operands)
        lg = jnp.asarray(problem.lg, b.dtype)
        state = init_fn(ops, prox, b, lg, gamma0, c, n=nloc)
        state = jax.lax.fori_loop(
            0, iterations,
            lambda _, s: step_fn(ops, prox, b, lg, gamma0, s, c), state)
        return state

    mapped = _shard_map(
        local_solve, mesh=problem.mesh,
        in_specs=(problem.operand_specs, problem.y_spec),
        out_specs=problem.state_specs)
    return jax.jit(mapped)


def make_solve_tol_fn(problem: DistProblem, prox: ProxOp, gamma0: float,
                      tol: float, max_iterations: int = 10_000,
                      algorithm: str = "a2", c: float = 3.0,
                      check_every: int | None = None):
    """jit(shard_map(solve_tol)): early exit on *global* relative feasibility
    ``||A xbar - b|| / max(1, ||b||) < tol`` checked every ``check_every``
    iterations — the distributed counterpart of ``core.solver.solve_tol``.

    Partial squared norms are computed per shard and psum'd over whatever
    mesh axes the residual is sharded on (``problem.y_spec``), so every
    device evaluates the same stopping verdict; the while loop lives inside
    shard_map, keeping operands device-resident across iterations like
    ``make_solve_fn``.  Like the local ``solve_tol``, ``max_iterations`` is
    a hard cap: the inner block is clamped to
    ``min(check_every, max_iterations - k)`` so the final partial block
    never oversteps the budget.
    """
    from repro.core.solver import DEFAULT_CHECK_EVERY
    check_every = DEFAULT_CHECK_EVERY if check_every is None else check_every
    init_fn, step_fn = _algo_fns(algorithm)
    nloc = _local_n(problem)
    y_axes = tuple(ax for ax in (problem.y_spec or ()) if ax is not None)

    def global_sq(v):
        s = jnp.sum(v * v)
        for ax in y_axes:
            s = jax.lax.psum(s, ax)
        return s

    def local_solve(operands, b):
        ops = make_local_ops(problem, operands)
        lg = jnp.asarray(problem.lg, b.dtype)
        state = init_fn(ops, prox, b, lg, gamma0, c, n=nloc)
        bnorm = jnp.maximum(jnp.sqrt(global_sq(b)), 1.0)

        def cond(s):
            feas = jnp.sqrt(global_sq(ops.matvec(s.xbar) - b)) / bnorm
            return jnp.logical_and(s.k < max_iterations, feas >= tol)

        def body(s):
            return jax.lax.fori_loop(
                0, jnp.minimum(check_every, max_iterations - s.k),
                lambda _, t: step_fn(ops, prox, b, lg, gamma0, t, c), s)

        return jax.lax.while_loop(cond, body, state)

    mapped = _shard_map(
        local_solve, mesh=problem.mesh,
        in_specs=(problem.operand_specs, problem.y_spec),
        out_specs=problem.state_specs)
    return jax.jit(mapped)


# ---------------------------------------------------------------------------
# Mesh-wide serving buckets (the engine's sharded placement)
# ---------------------------------------------------------------------------


def sharded_bucket_specs(axis, fmt: str = "ell",
                         strategy: str = "rowpart"):
    """(a_specs, at_specs) PartitionSpec pairs for one mesh-wide bucket's
    operand stacks — shared between ``make_sharded_bucket_fns`` (shard_map
    in_specs) and the engine's NamedSharding transfers, so the two can
    never disagree about a layout.

      fmt="ell"   a: vals/cols (S, m_pad, k), rows sharded
      fmt="bcsr"  a: vals (S, nbr, kb, bm, bn) + bcols (S, nbr, kb),
                  block-rows sharded (GLOBAL block-column indices)
      strategy="rowpart"   at: per-shard transpose blocks, sharded on the
                  LEADING (ndev,) axis — each shard holds a full-n
                  transpose of its own rows
      strategy="dualpart"  at: a ZERO-WIDTH stand-in laid out like ``a``'s
                  transpose — the shard-resident-x body needs no transpose
                  copy at all; the stand-in keeps the operand arity (and
                  the byte model's at term, which prices it at 0)
      strategy="gridpart"  ``axis`` is the (row_axis, col_axis) pair; a
                  and at are (R, C, S, ...) block grids sharded on both
                  leading dims — device (i, j) holds block (i, j) and its
                  transpose tile
    """
    if strategy not in ("rowpart", "dualpart", "gridpart"):
        raise KeyError(f"unknown sharded-bucket strategy {strategy!r}")
    if strategy == "gridpart":
        ra, ca = axis
        ell = (P(ra, ca, None, None, None),) * 2
        bcsr = (P(ra, ca, None, None, None, None, None),
                P(ra, ca, None, None, None))
        grid_specs = ell if fmt == "ell" else bcsr
        return grid_specs, grid_specs
    ell_a = (P(None, axis, None), P(None, axis, None))
    bcsr_a = (P(None, axis, None, None, None), P(None, axis, None))
    a_specs = ell_a if fmt == "ell" else bcsr_a
    if strategy == "rowpart":
        at_specs = ((P(axis, None, None, None),) * 2 if fmt == "ell" else
                    (P(axis, None, None, None, None, None),
                     P(axis, None, None, None)))
    else:
        at_specs = a_specs
    return a_specs, at_specs


def sharded_x_spec(axis, strategy: str = "rowpart") -> P:
    """The bucket's x-space (xbar/xstar) layout per strategy — shared
    between ``make_sharded_bucket_fns`` state specs and the engine:

      rowpart   P(): x replicated (the psum(n) backward rebuilds it).
      dualpart  P(None, axis): x SHARD-RESIDENT — the psum_scatter
                backward leaves each shard its own n/ndev slice; the
                all_gather happens only at harvest (device_get).
      gridpart  P(None, (col_axis, row_axis)): n is split into C column
                blocks (major) each split into R row tiles (minor), so the
                row-axis all_gather in the forward reassembles exactly the
                block's column slice inside each column group.
    """
    if strategy == "rowpart":
        return P()
    if strategy == "dualpart":
        return P(None, axis)
    ra, ca = axis
    return P(None, (ca, ra))


def make_sharded_bucket_fns(mesh: Mesh, n_pad: int, prox_builder: Callable,
                            algorithm: str = "a2", c: float = 3.0,
                            check_every: int | None = None,
                            axis: str | None = None,
                            fmt: str = "ell", strategy: str = "rowpart",
                            backend: str = "jnp",
                            interpret: bool | None = None):
    """jit(shard_map) bodies for ONE mesh-wide serving bucket: the
    ``make_solve_tol_fn`` while-loop body (check_every steps + psum'd
    feasibility verdict) wrapped in the serving engine's masked-slot
    machinery (repro.serve.solver_engine), so problems too large for one
    device are continuous-batched across the whole mesh.

    The bucket body is picked by ``(fmt, strategy, backend)`` — the table
    DESIGN.md section 5 documents — via the stacked shard-local operators
    of ``repro.operators.dist``:

      fmt      "ell" (VPU flat gathers) or "bcsr" (dense (bm, bn) tiles
               contracted with dot_general — the MXU path; with
               backend="pallas" the contraction runs the
               ``kernels/bcsr_spmv.py`` Pallas kernel per shard,
               ``interpret`` resolved by the caller).
      strategy "rowpart": per-shard TRANSPOSE blocks
               (sparse.partition.rowshard_transpose_ell/_bcsr) make the
               backward gather-only + psum(n) ~ MR1/MR3 with block2d's
               dual-copy trade; each shard stores a full-n transpose of
               its own rows (ndev copies of the n axis).
               "dualpart": row blocks only, x SHARD-RESIDENT — forward
               all_gather(n) + local gather, backward scatter +
               psum_scatter(n) straight back to the x shard (half the
               old two-all_gather wire bytes for m >= n); no transpose
               operand (a zero-width stand-in rides along for arity).
               "gridpart": A block-partitioned over a 2-D (row x col)
               sub-mesh, ``axis`` the (row_axis, col_axis) pair — forward
               all_gather(row) + gather + psum(col), backward gather from
               per-block transpose tiles + psum_scatter(row); per-device
               wire bytes shrink with BOTH mesh axes.

    Layout (global shapes; S = slots, sharded axis = ``axis``):

      a operands  row-ELL (S, m_pad, k) with GLOBAL columns, or BCSR
                  (S, nbr, kb, bm, bn) tiles with GLOBAL block-columns;
                  rows/block-rows sharded.  gridpart: (R, C, S, mb, k) /
                  (R, C, S, nbr_b, kb, bm, bn) block grids with
                  block-LOCAL indices, sharded on both leading dims.
      at operands rowpart: (ndev, S, n_pad, k_t) ELL / (ndev, S, nbt,
                  kb_t, bm, bn_t) BCSR per-shard transpose blocks, sharded
                  on the leading axis; dualpart: a zero-width stand-in
                  shaped like the plain transpose ((S, n_pad, 0) /
                  (S, nbt, 0, bm, bn_t)); gridpart: per-block transpose
                  tiles (R, C, S, nb, k_t) / (R, C, S, nbt_b, kb_t, bm,
                  bn_t), block-LOCAL indices.
      b, yhat     (S, m_pad)  row-sharded with A (gridpart: replicated
                  along the column axis)
      xbar/xstar  (S, n_pad)  ``sharded_x_spec``: replicated (rowpart) or
                  shard-resident (dualpart/gridpart; harvest's device_get
                  is the all_gather)
      lg/gamma0/reg/tol/maxit/masks  (S,)  replicated

    ``prox_builder`` maps a per-slot reg array (S,) to a ProxOp (the
    engine passes ``partial(batched_prox, family)``).

    Returns ``(splice_fn, advance_fn)``:

      splice_fn(a_vals, a_idx, at_vals, at_idx, b, lg, gamma0, reg, state,
                new_mask, active, tol, maxit) -> (state, feas, still)
          batched_init masked into freshly admitted slots + verdicts.
      advance_fn(a_vals, a_idx, at_vals, at_idx, b, lg, gamma0, reg, state,
                 active, tol, maxit) -> (state, feas, still)
          check_every masked batched steps (each slot additionally frozen
          at its max_iterations, like solve_tol's clamped inner block) +
          per-slot psum'd relative feasibility.

    Every device computes identical verdicts (feasibility is psum'd), and
    operands stay device-resident across ticks — the engine caches the
    sharded operand pytrees exactly like its single-device buckets.
    """
    from repro.core.solver import batched_init, batched_step, mask_state
    from repro.operators import make_operator
    from repro.core.solver import DEFAULT_CHECK_EVERY
    from repro.sparse.formats import StackedBCSR, StackedELL

    check_every = DEFAULT_CHECK_EVERY if check_every is None else check_every
    if strategy == "gridpart":
        axes = tuple(axis) if axis is not None else tuple(mesh.axis_names[-2:])
        ra, ca = axes
        csize = int(mesh.devices.shape[mesh.axis_names.index(ca)])
        ax = axes                           # spec-building handle
        y_axis = ra                         # feasibility psum axis
    else:
        ax = axis if axis is not None else mesh.axis_names[-1]
        y_axis = ax

    def local_ops(a_vals, a_idx, at_vals, at_idx):
        if strategy == "gridpart":
            # block grids come in with a local (1, 1) leading pair
            a_vals, a_idx = a_vals[0, 0], a_idx[0, 0]
            at_vals, at_idx = at_vals[0, 0], at_idx[0, 0]
            nb = n_pad // csize
            if fmt == "ell":
                a = StackedELL(vals=a_vals, cols=a_idx, n=nb)
                at = StackedELL(vals=at_vals, cols=at_idx,
                                n=a_vals.shape[1])
                op = make_operator("stacked_ell", "gridpart", a, ax, at)
            else:
                bm = a_vals.shape[3]
                mb = a_vals.shape[1] * bm
                a = StackedBCSR(vals=a_vals, bcols=a_idx, m=mb, n=nb)
                at = StackedBCSR(vals=at_vals, bcols=at_idx, m=nb, n=mb)
                op = make_operator("stacked_bcsr", "gridpart", a, ax, at,
                                   kernel_backend=backend,
                                   interpret=interpret)
            return op.solver_ops()
        if fmt == "ell":
            a = StackedELL(vals=a_vals, cols=a_idx, n=n_pad)
            if strategy == "rowpart":
                op = make_operator("stacked_ell", "rowpart", a, ax,
                                   at_vals[0], at_idx[0])
            else:                           # dualpart: at stand-in unused
                op = make_operator("stacked_ell", "dualpart", a, ax)
        else:
            bm = a_vals.shape[3]
            m_loc = a_vals.shape[1] * bm
            a = StackedBCSR(vals=a_vals, bcols=a_idx, m=m_loc, n=n_pad)
            if strategy == "rowpart":
                at = StackedBCSR(vals=at_vals[0], bcols=at_idx[0],
                                 m=n_pad, n=m_loc)
            else:
                at = None                   # dualpart: at stand-in unused
            op = make_operator("stacked_bcsr", strategy, a, ax, at,
                               kernel_backend=backend, interpret=interpret)
        return op.solver_ops()

    def global_sq(v):                       # (S, m_loc) -> (S,) global
        return jax.lax.psum(jnp.sum(v * v, axis=-1), y_axis)

    def feasibility(ops, b, state):
        r = ops.matvec(state.xbar) - b
        return (jnp.sqrt(global_sq(r))
                / jnp.maximum(jnp.sqrt(global_sq(b)), 1.0))

    def splice(a_vals, a_idx, at_vals, at_idx, b, lg, gamma0, reg, state,
               new_mask, active, tol, maxit):
        ops = local_ops(a_vals, a_idx, at_vals, at_idx)
        prox = prox_builder(reg)
        fresh = batched_init(ops, prox, b, lg, gamma0, algorithm, c)
        state = mask_state(new_mask, fresh, state)
        feas = feasibility(ops, b, state)
        still = active & (feas >= tol) & (state.k < maxit)
        return state, feas, still

    def advance(a_vals, a_idx, at_vals, at_idx, b, lg, gamma0, reg, state,
                active, tol, maxit):
        ops = local_ops(a_vals, a_idx, at_vals, at_idx)
        prox = prox_builder(reg)

        def one(_, s):
            return batched_step(ops, prox, b, lg, gamma0, s, algorithm, c,
                                mask=active & (s.k < maxit))

        state = jax.lax.fori_loop(0, check_every, one, state)
        feas = feasibility(ops, b, state)
        still = active & (feas >= tol) & (state.k < maxit)
        return state, feas, still

    row = P(None, y_axis)
    a_specs, at_specs = sharded_bucket_specs(ax, fmt, strategy)
    x_spec = sharded_x_spec(ax, strategy)
    state_specs = PDState(xbar=x_spec, xstar=x_spec, yhat=row, gamma=P(),
                          k=P())
    operand_specs = (*a_specs, *at_specs, row, P(), P(), P())
    out_specs = (state_specs, P(), P())
    splice_fn = jax.jit(_shard_map(
        splice, mesh=mesh,
        in_specs=(*operand_specs, state_specs, P(), P(), P(), P()),
        out_specs=out_specs))
    advance_fn = jax.jit(_shard_map(
        advance, mesh=mesh,
        in_specs=(*operand_specs, state_specs, P(), P(), P()),
        out_specs=out_specs))
    return splice_fn, advance_fn


def make_step_fn(problem: DistProblem, prox: ProxOp, gamma0: float,
                 algorithm: str = "a2", c: float = 3.0):
    """One shard_map'd iteration (the dry-run / roofline unit)."""
    _, step_fn = _algo_fns(algorithm)

    def local_step(operands, b, state):
        ops = make_local_ops(problem, operands)
        lg = jnp.asarray(problem.lg, b.dtype)
        return step_fn(ops, prox, b, lg, gamma0, state, c)

    mapped = _shard_map(
        local_step, mesh=problem.mesh,
        in_specs=(problem.operand_specs, problem.y_spec, problem.state_specs),
        out_specs=problem.state_specs)
    return jax.jit(mapped)


def solve_distributed(coo: COO, b, prox: ProxOp, mesh: Mesh,
                      strategy: str = "dualpart", gamma0: float = 1.0,
                      iterations: int = 100, algorithm: str = "a2",
                      dual_copy: bool = True):
    """Deprecated shim: partition, solve, return (xbar[:n], state).

    State the problem through the facade instead —
    ``repro.api.Problem(coo, b, prox).solve(strategy=..., mesh=...)`` — which
    compiles to the same ``build_problem`` + ``make_solve_fn`` kernel layer.
    """
    from repro.deprecation import warn_once

    warn_once("repro.core.distributed.solve_distributed",
              "repro.api.Problem(A, b, prox).solve(strategy=..., mesh=...)")
    problem = build_problem(coo, mesh, strategy, dual_copy=dual_copy)
    solve_fn = make_solve_fn(problem, prox, gamma0, iterations, algorithm)
    bp = _pad_to(b, problem.m_pad)
    state = solve_fn(problem.operands, bp)
    return state.xbar[:problem.n], state

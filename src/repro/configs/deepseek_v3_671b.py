"""DeepSeek-V3-671B [arXiv:2412.19437].

61L d_model=7168 128H MLA; 1 shared + 256 routed experts top-8 (first 3 layers
dense d_ff=18432); per-expert d_ff=2048; vocab=129280; MTP depth 1.
MLA: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v_head 128.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        d_ff=18432,                 # dense-layer FFN width
        vocab_size=129280,
        activation="swiglu",
        num_experts=256,
        num_experts_per_token=8,
        num_shared_experts=1,
        moe_d_ff=2048,
        first_dense_layers=3,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        mtp_depth=1,
        rope_theta=1.0e4,
        opt_state_dtype="bfloat16",
        microbatches_train=16,
    )

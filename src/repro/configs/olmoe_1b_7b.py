"""OLMoE-1B-7B [arXiv:2409.02060].

16L d_model=2048 16H (MHA kv=16) 64 experts top-8, per-expert d_ff=1024,
vocab=50304, qk_norm.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        qk_norm=True,
        activation="swiglu",
        num_experts=64,
        num_experts_per_token=8,
        moe_d_ff=1024,
        rope_theta=1.0e4,
        microbatches_train=2,
    )

"""Minitron-8B — width/depth-pruned Nemotron-4 [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000, squared-ReLU MLP.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=256000,
        activation="relu2",
        rope_theta=1.0e4,
        microbatches_train=4,
    )

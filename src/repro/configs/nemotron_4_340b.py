"""Nemotron-4-340B [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000, squared-ReLU.
Optimizer moments kept in bf16 so (params + states) fit 16 GB/chip on a single
16x16 pod; fp32 is used automatically when the `pod` axis shards the states.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        family="dense",
        num_layers=96,
        d_model=18432,
        num_heads=96,
        num_kv_heads=8,
        d_ff=73728,
        vocab_size=256000,
        activation="relu2",
        rope_theta=1.0e4,
        opt_state_dtype="bfloat16",
        microbatches_train=16,
    )

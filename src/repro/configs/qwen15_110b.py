"""Qwen1.5-110B [hf:Qwen/Qwen1.5 family].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias, SwiGLU.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=49152,
        vocab_size=152064,
        attn_bias=True,
        activation="swiglu",
        rope_theta=1.0e6,
        microbatches_train=8,
    )

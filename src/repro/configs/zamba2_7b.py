"""Zamba2-7B [arXiv:2411.15242] — hybrid Mamba-2 + weight-shared attention.

81 core Mamba-2 blocks, d_model=3584; one weight-SHARED GQA attention block
(32H kv=32 => MHA, d_ff=14336 for its paired MLP) applied every 6 core blocks.
ssm_state=64. vocab=32000.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        activation="swiglu",
        ssm_type="mamba2",
        ssm_state=64,
        d_inner=7168,
        conv_width=4,
        mamba2_head_dim=64,
        mamba2_n_groups=2,
        attn_every=6,
        microbatches_train=4,
    )

"""Architecture & problem config registry.

``get_config("minitron-8b")`` returns the exact assigned ModelConfig;
``get_config("paper-lasso-d3")`` returns a PaperProblemConfig.
"""
from __future__ import annotations

from repro.configs import paper_problems
from repro.configs.base import (
    SHAPES,
    SMOKE_SHAPES,
    ModelConfig,
    PaperProblemConfig,
    ShapeSpec,
    applicable,
    reduced,
)

_ARCH_MODULES = {
    "minitron-8b": "minitron_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen1.5-110b": "qwen15_110b",
    "qwen3-4b": "qwen3_4b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "zamba2-7b": "zamba2_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "musicgen-medium": "musicgen_medium",
}

ARCH_IDS = tuple(_ARCH_MODULES)
PAPER_IDS = tuple(f"paper-lasso-{d}" for d in paper_problems.ALL_DATASETS)


def get_config(arch: str):
    if arch.startswith("paper-lasso-"):
        return paper_problems.get_config(arch.removeprefix("paper-lasso-"))
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS + PAPER_IDS}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.get_config()


__all__ = [
    "ARCH_IDS", "PAPER_IDS", "SHAPES", "SMOKE_SHAPES", "ModelConfig",
    "PaperProblemConfig", "ShapeSpec", "applicable", "get_config", "reduced",
]

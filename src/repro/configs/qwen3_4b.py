"""Qwen3-4B [hf:Qwen/Qwen3 family].

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936, qk_norm, SwiGLU.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        head_dim=128,
        activation="swiglu",
        rope_theta=1.0e6,
        microbatches_train=4,
    )

"""Falcon-Mamba-7B [arXiv:2410.05355] — pure Mamba-1, attention-free.

64L d_model=4096, d_inner=8192, ssm_state=16, conv4, dt_rank=256, vocab=65024.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=65024,
        ssm_type="mamba1",
        ssm_state=16,
        d_inner=8192,
        conv_width=4,
        dt_rank=256,
        microbatches_train=4,
    )

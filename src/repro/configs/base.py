"""Config dataclasses for the repro framework.

Two kinds of workload are first-class:
  * ``ModelConfig``  — an LM-family transformer (the 10 assigned architectures).
  * ``PaperProblemConfig`` — a sparse primal-dual problem instance (the paper's
    own workload, datasets D1..D6 from Table 1).

Shapes (``ShapeSpec``) are the assigned input-shape set; ``applicable()``
encodes the skip rules (long_500k only for sub-quadratic archs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters. Field defaults = "feature absent"."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention options -------------------------------------------------
    attn_bias: bool = False          # qwen1.5 QKV bias
    qk_norm: bool = False            # qwen3 / olmoe per-head RMSNorm on q,k
    rope_theta: float = 1.0e4

    # --- FFN ----------------------------------------------------------------
    activation: str = "swiglu"       # swiglu | relu2 | gelu

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (d_ff used for dense layers)
    first_dense_layers: int = 0      # deepseek-v3: first k layers are dense FFN

    # --- MLA (deepseek) -----------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0               # multi-token-prediction extra blocks (train aux loss)

    # --- SSM ----------------------------------------------------------------
    ssm_type: str = ""               # mamba1 | mamba2
    ssm_state: int = 0
    d_inner: int = 0                 # 0 -> 2 * d_model
    conv_width: int = 4
    dt_rank: int = 0                 # mamba1; 0 -> d_model // 16
    mamba2_head_dim: int = 64
    mamba2_n_groups: int = 1

    # --- hybrid (zamba2) ----------------------------------------------------
    attn_every: int = 0              # weight-shared attn block applied every N core blocks

    # --- VLM ----------------------------------------------------------------
    cross_attn_every: int = 0        # cross-attn layer inserted every N layers
    num_image_tokens: int = 0        # stub frontend: precomputed image embeddings

    # --- audio (musicgen) ----------------------------------------------------
    num_codebooks: int = 0           # EnCodec codebooks; stub frontend sums embeddings

    # --- numerics / training knobs -------------------------------------------
    dtype: str = "bfloat16"
    opt_state_dtype: str = "float32" # bf16 for the 340B to fit one pod
    remat: bool = True
    microbatches_train: int = 8      # gradient-accumulation steps for train_4k
    tie_embeddings: bool = False

    # ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def resolved_d_inner(self) -> int:
        return self.d_inner if self.d_inner else 2 * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank if self.dt_rank else max(1, self.d_model // 16)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """Skip rules: long_500k only for sub-quadratic archs (full-attention
    O(S^2) at 524k is out of regime; recorded in DESIGN.md)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


@dataclasses.dataclass(frozen=True)
class PaperProblemConfig:
    """A sparse primal-dual problem instance (paper Table 1 datasets).

    min f(x)  s.t.  Ax = b, x in X   with A (m x n) uniform-sparse.
    """

    name: str
    m: int
    n: int
    nnz: int
    prox: str = "l1"                 # key into repro.core.prox registry
    reg: float = 0.1                 # l1 weight etc.
    gamma0: float = 1.0
    iterations: int = 200
    strategy: str = "dualpart"       # repro.core.distributed strategy
    fused: bool = True               # A2 (fused) vs A1 (faithful)
    dtype: str = "float32"

    @property
    def row_nnz(self) -> int:
        return max(1, round(self.nnz / self.m))

    @property
    def col_nnz(self) -> int:
        return max(1, round(self.nnz / self.n))


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (shapes only matter
    relative to each other; every structural feature stays enabled)."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        num_layers=min(cfg.num_layers, 4) if cfg.attn_every == 0 else 2 * max(2, cfg.attn_every),
        d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        d_ff=128,
        vocab_size=256,
        head_dim=16 if cfg.num_heads else 0,
        attn_bias=cfg.attn_bias,
        qk_norm=cfg.qk_norm,
        activation=cfg.activation,
        dtype="float32",
        remat=False,
        microbatches_train=1,
    )
    if cfg.num_experts:
        kw.update(
            num_experts=8,
            num_experts_per_token=min(cfg.num_experts_per_token, 2),
            num_shared_experts=min(cfg.num_shared_experts, 1),
            moe_d_ff=32,
            first_dense_layers=1 if cfg.first_dense_layers else 0,
        )
    if cfg.use_mla:
        kw.update(
            use_mla=True, q_lora_rank=32, kv_lora_rank=16,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
            mtp_depth=min(cfg.mtp_depth, 1),
        )
    if cfg.ssm_type:
        kw.update(
            ssm_type=cfg.ssm_type, ssm_state=min(cfg.ssm_state, 16),
            d_inner=128, conv_width=cfg.conv_width, dt_rank=8,
            mamba2_head_dim=32, mamba2_n_groups=1,
        )
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.cross_attn_every:
        kw.update(cross_attn_every=2, num_image_tokens=16)
    if cfg.num_codebooks:
        kw.update(num_codebooks=cfg.num_codebooks)
    return ModelConfig(**kw)


SMOKE_SHAPES: dict[str, ShapeSpec] = {
    "train": ShapeSpec("train_smoke", "train", 32, 2),
    "prefill": ShapeSpec("prefill_smoke", "prefill", 32, 2),
    "decode": ShapeSpec("decode_smoke", "decode", 32, 2),
}

"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 per codebook;
4 codebooks with the delay interleaving pattern. The EnCodec frontend is a
STUB: inputs are the 4-codebook token ids; embeddings are summed; the head
emits 4x2048 logits.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        activation="gelu",
        num_codebooks=4,
        rope_theta=1.0e4,
        microbatches_train=2,
    )

"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; cross-attention
image layers every 5 decoder layers. The vision tower is a STUB: input_specs
provides precomputed image-patch embeddings (B, 1600, d_model).
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        activation="swiglu",
        rope_theta=5.0e5,
        cross_attn_every=5,
        num_image_tokens=1600,
        microbatches_train=4,
    )

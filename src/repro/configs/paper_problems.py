"""Paper Table 1 datasets D1..D6 as problem configs.

Uniform-sparse A (m x n), nnz/row ~= nnz/m (paper reports min/mean/max per
row/col consistent with uniform placement). The paper's scalability runs use
LASSO-style l1 prox (and a "dummy" prox for pure-throughput tests).
"""
from repro.configs.base import PaperProblemConfig

# name: (m, n, nnz)  -- Table 1 ("2^8"/"5^8" are the report's typos for 2e8/5e8)
_TABLE1 = {
    "d1": (1_000_000, 10_000, 10_000_000),
    "d2": (2_000_000, 10_000, 20_000_000),
    "d3": (1_000_000, 50_000, 50_000_000),
    "d4": (2_000_000, 50_000, 100_000_000),
    "d5": (2_000_000, 100_000, 200_000_000),
    "d6": (10_000_000, 50_000, 500_000_000),
}


def get_config(dataset: str = "d1", **overrides) -> PaperProblemConfig:
    m, n, nnz = _TABLE1[dataset]
    kw = dict(name=f"paper-lasso-{dataset}", m=m, n=n, nnz=nnz,
              prox="l1", reg=0.1, gamma0=1.0, iterations=200,
              strategy="dualpart", fused=True)
    kw.update(overrides)
    return PaperProblemConfig(**kw)


def small_config(seed_scale: int = 1) -> PaperProblemConfig:
    """A laptop-scale instance for tests/examples (same nnz/row as D1)."""
    return PaperProblemConfig(
        name="paper-lasso-small", m=2000 * seed_scale, n=400 * seed_scale,
        nnz=20_000 * seed_scale, prox="l1", reg=0.1, gamma0=1.0,
        iterations=300, strategy="dualpart", fused=True)


ALL_DATASETS = tuple(_TABLE1)

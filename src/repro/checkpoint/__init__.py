from repro.checkpoint.checkpoint import AsyncSaver, latest_step, restore, save

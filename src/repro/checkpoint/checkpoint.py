"""Sharded, atomic, elastic checkpointing.

Layout:  <dir>/step_<N>/<escaped.path.leaf>.npy  + manifest.json + LATEST

  * atomic: written to step_<N>.tmp, fsync'd, renamed; LATEST updated last —
    a crash mid-save never corrupts the restore point (Hadoop's task-output
    commit protocol, reduced to POSIX rename).
  * sharded-on-restore / ELASTIC: leaves are stored as full logical arrays;
    restore device_puts them with the *target* mesh's NamedShardings, so a
    checkpoint taken on N devices restores onto any M-device mesh (grow or
    shrink) — the elastic-scaling path.
  * async: `save_async` snapshots to host then writes on a worker thread, so
    the train loop is blocked only for the device->host copy.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "."


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out


def save(tree, directory: str, step: int) -> str:
    """Blocking atomic save. Returns the finalized step directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


class AsyncSaver:
    """Snapshot-to-host + background write; at most one save in flight."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, tree, directory: str, step: int):
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # sync copy
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(host_tree, directory, step), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> int | None:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            name = f.read().strip()
        return int(name.removeprefix("step_"))
    except FileNotFoundError:
        return None


def restore(tree_like, directory: str, step: int | None = None,
            shardings=None):
    """Restore into the structure of `tree_like` (values ignored). With
    `shardings` (pytree of NamedSharding) the arrays are placed sharded —
    onto whatever mesh those shardings reference (elastic reshape)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    flat_keys = _flatten(tree_like)
    loaded = {}
    for key in flat_keys:
        meta = manifest[key]
        loaded[key] = np.load(os.path.join(d, meta["file"]))
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    sh_flat = (jax.tree_util.tree_leaves(shardings) if shardings is not None
               else [None] * len(leaves_with_path))
    out = []
    for (path, _), sh in zip(leaves_with_path, sh_flat):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = loaded[key]
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)

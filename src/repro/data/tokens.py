"""Synthetic data pipeline: deterministic, sharded, host-prefetched.

Batches are placed directly with the step's input NamedShardings (each
device gets only its shard — the multi-host layout generalizes via
jax.make_array_from_callback). A background thread keeps `prefetch` batches
ahead of the consumer, the standard device-feeding pattern.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


class SyntheticTokens:
    """Deterministic synthetic LM batches (zipf-ish marginal over vocab)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                 shardings=None, prefetch: int = 2,
                 batch_override: int | None = None,
                 seq_override: int | None = None):
        self.cfg = cfg
        self.batch = batch_override or shape.global_batch
        self.seq = seq_override or shape.seq_len
        self.shardings = shardings
        self._rng = np.random.default_rng(seed)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make(self):
        cfg = self.cfg
        v = cfg.vocab_size
        shape = ((self.batch, self.seq, cfg.num_codebooks)
                 if cfg.num_codebooks else (self.batch, self.seq))
        # zipf-flavored marginal, clipped to vocab
        toks = np.minimum(self._rng.zipf(1.3, size=shape) - 1, v - 1)
        batch = {"tokens": toks.astype(np.int32)}
        if cfg.family == "vlm":
            batch["image_embeds"] = self._rng.standard_normal(
                (self.batch, cfg.num_image_tokens, cfg.d_model)).astype(
                np.float32)
        return batch

    def _put_on_device(self, batch):
        if self.shardings is None:
            return jax.tree_util.tree_map(jnp.asarray, batch)
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), batch, self.shardings)

    def _producer(self):
        while not self._stop.is_set():
            b = self._make()
            try:
                self._q.put(b, timeout=0.5)
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._put_on_device(self._q.get())

    def close(self):
        self._stop.set()

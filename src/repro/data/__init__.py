from repro.data.tokens import SyntheticTokens

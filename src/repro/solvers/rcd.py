"""Randomized coordinate-descent solver family (primal RCD / dual SDCA).

Two coordinate bodies over the column-major ``CSC``/``StackedCSC`` operand
view (repro.sparse.formats):

  rcd_primal — randomized coordinate descent on the primal
      lasso     min_x 1/2 ||Ax - b||^2 + reg ||x||_1
      logistic  min_x sum_i log(1 + exp(-b_i a_i^T x)) + reg/2 ||x||^2
    One update picks column j, gathers its stored rows out of ``CSC(A)``,
    takes the 1-D prox/Newton step at the per-column curvature
    ``L_j = curv * ||A_j||^2``, and scatter-adds the change into the
    residual cache ``z = Ax``.

  rcd_dual — stochastic dual coordinate ascent (SDCA)
      svm       min_w sum_i max(0, 1 - b_i a_i^T w) + reg/2 ||w||^2
      logistic  min_w sum_i log(1 + exp(-b_i a_i^T w)) + reg/2 ||w||^2
    One update picks example i, gathers row a_i out of ``CSC(A^T)``, solves
    the 1-D dual subproblem exactly (closed form for hinge, a short damped
    Newton for the entropy term), and maintains
    ``w = (1/reg) sum_i beta_i b_i a_i`` incrementally.

Batched masked variants (``batched_rcd_init/step/solve_tol``) mirror
``repro.core.solver``'s A1/A2 batched API so RCD requests bucket, splice,
and early-exit through the serving engine unchanged: ``RCDState`` keeps the
primal iterate in ``.xbar`` and the epoch count in ``.k`` (the fields the
engine harvests), coordinates are drawn from a counter-based hash of
``(seed, k * updates + t)`` so replay after a splice is deterministic, and
``rcd_mask_state`` freezes retired slots exactly like ``mask_state``.

One engine "iteration" is one EPOCH: ``updates`` coordinate steps (the
padded coordinate count, a static loop bound), with the picked index drawn
modulo the slot's true dimension so padding is never touched.  Residuals
are fixed-point optimality measures (see ``batched_rcd_progress``), checked
after a full refresh of the cached quantity (z or w) so float drift from
thousands of incremental scatter-adds cannot mask convergence.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.formats import StackedCSC

LOSSES = ("lasso", "svm", "logistic")
FAMILY_LOSSES = {"rcd_primal": ("lasso", "logistic"),
                 "rcd_dual": ("svm", "logistic")}
DEFAULT_RCD_CHECK_EVERY = 4    # epochs between residual checks (~1 matvec each)
_HASH_MULT = np.uint32(2654435761)    # Knuth multiplicative hash
_NEWTON_STEPS = 8
_EPS = 1e-6


class RCDState(NamedTuple):
    """Coordinate-descent carry, engine-compatible by field name.

    xbar — the primal iterate: x (rcd_primal) or w (rcd_dual), (B, n_pad).
    aux  — the cached pairing: z = Ax (rcd_primal) or the dual variables
           beta (rcd_dual), (B, m_pad).
    k    — completed epochs per slot, (B,) int32 (the engine's iteration
           count; also the replay offset for the coordinate hash).
    """
    xbar: jax.Array
    aux: jax.Array
    k: jax.Array


def check_family_loss(family: str, loss: str) -> None:
    losses = FAMILY_LOSSES.get(family)
    if losses is None:
        raise ValueError(f"unknown RCD family {family!r}; "
                         f"have {sorted(FAMILY_LOSSES)}")
    if loss not in losses:
        raise ValueError(f"loss {loss!r} is not served by {family}: "
                         f"{'lasso has no strongly-convex dual' if loss == 'lasso' else 'the hinge is nonsmooth in the primal' if loss == 'svm' else f'choose from {losses}'}")


def pick_coordinate(seed: jax.Array, t: jax.Array, dim: jax.Array) -> jax.Array:
    """Counter-based coordinate draw: j = hash(seed + t) mod dim, (B,) int32.

    Stateless (no PRNG key threading through the engine's frozen-slot
    masters) and replayable — a respliced slot with the same (seed, k)
    visits the same coordinates.  ``dim`` is the slot's TRUE dimension, so
    bucket padding is never selected; inactive slots carry dim=1.
    """
    h = (seed.astype(jnp.uint32) + t.astype(jnp.uint32)) * _HASH_MULT
    return (h % dim.astype(jnp.uint32)).astype(jnp.int32)


# --------------------------------------------------------------------------
# Single-coordinate update bodies (shared by the jnp path and the Pallas
# kernel — repro.kernels.rcd_update loads refs and calls these on values)
# --------------------------------------------------------------------------

def primal_coord_body(col_v, col_r, x, z, b, j, reg, loss: str):
    """One primal RCD update at column j; returns (new x, new z).

    col_v/col_r: (k,) stored values / row indices of column j (CSC(A) row j).
    """
    zj = jnp.take(z, col_r)
    bj = jnp.take(b, col_r)
    if loss == "lasso":
        lprime = zj - bj
        curv = 1.0
    else:                                   # logistic
        lprime = -bj * jax.nn.sigmoid(-bj * zj)
        curv = 0.25
    g = jnp.sum(col_v * lprime)
    sq = jnp.sum(col_v * col_v)
    el = curv * sq
    xj = jnp.take(x, j)
    if loss == "lasso":
        safe = jnp.maximum(el, _EPS)
        u = xj - g / safe
        newx = jnp.sign(u) * jnp.maximum(jnp.abs(u) - reg / safe, 0.0)
        delta = jnp.where(el > 0.0, newx - xj, 0.0)
    else:                                   # logistic + l2: exact majorizer
        newx = (el * xj - g) / (el + reg)
        delta = newx - xj
    x = x.at[j].set(xj + delta)
    z = z.at[col_r].add(col_v * delta)      # padding rows: val=0, add 0 to z[0]
    return x, z


def dual_coord_body(row_v, row_c, w, beta, b, i, reg, loss: str):
    """One SDCA update at example i; returns (new w, new beta).

    row_v/row_c: (k,) stored values / column indices of row i (CSC(A^T) row i).
    """
    bi = jnp.take(b, i)
    margin = bi * jnp.sum(row_v * jnp.take(w, row_c))
    sq = jnp.sum(row_v * row_v)
    bet = jnp.take(beta, i)
    if loss == "svm":
        step = reg * (1.0 - margin) / jnp.maximum(sq, _EPS)
        delta = jnp.where(sq > 0.0,
                          jnp.clip(bet + step, 0.0, 1.0) - bet, 0.0)
    else:                                   # logistic: damped Newton on
        p0 = jnp.clip(jax.nn.sigmoid(-margin), _EPS, 1.0 - _EPS)

        def newton(_, p):                   # f(p) = log((1-p)/p) - margin
            f = (jnp.log1p(-p) - jnp.log(p)
                 - (margin + (p - bet) * sq / reg))
            fp = -1.0 / (p * (1.0 - p)) - sq / reg
            return jnp.clip(p - f / fp, _EPS, 1.0 - _EPS)

        p = jax.lax.fori_loop(0, _NEWTON_STEPS, newton, p0)
        delta = jnp.where(sq > 0.0, p - bet, 0.0)
    beta = beta.at[i].set(bet + delta)
    w = w.at[row_c].add((delta * bi / reg) * row_v)
    return w, beta


def _batched_coord_update(vals, rows, xbar, aux, b, j, reg, family: str,
                          loss: str):
    """vmap of the per-slot body over the bucket: one coordinate update in
    every slot (frozen slots are restored by ``rcd_mask_state`` afterwards).
    """
    def one(v, r, xb, ax, bb, jj, rg):
        cv = jax.lax.dynamic_index_in_dim(v, jj, axis=0, keepdims=False)
        cr = jax.lax.dynamic_index_in_dim(r, jj, axis=0, keepdims=False)
        if family == "rcd_primal":
            return primal_coord_body(cv, cr, xb, ax, bb, jj, rg, loss)
        w, beta = dual_coord_body(cv, cr, xb, ax, bb, jj, rg, loss)
        return w, beta

    return jax.vmap(one)(vals, rows, xbar, aux, b, j, reg)


# --------------------------------------------------------------------------
# Batched masked API (engine-shaped, mirrors core.solver.batched_*)
# --------------------------------------------------------------------------

def rcd_mask_state(mask: jax.Array, new: RCDState, old: RCDState) -> RCDState:
    """Per-slot freeze: keep ``new`` where mask is True, ``old`` elsewhere."""
    m2 = mask[:, None]
    return RCDState(xbar=jnp.where(m2, new.xbar, old.xbar),
                    aux=jnp.where(m2, new.aux, old.aux),
                    k=jnp.where(mask, new.k, old.k))


def batched_rcd_init(a: StackedCSC, at: StackedCSC, b, *,
                     family: str = "rcd_primal") -> RCDState:
    """Zero start: x=0, z=A0=0 (primal) / beta=0, w=0 (dual) — exact, so a
    spliced-in slot needs no refresh before its first epoch."""
    bsz = a.batch
    return RCDState(xbar=jnp.zeros((bsz, a.n), jnp.float32),
                    aux=jnp.zeros((bsz, a.m), jnp.float32),
                    k=jnp.zeros((bsz,), jnp.int32))


def rcd_updates_per_epoch(a: StackedCSC, family: str) -> int:
    """Static epoch length: the PADDED coordinate count (n_pad primal /
    m_pad dual) so the fori_loop bound is bucket-constant; draws land in
    the true range via ``dim``."""
    return int(a.n) if family == "rcd_primal" else int(a.m)


def batched_rcd_step(a: StackedCSC, at: StackedCSC, b, reg, dim, seed,
                     state: RCDState, *, family: str, loss: str,
                     mask: jax.Array | None = None,
                     kernel: str | None = None,
                     interpret: bool | None = None) -> RCDState:
    """One EPOCH per slot: ``updates`` hashed coordinate steps, then k += 1.

    a/at — StackedCSC of A and A^T (both orientations, gather-only).
    b    — (B, m_pad) targets/labels; reg, dim, seed — (B,) per-slot masters.
    mask — slots to advance; frozen slots are restored bit-for-bit.
    kernel — "pallas" routes each coordinate update through the
             repro.kernels.rcd_update gather-update kernel.
    """
    vals, rows = ((a.vals, a.rows) if family == "rcd_primal"
                  else (at.vals, at.rows))
    updates = rcd_updates_per_epoch(a, family)
    reg = jnp.broadcast_to(jnp.asarray(reg, jnp.float32), state.k.shape)
    if kernel == "pallas":
        from repro.kernels.rcd_update import rcd_update as _kernel_update

        def update(xbar, aux, j):
            return _kernel_update(vals, rows, xbar, aux, b, j, reg,
                                  family=family, loss=loss,
                                  interpret=interpret)
    else:
        def update(xbar, aux, j):
            return _batched_coord_update(vals, rows, xbar, aux, b, j, reg,
                                         family, loss)

    def body(t, carry):
        xbar, aux = carry
        j = pick_coordinate(seed, state.k * updates + t, dim)
        return update(xbar, aux, j)

    xbar, aux = jax.lax.fori_loop(0, updates, body, (state.xbar, state.aux))
    new = RCDState(xbar=xbar, aux=aux, k=state.k + 1)
    if mask is not None:
        new = rcd_mask_state(mask, new, state)
    return new


def batched_rcd_progress(a: StackedCSC, at: StackedCSC, b, reg,
                         state: RCDState, *, family: str, loss: str):
    """Refresh the cached quantity and measure optimality -> (state, resid).

    The refresh recomputes z = Ax (primal) / w = (1/reg) A^T(beta * b)
    (dual) from scratch, killing incremental-update drift.  Residuals are
    relative fixed-point gaps — zero exactly at optimality:

      lasso      x = soft(x - A^T(Ax - b), reg)
      logistic-P x = (x - A^T l'(Ax)) / (1 + reg)      (grad + reg x = 0)
      svm        beta = clip(beta + (1 - margin), 0, 1)
      logistic-D beta = sigmoid(-margin)

    Dual residuals are masked to rows with ||a_i|| > 0 so bucket padding
    (all-zero rows) cannot hold a slot open.
    """
    from repro.sparse.linalg import stacked_csc_gather_matvec

    reg = jnp.asarray(reg, jnp.float32)
    if reg.ndim == 1:
        reg2 = reg[:, None]
    else:
        reg2 = reg
    if family == "rcd_primal":
        x = state.xbar
        z = stacked_csc_gather_matvec(at, x)              # A x
        if loss == "lasso":
            grad = stacked_csc_gather_matvec(a, z - b)    # A^T (Ax - b)
            u = x - grad
            target = jnp.sign(u) * jnp.maximum(jnp.abs(u) - reg2, 0.0)
        else:
            lp = -b * jax.nn.sigmoid(-b * z)
            grad = stacked_csc_gather_matvec(a, lp)
            target = (x - grad) / (1.0 + reg2)
        num = jnp.linalg.norm(x - target, axis=-1)
        den = jnp.maximum(1.0, jnp.linalg.norm(target, axis=-1))
        return RCDState(xbar=x, aux=z, k=state.k), num / den
    beta = state.aux
    w = stacked_csc_gather_matvec(a, beta * b) / reg2     # (1/reg) A^T(b.beta)
    margin = b * stacked_csc_gather_matvec(at, w)         # b * (A w)
    rowsq = jnp.sum(at.vals * at.vals, axis=2)            # (B, m_pad)
    live = rowsq > 0.0
    if loss == "svm":
        target = jnp.clip(beta + (1.0 - margin), 0.0, 1.0)
    else:
        target = jax.nn.sigmoid(-margin)
    gap = jnp.where(live, beta - target, 0.0)
    num = jnp.linalg.norm(gap, axis=-1)
    den = jnp.maximum(1.0, jnp.linalg.norm(jnp.where(live, target, 0.0),
                                           axis=-1))
    return RCDState(xbar=w, aux=beta, k=state.k), num / den


def batched_rcd_solve_tol(a: StackedCSC, at: StackedCSC, b, reg, dim, seed, *,
                          family: str, loss: str, tol: float = 1e-6,
                          max_iterations: int = 1000,
                          check_every: int | None = None,
                          active: jax.Array | None = None,
                          kernel: str | None = None,
                          interpret: bool | None = None):
    """Masked early-exit driver (the RCD twin of ``batched_solve_tol``):
    blocks of ``check_every`` epochs between residual checks; converged /
    exhausted / inactive slots freeze while the rest continue.

    Returns (state, resid) — state.k holds per-slot epochs consumed.
    """
    check_family_loss(family, loss)
    ce = DEFAULT_RCD_CHECK_EVERY if check_every is None else check_every
    maxit = jnp.asarray(max_iterations, jnp.int32)
    state = batched_rcd_init(a, at, b, family=family)
    _, resid = batched_rcd_progress(a, at, b, reg, state, family=family,
                                    loss=loss)
    act = (jnp.ones(state.k.shape, bool) if active is None
           else jnp.asarray(active, bool))
    still = act & (resid >= tol) & (maxit > 0)

    def cond(carry):
        _, _, still = carry
        return jnp.any(still)

    def body(carry):
        state, resid, still = carry

        def inner(_, s):
            return batched_rcd_step(a, at, b, reg, dim, seed, s,
                                    family=family, loss=loss,
                                    mask=still & (s.k < maxit),
                                    kernel=kernel, interpret=interpret)

        state = jax.lax.fori_loop(0, ce, inner, state)
        fresh, resid2 = batched_rcd_progress(a, at, b, reg, state,
                                             family=family, loss=loss)
        state = rcd_mask_state(still, fresh, state)
        resid = jnp.where(still, resid2, resid)
        still = still & (resid >= tol) & (state.k < maxit)
        return state, resid, still

    state, resid, _ = jax.lax.while_loop(cond, body, (state, resid, still))
    return state, resid


# --------------------------------------------------------------------------
# Single-problem front door (B=1 over the batched bodies)
# --------------------------------------------------------------------------

def rcd_solve_tol(coo, b, reg, *, family: str, loss: str, seed: int = 0,
                  tol: float = 1e-6, max_iterations: int = 1000,
                  check_every: int | None = None, kernel: str | None = None,
                  interpret: bool | None = None):
    """Solve one problem given its COO: returns (solution, resid, epochs).

    ``solution`` is the primal vector (x or w) of length coo.n.
    """
    from repro.sparse.formats import coo_to_csc, stack_cscs, transpose_coo

    a = stack_cscs([coo_to_csc(coo)])
    at = stack_cscs([coo_to_csc(transpose_coo(coo))])
    bb = jnp.asarray(b, jnp.float32)[None, :]
    dim = jnp.asarray([coo.n if family == "rcd_primal" else coo.m], jnp.int32)
    seeds = jnp.asarray([seed], jnp.int32)
    regs = jnp.asarray([reg], jnp.float32)
    state, resid = batched_rcd_solve_tol(
        a, at, bb, regs, dim, seeds, family=family, loss=loss, tol=tol,
        max_iterations=max_iterations, check_every=check_every,
        kernel=kernel, interpret=interpret)
    return state.xbar[0], float(resid[0]), int(state.k[0])


# --------------------------------------------------------------------------
# Dense float64 reference (the oracle the RCD bodies are tested against —
# deliberately dependency-free: proximal/projected gradient, no sklearn)
# --------------------------------------------------------------------------

def dense_reference(A, b, reg, loss: str, max_iterations: int = 20_000,
                    tol: float = 1e-10) -> np.ndarray:
    """Primal minimizer by FISTA (lasso/logistic) or projected dual ascent
    (svm), all in numpy float64.  Small problems only — tests and docs."""
    A = np.asarray(A, np.float64)
    b = np.asarray(b, np.float64)
    m, n = A.shape
    lip_a = float(np.linalg.norm(A, 2)) ** 2 or 1.0
    if loss == "svm":                       # box QP on the dual
        beta = np.zeros(m)
        step = reg / (lip_a * max(1.0, float(np.max(b * b))) or 1.0)
        for _ in range(max_iterations):
            w = A.T @ (beta * b) / reg
            grad = 1.0 - b * (A @ w)
            nxt = np.clip(beta + step * grad, 0.0, 1.0)
            if np.linalg.norm(nxt - beta) <= tol * max(1.0, np.linalg.norm(beta)):
                beta = nxt
                break
            beta = nxt
        return A.T @ (beta * b) / reg

    def grad_smooth(x):
        if loss == "lasso":
            return A.T @ (A @ x - b)
        z = A @ x
        s = 1.0 / (1.0 + np.exp(b * z))     # sigmoid(-b z)
        return A.T @ (-b * s) + reg * x

    lip = lip_a if loss == "lasso" else 0.25 * lip_a + reg
    x = np.zeros(n)
    y, t = x.copy(), 1.0
    for _ in range(max_iterations):
        g = grad_smooth(y)
        u = y - g / lip
        if loss == "lasso":
            nxt = np.sign(u) * np.maximum(np.abs(u) - reg / lip, 0.0)
        else:
            nxt = u
        t2 = 0.5 * (1.0 + np.sqrt(1.0 + 4.0 * t * t))
        y = nxt + ((t - 1.0) / t2) * (nxt - x)
        if np.linalg.norm(nxt - x) <= tol * max(1.0, np.linalg.norm(x)):
            x = nxt
            break
        x, t = nxt, t2
    return x


# --------------------------------------------------------------------------
# Family registration
# --------------------------------------------------------------------------

from functools import partial  # noqa: E402

from repro.solvers.family import SolverFamily, register_family  # noqa: E402


def _rcd_family(name: str, side: str) -> SolverFamily:
    # ``family=`` is bound here; ``loss=`` stays free (it is per-request)
    return SolverFamily(
        name=name, kind="rcd", side=side, losses=FAMILY_LOSSES[name],
        state_cls=RCDState,
        init=partial(batched_rcd_init, family=name),
        step=partial(batched_rcd_step, family=name),
        progress=partial(batched_rcd_progress, family=name),
        mask_state=rcd_mask_state,
        solve_tol=partial(batched_rcd_solve_tol, family=name))


RCD_PRIMAL = register_family(_rcd_family("rcd_primal", "primal"))
RCD_DUAL = register_family(_rcd_family("rcd_dual", "dual"))


def reference_objective(A, b, reg, loss: str, x) -> float:
    """The primal objective value at x (float64; shared by tests/bench)."""
    A = np.asarray(A, np.float64)
    b = np.asarray(b, np.float64)
    x = np.asarray(x, np.float64)
    z = A @ x
    if loss == "lasso":
        return float(0.5 * np.sum((z - b) ** 2) + reg * np.sum(np.abs(x)))
    if loss == "svm":
        return float(np.sum(np.maximum(0.0, 1.0 - b * z))
                     + 0.5 * reg * np.sum(x * x))
    return float(np.sum(np.log1p(np.exp(-np.abs(b * z)))
                        + np.maximum(-b * z, 0.0))
                 + 0.5 * reg * np.sum(x * x))

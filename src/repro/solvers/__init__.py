# Solver families: the A1/A2 primal-dual smoothing bodies (re-homed from
# repro.core.solver) and the randomized coordinate-descent pair (primal RCD
# and dual SDCA over the column-major CSC operand view), all behind one
# SolverFamily registry the planner's face-off rule selects from.
# See DESIGN.md "Solver families".
from repro.solvers.family import (
    FAMILIES, FAMILY_NAMES, SolverFamily, get_family, register_family,
)
from repro.solvers import primal_dual as _primal_dual      # noqa: F401
from repro.solvers import rcd as _rcd                      # noqa: F401
from repro.solvers.rcd import (
    FAMILY_LOSSES, LOSSES, RCDState, batched_rcd_init, batched_rcd_progress,
    batched_rcd_solve_tol, batched_rcd_step, dense_reference, pick_coordinate,
    rcd_mask_state, rcd_solve_tol, rcd_updates_per_epoch, reference_objective,
)

__all__ = [
    "FAMILIES", "FAMILY_LOSSES", "FAMILY_NAMES", "LOSSES", "RCDState",
    "SolverFamily", "batched_rcd_init", "batched_rcd_progress",
    "batched_rcd_solve_tol", "batched_rcd_step", "dense_reference",
    "get_family", "pick_coordinate", "rcd_mask_state", "rcd_solve_tol",
    "rcd_updates_per_epoch", "reference_objective", "register_family",
]

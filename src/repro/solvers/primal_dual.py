"""The A1/A2 primal-dual smoothing bodies, re-homed as SolverFamily records.

The math stays in ``repro.core.solver`` (the paper-faithful implementation
every existing call site imports); this module wraps the batched masked
entry points behind the ``SolverFamily`` protocol so the planner and the
serving engine can treat "a2" and "rcd_primal" as peers in one registry.
"""
from __future__ import annotations

from functools import partial

from repro.core import solver as _core
from repro.solvers.family import SolverFamily, register_family


def _pd_family(algorithm: str) -> SolverFamily:
    return SolverFamily(
        name=algorithm,
        kind="primal_dual",
        side="saddle",
        losses=("",),           # constraint problems min f(x) s.t. Ax = b
        state_cls=_core.PDState,
        init=partial(_core.batched_init, algorithm=algorithm),
        step=partial(_core.batched_step, algorithm=algorithm),
        progress=None,          # feasibility lives on the ops, see below
        mask_state=_core.mask_state,
        solve_tol=partial(_core.batched_solve_tol, algorithm=algorithm),
    )


A1 = register_family(_pd_family("a1"))
A2 = register_family(_pd_family("a2"))

# The residual for this family is constraint feasibility, computed from the
# operator pair rather than the operand arrays (signature differs from the
# RCD progress on purpose — the engine branches on ``kind``).
batched_feasibility = _core.batched_feasibility

"""The SolverFamily protocol: one registry over every iterate-body in the
repo — the A1/A2 primal-dual smoothing pair (kind="primal_dual") and the
randomized coordinate-descent pair (kind="rcd").

A family is a named bundle of batched masked callables with a shared
life-cycle contract the serving engine relies on:

  init(...)        -> state with a (B, n_pad) ``.xbar`` and a (B,) ``.k``
  step(...)        -> one masked engine iteration (A2 step / RCD epoch)
  progress(...)    -> (refreshed state, per-slot residual)  [kind="rcd"]
  mask_state(m, new, old) -> per-slot freeze
  solve_tol(...)   -> masked early-exit driver

Signatures beyond that contract differ by kind — primal-dual bodies take
(ops, prox, b, lg, gamma0), coordinate bodies take the column-major operand
arrays (a, at, b, reg, dim, seed) — so the callables are stored rather than
abstracted: call sites branch on ``kind`` and get the real function with no
adapter layer in the hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

FAMILY_NAMES = ("a1", "a2", "rcd_primal", "rcd_dual")


@dataclasses.dataclass(frozen=True)
class SolverFamily:
    name: str                       # registry key ("a2", "rcd_primal", ...)
    kind: str                       # "primal_dual" | "rcd"
    side: str                       # "saddle" | "primal" | "dual"
    losses: tuple                   # loss names served ("" = constraint)
    state_cls: type                 # PDState | RCDState
    init: Callable[..., Any]
    step: Callable[..., Any]
    progress: Callable[..., Any] | None
    mask_state: Callable[..., Any]
    solve_tol: Callable[..., Any]

    def serves(self, loss: str) -> bool:
        return loss in self.losses


FAMILIES: dict[str, SolverFamily] = {}


def register_family(family: SolverFamily) -> SolverFamily:
    FAMILIES[family.name] = family
    return family


def get_family(name: str) -> SolverFamily:
    try:
        return FAMILIES[name]
    except KeyError:
        raise KeyError(f"unknown solver family {name!r}; "
                       f"have {sorted(FAMILIES)}") from None

"""Batched solver serving engine: many concurrent primal-dual problems.

The solver analogue of the token-serving engine next door (serve/engine.py):
where that one continuous-batches *sequences* over decode slots, this one
continuous-batches *optimization problems* over solve slots.

Serving traffic is many independent ``min f(x) s.t. Ax = b`` requests with
heterogeneous shapes, sparsity and regularizers.  Solving them one at a
time pays the per-call fixed costs — dispatch, trace/compile per shape,
pipeline prologue — once per problem per iteration; the whole point of the
paper's A2 schedule (2 sync points per iteration) is that everything else
batches.  So:

  1. **Bucket**: requests are grouped by (padded shape, storage format,
     prox family).  Padded dims round up to powers of two, so a handful of
     buckets covers a ragged workload, and every problem in a bucket
     stacks to identical arrays.
  2. **Pad + stack**: each bucket owns fixed slot-batched operands — a
     ``StackedELL``/``StackedBCSR`` pair (both orientations), b, lg,
     gamma0, reg, tol — with a leading slot axis.  Padding is exact by
     construction (zero rows/cols with b=0 and a zero prox center do not
     move), so a padded slot reproduces the standalone solve.
  3. **Step**: one jit'd masked batched A2 step per bucket
     (core.solver.batched_step) advances every active slot at once;
     schedule coefficients are per-slot because each problem sits at its
     own iteration k with its own (lg, gamma0).
  4. **Early-exit per slot**: the ``solve_tol`` stopping criterion
     (relative feasibility < tol, checked every ``check_every``
     iterations) is evaluated per slot; finished slots are mask-frozen —
     their iterates stop moving — harvested, and freed.
  5. **Continuous admission**: freed slots take queued requests
     immediately; a new problem's init splices into the running batch
     without disturbing neighbours.

The engine also serves a **device mesh** (the paper's whole point is a
cluster of workers; one device is the degenerate case).  ``slots`` is a
per-device budget, and placement is decided per bucket from the planner's
``decide_placement`` rule plus queue pressure (DESIGN.md section 5 has
the decision table):

  6. **Bucket placement** (placement="replicated"): a lightly-queued
     bucket is pinned to the least-loaded device (``jax.device_put``,
     round-robin on ties) — ``step()`` dispatches every bucket's advance
     before harvesting any, so independent buckets advance *concurrently*
     instead of serially on device 0; a deeply-queued bucket instead
     widens its slot axis to ``slots x ndev`` shard_map'd over a
     demand-sized sub-mesh (sharded batch axes, collective-free — slots
     are independent), so aggregate slot capacity scales with the mesh.
  7. **Sharded buckets** (placement="sharded"): a request whose
     planner-resolved placement says it exceeds the per-device capacity
     (``repro.plan.decide_placement`` — the same rule ``Problem.plan()``
     records) is admitted into a mesh-wide bucket: operands are
     partitioned over a capacity-sized sub-mesh and the advance body is
     the ``core.distributed.make_solve_tol_fn`` loop body (check_every
     steps + psum'd per-slot relative feasibility) run inside shard_map
     under this engine's masked-slot machinery
     (``core.distributed.make_sharded_bucket_fns``).  The bucket BODY is
     picked per (fmt, strategy, backend) — DESIGN.md section 5's table:
     row-ELL gathers or tiled-BCSR ``dot_general`` contractions (the MXU
     path; Pallas kernels when backend="pallas"), laid out ``rowpart``
     (per-shard transpose blocks, gather-only backward + psum(n)) or
     ``dualpart`` (both orientations resident per shard — the Spark
     dual-RDD cache — collective-free forward, all_gather backward, the
     transpose stored once mesh-wide).  The strategy is the planner's
     ``repro.plan.decide_bucket_body`` operand-byte rule, honored here
     rather than rewritten.  Operands stay device-resident across ticks
     exactly like single-device buckets.  On a 1-device engine the same
     request can neither shard nor stay resident: it is served
     **streamed** — the operand fraction beyond capacity re-uploads every
     iteration (chunked per check block) — which is the data-locality
     cost the mesh placements exist to avoid.

Throughput, not latency: a single request finishes no faster than a
standalone ``solve_tol`` (slightly slower — it rides along until its
check boundary), but requests/sec scales with slot count and, on a mesh,
with bucket concurrency and aggregate capacity (``benchmarks/run.py
solver_serving`` and ``sharded_serving`` measure the ratios).  The
latency side — open-loop arrivals on their own clock, per-request
deadlines/priorities, bounded-queue backpressure and byte-budget
admission control — lives one layer up in ``repro.serve.frontend``; this
engine stays tick-driven underneath it and contributes ``expire_overdue``
(slot reclamation) and priority-aware queue pops.

The bucket lifecycle — **admit** (operand slices spliced into the numpy
masters of the key's bucket) → **place** (pinned / slot-sharded /
mesh-wide, charged against the byte-based ``device_budget``) →
**advance** (check_every masked batched steps per tick) → **freeze**
(verdict flips, iterates stop moving, slot harvested and refilled) —
end to end:

>>> import numpy as np
>>> from repro.serve.solver_engine import SolveRequest, SolverEngine
>>> from repro.sparse.formats import COO
>>> eye = COO(rows=np.arange(8, dtype=np.int32),
...           cols=np.arange(8, dtype=np.int32),
...           vals=np.ones(8, np.float32), m=8, n=8)
>>> eng = SolverEngine(slots=2, check_every=8)
>>> key = eng.submit(SolveRequest(uid=0, coo=eye, b=np.ones(8, np.float32),
...                               prox="zero", gamma0=10.0, tol=1e-3))
>>> done = eng.run()      # admit -> place -> advance ... -> freeze+harvest
>>> (done[0].done, done[0].feasibility < 1e-3, float(round(done[0].x[0], 2)))
(True, True, 1.0)
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.strict import (
    CompileWatcher, guard_transfers, intended_transfers, is_transfer_error,
    strict_enabled,
)
from repro.core.prox import ProxOp, get_prox
from repro.serve.clock import WallClock
from repro.core.solver import (
    PDState, batched_feasibility, batched_init, batched_step, mask_state,
)
from repro.kernels.fused_check_block import (
    FUSED_CHECK_PROXES, fused_check_block,
)
from repro.sparse.formats import (
    COO, coo_bcsr_width, coo_to_bcsr, coo_to_ell, pad_coo, transpose_coo,
)

#: prox families the batched path supports: elementwise, parameterized by at
#: most a per-slot ``reg`` (group proxes would couple coordinates across the
#: slot axis after stacking and are not served).
BATCHED_PROX_FAMILIES = ("l1", "sq_l2", "elastic_net", "zero", "nonneg",
                         "dummy")


def batched_prox(name: str, reg: jax.Array) -> ProxOp:
    """Family ``name`` with per-slot regularization reg (S,) -> ProxOp whose
    closures broadcast (S, 1) against (S, n) iterates."""
    if name not in BATCHED_PROX_FAMILIES:
        raise KeyError(f"prox family {name!r} not servable in a batch; "
                       f"supported: {BATCHED_PROX_FAMILIES}")
    if name in ("l1", "sq_l2", "elastic_net"):
        return get_prox(name, reg=reg[:, None])
    return get_prox(name)


def _next_pow2(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


@dataclasses.dataclass
class SolveRequest:
    """One primal-dual solve: min f(x) s.t. Ax = b over the COO matrix A.

    ``lg`` (= sum_i ||A_i||^2, the paper's init step 1) is computed at
    construction when None.  Results land in x / iterations / feasibility /
    done.

    Open-loop serving fields: ``priority`` orders admission out of wait
    queues (higher first; FIFO within a priority class — both the
    engine's per-bucket queues and the front-end's bounded wait queue
    honor it), and ``deadline`` is an ABSOLUTE time on the serving clock
    (``repro.serve.frontend``'s injected clock; seconds) past which the
    request is expired — dropped from queues, or its slot reclaimed
    mid-flight — instead of completed (``expired`` flips, ``done`` stays
    False).  ``rejected``/``reject_reason`` record an admission-control
    verdict (bounded-queue backpressure or a byte-budget rejection from
    ``repro.plan.decide_admission``); ``timeline`` is the front-end's
    per-request latency account (arrive/admit/done stamps plus the
    queue/admit/compute/harvest breakdown layered on the engine's
    ``phase_s``).
    """

    uid: int
    coo: COO
    b: Any                               # (m,)
    prox: str = "l1"
    reg: float = 0.1
    lg: float | None = None
    gamma0: float = 100.0
    tol: float = 1e-3
    max_iterations: int = 10_000
    priority: int = 0                    # higher admits first
    deadline: float | None = None        # absolute serving-clock seconds
    # solver-family routing (repro.plan.decide_solver_family): "a2"/"a1"
    # requests run the engine's primal-dual body; "rcd_primal"/"rcd_dual"
    # route to the coordinate-descent family over csc buckets.  ``loss``
    # names the rcd objective ("lasso" | "svm" | "logistic"; "" for
    # constraint problems) and ``seed`` the coordinate stream (uid-derived
    # when None, so replay after a re-splice is deterministic).
    family: str = "a2"
    loss: str = ""
    seed: int | None = None
    # filled by the engine on completion
    x: np.ndarray | None = None          # (n,) final xbar
    iterations: int = 0
    feasibility: float = float("inf")
    done: bool = False
    expired: bool = False                # deadline passed before completion
    rejected: bool = False               # admission control turned it away
    reject_reason: str = ""
    timeline: dict | None = None         # frontend latency stamps

    def __post_init__(self):
        if self.lg is None:    # host-side: no device dispatch per request
            vals = np.asarray(self.coo.vals)
            self.lg = float(np.sum(np.square(vals)))
        if self.seed is None:
            self.seed = self.uid & 0x7FFFFFFF

    @property
    def is_rcd(self) -> bool:
        return self.family in ("rcd_primal", "rcd_dual")


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Requests sharing a key share slot buffers and one compiled step.

    ``family``/``loss`` extend the key for the coordinate-descent path:
    rcd requests bucket by (shape, "csc", family, loss) — the compiled
    epoch body is loss-specific — while primal-dual traffic keeps the
    default ("a2", "") and the pre-rcd key space unchanged."""

    m_pad: int
    n_pad: int
    width: int          # ELL k / BCSR kb of A, padded bucket-wide
                        # (csc: CSC column width, max col-nnz pow2)
    width_t: int        # same for A^T (csc: max row-nnz pow2)
    fmt: str
    prox: str
    family: str = "a2"
    loss: str = ""

    @property
    def is_rcd(self) -> bool:
        return self.family in ("rcd_primal", "rcd_dual")


@dataclasses.dataclass(frozen=True)
class ShardedBucketKey:
    """A mesh-wide bucket: operands partitioned over a capacity-sized
    sub-mesh, advanced by the (fmt, strategy) body
    ``core.distributed.make_sharded_bucket_fns`` builds (row-ELL gathers
    or tiled-BCSR MXU contractions; rowpart per-shard transpose blocks or
    dualpart dual-RDD caches — DESIGN.md section 5's table).  ``ndev`` is
    the number of devices the problem *needs* (ceil(stored entries /
    per-device capacity)), not the whole mesh: collectives only span the
    devices that hold shards."""

    m_pad: int          # divisible by 8 * ndev
    n_pad: int          # divisible by 8 * ndev
    width: int          # ELL k / BCSR kb of A, padded bucket-wide
    width_t: int        # transpose width for the key's strategy (0 for
                        # dualpart: shard-resident x, no transpose stored)
    prox: str
    ndev: int           # sub-mesh size
    fmt: str            # "ell" | "bcsr"
    strategy: str       # "rowpart" | "dualpart" | "gridpart"
                        # (repro.plan.decide_bucket_body)
    grid: tuple | None = None   # gridpart (rows, cols), rows*cols == ndev


@dataclasses.dataclass
class _Bucket:
    """Slot-batched operand buffers for one (shape, fmt, prox) bucket.

    Operand masters live host-side in numpy and are mutated in place at
    admission (an eager device scatter per slot write costs milliseconds;
    a numpy slice write is free).  ``dev`` caches the device-resident
    stacked pytrees and is rebuilt — one transfer per array — only when an
    admission dirtied the masters.  Solver state stays device-resident.
    """

    key: BucketKey
    a_vals: np.ndarray        # (S, ...) stacked A values
    a_idx: np.ndarray         # ELL cols / BCSR bcols / CSC rows of A
    at_vals: np.ndarray       # same for A^T
    at_idx: np.ndarray
    b: np.ndarray             # (S, m_pad)
    lg: np.ndarray            # (S,)
    gamma0: np.ndarray        # (S,)
    reg: np.ndarray           # (S,)
    tol: np.ndarray           # (S,)
    maxit: np.ndarray         # (S,) int32
    dim: np.ndarray           # (S,) int32 true coordinate count (rcd draw
                              # range; 1 in empty slots so modulo stays live)
    seed: np.ndarray          # (S,) int32 rcd coordinate-stream seeds
    state: Any                # batched, device (PDState | RCDState)
    active: np.ndarray        # (S,) bool occupancy mask
    dirty: bool = True
    dev: tuple | None = None
    requests: dict[int, SolveRequest] = dataclasses.field(default_factory=dict)
    device: Any = None        # round-robin pinned device (None: default)
    slot_mesh: Any = None     # slot axis S = slots*ndev over this sub-mesh
    active_dev: Any = None    # device-resident copy of ``active``
    charge: Any = None        # [(device_id, operand_bytes)] charge
    resident: bool = True     # False: operands exceed the device, streamed
    stream_chunks: int = 1    # operand uploads per check block (streamed)

    @property
    def slot_sharded(self) -> bool:
        return self.slot_mesh is not None

    @property
    def slots(self) -> int:
        return self.active.shape[0]


@dataclasses.dataclass
class _ShardedBucket:
    """Slot-batched operands for one mesh-wide (sharded) bucket.

    Same master/dev lifecycle as ``_Bucket``; the device cache holds
    NamedSharding-placed arrays (rows/tiles of A, b and yhat split over
    the mesh per ``core.distributed.sharded_bucket_specs``, x and per-slot
    scalars replicated), so operands stay mesh-resident across ticks.
    Array shapes follow the key's (fmt, strategy) layout
    (``_sharded_slot_shapes``): ELL (S, m_pad, width) / BCSR tile stacks
    (S, nbr, kb, bm, bn) forward; rowpart transpose blocks lead with an
    extra (ndev,) axis, dualpart transposes are plain (S, ...) stacks
    sharded on their own row axis."""

    key: ShardedBucketKey
    a_vals: np.ndarray        # forward values (GLOBAL col/block-col inds)
    a_idx: np.ndarray         # ELL cols / BCSR bcols of A
    at_vals: np.ndarray       # transpose values per the key's strategy
    at_idx: np.ndarray        # ELL rows / BCSR bcols of the transpose
    b: np.ndarray             # (S, m_pad)
    lg: np.ndarray            # (S,)
    gamma0: np.ndarray        # (S,)
    reg: np.ndarray           # (S,)
    tol: np.ndarray           # (S,)
    maxit: np.ndarray         # (S,) int32
    state: PDState            # batched; yhat row-sharded, x replicated
    active: np.ndarray        # (S,) bool occupancy mask
    dirty: bool = True
    dev: tuple | None = None
    requests: dict[int, SolveRequest] = dataclasses.field(default_factory=dict)
    active_dev: Any = None    # device-resident copy of ``active``
    charge: Any = None        # [(device_id, operand_bytes)] charge

    @property
    def slots(self) -> int:
        return self.active.shape[0]


def sharded_bucket_dims(m: int, n: int, ndev: int, min_rows: int = 64,
                        min_cols: int = 16) -> tuple[int, int]:
    """Padded (m_pad, n_pad) of a mesh-wide bucket: pow2 dims with the
    engine floors, both additionally multiples of ``8 * ndev`` so ELL
    rows AND BCSR 8-row tile stacks shard evenly in either orientation.
    Shared with ``repro.plan._cost_reasons`` so the plan's recorded
    bucket body is evaluated at the engine's own padding."""
    align = 8 * ndev
    m_pad = max(min_rows, _next_pow2(m), align)
    n_pad = max(min_cols, _next_pow2(n))
    return -(-m_pad // align) * align, -(-n_pad // align) * align


def sharded_bucket_widths(coo: COO, m_pad: int, n_pad: int, ndev: int,
                          fmt: str, need_row: bool = True,
                          need_dual: bool = True) -> tuple[int, int, int]:
    """pow2 ``(w, wt_row, wt_dual)`` storage widths at the PADDED dims —
    the exact widths ``SolverEngine.sharded_bucket_key`` keys buckets by,
    shared with ``repro.plan._cost_reasons`` so both sides feed
    ``decide_bucket_body`` identical inputs (a mismatch here makes the
    plan explain a different bucket than the engine builds).  Each is an
    O(nnz) host pass; a skipped width (forced strategy) returns 1.

    ``wt_dual`` is always 0: the shard-resident-x dualpart body scatters
    A^T y straight from the forward operand and psum_scatters the result,
    so no transpose is stored at all (``need_dual`` is kept for call-site
    symmetry but no longer triggers a host pass)."""
    from repro.sparse.partition import (
        rowshard_transpose_bcsr_width, rowshard_transpose_width,
    )

    del need_dual
    c = pad_coo(coo, m_pad, n_pad)
    if fmt == "bcsr":
        floor = 1
        w = coo_bcsr_width(c, bm=8, bn=min(128, n_pad))
        wt_row = rowshard_transpose_bcsr_width(
            c, ndev, bm=8, bn=min(128, m_pad // ndev)) if need_row else 1
    else:
        floor = 8
        rows = np.asarray(coo.rows)
        w = int(np.bincount(rows, minlength=coo.m).max()) if rows.size else 1
        wt_row = rowshard_transpose_width(c, ndev) if need_row else 1
    return (_next_pow2(max(floor, w)), _next_pow2(max(floor, wt_row)), 0)


def sharded_grid_widths(coo: COO, m_pad: int, n_pad: int,
                        grid: tuple[int, int], fmt: str) -> tuple[int, int]:
    """pow2 ``(width, width_t)`` storage widths of one gridpart candidate:
    the max per-block ELL row width (or BCSR tile count) over the
    (rows, cols) block grid of A, and the same over the per-block
    transpose tiles — the widths ``blockgrid_*``/``blockgrid_transpose_*``
    lay the operands out at.  Shared with ``repro.plan._cost_reasons`` so
    the plan prices the same grid candidates the engine would build.
    Each candidate is an O(nnz) host pass (the gridpart admission path
    scores every factorization of ndev)."""
    from repro.sparse.partition import (
        blockgrid_bcsr_width, blockgrid_ell_width,
        blockgrid_transpose_bcsr_width, blockgrid_transpose_ell_width,
    )

    R, C = grid
    c = pad_coo(coo, m_pad, n_pad)
    if fmt == "bcsr":
        floor = 1
        w = blockgrid_bcsr_width(c, R, C, bm=8, bn=min(128, n_pad // C))
        wt = blockgrid_transpose_bcsr_width(c, R, C, bm=8,
                                            bn=min(128, m_pad // R))
    else:
        floor = 8
        w = blockgrid_ell_width(c, R, C)
        wt = blockgrid_transpose_ell_width(c, R, C)
    return (_next_pow2(max(floor, w)), _next_pow2(max(floor, wt)))


def _sharded_slot_shapes(key: ShardedBucketKey):
    """(a_vals, a_idx, at_vals, at_idx) PER-SLOT master shapes for one
    mesh-wide bucket layout — the host-side mirror of the specs
    ``core.distributed.sharded_bucket_specs`` shards by.  The caller adds
    the slot axis (rowpart transpose blocks additionally lead with the
    (ndev,) shard axis; gridpart operands lead with the (R, C) grid axes;
    dualpart stores a ZERO-WIDTH transpose stand-in — width_t == 0 — so
    its at masters cost nothing but keep the call arity uniform)."""
    m, n, nd = key.m_pad, key.n_pad, key.ndev
    if key.strategy == "gridpart":
        R, C = key.grid
        mb, nb = m // R, n // C
        if key.fmt == "ell":
            return (mb, key.width), (mb, key.width), \
                   (nb, key.width_t), (nb, key.width_t)
        bm, bn, bn_t = 8, min(128, nb), min(128, mb)
        return ((mb // bm, key.width, bm, bn), (mb // bm, key.width),
                (-(-nb // bm), key.width_t, bm, bn_t),
                (-(-nb // bm), key.width_t))
    if key.fmt == "ell":
        return (m, key.width), (m, key.width), \
               (n, key.width_t), (n, key.width_t)
    bm, bn = 8, min(128, n)
    nbr, nbt = m // bm, -(-n // bm)
    bn_t = min(128, m // nd) if key.strategy == "rowpart" else min(128, m)
    return ((nbr, key.width, bm, bn), (nbr, key.width),
            (nbt, key.width_t, bm, bn_t), (nbt, key.width_t))


class SolverEngine:
    """Continuous-batching server for primal-dual solve requests.

    slots:   problems resident per bucket (the vmapped batch width).
    fmt:     "ell" (gather kernels) or "bcsr" (MXU tile kernels).
    backend: "jnp" (vmapped reference) or "pallas" (batch-grid kernels).
    check_every: iterations between per-slot feasibility checks — the
             early-exit granularity (matches solve_tol's check_every).
    devices: the device mesh to serve on — a list of jax devices, an int
             (first N of jax.devices()), or None for every local device.
             ``slots`` is a PER-DEVICE budget (resident problems one
             device's memory holds), so aggregate capacity scales with the
             mesh.  With >1 device, a replicated bucket is placed by queue
             pressure at creation: a lightly-loaded key is pinned
             round-robin to one device (jax.device_put — independent
             buckets advance concurrently), while a key whose queue
             exceeds ``slots`` gets a slot axis of ``slots * ndev``
             shard_map'd over a demand-sized sub-mesh (sharded batch axes
             — slots are independent, so the advance is collective-free
             and the whole queue admits in one generation).  Oversized
             requests go to mesh-wide sharded buckets on a capacity-sized
             sub-mesh; at 1 device they cannot shard OR stay resident and
             are served with streamed (re-uploaded per tick) operands.
    shard_above: per-device stored-entry capacity override for the
             placement rule (``repro.plan.decide_placement``; None -> env
             REPRO_SHARD_ABOVE_NNZ -> the planner default).
    device_budget: resident OPERAND-BYTE capacity of ONE device (None =
             unbounded, the legacy regime).  When set, bucket creation
             allocates slot widths against each device's remaining bytes,
             priced by the planner's cost model
             (``repro.plan.bucket_operand_bytes`` /
             ``sharded_bucket_bytes`` — BCSR tile bytes and ELL row bytes
             differ a lot for the same nonzeros, which slot counting
             cannot see): a device already hosting buckets hands out
             fewer slots to the next one (floor 1 on a mesh — every
             bucket keeps making progress, the serving fairness
             requirement), and a 1-device engine whose budget cannot hold
             even ONE slot of a bucket resident serves that bucket
             streamed (operands re-uploaded per check block).  This is
             the aggregate-capacity axis of multi-device serving (the
             benchmark's ``sharded_serving`` regime).
    sharded_strategy: bucket-body layout for mesh-wide buckets — None
             (default) applies the planner's byte-priced rule
             (``repro.plan.decide_bucket_body``: rowpart vs dualpart vs
             every gridpart factorization, scored on per-device resident
             bytes plus per-check-block collective wire bytes), or force
             "rowpart"/"dualpart"/"gridpart".  The fmt/backend knobs
             above select the kernel inside the body (ELL gathers vs
             BCSR/Pallas MXU tiles), so the MXU path and the mesh
             compose.
    grid:    force one (rows, cols) gridpart sub-mesh shape (implies
             ``sharded_strategy="gridpart"``); rows*cols also pins the
             sharded sub-mesh size.  None (default) lets the planner
             score every factorization of the capacity-sized ndev.
    sanitize: strict-mode tick guarding (``repro.analysis.strict``) —
             None resolves the process-wide strict flag (the pytest
             ``--strict-sanitize`` option / REPRO_STRICT env var), True/
             False force it.  When on, every tick phase that should be
             transfer-free runs under ``jax.transfer_guard("disallow")``:
             sanctioned host->device movement (admission splices,
             streamed re-uploads) goes through explicit ``device_put``
             inside ``intended_transfers()`` scopes, and a stray implicit
             transfer is counted in ``tick_counters`` (the phase then
             re-runs with transfers allowed, so serving stays correct —
             but the counter going nonzero is the regression signal).
             ``tick_counters`` also carries ``retraces``, the
             log_compiles-counted XLA compilations per tick window
             (sanitize on or off) — a warm engine must report 0/0, the
             enforcement form of PR 6's ``compile_s == 0`` claim.
    clock:   time source for the per-phase ``phase_s`` accounting
             (``repro.serve.clock`` protocol; default ``WallClock``).
             serve/ code never reads the wall directly — lint rule R5.
    """

    def __init__(self, slots: int = 8, fmt: str = "ell",
                 backend: str = "jnp", algorithm: str = "a2",
                 check_every: int | None = None, min_rows: int = 64,
                 min_cols: int = 16, interpret: bool | None = None,
                 devices: Any = None, shard_above: int | None = None,
                 device_budget: int | None = None,
                 sharded_strategy: str | None = None,
                 grid: tuple[int, int] | None = None,
                 fused: bool | None = None, sanitize: bool | None = None,
                 clock=None):
        if fmt not in ("ell", "bcsr"):
            raise ValueError(f"fmt must be ell|bcsr, got {fmt!r}")
        from repro.plan import decide_check_every

        self.slots = slots
        self.fmt = fmt
        self.backend = backend
        self.algorithm = algorithm
        self.check_every, _ = decide_check_every(check_every)
        # fused=None: one-kernel check blocks whenever the backend is
        # already the kernel path ("pallas"); True/False force it on/off
        # (fused applies only to plain resident buckets with a supported
        # prox family — everything else keeps the unfused step loop)
        self.fused = fused
        self.min_rows = min_rows
        self.min_cols = min_cols
        self.interpret = interpret
        if devices is None:
            devices = jax.devices()
        elif isinstance(devices, int):
            devices = jax.devices()[:devices]
        self.devices = list(devices)
        self.shard_above = shard_above
        self.device_budget = device_budget
        if sharded_strategy not in (None, "rowpart", "dualpart", "gridpart"):
            raise ValueError("sharded_strategy must be None (byte-model "
                             "rule) | 'rowpart' | 'dualpart' | 'gridpart', "
                             f"got {sharded_strategy!r}")
        if grid is not None:
            grid = tuple(int(v) for v in grid)
            if len(grid) != 2 or grid[0] < 1 or grid[1] < 1:
                raise ValueError(f"grid must be a (rows, cols) pair of "
                                 f"positive ints, got {grid!r}")
            if grid[0] * grid[1] > len(devices):
                raise ValueError(
                    f"grid {grid[0]}x{grid[1]} needs {grid[0] * grid[1]} "
                    f"devices, only {len(devices)} visible")
            if sharded_strategy is None:
                sharded_strategy = "gridpart"   # a forced shape forces the
            elif sharded_strategy != "gridpart":            # strategy too
                raise ValueError(f"grid= only applies to "
                                 f"sharded_strategy='gridpart', got "
                                 f"{sharded_strategy!r}")
        self.sharded_strategy = sharded_strategy
        self.grid = grid
        # per-device resident operand BYTES charged by bucket creation
        self._budget_used: dict[int, int] = {d.id: 0 for d in self.devices}
        self.mesh = None
        if len(self.devices) > 1:
            from jax.sharding import Mesh
            self.mesh = Mesh(np.array(self.devices), ("p",))
        self.queues: dict[Any, deque[SolveRequest]] = {}
        self.buckets: dict[Any, Any] = {}
        self.completed: list[SolveRequest] = []
        self.stats = {"steps": 0, "iterations": 0, "admitted": 0,
                      "sharded_admitted": 0}
        # per-phase wall time of the serve loop (seconds, cumulative);
        # compile_s is the one-time AOT lowering cost and is EXCLUDED from
        # the phase that triggered it, so a steady-state tick's admit /
        # splice / dispatch / harvest attribution is compile-free.  Kept
        # separate from ``stats`` (benchmarks reset that dict wholesale).
        self.phase_s = {"admit_s": 0.0, "splice_s": 0.0, "dispatch_s": 0.0,
                        "harvest_s": 0.0, "compile_s": 0.0}
        # strict-mode tick counters, phase_s-style cumulative (benchmarks
        # reset them per measured window): XLA compilations observed
        # during ticks and implicit transfers the strict guard caught.
        # A warm engine must report 0/0 (see the `sanitize` knob above).
        self.tick_counters = {"retraces": 0, "disallowed_transfers": 0}
        self.sanitize = sanitize
        self.clock = clock if clock is not None else WallClock()
        self._auto_uid = 0
        self._rr = 0                      # round-robin bucket device cursor
        # per-instance jit closures: the compile cache lives on the engine
        # (a static `self` argname would pin every engine — and its bucket
        # masters — in jit's global cache for the process lifetime)
        self._splice_init = jax.jit(self._splice_init_impl,
                                    static_argnames=("key",))
        self._advance = jax.jit(self._advance_impl,
                                static_argnames=("key", "steps"))
        # BucketKey-keyed AOT executables for the plain resident bodies:
        # splice + advance are .lower().compile()'d once per (kind, key,
        # slot width) at first use, so later admissions / re-splices into
        # the same bucket shape call a finished executable and never pay
        # jit tracing on the tick path (the lowering cost lands in
        # phase_s["compile_s"], not the tick's phase)
        self._aot_cache: dict = {}
        # (ndev, n_pad, prox) -> (splice_fn, advance_fn) row-shard bodies
        self._sharded_fn_cache: dict = {}
        # key -> (splice_fn, advance_fn) slot-axis shard_map bodies
        self._slotshard_fn_cache: dict = {}
        self._sub_meshes: dict = {}

    # -- bucketing policy --------------------------------------------------

    def placement_for(self, req: SolveRequest) -> str:
        """The planner's serving-placement verdict for one request
        ("single" | "replicated" | "sharded") — the same
        ``decide_placement`` rule ``Problem.plan()`` records."""
        from repro.plan import decide_placement

        placement, _ = decide_placement(
            req.coo.m, req.coo.n, req.coo.nnz, len(self.devices),
            self.shard_above)
        return placement

    def admission_for(self, req: SolveRequest, allow_streaming: bool = True
                      ) -> tuple[str, str]:
        """The planner's admission verdict for one request against THIS
        engine's live byte budget: ("resident" | "streamed" | "rejected",
        reason) from ``repro.plan.decide_admission`` — the same rule
        ``plan()`` records as the ``admission`` reason, evaluated here
        with the budget numbers only the engine knows.  With
        ``allow_streaming=False`` work that could only be served streamed
        (over-capacity on one device, or a saturated byte budget) is
        rejected instead of silently spilling to per-tick re-uploads —
        the open-loop front-end's backpressure contract."""
        from repro.plan import decide_admission

        slot_bytes = budget_left = None
        if self.device_budget is not None and len(self.devices) == 1:
            placement = self.placement_for(req)
            key = (self.sharded_bucket_key(req)
                   if self.mesh is not None and placement == "sharded"
                   and not getattr(req, "is_rcd", False)
                   else self.bucket_key(req))
            bucket = self.buckets.get(key)
            if bucket is not None:
                # an existing bucket's slots are already charged: resident
                # iff the bucket is (a streamed bucket stays streamed)
                if getattr(bucket, "resident", True):
                    return "resident", ("existing resident bucket; slot "
                                        "bytes already charged at creation")
                if not allow_streaming:
                    return "rejected", ("existing bucket for this key is "
                                        "streamed (over the byte budget) "
                                        "and streaming is disallowed")
                return "streamed", "existing streamed bucket for this key"
            slot_bytes = self.bucket_slot_bytes(key)
            budget_left = self.device_budget - min(
                self._budget_used.values())
        return decide_admission(
            req.coo.m, req.coo.n, req.coo.nnz, len(self.devices),
            slot_bytes=slot_bytes, budget_left=budget_left,
            shard_above=self.shard_above, allow_streaming=allow_streaming)

    def _ndev_for(self, nnz: int) -> int:
        """Capacity-sized sub-mesh: the fewest devices whose combined
        per-device capacity (the decide_placement threshold) holds the
        operands — collectives should span the shards, not the world."""
        from repro.plan import sharding_ndev

        return sharding_ndev(nnz, len(self.devices), self.shard_above)

    def sharded_bucket_key(self, req: SolveRequest) -> ShardedBucketKey:
        """Mesh-wide bucket key: pow2 dims (both additionally multiples of
        ``8 * ndev`` so ELL rows AND BCSR 8-row tile stacks shard evenly
        in either orientation) and pow2 widths, so oversized ragged
        traffic also collapses onto few compiled bodies.  The bucket-body
        strategy is the planner's byte-model rule
        (``repro.plan.decide_bucket_body``) over the engine's fmt, unless
        ``sharded_strategy`` forces one."""
        from repro.plan import decide_bucket_body, grid_shapes

        coo = req.coo
        ndev = (self.grid[0] * self.grid[1] if self.grid is not None
                else self._ndev_for(coo.nnz))
        m_pad, n_pad = sharded_bucket_dims(coo.m, coo.n, ndev,
                                           self.min_rows, self.min_cols)
        # only the widths the strategy decision can consult are computed
        # (each is an O(nnz) host pass; a forced strategy skips the rest)
        w, wt_row, wt_dual = sharded_bucket_widths(
            coo, m_pad, n_pad, ndev, self.fmt,
            need_row=self.sharded_strategy in (None, "rowpart"),
            need_dual=self.sharded_strategy in (None, "dualpart"))
        gw = None
        if self.sharded_strategy in (None, "gridpart"):
            shapes = ([self.grid] if self.grid is not None
                      else grid_shapes(ndev))
            gw = {g: sharded_grid_widths(coo, m_pad, n_pad, g, self.fmt)
                  for g in shapes}
        strategy, grid, _, _ = decide_bucket_body(
            self.fmt, m_pad, n_pad, w, wt_row, wt_dual, ndev,
            override=self.sharded_strategy, grid_widths=gw)
        if strategy == "gridpart":
            w, wt = gw[grid]
        else:
            wt = wt_row if strategy == "rowpart" else wt_dual
        return ShardedBucketKey(
            m_pad=m_pad, n_pad=n_pad, width=w, width_t=wt,
            prox=req.prox, ndev=ndev, fmt=self.fmt, strategy=strategy,
            grid=grid)

    def bucket_key(self, req: SolveRequest) -> BucketKey:
        """(shape-bucket, format, prox family): dims round up to powers of
        two (floors min_rows/min_cols), ELL/BCSR widths to powers of two,
        so ragged traffic collapses onto few compiled step functions.

        RCD requests key by (shape, "csc", family, loss) regardless of the
        engine's fmt knob — coordinate access needs the column-major view,
        and the epoch body is loss-specific."""
        coo = req.coo
        m_pad = max(self.min_rows, _next_pow2(coo.m))
        n_pad = max(self.min_cols, _next_pow2(coo.n))
        if getattr(req, "is_rcd", False):
            rows = np.asarray(coo.rows)
            cols = np.asarray(coo.cols)
            w = int(np.bincount(cols, minlength=coo.n).max()) if cols.size else 1
            wt = int(np.bincount(rows, minlength=coo.m).max()) if rows.size else 1
            return BucketKey(m_pad=m_pad, n_pad=n_pad,
                             width=_next_pow2(max(8, w)),
                             width_t=_next_pow2(max(8, wt)),
                             fmt="csc", prox=req.prox,
                             family=req.family, loss=req.loss)
        if self.fmt == "ell":
            rows = np.asarray(coo.rows)
            cols = np.asarray(coo.cols)
            w = int(np.bincount(rows, minlength=coo.m).max()) if rows.size else 1
            wt = int(np.bincount(cols, minlength=coo.n).max()) if cols.size else 1
            w, wt = _next_pow2(max(8, w)), _next_pow2(max(8, wt))
        else:
            c = pad_coo(coo, m_pad, n_pad)
            w = _next_pow2(coo_bcsr_width(c, bm=8, bn=min(128, n_pad)))
            wt = _next_pow2(coo_bcsr_width(transpose_coo(c), bm=8,
                                           bn=min(128, m_pad)))
        return BucketKey(m_pad=m_pad, n_pad=n_pad, width=w, width_t=wt,
                         fmt=self.fmt, prox=req.prox)

    # -- request lifecycle -------------------------------------------------

    def submit(self, req) -> BucketKey:
        """Queue one solve.  Accepts a ``SolveRequest`` or anything with a
        ``to_request`` adapter — i.e. a ``repro.api.Problem``, which makes
        the declarative Problem the engine's native admission type (uids
        are assigned engine-side)."""
        if not isinstance(req, SolveRequest):
            to_request = getattr(req, "to_request", None)
            if to_request is None:
                raise TypeError(
                    f"submit() takes a SolveRequest or a repro.api.Problem, "
                    f"got {type(req).__name__}")
            req = to_request(uid=self._auto_uid)
        # auto uids stay clear of every uid seen so far, so mixing explicit
        # SolveRequests and auto-uid'd Problems cannot collide
        self._auto_uid = max(self._auto_uid, req.uid + 1)
        if getattr(req, "is_rcd", False):
            # rcd runs its own 1-D loss updates — the prox knob is unused,
            # so the batched-prox restriction does not apply; family/loss
            # compatibility is what can actually be mis-stated
            from repro.solvers.rcd import check_family_loss
            check_family_loss(req.family, req.loss)
            # rcd buckets never shard mesh-wide (the epoch body's scattered
            # coordinate updates have no row-partitioned form); oversized
            # requests fall through to the plain bucket path, which streams
            # over-capacity operands exactly like primal-dual traffic
            key = self.bucket_key(req)
            self.queues.setdefault(key, deque()).append(req)
            return key
        if req.prox not in BATCHED_PROX_FAMILIES:
            raise KeyError(f"prox family {req.prox!r} not servable; "
                           f"supported: {BATCHED_PROX_FAMILIES}")
        # planner-resolved placement: oversized problems go to a mesh-wide
        # sharded bucket; on a single device they cannot be sharded NOR
        # stay resident — their bucket streams operands every tick (the
        # data-locality cost the mesh placement exists to avoid)
        placement = self.placement_for(req)
        if self.mesh is not None and placement == "sharded":
            key = self.sharded_bucket_key(req)
        else:
            key = self.bucket_key(req)
        self.queues.setdefault(key, deque()).append(req)
        return key

    def _sub_mesh_of(self, devices: list):
        """1-axis mesh over an explicit device list (cached)."""
        ids = tuple(d.id for d in devices)
        mesh = self._sub_meshes.get(ids)
        if mesh is None:
            from jax.sharding import Mesh
            mesh = Mesh(np.array(devices), ("p",))
            self._sub_meshes[ids] = mesh
        return mesh

    def _sub_mesh(self, ndev: int):
        """1-axis mesh over the first ``ndev`` engine devices — the
        row-sharded buckets' sub-mesh (one compiled body per ndev)."""
        return self._sub_mesh_of(self.devices[:ndev])

    def _grid_mesh(self, grid: tuple[int, int]):
        """2-axis ("r", "c") mesh over the first rows*cols engine devices
        — the gridpart buckets' sub-mesh (cached per (ids, shape): 2x4
        and 4x2 over the same devices are distinct meshes)."""
        R, C = grid
        devices = self.devices[:R * C]
        cache_key = (tuple(d.id for d in devices), (R, C))
        mesh = self._sub_meshes.get(cache_key)
        if mesh is None:
            from jax.sharding import Mesh
            mesh = Mesh(np.array(devices).reshape(R, C), ("r", "c"))
            self._sub_meshes[cache_key] = mesh
        return mesh

    def _mesh_for(self, key: ShardedBucketKey):
        """The sub-mesh a mesh-wide bucket's collectives span: the (R, C)
        grid for gridpart, the 1-axis ndev line otherwise."""
        if key.strategy == "gridpart":
            return self._grid_mesh(key.grid)
        return self._sub_mesh(key.ndev)

    def _pick_devices(self, count: int) -> list:
        """The ``count`` least-budget-used devices (round-robin cursor
        breaks ties, so unbudgeted engines keep pure round-robin)."""
        ndev = len(self.devices)
        order = sorted(range(ndev),
                       key=lambda i: (self._budget_used[self.devices[i].id],
                                      (i - self._rr) % ndev))
        self._rr += 1
        return [self.devices[i] for i in order[:count]]

    def bucket_slot_bytes(self, key) -> int:
        """Per-device resident operand bytes ONE slot of this bucket
        costs — the admission unit ``device_budget`` prices, from the
        planner's cost model (repro.plan: ``sharded_bucket_bytes`` for
        mesh-wide keys, ``bucket_operand_bytes`` otherwise), so BCSR tile
        stacks and ELL row stacks charge what they actually store."""
        from repro.plan import bucket_operand_bytes, sharded_bucket_bytes

        if isinstance(key, ShardedBucketKey):
            return sharded_bucket_bytes(
                key.fmt, key.strategy, 1, key.m_pad, key.n_pad,
                key.width, key.width_t, key.ndev, grid=key.grid)
        return bucket_operand_bytes(key.fmt, 1, key.m_pad, key.n_pad,
                                    key.width, key.width_t)

    def _charge(self, bucket, devices: list, per_dev_bytes: int) -> None:
        for d in devices:
            self._budget_used[d.id] += per_dev_bytes
        bucket.charge = [(d.id, per_dev_bytes) for d in devices]

    def _slots_affordable(self, devices: list, key) -> int:
        """Slots the tightest picked device's remaining byte budget holds
        (may be 0 — the caller decides between floor-1 fairness on a mesh
        and streaming on one device); unbudgeted engines afford the full
        per-device slot allowance."""
        if self.device_budget is None:
            return self.slots
        left = min(self.device_budget - self._budget_used[d.id]
                   for d in devices)
        return max(0, left) // max(1, self.bucket_slot_bytes(key))

    def _slot_width(self, devices: list, key) -> int:
        """Slots one bucket may hold per device: the byte budget's
        allowance clamped to ``slots`` (floor 1 — every bucket keeps
        making progress even when a device is oversubscribed; serving
        cannot park a tenant)."""
        return max(1, min(self.slots, self._slots_affordable(devices, key)))

    def _make_bucket(self, key):
        """Placement at bucket creation (queue pressure + budget decide):

        * ShardedBucketKey -> operands row-partitioned over a
          capacity-sized sub-mesh (the problem itself exceeds one device).
        * deep queue (> one device's slot allowance) on a mesh -> slot
          axis shard_map'd over enough devices that the whole queue
          admits in one generation (capped by the mesh): aggregate slot
          capacity scales with the device count.
        * otherwise -> pinned to the least-loaded device (jax.device_put,
          round-robin on ties): independent buckets advance concurrently
          with zero cross-device traffic.
        """
        depth = len(self.queues.get(key) or ())
        if isinstance(key, ShardedBucketKey):
            # slot width follows demand, clamped by the shard devices'
            # remaining byte budget (floor 1 — a sharded request cannot
            # stream on a mesh, so an over-budget tenant still gets one
            # slot and the queue drains over extra admission generations)
            shard_devs = self.devices[:key.ndev]
            width = min(self.slots, max(1, depth),
                        max(1, self._slots_affordable(shard_devs, key)))
            bucket = self._new_sharded_bucket(key, width)
            self._charge(bucket, shard_devs,
                         bucket.slots * self.bucket_slot_bytes(key))
            return bucket
        ndev = len(self.devices)
        from repro.plan import _shard_threshold
        cap = _shard_threshold(self.shard_above)
        if ndev == 1:
            over_cap = any(r.coo.nnz >= cap
                           for r in (self.queues.get(key) or ()))
            afford = self._slots_affordable(self.devices, key)
            if over_cap or afford < 1:
                # an over-capacity request on a single device — the nnz
                # threshold says so, OR the byte budget cannot hold even
                # one slot of this bucket's operand stacks resident (a
                # wide-tile BCSR bucket can exceed it at an nnz slot
                # counting would happily admit): nothing to pin, nothing
                # to cache — slot width matches demand, transfers repeat
                # per tick.  Decided per bucket CREATION from the live
                # queue (not a sticky per-key flag), so a later wave of
                # under-threshold traffic on the same shape key gets an
                # ordinary resident bucket after an evict.
                bucket = self._new_bucket(key,
                                          min(self.slots, max(1, depth)))
                bucket.resident = False
                if not over_cap:
                    # byte-induced streaming: the re-upload cadence follows
                    # the operand fraction the remaining budget cannot hold
                    left = max(0, self.device_budget
                               - self._budget_used[self.devices[0].id])
                    frac = 1.0 - left / max(1, self.bucket_slot_bytes(key))
                    bucket.stream_chunks = max(
                        bucket.stream_chunks,
                        int(np.ceil(self.check_every * max(0.0, frac))))
                return bucket
        if ndev > 1 and depth > self.slots:
            # capacity matched to demand: enough devices that the whole
            # queue admits in one generation, never more than the mesh
            ndev_s = min(ndev, -(-depth // self.slots))
            picked = self._pick_devices(ndev_s)
            width = self._slot_width(picked, key)
            bucket = self._new_bucket(key, width * ndev_s)
            bucket.slot_mesh = self._sub_mesh_of(picked)
            self._charge(bucket, picked,
                         width * self.bucket_slot_bytes(key))
            return bucket
        # full provisioned width (NOT depth-matched): continuous admission
        # means later traffic lands in this bucket, and a width frozen at
        # a shallow creation-time queue would serialize it
        picked = self._pick_devices(1)
        bucket = self._new_bucket(key, self._slot_width(picked, key))
        self._charge(bucket, picked,
                     bucket.slots * self.bucket_slot_bytes(key))
        # pinned placement: this bucket's operands, state and compiled
        # step live on one mesh device so independent buckets advance
        # concurrently (jit follows its committed inputs)
        if ndev > 1:
            bucket.device = picked[0]
            bucket.state = jax.device_put(bucket.state, bucket.device)
        return bucket

    def _new_sharded_bucket(self, key: ShardedBucketKey,
                            s: int | None = None) -> _ShardedBucket:
        s = self.slots if s is None else s
        m, n = key.m_pad, key.n_pad
        a_sh, ai_sh, at_sh, ati_sh = _sharded_slot_shapes(key)
        if key.strategy == "gridpart":
            # per-block operands lead with the (R, C) grid axes, slot third
            a_lead = at_lead = (*key.grid, s)
        else:
            a_lead = (s,)
            at_lead = (key.ndev, s) if key.strategy == "rowpart" else (s,)
        zeros_x = jnp.zeros((s, n), jnp.float32)
        state = PDState(xbar=zeros_x, xstar=zeros_x,
                        yhat=jnp.zeros((s, m), jnp.float32),
                        gamma=jnp.ones((s,), jnp.float32),
                        k=jnp.zeros((s,), jnp.int32))
        return _ShardedBucket(
            key=key,
            a_vals=np.zeros((*a_lead, *a_sh), np.float32),
            a_idx=np.zeros((*a_lead, *ai_sh), np.int32),
            at_vals=np.zeros((*at_lead, *at_sh), np.float32),
            at_idx=np.zeros((*at_lead, *ati_sh), np.int32),
            b=np.zeros((s, m), np.float32),
            lg=np.ones((s,), np.float32),
            gamma0=np.ones((s,), np.float32),
            reg=np.zeros((s,), np.float32),
            tol=np.full((s,), np.inf, np.float32),
            maxit=np.zeros((s,), np.int32),
            state=state, active=np.zeros((s,), bool))

    def _new_bucket(self, key: BucketKey, s: int | None = None) -> _Bucket:
        s = self.slots if s is None else s
        m, n = key.m_pad, key.n_pad
        if key.fmt == "csc":
            # column-major pair: CSC(A) one row per COLUMN (n rows), CSC(A^T)
            # one row per row of A — the coordinate-descent operand view
            a_shape = (s, n, key.width)
            at_shape = (s, m, key.width_t)
        elif key.fmt == "ell":
            a_shape = (s, m, key.width)
            at_shape = (s, n, key.width_t)
        else:
            bm, bn = 8, min(128, n)
            bnt = min(128, m)
            a_shape = (s, -(-m // bm), key.width, bm, bn)
            at_shape = (s, -(-n // bm), key.width_t, bm, bnt)
        zeros_x = jnp.zeros((s, n), jnp.float32)
        zeros_y = jnp.zeros((s, m), jnp.float32)
        if key.is_rcd:
            from repro.solvers.rcd import RCDState
            state = RCDState(xbar=zeros_x, aux=zeros_y,
                             k=jnp.zeros((s,), jnp.int32))
        else:
            state = PDState(xbar=zeros_x, xstar=zeros_x, yhat=zeros_y,
                            gamma=jnp.ones((s,), jnp.float32),
                            k=jnp.zeros((s,), jnp.int32))
        return _Bucket(
            key=key,
            a_vals=np.zeros(a_shape, np.float32),
            a_idx=np.zeros(a_shape[:3], np.int32),
            at_vals=np.zeros(at_shape, np.float32),
            at_idx=np.zeros(at_shape[:3], np.int32),
            b=np.zeros((s, m), np.float32),
            lg=np.ones((s,), np.float32),
            gamma0=np.ones((s,), np.float32),
            reg=np.zeros((s,), np.float32),
            tol=np.full((s,), np.inf, np.float32),
            maxit=np.zeros((s,), np.int32),
            dim=np.ones((s,), np.int32),
            seed=np.zeros((s,), np.int32),
            state=state, active=np.zeros((s,), bool))

    def _convert(self, key: BucketKey, coo: COO):
        """Host-side: pad to bucket dims, build both orientations at the
        bucket's fixed widths (numpy per-slot arrays, ready to splice)."""
        c = pad_coo(coo, key.m_pad, key.n_pad)
        if key.fmt == "csc":
            from repro.sparse.formats import coo_to_csc
            fa = coo_to_csc(c, k=key.width)
            fat = coo_to_csc(transpose_coo(c), k=key.width_t)
            return (fa.vals, fa.rows), (fat.vals, fat.rows)
        if key.fmt == "ell":
            fa = coo_to_ell(c, k=key.width)
            fat = coo_to_ell(transpose_coo(c), k=key.width_t)
            return (fa.vals, fa.cols), (fat.vals, fat.cols)
        bm, bn = 8, min(128, key.n_pad)
        bnt = min(128, key.m_pad)
        fa = coo_to_bcsr(c, bm=bm, bn=bn, kb=key.width)
        fat = coo_to_bcsr(transpose_coo(c), bm=bm, bn=bnt, kb=key.width_t)
        return (fa.vals, fa.bcols), (fat.vals, fat.bcols)

    def _write_slot(self, key, bucket, slot: int, req: SolveRequest) -> None:
        """Splice one request's converted operands into slot ``slot`` of
        the bucket's numpy masters."""
        if isinstance(key, ShardedBucketKey):
            from repro.sparse.partition import (
                block_partitioned_ell, blockgrid_bcsr,
                blockgrid_transpose_bcsr, blockgrid_transpose_ell,
                rowshard_transpose_bcsr, rowshard_transpose_ell,
            )

            c = pad_coo(req.coo, key.m_pad, key.n_pad)
            if key.strategy == "gridpart":
                R, C = key.grid
                if key.fmt == "ell":
                    fa, fi, _, _ = block_partitioned_ell(c, R, C,
                                                         k=key.width)
                    tv, ti = blockgrid_transpose_ell(c, R, C,
                                                     k=key.width_t)
                else:
                    bn = min(128, key.n_pad // C)
                    bn_t = min(128, key.m_pad // R)
                    fa, fi = blockgrid_bcsr(c, R, C, bm=8, bn=bn,
                                            kb=key.width)
                    tv, ti = blockgrid_transpose_bcsr(c, R, C, bm=8,
                                                      bn=bn_t,
                                                      kb=key.width_t)
                bucket.a_vals[:, :, slot] = np.asarray(fa)
                bucket.a_idx[:, :, slot] = np.asarray(fi)
                bucket.at_vals[:, :, slot] = np.asarray(tv)
                bucket.at_idx[:, :, slot] = np.asarray(ti)
                self.stats["sharded_admitted"] += 1
                return
            if key.fmt == "ell":
                e = coo_to_ell(c, k=key.width)
                fa, fi = e.vals, e.cols
                if key.strategy == "rowpart":
                    tv, ti = rowshard_transpose_ell(c, key.ndev,
                                                    k=key.width_t)
            else:
                bm = 8
                f = coo_to_bcsr(c, bm=bm, bn=min(128, key.n_pad),
                                kb=key.width)
                fa, fi = f.vals, f.bcols
                if key.strategy == "rowpart":
                    tv, ti = rowshard_transpose_bcsr(
                        c, key.ndev, bm=bm,
                        bn=min(128, key.m_pad // key.ndev), kb=key.width_t)
            bucket.a_vals[slot] = np.asarray(fa)
            bucket.a_idx[slot] = np.asarray(fi)
            if key.strategy == "rowpart":
                bucket.at_vals[:, slot] = np.asarray(tv)
                bucket.at_idx[:, slot] = np.asarray(ti)
            # dualpart: nothing to write — the zero-width at stand-ins
            # stay all-zero (the backward scatters from the forward operand)
            self.stats["sharded_admitted"] += 1
        else:
            (av, ai), (atv, ati) = self._convert(key, req.coo)
            bucket.a_vals[slot] = np.asarray(av)
            bucket.a_idx[slot] = np.asarray(ai)
            bucket.at_vals[slot] = np.asarray(atv)
            bucket.at_idx[slot] = np.asarray(ati)
            if not bucket.resident:
                # the operand fraction beyond the device's capacity must
                # re-stream every iteration: ceil(check_every * fraction)
                # uploads per check block (floor 1)
                from repro.plan import _shard_threshold
                cap = _shard_threshold(self.shard_above)
                frac = max(0.0, 1.0 - cap / max(1, req.coo.nnz))
                bucket.stream_chunks = max(
                    bucket.stream_chunks, 1,
                    int(np.ceil(self.check_every * frac)))

    @staticmethod
    def _pop_queued(queue: deque) -> SolveRequest:
        """Next request out of one bucket queue: highest ``priority``
        first, FIFO within a priority class (a plain popleft when nobody
        set priorities — the pre-open-loop behavior)."""
        best = 0
        for i in range(1, len(queue)):
            if queue[i].priority > queue[best].priority:
                best = i
        if best == 0:
            return queue.popleft()
        req = queue[best]
        del queue[best]
        return req

    def _admit(self, key, bucket) -> np.ndarray:
        queue = self.queues.get(key)
        new = np.zeros((bucket.slots,), bool)
        if not queue:
            return new
        for slot in range(bucket.slots):
            if not queue:
                break
            if bucket.active[slot]:
                continue
            req = self._pop_queued(queue)
            self._write_slot(key, bucket, slot, req)
            bucket.b[slot, :req.coo.m] = np.asarray(req.b, np.float32)
            bucket.b[slot, req.coo.m:] = 0.0
            bucket.lg[slot] = req.lg
            bucket.gamma0[slot] = req.gamma0
            bucket.reg[slot] = req.reg
            bucket.tol[slot] = req.tol
            bucket.maxit[slot] = req.max_iterations
            if getattr(key, "is_rcd", False):
                bucket.dim[slot] = (req.coo.n if key.family == "rcd_primal"
                                    else req.coo.m)
                bucket.seed[slot] = req.seed
            bucket.requests[slot] = req
            bucket.active[slot] = True
            bucket.active_dev = None
            bucket.dirty = True
            new[slot] = True
            self.stats["admitted"] += 1
        return new

    def _device_operands(self, bucket: _Bucket) -> tuple:
        """Device-resident (a, at, b, lg, gamma0, reg, dim, seed, tol,
        maxit); one transfer per array, only after admissions dirtied the
        masters.  With a pinned bucket device the transfers target it, so
        the jit'd bodies (which follow their committed inputs) run there
        too.  dim/seed ride along for every bucket (two (S,) int arrays)
        so the operand tuple has one shape engine-wide; only the rcd
        bodies read them."""
        if bucket.dirty or bucket.dev is None:
            key = bucket.key
            if bucket.slot_sharded:
                from jax.sharding import NamedSharding, PartitionSpec as P

                def _target(v):
                    # numpy master -> sharded buffers directly (jnp.asarray
                    # first would materialize the FULL array on the default
                    # device, the exact thing sharded placement avoids)
                    return NamedSharding(
                        bucket.slot_mesh,
                        P("p", *([None] * (np.ndim(v) - 1))))
            elif bucket.device is None:
                _target = lambda v: None       # default device, explicitly
            else:
                _target = lambda v: bucket.device

            def put(v):
                # explicit device_put inside an intended_transfers scope:
                # this is THE sanctioned host->device edge of admission, and
                # it stays legal under the strict tick guard ("disallow"
                # only blocks implicit transfers)
                with intended_transfers():
                    return jax.device_put(v, _target(v))
            if key.fmt == "csc":
                from repro.sparse.formats import StackedCSC
                a = StackedCSC(vals=put(bucket.a_vals),
                               rows=put(bucket.a_idx), m=key.m_pad)
                at = StackedCSC(vals=put(bucket.at_vals),
                                rows=put(bucket.at_idx), m=key.n_pad)
            elif key.fmt == "ell":
                from repro.sparse.formats import StackedELL
                a = StackedELL(vals=put(bucket.a_vals),
                               cols=put(bucket.a_idx), n=key.n_pad)
                at = StackedELL(vals=put(bucket.at_vals),
                                cols=put(bucket.at_idx), n=key.m_pad)
            else:
                from repro.sparse.formats import StackedBCSR
                a = StackedBCSR(vals=put(bucket.a_vals),
                                bcols=put(bucket.a_idx),
                                m=key.m_pad, n=key.n_pad)
                at = StackedBCSR(vals=put(bucket.at_vals),
                                 bcols=put(bucket.at_idx),
                                 m=key.n_pad, n=key.m_pad)
            bucket.dev = (a, at, put(bucket.b),
                          put(bucket.lg), put(bucket.gamma0),
                          put(bucket.reg), put(bucket.dim),
                          put(bucket.seed), put(bucket.tol),
                          put(bucket.maxit))
            bucket.dirty = False
        return bucket.dev

    def _sharded_device_operands(self, bucket: _ShardedBucket) -> tuple:
        """Mesh-resident (a_vals, a_idx, at_vals, at_idx, b, lg, gamma0,
        reg, tol, maxit): operand stacks split per the bucket body's
        layout (``core.distributed.sharded_bucket_specs`` — the same
        specs the shard_map traces against), per-slot scalars
        replicated — one sharded transfer per array, only after
        admissions dirtied the masters, so operands stay device-resident
        across ticks."""
        if bucket.dirty or bucket.dev is None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.core.distributed import sharded_bucket_specs
            mesh = self._mesh_for(bucket.key)
            gridded = bucket.key.strategy == "gridpart"
            axis = ("r", "c") if gridded else "p"
            a_specs, at_specs = sharded_bucket_specs(
                axis, bucket.key.fmt, bucket.key.strategy)
            ns = lambda spec: NamedSharding(mesh, spec)
            rep = ns(P())
            # numpy masters -> sharded buffers directly: materializing on
            # the default device first would need the whole over-capacity
            # stack to fit one device
            with intended_transfers():
                bucket.dev = (
                    jax.device_put(bucket.a_vals, ns(a_specs[0])),
                    jax.device_put(bucket.a_idx, ns(a_specs[1])),
                    jax.device_put(bucket.at_vals, ns(at_specs[0])),
                    jax.device_put(bucket.at_idx, ns(at_specs[1])),
                    jax.device_put(bucket.b,
                                   ns(P(None, "r" if gridded else "p"))),
                    jax.device_put(bucket.lg, rep),
                    jax.device_put(bucket.gamma0, rep),
                    jax.device_put(bucket.reg, rep),
                    jax.device_put(bucket.tol, rep),
                    jax.device_put(bucket.maxit, rep))
            bucket.dirty = False
        return bucket.dev

    def _sharded_fns(self, key: ShardedBucketKey):
        """(splice_fn, advance_fn) shard_map bodies for mesh-wide buckets
        (core.distributed.make_sharded_bucket_fns), cached per
        (ndev, n_pad, prox, fmt, strategy, grid) — jit retraces per
        operand shape underneath; fmt/strategy/grid change the spec ranks
        or mesh so they pin distinct bodies."""
        cache_key = (key.ndev, key.n_pad, key.prox, key.fmt, key.strategy,
                     key.grid)
        fns = self._sharded_fn_cache.get(cache_key)
        if fns is None:
            from repro.core.distributed import make_sharded_bucket_fns
            fns = make_sharded_bucket_fns(
                self._mesh_for(key), key.n_pad,
                partial(batched_prox, key.prox),
                algorithm=self.algorithm, check_every=self.check_every,
                axis=("r", "c") if key.strategy == "gridpart" else "p",
                fmt=key.fmt, strategy=key.strategy, backend=self.backend,
                interpret=self.interpret)
            self._sharded_fn_cache[cache_key] = fns
        return fns

    def _slotshard_fns(self, key: BucketKey, mesh, example_args):
        """(splice_fn, advance_fn) for slot-axis-sharded buckets: the
        engine's own jit bodies wrapped in shard_map with EVERY operand,
        state leaf and mask split on its leading slot axis — slots are
        independent problems, so the mapped body is collective-free and
        each device advances its own slice of the bucket."""
        cache_key = (key, tuple(d.id for d in mesh.devices.flat))
        fns = self._slotshard_fn_cache.get(cache_key)
        if fns is None:
            from jax.sharding import PartitionSpec as P

            from repro.distributed.sharding import shard_map

            def slot_spec(leaf):
                return P("p", *([None] * (jnp.ndim(leaf) - 1)))

            a, at, b, lg, gamma0, reg, dim, seed, tol, maxit = example_args
            tree_specs = jax.tree_util.tree_map(
                slot_spec, (a, at, b, lg, gamma0, reg, dim, seed))
            # every state leaf leads with the slot axis, whatever the
            # family's carry (PDState or RCDState) — derive the specs
            # instead of naming the fields
            if getattr(key, "is_rcd", False):
                from repro.solvers.rcd import RCDState
                state_specs = RCDState(xbar=P("p", None), aux=P("p", None),
                                       k=P("p"))
            else:
                state_specs = PDState(xbar=P("p", None), xstar=P("p", None),
                                      yhat=P("p", None), gamma=P("p"),
                                      k=P("p"))
            out_specs = (state_specs, P("p"), P("p"))
            splice = shard_map(
                lambda *args: self._splice_init_impl(key, *args),
                mesh=mesh,
                in_specs=(*tree_specs, state_specs, P("p"), P("p"), P("p"),
                          P("p")),
                out_specs=out_specs)
            advance = shard_map(
                lambda *args: self._advance_impl(key, *args),
                mesh=mesh,
                in_specs=(*tree_specs, state_specs, P("p"), P("p"), P("p")),
                out_specs=out_specs)
            fns = (jax.jit(splice), jax.jit(advance))
            self._slotshard_fn_cache[cache_key] = fns
        return fns

    # -- the compiled per-bucket bodies ------------------------------------

    def _operator(self, key: BucketKey, a, at):
        from repro.operators import make_operator
        fmt = "stacked_ell" if key.fmt == "ell" else "stacked_bcsr"
        if self.backend == "pallas":
            return make_operator(fmt, "pallas", a, at,
                                 interpret=self.interpret)
        return make_operator(fmt, self.backend, a, at)

    def _splice_init_impl(self, key, a, at, b, lg, gamma0, reg, dim, seed,
                          state, new_mask, active, tol, maxit):
        """Init only the freshly admitted slots (others keep their state),
        then re-check every active slot — a request that is already feasible
        at k=0 must finish with 0 iterations, like solve_tol."""
        if getattr(key, "is_rcd", False):
            from repro.solvers.rcd import (
                batched_rcd_init, batched_rcd_progress, rcd_mask_state,
            )
            fresh = batched_rcd_init(a, at, b, family=key.family)
            state = rcd_mask_state(new_mask, fresh, state)
            # measure only — the zero init is exact (z = A0, w = 0), and a
            # refresh here would recompute frozen neighbours' caches too
            _, resid = batched_rcd_progress(a, at, b, reg, state,
                                            family=key.family, loss=key.loss)
            still = active & (resid >= tol) & (state.k < maxit)
            return state, resid, still
        ops = self._operator(key, a, at).solver_ops()
        prox = batched_prox(key.prox, reg)
        fresh = batched_init(ops, prox, b, lg, gamma0, self.algorithm)
        state = mask_state(new_mask, fresh, state)
        feas = batched_feasibility(ops, b, state)
        still = active & (feas >= tol) & (state.k < maxit)
        return state, feas, still

    def _advance_impl(self, key, a, at, b, lg, gamma0, reg, dim, seed,
                      state, active, tol, maxit, steps=None):
        """``steps`` (default check_every) masked steps + per-slot
        feasibility verdicts.  Each slot additionally freezes at its own
        max_iterations inside the block (solve_tol's clamped inner loop,
        per slot), so ragged budgets never overrun by a partial block.
        Streamed buckets advance a check block in several chunks (operands
        re-uploaded between chunks); the chunked trajectory is identical —
        only the final chunk's verdicts are harvested."""
        steps = self.check_every if steps is None else steps
        if getattr(key, "is_rcd", False):
            from repro.solvers.rcd import (
                batched_rcd_progress, batched_rcd_step, rcd_mask_state,
            )
            kern = "pallas" if self.backend == "pallas" else None

            def one(_, st):
                return batched_rcd_step(
                    a, at, b, reg, dim, seed, st, family=key.family,
                    loss=key.loss, mask=active & (st.k < maxit),
                    kernel=kern, interpret=self.interpret)

            state = jax.lax.fori_loop(0, steps, one, state)
            # the check refreshes the incremental cache (z = Ax / the dual
            # w) before measuring, so drift can never freeze a wrong slot;
            # frozen neighbours keep their exact bits via the mask
            fresh, resid = batched_rcd_progress(a, at, b, reg, state,
                                                family=key.family,
                                                loss=key.loss)
            state = rcd_mask_state(active, fresh, state)
            still = active & (resid >= tol) & (state.k < maxit)
            return state, resid, still
        ops = self._operator(key, a, at).solver_ops()
        prox = batched_prox(key.prox, reg)

        def one(_, st):
            return batched_step(ops, prox, b, lg, gamma0, st, self.algorithm,
                                mask=active & (st.k < maxit))

        state = jax.lax.fori_loop(0, steps, one, state)
        feas = batched_feasibility(ops, b, state)
        still = active & (feas >= tol) & (state.k < maxit)
        return state, feas, still

    def _advance_fused_impl(self, key, a, at, b, lg, gamma0, reg, dim, seed,
                            state, active, tol, maxit):
        """One-kernel check block: the whole ``check_every`` inner loop
        (forward spmv, fused dual update, prox, per-slot freeze) runs inside
        a single batch-grid Pallas launch with the bucket's operands
        VMEM-resident across inner iterations, emitting only the final
        state + per-slot feasibility (repro.kernels.fused_check_block).
        Same verdict contract as ``_advance_impl``."""
        state, feas = fused_check_block(
            a, at, b, lg, gamma0, reg, state, active, maxit,
            prox=key.prox, steps=self.check_every, interpret=self.interpret)
        still = active & (feas >= tol) & (state.k < maxit)
        return state, feas, still

    def _use_fused(self, key, bucket) -> bool:
        """Fused one-kernel check blocks serve plain resident buckets whose
        prox family has an inlined closed form; sharded / slot-sharded /
        streamed buckets keep the unfused step loop."""
        if not (isinstance(key, BucketKey) and bucket.resident
                and not bucket.slot_sharded):
            return False
        if getattr(key, "is_rcd", False):
            return False       # rcd epochs are their own body, never fused
        if key.prox not in FUSED_CHECK_PROXES:
            return False
        return self.backend == "pallas" if self.fused is None else self.fused

    def _aot_exe(self, kind: str, key, bucket, args):
        """The AOT-compiled executable for one plain-resident bucket body
        (kind: "splice" | "advance" | "advance_fused"), compiled once per
        (kind, key, slot width, device) and cached on the engine.  The
        tick path then calls a finished executable — re-splicing or
        re-admitting into a warm bucket never traces."""
        dev_id = None if bucket.device is None else bucket.device.id
        ck = (kind, key, int(bucket.active.shape[0]), dev_id)
        exe = self._aot_cache.get(ck)
        if exe is None:
            impl = {"splice": self._splice_init_impl,
                    "advance": self._advance_impl,
                    "advance_fused": self._advance_fused_impl}[kind]
            t0 = self.clock.now()
            exe = jax.jit(lambda *a: impl(key, *a)).lower(*args).compile()
            self.phase_s["compile_s"] += self.clock.now() - t0
            self._aot_cache[ck] = exe
        return exe

    # -- the serve loop ----------------------------------------------------

    def _harvest(self, bucket: _Bucket, feas, still) -> None:
        """Retire slots whose verdict flipped: copy out iterates, free.
        Device reads are explicit ``jax.device_get`` — the intended
        device->host edge of a tick, visible to the strict transfer
        guard as sanctioned."""
        still_h = np.asarray(jax.device_get(still))
        finished = bucket.active & ~still_h
        if finished.any():
            feas_h = jax.device_get(feas)
            ks = jax.device_get(bucket.state.k)
            xbar = jax.device_get(bucket.state.xbar)
            for slot in np.nonzero(finished)[0]:
                req = bucket.requests.pop(int(slot))
                req.x = xbar[slot, :req.coo.n].copy()
                req.iterations = int(ks[slot])
                req.feasibility = float(feas_h[slot])
                req.done = True
                self.completed.append(req)
            bucket.active = bucket.active & still_h
            bucket.active_dev = None

    def _put_mask(self, key, bucket, mask):
        """Explicit placed upload of an ``(S,)`` bool slot mask, matching
        the bucket's placement (mesh-replicated / slot-sharded / pinned /
        default device).  Every mask that enters a tick body goes through
        here so the upload is a sanctioned, explicit transfer."""
        if isinstance(key, ShardedBucketKey):
            from jax.sharding import NamedSharding, PartitionSpec as P
            tgt = NamedSharding(self._mesh_for(key), P())
        elif bucket.slot_sharded:
            from jax.sharding import NamedSharding, PartitionSpec as P
            tgt = NamedSharding(bucket.slot_mesh, P("p"))
        else:
            tgt = bucket.device        # None -> default device, explicitly
        with intended_transfers():
            return jax.device_put(mask, tgt)

    def _active_mask(self, key, bucket):
        """Device-resident occupancy mask, re-transferred only when an
        admission or harvest changed it (the mask is an input of every
        tick; a fresh host scatter per tick costs more than the tick)."""
        if bucket.active_dev is None:
            bucket.active_dev = self._put_mask(key, bucket, bucket.active)
        return bucket.active_dev

    def _sanitize_now(self) -> bool:
        """Whether this tick runs under the strict transfer guard: the
        constructor knob wins; ``sanitize=None`` resolves the process-wide
        flag dynamically, so ``--strict-sanitize`` / ``set_strict`` affect
        engines constructed before the flag flipped."""
        return strict_enabled() if self.sanitize is None else self.sanitize

    def _guarded(self, phase_fn, *args):
        """Run one tick phase under ``transfer_guard("disallow")`` when
        sanitizing.  Explicit device_put/device_get inside still pass; a
        stray implicit transfer raises — we count it as a
        ``disallowed_transfers`` tick counter and re-run the phase with
        transfers allowed (correct result, flagged run).  The retry can
        redo a phase's host work, which is fine: the counter is a red
        flag for a broken residency invariant, not a perf statistic."""
        if not self._sanitize_now():
            return phase_fn(*args)
        try:
            with guard_transfers():
                return phase_fn(*args)
        except Exception as e:
            if not is_transfer_error(e):
                raise
            self.tick_counters["disallowed_transfers"] += 1
            with intended_transfers():
                return phase_fn(*args)

    def _dispatch_splice(self, key, bucket, new):
        """Launch the (masked) init of freshly admitted slots; async."""
        if isinstance(key, ShardedBucketKey):
            vals, cols, atv, atr, b, lg, gamma0, reg, tol, maxit = \
                self._sharded_device_operands(bucket)
            splice_fn, _ = self._sharded_fns(key)
            return splice_fn(vals, cols, atv, atr, b, lg, gamma0, reg,
                             bucket.state, self._put_mask(key, bucket, new),
                             self._active_mask(key, bucket), tol, maxit)
        args = self._device_operands(bucket)
        a, at, b, lg, gamma0, reg, dim, seed, tol, maxit = args
        if bucket.slot_sharded:
            splice_fn, _ = self._slotshard_fns(key, bucket.slot_mesh, args)
            return splice_fn(a, at, b, lg, gamma0, reg, dim, seed,
                             bucket.state, self._put_mask(key, bucket, new),
                             self._active_mask(key, bucket), tol, maxit)
        call = (a, at, b, lg, gamma0, reg, dim, seed, bucket.state,
                self._put_mask(key, bucket, new),
                self._active_mask(key, bucket), tol, maxit)
        if bucket.resident:
            return self._aot_exe("splice", key, bucket, call)(*call)
        return self._splice_init(key, *call)

    def _dispatch_advance(self, key, bucket):
        """Launch one check_every block for the bucket; async — the result
        arrays are only synced on when harvested."""
        if isinstance(key, ShardedBucketKey):
            vals, cols, atv, atr, b, lg, gamma0, reg, tol, maxit = \
                self._sharded_device_operands(bucket)
            _, advance_fn = self._sharded_fns(key)
            return advance_fn(vals, cols, atv, atr, b, lg, gamma0, reg,
                              bucket.state,
                              self._active_mask(key, bucket), tol, maxit)
        if not bucket.resident:
            # out-of-core: the non-resident operand fraction re-streams
            # every iteration; modeled as ceil(check_every * fraction)
            # chunk uploads per check block (the chunked trajectory is
            # step-for-step identical, verdicts read once at the end)
            chunks = max(1, min(self.check_every, bucket.stream_chunks))
            base, extra = divmod(self.check_every, chunks)
            out = None
            for i in range(chunks):
                a, at, b, lg, gamma0, reg, dim, seed, tol, maxit = \
                    self._device_operands(bucket)
                out = self._advance(
                    key, a, at, b, lg, gamma0, reg, dim, seed, bucket.state,
                    self._active_mask(key, bucket), tol, maxit,
                    steps=base + (1 if i < extra else 0))
                bucket.state = out[0]
                bucket.dev = None
            return out
        args = self._device_operands(bucket)
        a, at, b, lg, gamma0, reg, dim, seed, tol, maxit = args
        if bucket.slot_sharded:
            _, advance_fn = self._slotshard_fns(key, bucket.slot_mesh, args)
            return advance_fn(a, at, b, lg, gamma0, reg, dim, seed,
                              bucket.state,
                              self._active_mask(key, bucket), tol, maxit)
        call = (a, at, b, lg, gamma0, reg, dim, seed, bucket.state,
                self._active_mask(key, bucket), tol, maxit)
        kind = "advance_fused" if self._use_fused(key, bucket) else "advance"
        return self._aot_exe(kind, key, bucket, call)(*call)

    def step(self) -> bool:
        """One engine tick: admit -> splice inits -> advance -> harvest.
        Returns False when every bucket is drained (queues empty, no active
        slots).

        Advances are dispatched for EVERY bucket before any bucket is
        harvested: jax dispatch is async, so with buckets pinned to
        different devices (or sharded mesh-wide) the per-bucket compute
        overlaps — the harvest phase then blocks on each bucket's verdicts
        in turn.

        Every tick runs inside a ``CompileWatcher``: XLA compilations it
        sees accrue to ``tick_counters["retraces"]`` (cumulative like
        ``phase_s``; a warm engine must add zero).  Under strict mode
        (``sanitize``) the splice/advance phases additionally run under
        ``transfer_guard("disallow")`` via ``_guarded``."""
        with CompileWatcher() as watcher:
            alive = self._step_inner()
        self.tick_counters["retraces"] += watcher.count
        return alive

    def _step_inner(self) -> bool:
        alive = False
        ticking = []
        ph = self.phase_s

        def charge(phase, t0, c0):
            # clock time minus any AOT lowering that happened inside the
            # phase (already booked under compile_s)
            ph[phase] += (self.clock.now() - t0) - (ph["compile_s"] - c0)

        # every bucket's key stays in self.queues (entries are never
        # deleted), so iterating the queues covers all buckets
        for key in list(self.queues):
            t0, c0 = self.clock.now(), ph["compile_s"]
            bucket = self.buckets.get(key)
            if bucket is None:
                if not self.queues.get(key):
                    continue
                bucket = self.buckets[key] = self._make_bucket(key)
            new = self._admit(key, bucket)
            charge("admit_s", t0, c0)
            if new.any():
                t0, c0 = self.clock.now(), ph["compile_s"]
                bucket.state, feas, still = self._guarded(
                    self._dispatch_splice, key, bucket, new)
                self._harvest(bucket, feas, still)
                charge("splice_s", t0, c0)
            if not bucket.active.any():
                continue
            alive = True
            t0, c0 = self.clock.now(), ph["compile_s"]
            bucket.state, feas, still = self._guarded(
                self._dispatch_advance, key, bucket)
            charge("dispatch_s", t0, c0)
            ticking.append((bucket, feas, still))
            self.stats["steps"] += 1
            self.stats["iterations"] += self.check_every * int(
                bucket.active.sum())
        t0, c0 = self.clock.now(), ph["compile_s"]
        for bucket, feas, still in ticking:
            self._harvest(bucket, feas, still)
            if not getattr(bucket, "resident", True):
                bucket.dev = None      # streamed: re-upload next tick
        charge("harvest_s", t0, c0)
        pending = any(self.queues.values())
        return alive or pending

    def run(self) -> list[SolveRequest]:
        """Drain all queues; returns the completed requests (also recorded
        on each request in place)."""
        while self.step():
            pass
        done, self.completed = self.completed, []
        return done

    def expire_overdue(self, now: float) -> list[SolveRequest]:
        """Expire every queued or in-flight request whose ``deadline`` has
        passed (deadline < now on the caller's serving clock): queued ones
        are dropped before ever touching a device, in-flight ones have
        their slot reclaimed THIS tick — the occupancy mask is cleared, so
        the very next admission splices a fresh request into the freed
        slot (masked steps already freeze inactive slots; no device work
        is spent finishing a result nobody will wait for).  Expired
        requests come back with ``expired=True`` and ``done=False`` (no
        iterate is harvested — reading a mid-flight iterate would sync on
        the in-progress tick).  Called by the open-loop front-end at every
        tick boundary; harmless on requests without deadlines."""
        out: list[SolveRequest] = []
        for queue in self.queues.values():
            if not queue:
                continue
            live = [r for r in queue
                    if r.deadline is None or r.deadline >= now]
            if len(live) != len(queue):
                out.extend(r for r in queue
                           if r.deadline is not None and r.deadline < now)
                queue.clear()
                queue.extend(live)
        for bucket in self.buckets.values():
            for slot, req in list(bucket.requests.items()):
                if req.deadline is not None and req.deadline < now:
                    bucket.requests.pop(slot)
                    bucket.active[slot] = False
                    bucket.active_dev = None
                    out.append(req)
        for req in out:
            req.expired = True
        if out:
            self.stats["expired"] = self.stats.get("expired", 0) + len(out)
        return out

    def evict_idle_buckets(self) -> int:
        """Free operand masters + device caches of buckets with no active
        slots and no queued requests; returns how many were evicted.

        Buckets (and their compiled step functions, which stay in this
        engine's jit caches) are otherwise retained forever as warm state —
        right for steady traffic, unbounded for a long-lived engine seeing
        ever-new shapes.  Call this between traffic waves to bound memory;
        the next request for an evicted key pays one bucket rebuild and, if
        its shapes were never seen, one compile."""
        idle = [k for k, bkt in self.buckets.items()
                if not bkt.active.any() and not self.queues.get(k)]
        for k in idle:
            for dev_id, per_dev in (self.buckets[k].charge or ()):
                self._budget_used[dev_id] -= per_dev
            del self.buckets[k]
            self.queues.pop(k, None)
        return len(idle)

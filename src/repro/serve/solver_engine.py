"""Batched solver serving engine: many concurrent primal-dual problems.

The solver analogue of the token-serving engine next door (serve/engine.py):
where that one continuous-batches *sequences* over decode slots, this one
continuous-batches *optimization problems* over solve slots.

Serving traffic is many independent ``min f(x) s.t. Ax = b`` requests with
heterogeneous shapes, sparsity and regularizers.  Solving them one at a
time pays the per-call fixed costs — dispatch, trace/compile per shape,
pipeline prologue — once per problem per iteration; the whole point of the
paper's A2 schedule (2 sync points per iteration) is that everything else
batches.  So:

  1. **Bucket**: requests are grouped by (padded shape, storage format,
     prox family).  Padded dims round up to powers of two, so a handful of
     buckets covers a ragged workload, and every problem in a bucket
     stacks to identical arrays.
  2. **Pad + stack**: each bucket owns fixed slot-batched operands — a
     ``StackedELL``/``StackedBCSR`` pair (both orientations), b, lg,
     gamma0, reg, tol — with a leading slot axis.  Padding is exact by
     construction (zero rows/cols with b=0 and a zero prox center do not
     move), so a padded slot reproduces the standalone solve.
  3. **Step**: one jit'd masked batched A2 step per bucket
     (core.solver.batched_step) advances every active slot at once;
     schedule coefficients are per-slot because each problem sits at its
     own iteration k with its own (lg, gamma0).
  4. **Early-exit per slot**: the ``solve_tol`` stopping criterion
     (relative feasibility < tol, checked every ``check_every``
     iterations) is evaluated per slot; finished slots are mask-frozen —
     their iterates stop moving — harvested, and freed.
  5. **Continuous admission**: freed slots take queued requests
     immediately; a new problem's init splices into the running batch
     without disturbing neighbours.

Throughput, not latency: a single request finishes no faster than a
standalone ``solve_tol`` (slightly slower — it rides along until its
check boundary), but requests/sec scales with slot count
(``benchmarks/run.py solver_serving`` measures the ratio).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prox import ProxOp, get_prox
from repro.core.solver import (
    PDState, batched_feasibility, batched_init, batched_step, mask_state,
)
from repro.sparse.formats import (
    COO, coo_bcsr_width, coo_to_bcsr, coo_to_ell, pad_coo, transpose_coo,
)

#: prox families the batched path supports: elementwise, parameterized by at
#: most a per-slot ``reg`` (group proxes would couple coordinates across the
#: slot axis after stacking and are not served).
BATCHED_PROX_FAMILIES = ("l1", "sq_l2", "elastic_net", "zero", "nonneg",
                         "dummy")


def batched_prox(name: str, reg: jax.Array) -> ProxOp:
    """Family ``name`` with per-slot regularization reg (S,) -> ProxOp whose
    closures broadcast (S, 1) against (S, n) iterates."""
    if name not in BATCHED_PROX_FAMILIES:
        raise KeyError(f"prox family {name!r} not servable in a batch; "
                       f"supported: {BATCHED_PROX_FAMILIES}")
    if name in ("l1", "sq_l2", "elastic_net"):
        return get_prox(name, reg=reg[:, None])
    return get_prox(name)


def _next_pow2(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


@dataclasses.dataclass
class SolveRequest:
    """One primal-dual solve: min f(x) s.t. Ax = b over the COO matrix A.

    ``lg`` (= sum_i ||A_i||^2, the paper's init step 1) is computed at
    construction when None.  Results land in x / iterations / feasibility /
    done.
    """

    uid: int
    coo: COO
    b: Any                               # (m,)
    prox: str = "l1"
    reg: float = 0.1
    lg: float | None = None
    gamma0: float = 100.0
    tol: float = 1e-3
    max_iterations: int = 10_000
    # filled by the engine on completion
    x: np.ndarray | None = None          # (n,) final xbar
    iterations: int = 0
    feasibility: float = float("inf")
    done: bool = False

    def __post_init__(self):
        if self.lg is None:    # host-side: no device dispatch per request
            vals = np.asarray(self.coo.vals)
            self.lg = float(np.sum(np.square(vals)))


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Requests sharing a key share slot buffers and one compiled step."""

    m_pad: int
    n_pad: int
    width: int          # ELL k / BCSR kb of A, padded bucket-wide
    width_t: int        # same for A^T
    fmt: str
    prox: str


@dataclasses.dataclass
class _Bucket:
    """Slot-batched operand buffers for one (shape, fmt, prox) bucket.

    Operand masters live host-side in numpy and are mutated in place at
    admission (an eager device scatter per slot write costs milliseconds;
    a numpy slice write is free).  ``dev`` caches the device-resident
    stacked pytrees and is rebuilt — one transfer per array — only when an
    admission dirtied the masters.  Solver state stays device-resident.
    """

    key: BucketKey
    a_vals: np.ndarray        # (S, ...) stacked A values
    a_idx: np.ndarray         # ELL cols / BCSR bcols of A
    at_vals: np.ndarray       # same for A^T
    at_idx: np.ndarray
    b: np.ndarray             # (S, m_pad)
    lg: np.ndarray            # (S,)
    gamma0: np.ndarray        # (S,)
    reg: np.ndarray           # (S,)
    tol: np.ndarray           # (S,)
    maxit: np.ndarray         # (S,) int32
    state: PDState            # batched, device
    active: np.ndarray        # (S,) bool occupancy mask
    dirty: bool = True
    dev: tuple | None = None
    requests: dict[int, SolveRequest] = dataclasses.field(default_factory=dict)


class SolverEngine:
    """Continuous-batching server for primal-dual solve requests.

    slots:   problems resident per bucket (the vmapped batch width).
    fmt:     "ell" (gather kernels) or "bcsr" (MXU tile kernels).
    backend: "jnp" (vmapped reference) or "pallas" (batch-grid kernels).
    check_every: iterations between per-slot feasibility checks — the
             early-exit granularity (matches solve_tol's check_every).
    """

    def __init__(self, slots: int = 8, fmt: str = "ell",
                 backend: str = "jnp", algorithm: str = "a2",
                 check_every: int = 16, min_rows: int = 64,
                 min_cols: int = 16, interpret: bool | None = None):
        if fmt not in ("ell", "bcsr"):
            raise ValueError(f"fmt must be ell|bcsr, got {fmt!r}")
        self.slots = slots
        self.fmt = fmt
        self.backend = backend
        self.algorithm = algorithm
        self.check_every = check_every
        self.min_rows = min_rows
        self.min_cols = min_cols
        self.interpret = interpret
        self.queues: dict[BucketKey, deque[SolveRequest]] = {}
        self.buckets: dict[BucketKey, _Bucket] = {}
        self.completed: list[SolveRequest] = []
        self.stats = {"steps": 0, "iterations": 0, "admitted": 0}
        self._auto_uid = 0
        # per-instance jit closures: the compile cache lives on the engine
        # (a static `self` argname would pin every engine — and its bucket
        # masters — in jit's global cache for the process lifetime)
        self._splice_init = jax.jit(self._splice_init_impl,
                                    static_argnames=("key",))
        self._advance = jax.jit(self._advance_impl, static_argnames=("key",))

    # -- bucketing policy --------------------------------------------------

    def bucket_key(self, req: SolveRequest) -> BucketKey:
        """(shape-bucket, format, prox family): dims round up to powers of
        two (floors min_rows/min_cols), ELL/BCSR widths to powers of two,
        so ragged traffic collapses onto few compiled step functions."""
        coo = req.coo
        m_pad = max(self.min_rows, _next_pow2(coo.m))
        n_pad = max(self.min_cols, _next_pow2(coo.n))
        if self.fmt == "ell":
            rows = np.asarray(coo.rows)
            cols = np.asarray(coo.cols)
            w = int(np.bincount(rows, minlength=coo.m).max()) if rows.size else 1
            wt = int(np.bincount(cols, minlength=coo.n).max()) if cols.size else 1
            w, wt = _next_pow2(max(8, w)), _next_pow2(max(8, wt))
        else:
            c = pad_coo(coo, m_pad, n_pad)
            w = _next_pow2(coo_bcsr_width(c, bm=8, bn=min(128, n_pad)))
            wt = _next_pow2(coo_bcsr_width(transpose_coo(c), bm=8,
                                           bn=min(128, m_pad)))
        return BucketKey(m_pad=m_pad, n_pad=n_pad, width=w, width_t=wt,
                         fmt=self.fmt, prox=req.prox)

    # -- request lifecycle -------------------------------------------------

    def submit(self, req) -> BucketKey:
        """Queue one solve.  Accepts a ``SolveRequest`` or anything with a
        ``to_request`` adapter — i.e. a ``repro.api.Problem``, which makes
        the declarative Problem the engine's native admission type (uids
        are assigned engine-side)."""
        if not isinstance(req, SolveRequest):
            to_request = getattr(req, "to_request", None)
            if to_request is None:
                raise TypeError(
                    f"submit() takes a SolveRequest or a repro.api.Problem, "
                    f"got {type(req).__name__}")
            req = to_request(uid=self._auto_uid)
        # auto uids stay clear of every uid seen so far, so mixing explicit
        # SolveRequests and auto-uid'd Problems cannot collide
        self._auto_uid = max(self._auto_uid, req.uid + 1)
        if req.prox not in BATCHED_PROX_FAMILIES:
            raise KeyError(f"prox family {req.prox!r} not servable; "
                           f"supported: {BATCHED_PROX_FAMILIES}")
        key = self.bucket_key(req)
        self.queues.setdefault(key, deque()).append(req)
        return key

    def _new_bucket(self, key: BucketKey) -> _Bucket:
        s, m, n = self.slots, key.m_pad, key.n_pad
        if key.fmt == "ell":
            a_shape = (s, m, key.width)
            at_shape = (s, n, key.width_t)
        else:
            bm, bn = 8, min(128, n)
            bnt = min(128, m)
            a_shape = (s, -(-m // bm), key.width, bm, bn)
            at_shape = (s, -(-n // bm), key.width_t, bm, bnt)
        zeros_x = jnp.zeros((s, n), jnp.float32)
        zeros_y = jnp.zeros((s, m), jnp.float32)
        state = PDState(xbar=zeros_x, xstar=zeros_x, yhat=zeros_y,
                        gamma=jnp.ones((s,), jnp.float32),
                        k=jnp.zeros((s,), jnp.int32))
        return _Bucket(
            key=key,
            a_vals=np.zeros(a_shape, np.float32),
            a_idx=np.zeros(a_shape[:3], np.int32),
            at_vals=np.zeros(at_shape, np.float32),
            at_idx=np.zeros(at_shape[:3], np.int32),
            b=np.zeros((s, m), np.float32),
            lg=np.ones((s,), np.float32),
            gamma0=np.ones((s,), np.float32),
            reg=np.zeros((s,), np.float32),
            tol=np.full((s,), np.inf, np.float32),
            maxit=np.zeros((s,), np.int32),
            state=state, active=np.zeros((s,), bool))

    def _convert(self, key: BucketKey, coo: COO):
        """Host-side: pad to bucket dims, build both orientations at the
        bucket's fixed widths (numpy per-slot arrays, ready to splice)."""
        c = pad_coo(coo, key.m_pad, key.n_pad)
        if key.fmt == "ell":
            fa = coo_to_ell(c, k=key.width)
            fat = coo_to_ell(transpose_coo(c), k=key.width_t)
            return (fa.vals, fa.cols), (fat.vals, fat.cols)
        bm, bn = 8, min(128, key.n_pad)
        bnt = min(128, key.m_pad)
        fa = coo_to_bcsr(c, bm=bm, bn=bn, kb=key.width)
        fat = coo_to_bcsr(transpose_coo(c), bm=bm, bn=bnt, kb=key.width_t)
        return (fa.vals, fa.bcols), (fat.vals, fat.bcols)

    def _admit(self, key: BucketKey, bucket: _Bucket) -> np.ndarray:
        queue = self.queues.get(key)
        new = np.zeros((self.slots,), bool)
        if not queue:
            return new
        for slot in range(self.slots):
            if not queue:
                break
            if bucket.active[slot]:
                continue
            req = queue.popleft()
            (av, ai), (atv, ati) = self._convert(key, req.coo)
            bucket.a_vals[slot] = np.asarray(av)
            bucket.a_idx[slot] = np.asarray(ai)
            bucket.at_vals[slot] = np.asarray(atv)
            bucket.at_idx[slot] = np.asarray(ati)
            bucket.b[slot, :req.coo.m] = np.asarray(req.b, np.float32)
            bucket.b[slot, req.coo.m:] = 0.0
            bucket.lg[slot] = req.lg
            bucket.gamma0[slot] = req.gamma0
            bucket.reg[slot] = req.reg
            bucket.tol[slot] = req.tol
            bucket.maxit[slot] = req.max_iterations
            bucket.requests[slot] = req
            bucket.active[slot] = True
            bucket.dirty = True
            new[slot] = True
            self.stats["admitted"] += 1
        return new

    def _device_operands(self, bucket: _Bucket) -> tuple:
        """Device-resident (a, at, b, lg, gamma0, reg, tol, maxit); one
        transfer per array, only after admissions dirtied the masters."""
        if bucket.dirty or bucket.dev is None:
            key = bucket.key
            if key.fmt == "ell":
                from repro.sparse.formats import StackedELL
                a = StackedELL(vals=jnp.asarray(bucket.a_vals),
                               cols=jnp.asarray(bucket.a_idx), n=key.n_pad)
                at = StackedELL(vals=jnp.asarray(bucket.at_vals),
                                cols=jnp.asarray(bucket.at_idx), n=key.m_pad)
            else:
                from repro.sparse.formats import StackedBCSR
                a = StackedBCSR(vals=jnp.asarray(bucket.a_vals),
                                bcols=jnp.asarray(bucket.a_idx),
                                m=key.m_pad, n=key.n_pad)
                at = StackedBCSR(vals=jnp.asarray(bucket.at_vals),
                                 bcols=jnp.asarray(bucket.at_idx),
                                 m=key.n_pad, n=key.m_pad)
            bucket.dev = (a, at, jnp.asarray(bucket.b),
                          jnp.asarray(bucket.lg), jnp.asarray(bucket.gamma0),
                          jnp.asarray(bucket.reg), jnp.asarray(bucket.tol),
                          jnp.asarray(bucket.maxit))
            bucket.dirty = False
        return bucket.dev

    # -- the compiled per-bucket bodies ------------------------------------

    def _operator(self, key: BucketKey, a, at):
        from repro.operators import make_operator
        fmt = "stacked_ell" if key.fmt == "ell" else "stacked_bcsr"
        if self.backend == "pallas":
            return make_operator(fmt, "pallas", a, at,
                                 interpret=self.interpret)
        return make_operator(fmt, self.backend, a, at)

    def _splice_init_impl(self, key, a, at, b, lg, gamma0, reg, state,
                          new_mask, active, tol, maxit):
        """Init only the freshly admitted slots (others keep their state),
        then re-check every active slot — a request that is already feasible
        at k=0 must finish with 0 iterations, like solve_tol."""
        ops = self._operator(key, a, at).solver_ops()
        prox = batched_prox(key.prox, reg)
        fresh = batched_init(ops, prox, b, lg, gamma0, self.algorithm)
        state = mask_state(new_mask, fresh, state)
        feas = batched_feasibility(ops, b, state)
        still = active & (feas >= tol) & (state.k < maxit)
        return state, feas, still

    def _advance_impl(self, key, a, at, b, lg, gamma0, reg, state, active,
                      tol, maxit):
        """check_every masked steps + per-slot feasibility verdicts."""
        ops = self._operator(key, a, at).solver_ops()
        prox = batched_prox(key.prox, reg)

        def one(_, st):
            return batched_step(ops, prox, b, lg, gamma0, st, self.algorithm,
                                mask=active)

        state = jax.lax.fori_loop(0, self.check_every, one, state)
        feas = batched_feasibility(ops, b, state)
        still = active & (feas >= tol) & (state.k < maxit)
        return state, feas, still

    # -- the serve loop ----------------------------------------------------

    def _harvest(self, bucket: _Bucket, feas, still) -> None:
        """Retire slots whose verdict flipped: copy out iterates, free."""
        still_h = np.asarray(still)
        finished = bucket.active & ~still_h
        if finished.any():
            feas_h = np.asarray(feas)
            ks = np.asarray(bucket.state.k)
            xbar = np.asarray(bucket.state.xbar)
            for slot in np.nonzero(finished)[0]:
                req = bucket.requests.pop(int(slot))
                req.x = xbar[slot, :req.coo.n].copy()
                req.iterations = int(ks[slot])
                req.feasibility = float(feas_h[slot])
                req.done = True
                self.completed.append(req)
            bucket.active = bucket.active & still_h

    def step(self) -> bool:
        """One engine tick: admit -> splice inits -> advance -> harvest.
        Returns False when every bucket is drained (queues empty, no active
        slots)."""
        alive = False
        # every bucket's key stays in self.queues (entries are never
        # deleted), so iterating the queues covers all buckets
        for key in list(self.queues):
            bucket = self.buckets.get(key)
            if bucket is None:
                if not self.queues.get(key):
                    continue
                bucket = self.buckets[key] = self._new_bucket(key)
            new = self._admit(key, bucket)
            if new.any():
                a, at, b, lg, gamma0, reg, tol, maxit = \
                    self._device_operands(bucket)
                bucket.state, feas, still = self._splice_init(
                    key, a, at, b, lg, gamma0, reg, bucket.state,
                    jnp.asarray(new), jnp.asarray(bucket.active), tol, maxit)
                self._harvest(bucket, feas, still)
            if not bucket.active.any():
                continue
            alive = True
            a, at, b, lg, gamma0, reg, tol, maxit = \
                self._device_operands(bucket)
            bucket.state, feas, still = self._advance(
                key, a, at, b, lg, gamma0, reg, bucket.state,
                jnp.asarray(bucket.active), tol, maxit)
            self.stats["steps"] += 1
            self.stats["iterations"] += self.check_every * int(
                bucket.active.sum())
            self._harvest(bucket, feas, still)
        pending = any(self.queues.values())
        return alive or pending

    def run(self) -> list[SolveRequest]:
        """Drain all queues; returns the completed requests (also recorded
        on each request in place)."""
        while self.step():
            pass
        done, self.completed = self.completed, []
        return done

    def evict_idle_buckets(self) -> int:
        """Free operand masters + device caches of buckets with no active
        slots and no queued requests; returns how many were evicted.

        Buckets (and their compiled step functions, which stay in this
        engine's jit caches) are otherwise retained forever as warm state —
        right for steady traffic, unbounded for a long-lived engine seeing
        ever-new shapes.  Call this between traffic waves to bound memory;
        the next request for an evicted key pays one bucket rebuild and, if
        its shapes were never seen, one compile."""
        idle = [k for k, bkt in self.buckets.items()
                if not bkt.active.any() and not self.queues.get(k)]
        for k in idle:
            del self.buckets[k]
            self.queues.pop(k, None)
        return len(idle)

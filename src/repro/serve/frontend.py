"""Open-loop serving front-end: arrivals on their own clock.

The engine next door (``serve/solver_engine.py``) is tick-driven and has
only ever been benchmarked closed-loop — submit a batch, step until
drained — which is the one workload a production deployment never sees.
Real traffic is OPEN-LOOP: requests arrive on their own schedule whether
or not the system is keeping up, each one has a deadline its caller cares
about, and when the system saturates the only honest answers are
backpressure and rejection, not an unbounded queue.  This module is that
service layer:

  1. **Arrival process**: a seeded Poisson stream (``poisson_arrivals``,
     exponential interarrivals at a given offered rate — bit-reproducible
     per seed) or a recorded trace (``trace_arrivals``).  Arrivals are
     data, not threads: each is (absolute time, request).
  2. **Injectable clock**: the front-end never sleeps and never reads the
     wall unless asked.  ``VirtualClock`` advances only when the loop
     advances it — the whole layer becomes a deterministic discrete-event
     simulation (every test in ``tests/test_open_loop.py`` runs on it) —
     while ``WallClock`` reads ``time.perf_counter`` for real
     measurements (``benchmarks/run.py open_loop_serving``).  Idle gaps
     are *skipped*, never slept through, on both clocks.
  3. **Bounded priority wait queue**: due arrivals land in a wait queue
     of capacity ``queue_limit``; an arrival that finds it full is
     REJECTED on the spot (backpressure — the caller finds out now, not
     after timing out).  Admission out of the queue is priority-first
     (FIFO within a priority class), so a high-priority arrival overtakes
     earlier low-priority traffic.
  4. **Planner-reasoned admission**: before a request reaches the engine
     the planner's admission rule (``repro.plan.decide_admission``, via
     ``SolverEngine.admission_for`` which supplies the live byte-budget
     numbers) decides resident / streamed / rejected.  Under the default
     ``admission="auto"`` over-budget work is still served streamed —
     but now as an explicit, reasoned decision stamped on the request;
     under ``admission="strict"`` it is rejected with that reason
     instead (shed load rather than degrade every tenant with per-tick
     operand re-uploads).
  5. **Deadline expiry**: at every tick boundary the front-end expires
     overdue requests — waiting ones are dropped before touching a
     device, in-flight ones get their slot reclaimed that same tick
     (``SolverEngine.expire_overdue``), so a burst of doomed work frees
     capacity for requests that can still make their deadlines.
  6. **Per-request latency accounting**: every completed request carries
     a ``timeline`` — arrive/admit/done stamps on the serving clock
     (queue wait and service time fall out), plus an admit / compute /
     harvest attribution layered on the engine's per-phase ``phase_s``
     tick breakdown.  ``report()`` aggregates p50/p99 latency and
     goodput-under-SLO (completed within ``slo`` seconds of arrival, per
     second of serving time) — the numbers
     ``experiments/bench/open_loop_serving.json`` records per offered
     load.

The whole layer is synchronous and single-threaded: ``step()`` is one
tick (arrivals -> expiry -> admission -> engine tick -> harvest) and
``run()`` loops it until the arrival stream, wait queue and engine are
all drained.  Determinism is the point — with a ``VirtualClock`` and a
seeded arrival stream, two runs are bit-identical.

>>> import numpy as np
>>> from repro.serve.frontend import (OpenLoopFrontend, VirtualClock,
...                                   poisson_arrivals)
>>> from repro.serve.solver_engine import SolveRequest, SolverEngine
>>> from repro.sparse.formats import COO
>>> def req(uid):
...     eye = COO(rows=np.arange(8, dtype=np.int32),
...               cols=np.arange(8, dtype=np.int32),
...               vals=np.ones(8, np.float32), m=8, n=8)
...     return SolveRequest(uid=uid, coo=eye, b=np.ones(8, np.float32),
...                         prox="zero", gamma0=10.0, tol=1e-3)
>>> fe = OpenLoopFrontend(SolverEngine(slots=2, check_every=8),
...                       poisson_arrivals([req(0), req(1)], rate=2.0,
...                                        seed=7),
...                       clock=VirtualClock())
>>> rep = fe.run()
>>> (rep["completed"], rep["rejected_backpressure"],
...  rep["p50_latency_s"] <= rep["p99_latency_s"])
(2, 0, True)
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.serve.clock import Clock, VirtualClock, WallClock
from repro.serve.solver_engine import SolveRequest, SolverEngine

__all__ = ["Arrival", "Clock", "OpenLoopFrontend", "VirtualClock",
           "WallClock", "poisson_arrivals", "trace_arrivals"]


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop arrival: ``request`` becomes visible at absolute
    serving-clock time ``t``."""

    t: float
    request: SolveRequest


def poisson_arrivals(requests, rate: float, seed: int = 0,
                     t0: float = 0.0,
                     deadline: Optional[float] = None) -> list[Arrival]:
    """Open-loop Poisson arrival process: exponential interarrivals at
    ``rate`` requests/second from a seeded generator, so the stream is
    bit-reproducible per (requests, rate, seed).  With ``deadline`` set,
    each request's absolute deadline is its arrival time + ``deadline``
    seconds (a relative latency bound, the usual SLO shape)."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0 req/s, got {rate}")
    rng = np.random.default_rng(seed)
    t = float(t0)
    out = []
    for req in requests:
        t += float(rng.exponential(1.0 / rate))
        if deadline is not None:
            req.deadline = t + float(deadline)
        out.append(Arrival(t=t, request=req))
    return out


def trace_arrivals(times, requests,
                   deadline: Optional[float] = None) -> list[Arrival]:
    """A recorded trace: pair absolute arrival ``times`` with requests
    (sorted by time — a trace replays in order regardless of how it was
    logged).  Same relative-``deadline`` convention as
    ``poisson_arrivals``."""
    times = [float(t) for t in times]
    if len(times) != len(requests):
        raise ValueError(f"{len(times)} arrival times for "
                         f"{len(requests)} requests")
    out = sorted((t, i) for i, t in enumerate(times))
    arrivals = []
    for t, i in out:
        req = requests[i]
        if deadline is not None:
            req.deadline = t + float(deadline)
        arrivals.append(Arrival(t=t, request=req))
    return arrivals


class OpenLoopFrontend:
    """Drives a ``SolverEngine`` from an arrival process against an
    injectable clock — one tick per ``step()``:

        arrivals due -> [bounded wait queue | backpressure-reject]
        -> expire overdue (waiting dropped, in-flight slots reclaimed)
        -> admit by priority (planner admission: resident/streamed/reject)
        -> engine tick (check_every masked steps per bucket)
        -> harvest (latency stamps from the clock)

    engine:       the solver engine to serve (any configuration — mesh,
                  budget and format knobs all compose underneath).
    arrivals:     list of ``Arrival``s (``poisson_arrivals`` /
                  ``trace_arrivals``), in nondecreasing time order.
    clock:        ``VirtualClock`` (default — deterministic simulation)
                  or ``WallClock`` (real measurements); anything with
                  now/advance/skip_to.
    queue_limit:  wait-queue capacity; arrivals beyond it are rejected
                  (``rejected=True``, backpressure) the tick they land.
    tick_s:       virtual seconds one engine tick costs (VirtualClock
                  only — a WallClock's ticks cost what they cost).  One
                  tick is one ``check_every`` block per active bucket,
                  so this is the simulation's unit of service time.
    admission:    "auto" (planner verdict; streamed work admitted with
                  its reason stamped) or "strict" (would-stream work is
                  rejected — shed load instead of degrading the node).
    inflight_limit: requests submitted-but-unfinished the front-end will
                  tolerate before letting the wait queue absorb the rest
                  (default: the engine's aggregate slot capacity,
                  slots x devices).  Admission order is decided by the
                  wait queue's priority heap, so capping in-flight depth
                  is what makes priority meaningful under overload.
    """

    def __init__(self, engine: SolverEngine, arrivals, clock=None,
                 queue_limit: int = 64, tick_s: float = 1.0,
                 admission: str = "auto",
                 inflight_limit: Optional[int] = None):
        if admission not in ("auto", "strict"):
            raise ValueError(f"admission must be auto|strict, "
                             f"got {admission!r}")
        self.engine = engine
        self.arrivals = sorted(arrivals, key=lambda a: a.t)
        self.clock = clock if clock is not None else VirtualClock()
        self.queue_limit = queue_limit
        self.tick_s = float(tick_s)
        self.admission = admission
        self.inflight_limit = (engine.slots * len(engine.devices)
                               if inflight_limit is None
                               else int(inflight_limit))
        if self.inflight_limit < 1:
            raise ValueError("inflight_limit must be >= 1 — an open loop "
                             "that can never admit anything only spins")
        self._next = 0                      # arrival stream cursor
        self._seq = 0                       # FIFO tie-break within priority
        self._wait: list = []               # heap of (-priority, seq, req)
        self._inflight: dict[int, SolveRequest] = {}
        self.completed: list[SolveRequest] = []
        self.expired: list[SolveRequest] = []
        self.rejected: list[SolveRequest] = []
        self.ticks = 0
        # front-end mirror of the engine's per-phase accounting, plus the
        # wait-queue time requests spent before admission
        self.phase_s = {"queue_s": 0.0, "admit_s": 0.0, "compute_s": 0.0,
                        "harvest_s": 0.0}

    # -- queue plumbing ----------------------------------------------------

    def _push_wait(self, req: SolveRequest) -> None:
        heapq.heappush(self._wait, (-req.priority, self._seq, req))
        self._seq += 1

    def _reject(self, req: SolveRequest, reason: str, now: float) -> None:
        req.rejected = True
        req.reject_reason = reason
        req.timeline = dict(req.timeline or {})
        req.timeline["t_reject"] = now
        self.rejected.append(req)

    def _expire(self, req: SolveRequest, now: float) -> None:
        req.timeline = dict(req.timeline or {})
        req.timeline["t_expire"] = now
        tl = req.timeline
        if "t_admit" not in tl:
            tl["queue_s"] = now - tl["t_arrive"]
        tl["latency_s"] = now - tl["t_arrive"]
        self.expired.append(req)

    # -- the serve loop ----------------------------------------------------

    def _pull_arrivals(self, now: float) -> None:
        while self._next < len(self.arrivals) \
                and self.arrivals[self._next].t <= now:
            arr = self.arrivals[self._next]
            self._next += 1
            req = arr.request
            req.timeline = dict(req.timeline or {})
            req.timeline["t_arrive"] = arr.t
            if len(self._wait) >= self.queue_limit:
                self._reject(req, f"backpressure: wait queue at its "
                                  f"{self.queue_limit}-request limit", now)
            else:
                self._push_wait(req)

    def _expire_overdue(self, now: float) -> None:
        if self._wait:
            live = []
            for item in self._wait:
                req = item[2]
                if req.deadline is not None and req.deadline < now:
                    req.expired = True
                    self._expire(req, now)
                else:
                    live.append(item)
            if len(live) != len(self._wait):
                heapq.heapify(live)
                self._wait = live
        for req in self.engine.expire_overdue(now):
            self._inflight.pop(req.uid, None)
            self._expire(req, now)

    def _admit_from_queue(self, now: float) -> list[SolveRequest]:
        admitted = []
        while self._wait and len(self._inflight) < self.inflight_limit:
            req = self._wait[0][2]
            decision, reason = self.engine.admission_for(
                req, allow_streaming=self.admission != "strict")
            heapq.heappop(self._wait)
            if decision == "rejected":
                self._reject(req, reason, now)
                continue
            tl = req.timeline
            tl["t_admit"] = now
            tl["queue_s"] = now - tl["t_arrive"]
            tl["admission"] = decision
            tl["admission_reason"] = reason
            for k in ("admit_s", "compute_s", "harvest_s"):
                tl[k] = 0.0
            self.engine.submit(req)
            self._inflight[req.uid] = req
            admitted.append(req)
        return admitted

    def _attribute_phases(self, deltas: dict, admitted, harvested) -> None:
        """Layer the engine's per-phase tick breakdown onto requests: the
        tick's admit+splice cost to this tick's admissions, dispatch (and
        harvest, when nobody finished) spread over every in-flight
        request, harvest to the requests it synced out.  Sums over all
        requests preserve the engine's totals, so per-request accounts
        and the aggregate ``phase_s`` stay consistent."""
        admit = deltas["admit_s"] + deltas["splice_s"] + deltas["compile_s"]
        compute = deltas["dispatch_s"]
        harvest = deltas["harvest_s"]
        if not harvested:
            # nobody finished: the harvest phase was pure verdict-polling
            # for in-flight work — book it as compute in both views
            compute += harvest
            harvest = 0.0
        self.phase_s["admit_s"] += admit
        self.phase_s["compute_s"] += compute
        self.phase_s["harvest_s"] += harvest
        if admitted:
            for req in admitted:
                req.timeline["admit_s"] += admit / len(admitted)
        inflight = list(self._inflight.values()) + list(harvested)
        if inflight:
            for req in inflight:
                req.timeline["compute_s"] += compute / len(inflight)
        if harvested:
            for req in harvested:
                req.timeline["harvest_s"] += harvest / len(harvested)

    def step(self) -> bool:
        """One front-end tick; returns False when the arrival stream, the
        wait queue, and the engine are all drained."""
        now = self.clock.now()
        self._pull_arrivals(now)
        self._expire_overdue(now)
        admitted = self._admit_from_queue(now)
        if self._inflight:
            ph0 = dict(self.engine.phase_s)
            self.engine.step()
            self.clock.advance(self.tick_s)
            self.ticks += 1
            deltas = {k: self.engine.phase_s[k] - ph0[k] for k in ph0}
            harvested, self.engine.completed = self.engine.completed, []
            t_done = self.clock.now()
            for req in harvested:
                self._inflight.pop(req.uid, None)
                tl = req.timeline
                tl["t_done"] = t_done
                tl["service_s"] = t_done - tl["t_admit"]
                tl["latency_s"] = t_done - tl["t_arrive"]
                self.phase_s["queue_s"] += tl["queue_s"]
                self.completed.append(req)
            self._attribute_phases(deltas, admitted, harvested)
            return True
        if self._wait:
            # defensive: nothing running but the queue holds work — advance
            # so expiry/admission make progress instead of spinning
            self.clock.advance(self.tick_s)
            return True
        if self._next < len(self.arrivals):
            self.clock.skip_to(self.arrivals[self._next].t)  # idle: jump
            return True
        return False

    def run(self, slo: Optional[float] = None) -> dict:
        """Drain the arrival stream; returns ``report(slo)``."""
        while self.step():
            pass
        return self.report(slo)

    # -- reporting ---------------------------------------------------------

    def report(self, slo: Optional[float] = None) -> dict:
        """Latency/goodput summary over everything served so far.

        p50/p99 are over COMPLETED requests' arrive-to-done latency.
        ``goodput_rps`` counts only requests completed within ``slo``
        seconds of arrival (all completions when slo is None), per second
        of serving time — the metric that punishes both rejection and
        lateness, which raw rps cannot see.
        """
        lat = sorted(r.timeline["latency_s"] for r in self.completed)
        elapsed = max(self.clock.now(), 1e-12)
        met = len(lat) if slo is None else \
            sum(1 for v in lat if v <= slo)
        n_bp = sum(1 for r in self.rejected
                   if r.reject_reason.startswith("backpressure"))
        return {
            "offered": len(self.arrivals),
            "completed": len(self.completed),
            "expired": len(self.expired),
            "rejected_backpressure": n_bp,
            "rejected_admission": len(self.rejected) - n_bp,
            "elapsed_s": elapsed,
            "ticks": self.ticks,
            "p50_latency_s": float(np.percentile(lat, 50)) if lat else None,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else None,
            "mean_queue_s": (float(np.mean([r.timeline["queue_s"]
                                            for r in self.completed]))
                             if self.completed else None),
            "slo_s": slo,
            "met_slo": met,
            "goodput_rps": met / elapsed,
            "offered_rps": len(self.arrivals) / elapsed,
            "phase_s": dict(self.phase_s),
        }

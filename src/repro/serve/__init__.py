# Two continuous-batching engines over fixed slots: Engine serves token
# decode traffic (models), SolverEngine serves primal-dual solve traffic
# (bucketed, padded, vmapped A2 with per-slot early exit).
from repro.serve.engine import Engine, Request
from repro.serve.solver_engine import (
    BATCHED_PROX_FAMILIES, BucketKey, SolveRequest, SolverEngine,
    batched_prox,
)

__all__ = ["BATCHED_PROX_FAMILIES", "BucketKey", "Engine", "Request",
           "SolveRequest", "SolverEngine", "batched_prox"]

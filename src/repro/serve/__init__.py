# The serving layer behind ONE entry point: ``create_engine(kind)``.
#
# Two continuous-batching engines over fixed slots exist — TokenEngine
# (serve/engine.py: token decode traffic, serves Models) and SolverEngine
# (serve/solver_engine.py: primal-dual solve traffic, serves
# repro.api.Problem / SolveRequest, bucketed + padded + vmapped A2 with
# per-slot early exit).  ``Engine`` was the token engine's old name and is
# kept as a deprecated alias.
from repro.serve.engine import Request, TokenEngine
from repro.serve.frontend import (
    Arrival, OpenLoopFrontend, VirtualClock, WallClock, poisson_arrivals,
    trace_arrivals,
)
from repro.serve.solver_engine import (
    BATCHED_PROX_FAMILIES, BucketKey, ShardedBucketKey, SolveRequest,
    SolverEngine, batched_prox,
)

__all__ = ["Arrival", "BATCHED_PROX_FAMILIES", "BucketKey",
           "OpenLoopFrontend", "Request", "ShardedBucketKey", "SolveRequest",
           "SolverEngine", "TokenEngine", "VirtualClock", "WallClock",
           "batched_prox", "create_engine", "poisson_arrivals",
           "trace_arrivals"]

_ENGINES = {"solver": SolverEngine, "token": TokenEngine}


def create_engine(kind: str = "solver", **kwargs):
    """The single serving entry point.

    kind="solver" -> SolverEngine (continuous-batched primal-dual solves;
    submit ``repro.api.Problem``s or ``SolveRequest``s).
    kind="token"  -> TokenEngine (continuous-batched decode; submit
    ``Request``s).  Keyword arguments go to the engine constructor.
    """
    try:
        cls = _ENGINES[kind]
    except KeyError:
        raise KeyError(f"unknown engine kind {kind!r}; "
                       f"available: {sorted(_ENGINES)}") from None
    return cls(**kwargs)


def __getattr__(name):
    if name == "Engine":        # pre-facade name of the token engine
        from repro.deprecation import warn_once
        warn_once("repro.serve.Engine",
                  "repro.serve.TokenEngine (or create_engine('token'))")
        return TokenEngine
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")

"""Token serving engine: continuous batching over fixed decode slots.

vLLM-style slot management reduced to its JAX-native core: a fixed decode
batch of `slots` sequences sharing one jit'd decode_step; prefill fills a
free slot's cache region; finished sequences (EOS or max_len) free their
slot for the next queued request. Works with any family's cache pytree
(the slot axis is the cache's batch axis — updated functionally via
dynamic_update_index_in_dim).

This is the *token* engine (decode traffic, serves Models); its solver
sibling is ``repro.serve.solver_engine.SolverEngine`` (solve traffic,
serves ``repro.api.Problem``s).  Both are reached through the single
``repro.serve.create_engine`` entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.api import Model

tmap = jax.tree_util.tree_map


@dataclasses.dataclass
class Request:
    uid: int
    prompt: Any                        # (S,) or (S, n_cb) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class TokenEngine:
    def __init__(self, model: Model, slots: int = 4, max_len: int = 64,
                 sh=None):
        self.model = model
        self.cfg = model.cfg
        self.slots = slots
        self.max_len = max_len
        self.sh = sh
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.cur_index = jnp.zeros((slots,), jnp.int32)
        self.tokens = jnp.zeros(
            (slots, 1, self.cfg.num_codebooks) if self.cfg.num_codebooks
            else (slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t, i: model.decode(p, c, t, i, sh))

    def init_state(self, params):
        self.params = params
        self.cache = self.model.init_cache(
            self.slots, self.max_len, dtype=jnp.dtype(self.cfg.dtype))

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_into_slot(self, slot: int, req: Request):
        """Greedy: run prompt tokens one-by-one through decode (cache-true
        prefill; a chunked prefill path is a straightforward extension)."""
        prompt = jnp.asarray(req.prompt)[None]          # (1, S, ...)
        s_len = prompt.shape[1]
        self.cur_index = self.cur_index.at[slot].set(0)
        for t in range(s_len):
            tok = prompt[:, t:t + 1]
            self.tokens = jax.lax.dynamic_update_index_in_dim(
                self.tokens, tok[0], slot, 0)
            lg, self.cache = self._decode(
                self.params, self.cache, self.tokens, self.cur_index)
            self.cur_index = self.cur_index.at[slot].add(1)
        nxt = jnp.argmax(lg[slot, -1], axis=-1).astype(jnp.int32)
        return nxt

    def step(self):
        """Admit from queue, one decode step for all active slots."""
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.queue:
            slot = free.pop()
            req = self.queue.pop(0)
            nxt = self._prefill_into_slot(slot, req)
            req.out.append(int(nxt) if nxt.ndim == 0 else list(map(int, nxt)))
            self.active[slot] = req
            upd = nxt.reshape((1,) if nxt.ndim == 0 else nxt.shape)[None] \
                if not self.cfg.num_codebooks else nxt[None, None]
            self.tokens = jax.lax.dynamic_update_index_in_dim(
                self.tokens, jnp.asarray(upd[0], jnp.int32), slot, 0)
        if not self.active:
            return False
        lg, self.cache = self._decode(self.params, self.cache, self.tokens,
                                      self.cur_index)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)  # (slots[,cb])
        self.cur_index = self.cur_index + 1
        for slot, req in list(self.active.items()):
            tok = nxt[slot]
            val = int(tok) if tok.ndim == 0 else list(map(int, tok))
            req.out.append(val)
            tok_arr = tok.reshape(1, 1, -1) if self.cfg.num_codebooks \
                else tok.reshape(1, 1)
            self.tokens = jax.lax.dynamic_update_index_in_dim(
                self.tokens, tok_arr[0], slot, 0)
            hit_eos = (not self.cfg.num_codebooks and val == req.eos_id)
            if (len(req.out) >= req.max_new_tokens or hit_eos
                    or int(self.cur_index[slot]) >= self.max_len - 1):
                req.done = True
                del self.active[slot]
        return True

    def run(self):
        while self.queue or self.active:
            self.step()

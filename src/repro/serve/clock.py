"""The serving Clock protocol — the ONE wall-time boundary in ``serve/``.

The open-loop layer (PR 7) is a deterministic discrete-event simulation:
arrivals, deadlines and latency stamps all read an injectable clock, and
on a ``VirtualClock`` two runs are bit-identical.  That property held by
convention only — any ``time.time()`` added anywhere in ``serve/`` would
silently break it.  The convention is now enforced: lint rule R5
(``repro.analysis.rules``) forbids wall-clock reads inside ``serve/``
outside THIS file, so every consumer — the front-end's serving clock AND
the engine's per-phase tick accounting — must route through a Clock.

Protocol (duck-typed; anything with these three methods serves):

    now() -> float        current time in seconds
    advance(dt) -> None   move a virtual clock forward (no-op on walls)
    skip_to(t) -> None    jump over idle gaps without sleeping

``VirtualClock`` advances only when told (simulation), ``WallClock``
reads ``time.perf_counter`` zeroed at construction (real measurements).
"""
from __future__ import annotations

import time

__all__ = ["Clock", "VirtualClock", "WallClock"]


class Clock:
    """Protocol base (also a usable zero clock for code that only needs
    ``now()`` deltas disabled — e.g. an engine whose phase accounting
    should cost nothing)."""

    def now(self) -> float:
        return 0.0

    def advance(self, dt: float) -> None:
        pass

    def skip_to(self, t: float) -> None:
        pass


class VirtualClock(Clock):
    """Deterministic discrete-event clock: ``now()`` moves only when the
    serve loop calls ``advance``/``skip_to``.  No wall reads, no sleeps —
    a front-end on this clock is a pure simulation, which is what makes
    deadline/priority/backpressure behavior unit-testable."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._t += float(dt)

    def skip_to(self, t: float) -> None:
        self._t = max(self._t, float(t))


class WallClock(Clock):
    """Real serving time (``time.perf_counter``), zeroed at construction.
    ``advance`` is a no-op — real time advances itself while the engine
    computes — and ``skip_to`` jumps over idle gaps by offsetting the
    origin instead of sleeping, so an idle open-loop system costs no wall
    time to simulate and latency stamps still measure arrival-to-done."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._skip = 0.0

    def now(self) -> float:
        return time.perf_counter() - self._t0 + self._skip

    def advance(self, dt: float) -> None:
        pass

    def skip_to(self, t: float) -> None:
        gap = t - self.now()
        if gap > 0:
            self._skip += gap

"""Project-specific AST invariant rules (R1-R6).

Each rule is a past bug or a load-bearing convention promoted into a
statically checked invariant:

R1  no literal ``interpret=True/False`` at a kernel call site — the PR-4
    bug: kernels hardcoding ``interpret=True`` ran the "pallas" backend
    under the interpreter on real hardware.  The flag must flow through
    (``interpret=interpret``) so ``kernels.default_interpret`` stays the
    one resolution point (``kernels/interpret.py`` is the only file that
    may spell the literal).
R2  no hand-assembled solver ops outside ``core/``/``operators/`` — the
    PR-1/PR-3 facade contract: consumers route through the registry
    (``repro.operators.make_operator``) or ``repro.api.Problem``; direct
    ``SolverOps(...)`` construction and the legacy ``dense_ops`` /
    ``ell_ops`` / ``solve_distributed`` / ``serve.Engine`` signatures are
    the hand-wiring the facade exists to retire.  (This rule replaces the
    PR-3 grep-style test ``test_no_legacy_imports_outside_kernel_layer``.)
R3  no unseeded randomness — module-level ``np.random.*`` calls share
    hidden global state and break the bit-reproducibility contract every
    serving bench relies on (PR 7 threaded seeds through all of them);
    ``default_rng()``/``RandomState()`` without a seed and ``PRNGKey``
    derived from wall-clock/entropy calls are the same bug.
R4  no float64 construction outside the oracle whitelist — the PR-4
    dtype canonicalization fix: operands are float32 (jax x64 is off);
    a stray float64 array silently downcasts somewhere downstream and
    changes tolerance semantics.  The float64 *reference oracles*
    (``solvers/rcd.py``, ``core/reference.py``) are whitelisted; any
    other intentional use carries an inline allow with its reason.
R5  no wall-clock reads inside ``serve/`` except the ``Clock`` protocol
    implementations (``serve/clock.py``) — the open-loop layer is a
    deterministic discrete-event simulation (PR 7); one stray
    ``time.time()`` makes deadlines/latency stamps unreproducible.
R6  every ``decide_*`` planner branch returns a reason string — the
    planner's explainability contract (PR 3): each ``return`` in a
    ``decide_*`` function must be a tuple whose last element is a
    string-valued reason, so no decision path can go dark.

Suppression syntax (same line or the line above the violation)::

    # repro: allow[R4] -- float64 residual oracle, never an operand

A suppression without the ``-- reason`` tail is itself a violation (R0):
the escape hatch must leave an audit trail.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Callable, Iterable, Iterator

__all__ = ["RULES", "RULES_BY_ID", "Rule", "Violation", "check_source"]


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def to_json(self) -> dict:
        rationale = (RULES_BY_ID[self.rule].rationale
                     if self.rule in RULES_BY_ID else SUPPRESSION_RATIONALE)
        return {"rule": self.rule, "file": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "rationale": rationale}

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    rationale: str
    check: Callable[[ast.Module, str], Iterator[tuple[int, int, str]]]


def _pkg_rel(path: str) -> str:
    """Path relative to the ``repro`` package root when under it (rule
    whitelists are package-relative: "kernels/interpret.py"), else the
    given path unchanged (posix separators either way)."""
    p = path.replace("\\", "/")
    marker = "repro/"
    i = p.rfind("/" + marker)
    if i >= 0:
        return p[i + 1 + len(marker):]
    if p.startswith(marker):
        return p[len(marker):]
    return p


def _dotted(node: ast.AST) -> str:
    """'np.random.rand' for nested Attribute/Name chains ('' otherwise)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# ---------------------------------------------------------------------------
# R1: literal interpret= at kernel call sites
# ---------------------------------------------------------------------------

R1_ALLOWED_FILES = ("kernels/interpret.py",)


def _check_r1(tree: ast.Module, path: str):
    if _pkg_rel(path) in R1_ALLOWED_FILES:
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg == "interpret" and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, bool):
                callee = _dotted(node.func) or "<call>"
                yield (kw.value.lineno, kw.value.col_offset,
                       f"literal interpret={kw.value.value} at {callee}(...) "
                       f"— pass the flag through and resolve it via "
                       f"kernels.default_interpret (the PR-4 bug: hardcoded "
                       f"interpret silently runs interpreted on real "
                       f"hardware)")


# ---------------------------------------------------------------------------
# R2: hand-assembled solver ops outside core/ and operators/
# ---------------------------------------------------------------------------

R2_ALLOWED_PREFIXES = ("core/", "operators/")
#: shim definition sites: the deprecation layer and the serve alias
R2_ALLOWED_FILES = ("deprecation.py", "serve/__init__.py")
R2_LEGACY_NAMES = ("dense_ops", "ell_ops", "solve_distributed")


def _r2_scoped(path: str) -> bool:
    rel = _pkg_rel(path)
    return not (rel.startswith(R2_ALLOWED_PREFIXES)
                or rel in R2_ALLOWED_FILES)


def _check_r2(tree: ast.Module, path: str):
    if not _r2_scoped(path):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            callee = _dotted(node.func)
            tail = callee.rsplit(".", 1)[-1]
            if tail == "SolverOps":
                yield (node.lineno, node.col_offset,
                       "direct SolverOps(...) construction — build "
                       "operators through repro.operators.make_operator "
                       "(the registry) or solve through repro.api.Problem")
            elif tail in R2_LEGACY_NAMES:
                yield (node.lineno, node.col_offset,
                       f"legacy signature {tail}() — route through "
                       f"repro.api.Problem / make_operator(...).solver_ops()")
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            for alias in node.names:
                if alias.name in R2_LEGACY_NAMES:
                    yield (node.lineno, node.col_offset,
                           f"import of legacy signature {alias.name} from "
                           f"{mod} — route through the repro.api facade")
                if alias.name == "Engine" and mod.endswith("serve"):
                    yield (node.lineno, node.col_offset,
                           "deprecated serve.Engine alias — import "
                           "TokenEngine or create_engine('tokens')")
        elif isinstance(node, ast.Attribute):
            if node.attr == "Engine" and _dotted(node.value) \
                    .rsplit(".", 1)[-1] == "serve":
                yield (node.lineno, node.col_offset,
                       "deprecated serve.Engine alias — use "
                       "serve.TokenEngine or create_engine('tokens')")


# ---------------------------------------------------------------------------
# R3: unseeded randomness
# ---------------------------------------------------------------------------

#: np.random attributes that are NOT the hidden-global-state legacy API
R3_SEEDED_CTORS = ("default_rng", "Generator", "SeedSequence", "PCG64",
                   "Philox", "SFC64", "MT19937", "RandomState", "BitGenerator")
R3_ENTROPY_CALLS = ("time.time", "time.time_ns", "time.perf_counter",
                    "time.monotonic", "os.urandom", "os.getpid",
                    "secrets.randbits", "secrets.token_bytes", "uuid.uuid4")


def _check_r3(tree: ast.Module, path: str):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _dotted(node.func)
        head, _, tail = callee.rpartition(".")
        if head in ("np.random", "numpy.random"):
            if tail not in R3_SEEDED_CTORS:
                yield (node.lineno, node.col_offset,
                       f"{callee}() uses numpy's hidden global RNG state — "
                       f"thread an explicit np.random.default_rng(seed) "
                       f"through (bit-reproducibility contract)")
            elif tail in ("default_rng", "RandomState") and not node.args \
                    and not node.keywords:
                yield (node.lineno, node.col_offset,
                       f"{callee}() without a seed draws OS entropy — pass "
                       f"an explicit seed (bit-reproducibility contract)")
        elif tail in ("PRNGKey", "key") and head.endswith("random"):
            for sub in node.args:
                for inner in ast.walk(sub):
                    if isinstance(inner, ast.Call) \
                            and _dotted(inner.func) in R3_ENTROPY_CALLS:
                        yield (inner.lineno, inner.col_offset,
                               f"PRNGKey seeded from {_dotted(inner.func)}()"
                               f" — keys must derive from an explicit seed, "
                               f"not wall clock/entropy")


# ---------------------------------------------------------------------------
# R4: float64 construction outside the oracle whitelist
# ---------------------------------------------------------------------------

R4_ALLOWED_FILES = ("solvers/rcd.py", "core/reference.py")


def _is_float64(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "float64":
        return True
    return isinstance(node, ast.Constant) and node.value == "float64"


def _check_r4(tree: ast.Module, path: str):
    if _pkg_rel(path) in R4_ALLOWED_FILES:
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _dotted(node.func)
        # np.dtype(np.float64) builds a dtype object to *compare* against,
        # not a float64 array — the canonicalization code does exactly this
        if callee.rsplit(".", 1)[-1] == "dtype":
            continue
        if callee.rsplit(".", 1)[-1] == "float64":
            yield (node.lineno, node.col_offset,
                   "float64 scalar/array construction — operands are "
                   "float32 (jax x64 off); keep float64 inside the "
                   "whitelisted reference oracles or carry an allow with "
                   "a reason")
            continue
        for arg in [*node.args, *[k.value for k in node.keywords]]:
            if _is_float64(arg):
                yield (arg.lineno, arg.col_offset,
                       f"float64 passed to {callee or '<call>'}(...) — "
                       f"operands are float32 (jax x64 off, PR-4 downcast "
                       f"fix); float64 belongs to the reference oracles "
                       f"({', '.join(R4_ALLOWED_FILES)}) or needs a "
                       f"reasoned allow")


# ---------------------------------------------------------------------------
# R5: wall-clock reads inside serve/
# ---------------------------------------------------------------------------

R5_ALLOWED_FILES = ("serve/clock.py",)
R5_WALL_ATTRS = ("time", "perf_counter", "perf_counter_ns", "monotonic",
                 "monotonic_ns", "process_time", "time_ns")


def _check_r5(tree: ast.Module, path: str):
    rel = _pkg_rel(path)
    if not rel.startswith("serve/") or rel in R5_ALLOWED_FILES:
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr in R5_WALL_ATTRS \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "time":
            yield (node.lineno, node.col_offset,
                   f"time.{node.attr} read inside serve/ — serving time "
                   f"must flow through the Clock protocol "
                   f"(repro.serve.clock), or the discrete-event "
                   f"simulation stops being deterministic")
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in R5_WALL_ATTRS:
                    yield (node.lineno, node.col_offset,
                           f"from time import {alias.name} inside serve/ — "
                           f"route through the Clock protocol "
                           f"(repro.serve.clock)")


# ---------------------------------------------------------------------------
# R6: decide_* branches must return a reason string
# ---------------------------------------------------------------------------

def _stringish(node: ast.AST) -> bool:
    """Statically string-valued: literals, f-strings, concatenations,
    conditionals of those, str(...) calls, or a variable whose name says
    it is a reason."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, str)
    if isinstance(node, ast.JoinedStr):
        return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.Add,
                                                            ast.Mod)):
        return _stringish(node.left) or _stringish(node.right)
    if isinstance(node, ast.IfExp):
        return _stringish(node.body) and _stringish(node.orelse)
    if isinstance(node, ast.Call):
        callee = _dotted(node.func)
        return callee in ("str", "repr", "format") \
            or callee.endswith((".join", ".format"))
    if isinstance(node, (ast.Name, ast.Attribute)):
        name = node.id if isinstance(node, ast.Name) else node.attr
        low = name.lower()
        return any(t in low for t in ("reason", "why", "msg", "explan"))
    return False


def _check_r6(tree: ast.Module, path: str):
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                or not fn.name.startswith("decide_"):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            val = node.value
            if not isinstance(val, ast.Tuple) or len(val.elts) < 2 \
                    or not _stringish(val.elts[-1]):
                yield (node.lineno, node.col_offset,
                       f"return in {fn.name}() without a trailing reason "
                       f"string — every planner decision branch must "
                       f"explain itself (plan reasons contract): return "
                       f"(decision, ..., reason)")


# ---------------------------------------------------------------------------
# the rule set + suppression machinery
# ---------------------------------------------------------------------------

RULES: tuple[Rule, ...] = (
    Rule("R1", "no literal interpret= at kernel call sites",
         "PR-4 bug class: hardcoded interpret=True runs the pallas "
         "backend interpreted on real hardware; kernels/interpret.py is "
         "the one resolution point", _check_r1),
    Rule("R2", "no hand-assembled solver ops outside core/ and operators/",
         "facade contract (PR 1/3): consumers build operators through the "
         "registry or repro.api.Problem, never SolverOps(...)/legacy "
         "signatures", _check_r2),
    Rule("R3", "no unseeded randomness",
         "bit-reproducibility contract (PR 7): hidden-global-state "
         "np.random calls and entropy-derived PRNGKeys make benches and "
         "simulations unreplayable", _check_r3),
    Rule("R4", "no float64 construction outside the oracle whitelist",
         "PR-4 dtype canonicalization: operands are float32 with x64 off; "
         "stray float64 silently downcasts and changes tolerance "
         "semantics", _check_r4),
    Rule("R5", "no wall-clock reads inside serve/ outside the Clock "
         "protocol",
         "PR-7 determinism: the open-loop layer is a discrete-event "
         "simulation; serve/clock.py is the only wall-time boundary",
         _check_r5),
    Rule("R6", "every decide_* branch returns a reason string",
         "planner explainability contract (PR 3): each decision records "
         "why, so plans stay inspectable and overridable", _check_r6),
)

RULES_BY_ID = {r.id: r for r in RULES}

SUPPRESSION_RATIONALE = ("the escape hatch must leave an audit trail: "
                         "allows without a reason rot into unexplained "
                         "exemptions")

_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\[(?P<ids>[^\]]*)\]\s*(?:--\s*(?P<why>\S.*))?")


def _comments(source: str) -> Iterator[tuple[int, int, str]]:
    """(line, col, text) for real COMMENT tokens only — a docstring that
    *mentions* the allow grammar is documentation, not a suppression."""
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.start[1], tok.string
    except (tokenize.TokenError, IndentationError):
        return


def _suppressions(source: str):
    """(line -> set of rule ids allowed there) plus R0 violations for
    allows without a reason or with unknown rule ids.  An allow on line L
    covers violations on L and L+1 (comment-above style)."""
    allowed: dict[int, set[str]] = {}
    bad: list[tuple[int, int, str]] = []
    for i, col0, text in _comments(source):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        ids = {s.strip() for s in m.group("ids").split(",") if s.strip()}
        unknown = sorted(ids - set(RULES_BY_ID))
        if unknown:
            bad.append((i, col0 + m.start(),
                        f"allow[] names unknown rule(s) "
                        f"{', '.join(unknown)} (known: "
                        f"{', '.join(RULES_BY_ID)})"))
            ids &= set(RULES_BY_ID)
        if not m.group("why"):
            bad.append((i, col0 + m.start(),
                        "suppression without a reason — write "
                        "'# repro: allow[Rn] -- why'"))
            continue
        for ln in (i, i + 1):
            allowed.setdefault(ln, set()).update(ids)
    return allowed, bad


def check_source(source: str, path: str,
                 rules: Iterable[Rule] = RULES) -> list[Violation]:
    """Run the rule set over one file's source; returns violations with
    suppressions applied (and R0 violations for malformed suppressions)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Violation("R0", path, e.lineno or 1, e.offset or 0,
                          f"file does not parse: {e.msg}")]
    allowed, bad = _suppressions(source)
    out = [Violation("R0", path, ln, col, msg) for ln, col, msg in bad]
    for rule in rules:
        for line, col, msg in rule.check(tree, path):
            if rule.id in allowed.get(line, ()):
                continue
            out.append(Violation(rule.id, path, line, col, msg))
    out.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return out

"""Strict-mode runtime sanitizers: the dynamic half of ``repro.analysis``.

The AST rules catch what is visible in source; this module catches what
only shows up at runtime — and turns two of this repo's load-bearing
*claims* into machine-checked assertions:

* **No implicit transfers on warm engine ticks.**  The serving engine's
  steady state keeps operands device-resident; a stray ``jnp.asarray``
  on a numpy master would silently re-upload per tick.  Under strict
  mode the engine runs its tick phases inside
  ``jax.transfer_guard("disallow")`` — intentional host->device splices
  at admission go through explicit ``jax.device_put`` inside
  ``intended_transfers()`` scopes, and anything else is counted (and
  recovered from) as a ``disallowed_transfers`` tick counter.
* **Zero recompiles per warm tick.**  PR 6's whole value is
  ``compile_s == 0`` on warm ticks via AOT bucket executables; that was
  a *reported statistic*, never an enforced invariant.
  ``CompileWatcher`` counts XLA compilations through the
  ``jax.log_compiles`` logging stream, the engine surfaces the per-tick
  count as a ``retraces`` counter, and ``expect_no_retraces()`` raises
  when a supposedly-warm region compiled anything.

``strict_mode()`` bundles the test-suite-wide pieces (rank promotion =
raise, optional NaN/leak checking, a compile watcher) with the
process-wide flag (``set_strict``) that the pytest ``--strict-sanitize``
option flips and ``SolverEngine(sanitize=None)`` resolves against.

jax imports are deliberately lazy: ``repro.analysis.lint`` must stay
importable (and fast) in a bare CI job.
"""
from __future__ import annotations

import contextlib
import logging
import os
from typing import Iterator, Optional

__all__ = ["CompileWatcher", "StrictViolation", "expect_no_retraces",
           "intended_transfers", "guard_transfers", "set_strict",
           "strict_enabled", "strict_mode"]

#: process-wide strict default: --strict-sanitize / REPRO_STRICT flip it;
#: SolverEngine(sanitize=None) resolves here.
_STRICT = None


class StrictViolation(AssertionError):
    """A strict-mode invariant failed (retraces on a warm region, ...)."""


def set_strict(value: Optional[bool]) -> None:
    """Set (or with None, clear back to the env default) the process-wide
    strict flag."""
    global _STRICT
    _STRICT = value


def strict_enabled() -> bool:
    """Resolution order: set_strict() > REPRO_STRICT env var > off."""
    if _STRICT is not None:
        return _STRICT
    return os.environ.get("REPRO_STRICT", "").strip().lower() in (
        "1", "true", "yes", "on")


# ---------------------------------------------------------------------------
# the retrace detector
# ---------------------------------------------------------------------------

#: the logger jax.log_compiles routes "Compiling <fn> ..." records through
_COMPILE_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")


class _CountingHandler(logging.Handler):
    def __init__(self, watcher: "CompileWatcher"):
        super().__init__(level=logging.DEBUG)
        self.watcher = watcher

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith("Compiling "):
            self.watcher.count += 1
            if len(self.watcher.compiled) < 64:
                self.watcher.compiled.append(msg.split(" with ", 1)[0]
                                             .removeprefix("Compiling "))


class CompileWatcher:
    """Counts XLA compilations inside a ``with`` region via the
    ``jax.log_compiles`` logging stream.

    Re-entrant and nestable (each instance attaches its own handler);
    while active, the jax compile loggers stop propagating so enabling
    ``log_compiles`` does not spam stderr.  ``count`` is the number of
    ``Compiling <fn>`` records seen; ``compiled`` names the first few.

    >>> import jax, jax.numpy as jnp
    >>> f = jax.jit(lambda x: x * 2.0)
    >>> _ = f(jnp.ones(3))                      # compiled outside
    >>> with CompileWatcher() as w:
    ...     _ = f(jnp.ones(3))                  # cache hit: no compile
    >>> w.count
    0
    """

    def __init__(self):
        self.count = 0
        self.compiled: list[str] = []
        self._stack = None

    def __enter__(self) -> "CompileWatcher":
        import jax

        self._stack = contextlib.ExitStack()
        self._stack.enter_context(jax.log_compiles(True))
        self._handler = _CountingHandler(self)
        for name in _COMPILE_LOGGERS:
            logger = logging.getLogger(name)
            prev_prop, prev_level = logger.propagate, logger.level
            logger.addHandler(self._handler)
            logger.propagate = False
            if logger.level > logging.DEBUG:
                logger.setLevel(logging.DEBUG)
            self._stack.callback(self._restore, logger, prev_prop,
                                 prev_level)
        return self

    def _restore(self, logger, prev_prop, prev_level):
        logger.removeHandler(self._handler)
        # an inner watcher must not undo an outer watcher's quieting
        if not any(isinstance(h, _CountingHandler) for h in logger.handlers):
            logger.propagate = prev_prop
        logger.setLevel(prev_level)

    def __exit__(self, *exc) -> None:
        self._stack.close()
        self._stack = None


@contextlib.contextmanager
def expect_no_retraces(what: str = "warm region") -> Iterator[CompileWatcher]:
    """Assert ZERO XLA compilations inside the region — the enforcement
    form of the AOT warm-tick claim.  Raises StrictViolation naming the
    recompiled computations."""
    with CompileWatcher() as w:
        yield w
    if w.count:
        raise StrictViolation(
            f"{what}: {w.count} recompile(s) where zero were promised "
            f"(first: {', '.join(w.compiled[:8])}) — a warm tick must hit "
            f"the AOT/jit caches")


# ---------------------------------------------------------------------------
# transfer scoping
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def intended_transfers() -> Iterator[None]:
    """Scoped allow for *sanctioned* host<->device movement (admission
    splices, streamed-operand re-uploads, harvest reads).  Inside the
    engine these sites also use explicit device_put/device_get, so the
    scope is belt-and-braces documentation that the transfer is the
    point, not an accident."""
    import jax

    with jax.transfer_guard("allow"):
        yield


def guard_transfers():
    """The enforcement guard for engine tick phases:
    ``jax.transfer_guard("disallow")`` — explicit device_put/device_get
    still pass; implicit transfers raise (and the engine counts the
    recovery as a ``disallowed_transfers`` tick counter)."""
    import jax

    return jax.transfer_guard("disallow")


def is_transfer_error(exc: BaseException) -> bool:
    """Whether an exception is the transfer guard firing (jaxlib raises a
    plain XlaRuntimeError; match on the guard's message shape)."""
    return "Disallowed" in str(exc) and "transfer" in str(exc)


# ---------------------------------------------------------------------------
# the bundled strict mode
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def strict_mode(*, rank_promotion: str = "raise", nan_checks: bool = False,
                leak_checks: bool = False,
                engine_sanitize: bool = True) -> Iterator[CompileWatcher]:
    """Run a region under the full sanitizer matrix:

    * ``jax_numpy_rank_promotion = rank_promotion`` ("raise": silent
      broadcasts across ranks become errors),
    * the process-wide strict flag set, so every ``SolverEngine``
      constructed inside guards its tick phases under
      ``transfer_guard("disallow")`` and counts retraces/transfers
      (``engine_sanitize=False`` leaves engines alone),
    * a ``CompileWatcher`` (yielded, for callers that want to assert on
      compile counts),
    * optionally ``jax.debug_nans`` / ``jax.checking_leaks`` — off by
      default: NaN checking syncs every primitive (slow) and flags
      legitimately-masked lanes, so it is a per-test opt-in.

    This is the context-manager form of the pytest ``--strict-sanitize``
    flag (tests/conftest.py applies the same matrix suite-wide).
    """
    import jax

    prev = _STRICT
    with contextlib.ExitStack() as es:
        es.enter_context(jax.numpy_rank_promotion(rank_promotion))
        if nan_checks:
            es.enter_context(jax.debug_nans(True))
        if leak_checks:
            es.enter_context(jax.checking_leaks())
        if engine_sanitize:
            set_strict(True)
            es.callback(set_strict, prev)
        watcher = es.enter_context(CompileWatcher())
        yield watcher

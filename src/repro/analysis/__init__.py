"""Static analysis + strict-mode runtime sanitizers for this repo.

The paper's claim is a *system* claim, and this repo's own history shows
that what silently rots is never the math — it is the invariants nobody
re-checks: Pallas kernels hardcoding ``interpret=True`` (PR 4), the
"``compile_s == 0`` on warm ticks" AOT claim (PR 6), the "no wall clock
inside ``serve/``" determinism convention (PR 7).  Every one of those is
statically checkable or runtime-assertable, so this package turns them
into enforced rules:

``repro.analysis.rules``
    The project-specific AST rule set (R1-R6), each with an id, a
    rationale, and an inline-suppression escape hatch that *requires a
    written reason* (``# repro: allow[Rn] -- why``).
``repro.analysis.lint``
    The linter CLI over those rules::

        python -m repro.analysis.lint src/            # text, exit 1 on hit
        python -m repro.analysis.lint --json src/     # machine-readable

``repro.analysis.strict``
    The runtime half: ``strict_mode()`` (transfer_guard +
    rank-promotion=raise + retrace watcher + optional NaN/leak checks),
    ``CompileWatcher`` (the ``jax.log_compiles``-based retrace detector),
    and the process-wide strict flag the pytest ``--strict-sanitize``
    option flips (the serving engine reads it to guard its tick phases
    under ``jax.transfer_guard("disallow")``).

DESIGN.md section "Static analysis & strict mode" carries the rule table
and the sanitizer matrix.
"""
from repro.analysis.rules import RULES, Violation  # noqa: F401
from repro.analysis.strict import (  # noqa: F401
    CompileWatcher, intended_transfers, set_strict, strict_enabled,
    strict_mode,
)

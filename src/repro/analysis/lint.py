"""AST invariant linter CLI: ``python -m repro.analysis.lint src/``.

Walks the given files/directories, runs the R1-R6 rule set
(``repro.analysis.rules``) over every ``*.py`` file, and reports
violations as ``file:line:col: Rn message`` lines (or, with ``--json``, a
machine-readable array carrying each rule's rationale).  Exit status 1 on
any violation — including R0, the meta-rule that an inline suppression
(``# repro: allow[Rn] -- why``) must carry a reason.

Deliberately dependency-free (stdlib ``ast`` only): the lint CI job runs
before anything heavyweight imports.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.rules import RULES, RULES_BY_ID, check_source

#: directories never worth descending into
_SKIP_DIRS = {"__pycache__", ".git", ".hypothesis", "build", "dist",
              ".pytest_cache"}


def iter_files(paths: list[str]):
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                yield path
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            for name in sorted(files):
                if name.endswith(".py"):
                    yield os.path.join(root, name)


def lint_paths(paths: list[str], rules=RULES):
    """All violations over every python file under ``paths``."""
    out = []
    for path in iter_files(paths):
        with open(path, encoding="utf-8") as f:
            source = f.read()
        out.extend(check_source(source, path, rules))
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="project AST invariant linter (rules R1-R6; see "
                    "DESIGN.md 'Static analysis & strict mode')")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--json", action="store_true",
                    help="emit violations as a JSON array on stdout")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    args = ap.parse_args(argv)
    rules = RULES
    if args.rules:
        wanted = [s.strip() for s in args.rules.split(",") if s.strip()]
        unknown = [w for w in wanted if w not in RULES_BY_ID]
        if unknown:
            ap.error(f"unknown rule ids {unknown}; known: "
                     f"{', '.join(RULES_BY_ID)}")
        rules = tuple(RULES_BY_ID[w] for w in wanted)
    violations = lint_paths(args.paths, rules)
    if args.json:
        json.dump([v.to_json() for v in violations], sys.stdout, indent=1)
        sys.stdout.write("\n")
    else:
        for v in violations:
            print(v)
        if violations:
            print(f"{len(violations)} violation(s); rules: "
                  f"{', '.join(sorted({v.rule for v in violations}))} — "
                  f"suppress a justified exception with "
                  f"'# repro: allow[Rn] -- why'", file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())

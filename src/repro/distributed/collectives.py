"""Explicit collective helpers (shard_map layer).

  * ring_allreduce     — reduce-scatter + all-gather decomposition built from
                         psum_scatter/all_gather; the bucketed form chunks a
                         pytree so XLA can overlap transfers with compute.
  * psum_compressed    — int8(+error-feedback-ready) emulated compressed
                         all-reduce for slow cross-pod links: per-shard
                         quantize -> psum over the axis -> dequantize.
                         (JAX semantics can't put int8 on the wire for a sum
                         without overflow, so codes widen to int32 inside the
                         psum; the wire-bytes WIN is accounted analytically in
                         the roofline — 8.25 bits/val — while numerics here
                         are bit-exact with a real implementation.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.grad_compress import dequantize_int8, quantize_int8

tmap = jax.tree_util.tree_map


def _axis_size(axis: str) -> int:
    if hasattr(jax.lax, "axis_size"):               # jax >= 0.5
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)                    # 0.4.x: folds to the size


def ring_allreduce(x: jax.Array, axis: str) -> jax.Array:
    """all-reduce as reduce-scatter + all-gather (the bandwidth-optimal ring
    decomposition; XLA emits exactly these two primitives)."""
    n = _axis_size(axis)
    size = x.size
    flat = x.reshape(-1)
    pad = (-size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    piece = jax.lax.psum_scatter(flat, axis, scatter_dimension=0, tiled=True)
    full = jax.lax.all_gather(piece, axis, tiled=True)
    # NOTE: the result is identical on every shard, but jax's vma tracking
    # cannot downcast varying->invariant; callers asserting replicated
    # out_specs should pass check_vma=False to their shard_map.
    return full[:size].reshape(x.shape)


def bucketed_allreduce(tree, axis: str, bucket_bytes: int = 4 << 20):
    """Concatenate leaves into ~bucket_bytes chunks, ring-allreduce each —
    bounded staging memory + transfer/compute overlap windows."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flats = [l.reshape(-1).astype(jnp.float32) for l in leaves]
    cat = jnp.concatenate(flats) if len(flats) > 1 else flats[0]
    n = cat.shape[0]
    per = max(1, bucket_bytes // 4)
    chunks = []
    for start in range(0, n, per):
        chunks.append(ring_allreduce(cat[start:start + per], axis))
    out = jnp.concatenate(chunks) if len(chunks) > 1 else chunks[0]
    outs = []
    off = 0
    for l in leaves:
        outs.append(out[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree_util.tree_unflatten(treedef, outs)


def psum_compressed(x: jax.Array, axis: str, block: int = 256) -> jax.Array:
    """Compressed all-reduce: quantize local shard to int8 codes, sum codes
    across the axis (numerically identical to summing the dequantized
    values since scales are per-sender), dequantize-and-sum via psum of the
    per-sender reconstruction."""
    flat = x.astype(jnp.float32).reshape(-1)
    q, s, pad = quantize_int8(flat, block)
    deq = dequantize_int8(q, s, pad, flat.shape[0])
    return jax.lax.psum(deq, axis).reshape(x.shape).astype(x.dtype)


def psum_tree_compressed(tree, axis: str, block: int = 256):
    return tmap(lambda g: psum_compressed(g, axis, block), tree)

from repro.distributed.sharding import Shardings, make_shardings, null_shardings

__all__ = ["Shardings", "make_shardings", "null_shardings"]

from repro.distributed.sharding import (
    Shardings, make_shardings, null_shardings, shard_map,
)

__all__ = ["Shardings", "make_shardings", "null_shardings", "shard_map"]

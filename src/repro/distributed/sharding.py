"""Logical-axis activation sharding: the glue between mesh-agnostic model
code and a concrete mesh. Models annotate activations with LOGICAL axes
("dp", "tp", "seq", "fsdp", "ep", None); `Shardings` resolves them through
the same rules table used for parameters (repro.models.params.rules_for_mesh)
and applies with_sharding_constraint. With mesh=None (single-device smoke
tests) everything is a no-op.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, mesh=None, in_specs=None, out_specs=None, check_vma=True):
    """Version-portable shard_map: jax>=0.5 exposes jax.shard_map
    (check_vma); 0.4.x only jax.experimental.shard_map (check_rep, whose
    replication checker rejects valid scan carries that are refined inside
    the loop — so it is disabled there; partitioning is unaffected)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


@dataclasses.dataclass(frozen=True)
class Shardings:
    mesh: Mesh | None
    rules: dict

    def spec(self, *logical) -> P:
        return P(*[self.rules.get(a) if a is not None else None
                   for a in logical])

    def named(self, *logical) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(*logical))

    def act(self, x, *logical):
        """Constrain activation x to the resolved spec (no-op without mesh)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(*logical))


def null_shardings() -> Shardings:
    return Shardings(mesh=None, rules={})


def make_shardings(mesh: Mesh | None, overrides: dict | None = None) -> Shardings:
    from repro.models.params import rules_for_mesh

    if mesh is None:
        return null_shardings()
    rules = rules_for_mesh(mesh)
    if overrides:
        rules.update(overrides)
    return Shardings(mesh=mesh, rules=rules)

from repro.models.api import (
    Model, batch_shardings, batch_specs, build_model, cache_sds,
    cache_shardings,
)

__all__ = ["Model", "batch_shardings", "batch_specs", "build_model",
           "cache_sds", "cache_shardings"]

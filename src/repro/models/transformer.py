"""Unified decoder-LM assembly for all 10 assigned architectures.

Families compose from the same block set with scan-over-layers (stacked
params, static trip counts — required both for compile-time control and for
the roofline's trip-count-corrected FLOP accounting):

  dense / audio      [attn + ffn] x L
  moe                [attn + moe] x L (deepseek: first 3 dense, + MTP block)
  vlm                groups of [cross_attn_every self layers + 1 cross block]
  ssm                [mamba1] x L
  hybrid (zamba2)    groups of [attn_every mamba2 blocks] + ONE weight-shared
                     attention block applied per group (+ tail mamba blocks)

Entry points per config:
  loss_fn(params, batch, cfg, sh)                       (training)
  forward(..., collect_kv=True)                         (prefill: logits+cache)
  decode_step(params, cache, tokens, cur_index, cfg)    (one-token serve)
  init_cache(cfg, batch, seq_len)                       (decode cache pytree)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import Shardings, null_shardings
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    cross_entropy, embed, embed_params, logits, mlp, mlp_params, rms_norm,
    rms_norm_params,
)
from repro.models.params import PSpec

F32 = jnp.float32
tmap = jax.tree_util.tree_map


def _stack(tree, n: int):
    """Prepend a scan dim of size n to every PSpec in tree."""
    return tmap(lambda p: PSpec((n,) + p.shape, (None,) + p.axes, p.scale,
                                p.dtype),
                tree, is_leaf=lambda x: isinstance(x, PSpec))


# --------------------------------------------------------------------------
# Parameter tree
# --------------------------------------------------------------------------

def _layer_params(cfg: ModelConfig, ffn: str):
    p: dict[str, Any] = {
        "ln1": rms_norm_params(cfg.d_model),
        "attn": attn.mla_params(cfg) if cfg.use_mla else attn.gqa_params(cfg),
        "ln2": rms_norm_params(cfg.d_model),
    }
    if ffn == "moe":
        p["moe"] = moe_mod.moe_params(cfg)
    else:
        p["mlp"] = mlp_params(cfg)
    return p


def param_tree(cfg: ModelConfig):
    t: dict[str, Any] = {"embed": embed_params(cfg),
                         "final_ln": rms_norm_params(cfg.d_model)}
    fam = cfg.family
    if fam in ("dense", "audio"):
        t["layers"] = _stack(_layer_params(cfg, "mlp"), cfg.num_layers)
    elif fam == "moe":
        if cfg.first_dense_layers:
            t["dense_layers"] = _stack(_layer_params(cfg, "mlp"),
                                       cfg.first_dense_layers)
        t["moe_layers"] = _stack(
            _layer_params(cfg, "moe"),
            cfg.num_layers - cfg.first_dense_layers)
        if cfg.mtp_depth:
            t["mtp"] = _stack(_layer_params(cfg, "moe"), cfg.mtp_depth)
    elif fam == "vlm":
        n_groups = cfg.num_layers // cfg.cross_attn_every
        t["layers"] = _stack(_layer_params(cfg, "mlp"), cfg.num_layers)
        t["cross"] = _stack({"ln": rms_norm_params(cfg.d_model),
                             "xattn": attn.cross_attn_params(cfg),
                             "ln2": rms_norm_params(cfg.d_model),
                             "mlp": mlp_params(cfg)}, n_groups)
    elif fam == "ssm":
        t["layers"] = _stack({"ln1": rms_norm_params(cfg.d_model),
                              "mamba": ssm_mod.mamba1_params(cfg)},
                             cfg.num_layers)
    elif fam == "hybrid":
        t["mamba"] = _stack({"ln1": rms_norm_params(cfg.d_model),
                             "mamba": ssm_mod.mamba2_params(cfg)},
                            cfg.num_layers)
        t["shared_attn"] = _layer_params(cfg, "mlp")   # ONE copy, reused
    else:
        raise ValueError(cfg.family)
    return t


def _hybrid_split(cfg: ModelConfig, tree):
    """Split the stacked mamba tree into (groups of attn_every, tail)."""
    g = cfg.attn_every
    n_groups = cfg.num_layers // g
    grouped = tmap(lambda a: a[: n_groups * g].reshape(
        (n_groups, g) + a.shape[1:]), tree)
    tail = tmap(lambda a: a[n_groups * g:], tree)
    return grouped, tail


# --------------------------------------------------------------------------
# Block forwards (training / prefill). Each returns (x, aux, kv|None).
# --------------------------------------------------------------------------

def _attn_ffn_fwd(p, x, cfg, sh: Shardings, *, use_mla, ffn, chunk, unroll,
                  collect_kv=False):
    h_in = rms_norm(p["ln1"], x)
    kv = None
    if use_mla:
        h = attn.mla_forward(p["attn"], h_in, cfg, chunk=chunk, unroll=unroll)
        if collect_kv:
            B, S, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            *_, c, k_rope = attn._mla_qc(p["attn"], h_in, cfg, positions)
            kv = {"latent": jnp.concatenate([c, k_rope], axis=-1)}
    else:
        if collect_kv:
            h, (k, v) = attn.gqa_forward(p["attn"], h_in, cfg, chunk=chunk,
                                         unroll=unroll, return_kv=True)
            kv = {"k": k, "v": v}
        else:
            h = attn.gqa_forward(p["attn"], h_in, cfg, chunk=chunk,
                                 unroll=unroll)
    x = sh.act(x + h, "dp", None, None)
    aux = jnp.zeros((), F32)
    h2_in = rms_norm(p["ln2"], x)
    if ffn == "moe":
        f, aux = moe_mod.moe_forward(p["moe"], h2_in, cfg, sh)
    else:
        f = mlp(p["mlp"], h2_in, cfg)
    x = sh.act(x + f, "dp", None, None)
    return x, aux, kv


def _mamba_fwd(p, x, cfg, sh: Shardings, cache=None):
    h, new_cache = (ssm_mod.mamba1_forward if cfg.ssm_type == "mamba1"
                    else ssm_mod.mamba2_forward)(
        p["mamba"], rms_norm(p["ln1"], x), cfg, cache)
    return sh.act(x + h, "dp", None, None), new_cache


def _aux0(x):
    """Scalar 0 whose shard_map varying-axes match x (scan-carry vma).
    Scalar-indexes BEFORE any cast/reshape — reshape(-1) on a sharded array
    would materialize a gathered copy (measured +2GB/layer wire)."""
    return (x[(0,) * x.ndim] * 0).astype(F32)


def _scan_layers(body, x, stacked, remat: bool, collect=False):
    f = jax.checkpoint(body) if remat else body

    def wrapped(carry, lp):
        xx, aux = carry
        xx, a, kv = f(xx, lp)
        return (xx, aux + a), (kv if collect else None)

    (x, aux), kvs = jax.lax.scan(wrapped, (x, _aux0(x)), stacked)
    return x, aux, kvs


# --------------------------------------------------------------------------
# Full forward (training / prefill)
# --------------------------------------------------------------------------

def forward(params, tokens, cfg: ModelConfig, sh: Shardings | None = None,
            extras=None, *, unroll: bool = False, chunk: int = 512,
            collect_kv: bool = False):
    """Returns (hidden (B,S,d), aux_loss, caches|None)."""
    sh = sh or null_shardings()
    x = embed(params["embed"], tokens, cfg)
    x = sh.act(x, "dp", None, None)
    caches: dict[str, Any] = {}
    aux = _aux0(x)
    fam = cfg.family
    B = x.shape[0]

    def attn_body(ffn, use_mla):
        def body(xx, lp):
            return _attn_ffn_fwd(lp, xx, cfg, sh, use_mla=use_mla, ffn=ffn,
                                 chunk=chunk, unroll=unroll,
                                 collect_kv=collect_kv)
        return body

    if fam in ("dense", "audio"):
        x, aux, kv = _scan_layers(attn_body("mlp", False), x,
                                  params["layers"], cfg.remat, collect_kv)
        if collect_kv:
            caches["layers"] = kv

    elif fam == "moe":
        if cfg.first_dense_layers:
            x, a, kv = _scan_layers(attn_body("mlp", cfg.use_mla), x,
                                    params["dense_layers"], cfg.remat,
                                    collect_kv)
            aux += a
            if collect_kv:
                caches["dense_layers"] = kv
        x, a, kv = _scan_layers(attn_body("moe", cfg.use_mla), x,
                                params["moe_layers"], cfg.remat, collect_kv)
        aux += a
        if collect_kv:
            caches["moe_layers"] = kv

    elif fam == "vlm":
        g = cfg.cross_attn_every
        n_groups = cfg.num_layers // g
        img = extras["image_embeds"]
        stacked = tmap(lambda a: a.reshape((n_groups, g) + a.shape[1:]),
                       params["layers"])

        def group_body(carry, gp):
            xx, aux_c = carry
            lp, cp = gp
            xx, a, kvs = _scan_layers(attn_body("mlp", False), xx, lp,
                                      cfg.remat, collect_kv)
            h = attn.cross_attn_forward(cp["xattn"], rms_norm(cp["ln"], xx),
                                        img, cfg)
            xx = sh.act(xx + h, "dp", None, None)
            f = mlp(cp["mlp"], rms_norm(cp["ln2"], xx), cfg)
            xx = sh.act(xx + f, "dp", None, None)
            return (xx, aux_c + a), kvs

        (x, aux), kvs = jax.lax.scan(group_body, (x, aux),
                                     (stacked, params["cross"]))
        if collect_kv:
            caches["layers"] = tmap(
                lambda a: a.reshape((-1,) + a.shape[2:]), kvs)
            caches["cross_kv"] = {
                "k": jnp.einsum("bnd,gdhk->gbnhk", img,
                                params["cross"]["xattn"]["wk"]),
                "v": jnp.einsum("bnd,gdhk->gbnhk", img,
                                params["cross"]["xattn"]["wv"]),
            }

    elif fam == "ssm":
        if collect_kv:
            c0 = init_ssm_cache(cfg, B, x.dtype, stacked=True)

            def body(xx, inp):
                lp, lc = inp
                xx, nc = _mamba_fwd(lp, xx, cfg, sh, lc)
                return xx, nc

            f = jax.checkpoint(body) if cfg.remat else body
            x, nc = jax.lax.scan(f, x, (params["layers"], c0))
            caches["ssm"] = nc
        else:
            def body(xx, lp):
                xx, _ = _mamba_fwd(lp, xx, cfg, sh, None)
                return xx, None

            f = jax.checkpoint(body) if cfg.remat else body
            x, _ = jax.lax.scan(f, x, params["layers"])

    elif fam == "hybrid":
        m_grouped, m_tail = _hybrid_split(cfg, params["mamba"])
        shared = params["shared_attn"]

        if collect_kv:
            c0 = init_ssm_cache(cfg, B, x.dtype, stacked=True)
            gcache, tcache = _hybrid_split(cfg, c0)

            def mamba_body(xx, inp):
                lp, lc = inp
                xx, nc = _mamba_fwd(lp, xx, cfg, sh, lc)
                return xx, nc

            mf = jax.checkpoint(mamba_body) if cfg.remat else mamba_body

            def group_body(carry, inp):
                xx, aux_c = carry
                gp, gc = inp
                xx, nc = jax.lax.scan(mf, xx, (gp, gc))
                xx, a, kv = _attn_ffn_fwd(shared, xx, cfg, sh, use_mla=False,
                                          ffn="mlp", chunk=chunk,
                                          unroll=unroll, collect_kv=True)
                return (xx, aux_c + a), (nc, kv)

            (x, aux), (ncaches, kvs) = jax.lax.scan(group_body, (x, aux),
                                                    (m_grouped, gcache))
            x, tnew = jax.lax.scan(mf, x, (m_tail, tcache))
            caches["ssm_groups"] = ncaches
            caches["ssm_tail"] = tnew
            caches["attn_kv"] = kvs
        else:
            def mb(xx, lp):
                xx, _ = _mamba_fwd(lp, xx, cfg, sh, None)
                return xx, None

            mbf = jax.checkpoint(mb) if cfg.remat else mb

            def group_body(carry, gp):
                xx, aux_c = carry
                xx, _ = jax.lax.scan(mbf, xx, gp)
                xx, a, _ = _attn_ffn_fwd(shared, xx, cfg, sh, use_mla=False,
                                         ffn="mlp", chunk=chunk, unroll=unroll)
                return (xx, aux_c + a), None

            (x, aux), _ = jax.lax.scan(group_body, (x, aux), m_grouped)
            x, _ = jax.lax.scan(mbf, x, m_tail)
    else:
        raise ValueError(fam)

    x = rms_norm(params["final_ln"], x)
    return x, aux, (caches if collect_kv else None)


# --------------------------------------------------------------------------
# Loss (training)
# --------------------------------------------------------------------------

def loss_fn(params, batch, cfg: ModelConfig, sh: Shardings | None = None,
            *, unroll: bool = False, chunk: int = 512):
    tokens = batch["tokens"]
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    h, aux, _ = forward(params, tokens, cfg, sh, extras or None,
                        unroll=unroll, chunk=chunk)
    lg = logits(params["embed"], h[:, :-1], cfg)
    if cfg.num_codebooks:
        loss = cross_entropy(lg, tokens[:, 1:])       # (B,S-1,n_cb,V) vs ids
    else:
        loss = cross_entropy(lg, tokens[:, 1:])
    if cfg.family == "moe" and cfg.mtp_depth:
        sh_ = sh or null_shardings()

        def body(xx, lp):
            xx, a, _ = _attn_ffn_fwd(lp, xx, cfg, sh_, use_mla=cfg.use_mla,
                                     ffn="moe", chunk=chunk, unroll=unroll)
            return xx, a

        h2, _ = jax.lax.scan(body, h, params["mtp"])
        lg2 = logits(params["embed"], h2[:, :-2], cfg)
        loss = loss + 0.3 * cross_entropy(lg2, tokens[:, 2:])
    return loss + 0.01 * aux


def prefill(params, tokens, cfg: ModelConfig, sh: Shardings | None = None,
            extras=None, *, chunk: int = 512):
    """Full-prompt forward; returns (last-position logits, cache)."""
    h, _, caches = forward(params, tokens, cfg, sh, extras, chunk=chunk,
                           collect_kv=True)
    lg = logits(params["embed"], h[:, -1:], cfg)
    return lg, caches


# --------------------------------------------------------------------------
# Cache construction
# --------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch: int, dtype, stacked=False,
                   n: int | None = None):
    di, st, w = cfg.resolved_d_inner, cfg.ssm_state, cfg.conv_width
    n = n if n is not None else cfg.num_layers
    if cfg.ssm_type == "mamba1":
        conv_dim = di
        ssm_shape = (batch, di, st)
    else:
        conv_dim = di + 2 * cfg.mamba2_n_groups * cfg.ssm_state
        nh = di // cfg.mamba2_head_dim
        ssm_shape = (batch, nh, cfg.mamba2_head_dim, st)
    conv = jnp.zeros((n, batch, w - 1, conv_dim) if stacked
                     else (batch, w - 1, conv_dim), dtype)
    ssm = jnp.zeros(((n,) + ssm_shape) if stacked else ssm_shape, dtype)
    return {"conv": conv, "ssm": ssm}


def _kv_zeros(cfg, n, batch, seq_len, dtype):
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((n, batch, seq_len, K, hd), dtype),
            "v": jnp.zeros((n, batch, seq_len, K, hd), dtype)}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    fam = cfg.family
    if fam in ("dense", "audio"):
        return {"layers": _kv_zeros(cfg, cfg.num_layers, batch, seq_len, dtype)}
    if fam == "moe":
        c: dict[str, Any] = {}
        if cfg.use_mla:
            lat = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            if cfg.first_dense_layers:
                c["dense_layers"] = {"latent": jnp.zeros(
                    (cfg.first_dense_layers, batch, seq_len, lat), dtype)}
            c["moe_layers"] = {"latent": jnp.zeros(
                (cfg.num_layers - cfg.first_dense_layers, batch, seq_len, lat),
                dtype)}
        else:
            c["moe_layers"] = _kv_zeros(cfg, cfg.num_layers, batch, seq_len,
                                        dtype)
        return c
    if fam == "vlm":
        n_groups = cfg.num_layers // cfg.cross_attn_every
        K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        return {
            "layers": _kv_zeros(cfg, cfg.num_layers, batch, seq_len, dtype),
            "cross_kv": {
                "k": jnp.zeros((n_groups, batch, cfg.num_image_tokens, K, hd),
                               dtype),
                "v": jnp.zeros((n_groups, batch, cfg.num_image_tokens, K, hd),
                               dtype)},
        }
    if fam == "ssm":
        return {"ssm": init_ssm_cache(cfg, batch, dtype, stacked=True)}
    if fam == "hybrid":
        g = cfg.attn_every
        n_groups = cfg.num_layers // g
        full = init_ssm_cache(cfg, batch, dtype, stacked=True)
        grouped, tail = _hybrid_split(cfg, full)
        return {"ssm_groups": grouped, "ssm_tail": tail,
                "attn_kv": _kv_zeros(cfg, n_groups, batch, seq_len, dtype)}
    raise ValueError(fam)


# --------------------------------------------------------------------------
# Decode step
# --------------------------------------------------------------------------

def _attn_ffn_decode(p, x, cfg, cache, cur_index, *, use_mla, ffn, sh=None):
    h_in = rms_norm(p["ln1"], x)
    if use_mla:
        h, new_cache = attn.mla_decode(p["attn"], h_in, cfg, cache, cur_index)
    else:
        h, new_cache = attn.gqa_decode(p["attn"], h_in, cfg, cache, cur_index)
    x = x + h
    h2 = rms_norm(p["ln2"], x)
    if ffn == "moe":
        f, _ = moe_mod.moe_forward(p["moe"], h2, cfg, sh)
    else:
        f = mlp(p["mlp"], h2, cfg)
    return x + f, new_cache


def decode_step(params, cache, tokens, cur_index, cfg: ModelConfig,
                sh: Shardings | None = None):
    """tokens: (B, 1[, n_cb]); cur_index: (B,). Returns (logits, new_cache)."""
    sh = sh or null_shardings()
    x = embed(params["embed"], tokens, cfg)
    fam = cfg.family
    new_cache: dict[str, Any] = {}

    def scan_decode(x, stack_params, stack_cache, use_mla, ffn):
        def body(xx, inp):
            lp, lc = inp
            xx, nc = _attn_ffn_decode(lp, xx, cfg, lc, cur_index,
                                      use_mla=use_mla, ffn=ffn, sh=sh)
            return xx, nc
        return jax.lax.scan(body, x, (stack_params, stack_cache))

    if fam in ("dense", "audio"):
        x, nc = scan_decode(x, params["layers"], cache["layers"], False, "mlp")
        new_cache["layers"] = nc

    elif fam == "moe":
        if cfg.first_dense_layers:
            x, nc = scan_decode(x, params["dense_layers"],
                                cache["dense_layers"], cfg.use_mla, "mlp")
            new_cache["dense_layers"] = nc
        x, nc = scan_decode(x, params["moe_layers"], cache["moe_layers"],
                            cfg.use_mla, "moe")
        new_cache["moe_layers"] = nc

    elif fam == "vlm":
        g = cfg.cross_attn_every
        n_groups = cfg.num_layers // g
        stacked = tmap(lambda a: a.reshape((n_groups, g) + a.shape[1:]),
                       params["layers"])
        kv_stacked = tmap(lambda a: a.reshape((n_groups, g) + a.shape[1:]),
                          cache["layers"])

        def self_body(xx, inp):
            lp, lc = inp
            xx, nc = _attn_ffn_decode(lp, xx, cfg, lc, cur_index,
                                      use_mla=False, ffn="mlp", sh=sh)
            return xx, nc

        def group_body(xx, inp):
            lp, lc, cp, ckv = inp
            xx, nc = jax.lax.scan(self_body, xx, (lp, lc))
            q = jnp.einsum("bsd,dhk->bshk", rms_norm(cp["ln"], xx),
                           cp["xattn"]["wq"])
            n_img = ckv["k"].shape[1]
            o = attn.decode_attention(
                attn._group(q, cfg.num_kv_heads), ckv["k"], ckv["v"],
                jnp.full_like(cur_index, n_img - 1))
            o = jnp.einsum("bshk,hkd->bsd", o, cp["xattn"]["wo"])
            xx = xx + jnp.tanh(cp["xattn"]["gate"].astype(F32)).astype(
                xx.dtype) * o
            xx = xx + mlp(cp["mlp"], rms_norm(cp["ln2"], xx), cfg)
            return xx, nc

        x, nc = jax.lax.scan(group_body, x, (stacked, kv_stacked,
                                             params["cross"],
                                             cache["cross_kv"]))
        new_cache["layers"] = tmap(lambda a: a.reshape((-1,) + a.shape[2:]),
                                   nc)
        new_cache["cross_kv"] = cache["cross_kv"]

    elif fam == "ssm":
        def body(xx, inp):
            lp, lc = inp
            xx, nc = _mamba_fwd(lp, xx, cfg, sh, lc)
            return xx, nc

        x, nc = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        new_cache["ssm"] = nc

    elif fam == "hybrid":
        m_grouped, m_tail = _hybrid_split(cfg, params["mamba"])
        shared = params["shared_attn"]

        def mamba_body(xx, inp):
            lp, lc = inp
            xx, nc = _mamba_fwd(lp, xx, cfg, sh, lc)
            return xx, nc

        def group_body(xx, inp):
            gp, gc, akv = inp
            xx, nc = jax.lax.scan(mamba_body, xx, (gp, gc))
            xx, akv_new = _attn_ffn_decode(shared, xx, cfg, akv, cur_index,
                                           use_mla=False, ffn="mlp", sh=sh)
            return xx, (nc, akv_new)

        x, (nc, akv) = jax.lax.scan(group_body, x,
                                    (m_grouped, cache["ssm_groups"],
                                     cache["attn_kv"]))
        x, tnc = jax.lax.scan(mamba_body, x, (m_tail, cache["ssm_tail"]))
        new_cache["ssm_groups"] = nc
        new_cache["ssm_tail"] = tnc
        new_cache["attn_kv"] = akv
    else:
        raise ValueError(fam)

    x = rms_norm(params["final_ln"], x)
    return logits(params["embed"], x, cfg), new_cache

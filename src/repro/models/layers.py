"""Shared layer primitives: norms, rotary embeddings, MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm_params(dim: int):
    return {"scale": PSpec((dim,), (None,), scale="zero")}  # stored as (w-1)


def rms_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"].astype(jnp.float32))
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------

def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq   # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP (dense FFN)
# --------------------------------------------------------------------------

def mlp_params(cfg: ModelConfig, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.activation == "swiglu":
        return {
            "wi": PSpec((d, 2 * ff), ("fsdp", "tp")),   # gate+up fused
            "wo": PSpec((ff, d), ("tp", "fsdp")),
        }
    return {
        "wi": PSpec((d, ff), ("fsdp", "tp")),
        "wo": PSpec((ff, d), ("tp", "fsdp")),
    }


def mlp(p, x, cfg: ModelConfig):
    h = x @ p["wi"]
    if cfg.activation == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif cfg.activation == "relu2":
        r = jnp.maximum(h, 0.0)
        h = r * r
    else:  # gelu
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return h @ p["wo"]


# --------------------------------------------------------------------------
# Embedding / head
# --------------------------------------------------------------------------

def embed_params(cfg: ModelConfig):
    v, d = cfg.vocab_size, cfg.d_model
    n_emb = max(cfg.num_codebooks, 1)
    p = {"embedding": PSpec((n_emb, v, d), (None, "tp", None), scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = PSpec((n_emb, d, v), (None, None, "tp"))
    return p


def embed(p, tokens, cfg: ModelConfig):
    """tokens: (B, S) int32 or (B, S, n_codebooks) for audio — summed."""
    table = p["embedding"]
    if cfg.num_codebooks:
        outs = [jnp.take(table[c], tokens[..., c], axis=0)
                for c in range(cfg.num_codebooks)]
        return sum(outs)
    return jnp.take(table[0], tokens, axis=0)


def logits(p, h, cfg: ModelConfig):
    """h: (B, S, d) -> (B, S, n_codebooks, V) (n_codebooks=1 squeezed)."""
    if cfg.tie_embeddings:
        w = jnp.swapaxes(p["embedding"], 1, 2)      # (n, d, V)
    else:
        w = p["head"]
    out = jnp.einsum("bsd,ndv->bsnv", h, w)
    if not cfg.num_codebooks:
        out = out[..., 0, :]
    return out


def cross_entropy(lg, targets):
    """lg: (..., V) any dtype; stable CE in fp32; targets int32 same leading."""
    lg = lg.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    shifted = lg - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - tgt)

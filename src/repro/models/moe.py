"""Mixture-of-Experts with sort + static-capacity dispatch (MegaBlocks-style
token dropping) and expert parallelism over the `ep` (model) axis.

FLOP-exact formulation (no dense all-experts overcompute): tokens are sorted
by assigned expert, scattered into a static (E, C, d) buffer (overflow slots
dropped — standard capacity-factor semantics), processed with two batched
einsums sharded over E, and combined back with the router gates. The expert
buffers/weights shard over `ep`; GSPMD inserts the dispatch/return
all-to-alls across the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec

F32 = jnp.float32


def moe_params(cfg: ModelConfig):
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    wi_cols = 2 * f if cfg.activation == "swiglu" else f
    p = {
        "router": PSpec((d, E), (None, None)),
        "wi": PSpec((E, d, wi_cols), ("ep", "fsdp", None)),
        "wo": PSpec((E, f, d), ("ep", None, "fsdp")),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared_wi"] = PSpec((d, 2 * fs if cfg.activation == "swiglu" else fs),
                               ("fsdp", "tp"))
        p["shared_wo"] = PSpec((fs, d), ("tp", "fsdp"))
    return p


def _act(h, cfg: ModelConfig):
    if cfg.activation == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        return jax.nn.silu(gate.astype(F32)).astype(h.dtype) * up
    if cfg.activation == "relu2":
        r = jnp.maximum(h, 0.0)
        return r * r
    return jax.nn.gelu(h.astype(F32)).astype(h.dtype)


def moe_forward(p, x, cfg: ModelConfig, sh=None,
                capacity_factor: float = 1.25):
    """x: (B, S, d) -> (B, S, d). `sh`: Shardings for the (E, C, ...) buffer
    constraints — without them GSPMD replicates the dispatch buffers
    (observed: 256 GB/device temp on deepseek prefill; see EXPERIMENTS §Perf).

    Two dispatch regimes (§Perf hillclimb, deepseek decode):
      * T > E:  sort + static-capacity buffers (training/prefill — FLOP-exact)
      * T <= E: dense local-experts einsum — every device runs ALL tokens
        through ITS expert shard and the contraction over E psums the gated
        mix. Overcompute factor E/topk is cheap below the weights-bandwidth
        floor at decode batch sizes, and it removes the sharded
        gather/scatter that otherwise forces buffer replication.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(F32)
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(gates_all, k)               # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    if T <= 4 * E:   # decode regime: overcompute E/topk is below the
        #            weights-bandwidth floor; avoids sharded gather/scatter
        gate_dense = jnp.zeros((T, E), F32).at[
            jnp.repeat(jnp.arange(T), k), ids.reshape(-1)].set(
            gates.reshape(-1))                             # (T, E)
        h = jnp.einsum("td,edf->tef", xt, p["wi"])
        h = _act(h, cfg)
        if sh is not None:
            h = sh.act(h, None, "ep", None)
        # gate folded into h; ONE dot contracting (e, f) jointly => GSPMD
        # partial-sums over the local expert shard and all-reduces (T, d) —
        # a gather of the (T, E, d) per-expert outputs would be 256 GB/step
        # (measured; see EXPERIMENTS §Perf iteration log).
        hg = h * gate_dense[:, :, None].astype(h.dtype)
        out = jnp.einsum("tef,efd->td", hg, p["wo"]).astype(x.dtype)
        if cfg.num_shared_experts:
            hs = _act(xt @ p["shared_wi"], cfg)
            out = out + hs @ p["shared_wo"]
        frac = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=F32), axis=0)
        aux = E * jnp.sum(frac * jnp.mean(gates_all, axis=0))
        return out.reshape(B, S, d), aux

    C = max(8, int(T * k / E * capacity_factor))
    ids_f = ids.reshape(-1)                                # (T*k,)
    gate_f = gates.reshape(-1)
    tok_f = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(ids_f)                             # stable
    ids_s, tok_s, gate_s = ids_f[order], tok_f[order], gate_f[order]
    # position within expert = rank - start_of_expert
    start = jnp.searchsorted(ids_s, jnp.arange(E))
    pos = jnp.arange(T * k) - start[ids_s]
    slot = jnp.where(pos < C, pos, C)                      # overflow -> slot C

    buf = jnp.zeros((E, C + 1, d), x.dtype)
    buf = buf.at[ids_s, slot].set(xt[tok_s])               # dispatch scatter
    buf = buf[:, :C]
    # NOTE (§Perf iteration log): explicit sharding constraints on buf/h
    # ("ep" or C-over-dp) were tried and REGRESS 5x — GSPMD reshards the
    # dispatch through replication. Unconstrained propagation is the best
    # GSPMD-expressible layout; the identified next step is a shard_map
    # all-to-all EP dispatch (~17x wire headroom on deepseek train,
    # napkin math in EXPERIMENTS.md) — not yet implemented.

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    h = _act(h, cfg)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])       # (E, C, d)

    out_pad = jnp.concatenate(
        [out_buf, jnp.zeros((E, 1, d), out_buf.dtype)], axis=1)
    expert_out = out_pad[ids_s, slot]                      # (T*k, d), 0 if dropped
    combined = jnp.zeros((T, d), F32).at[tok_s].add(
        expert_out.astype(F32) * gate_s[:, None])

    out = combined.astype(x.dtype)
    if cfg.num_shared_experts:
        hs = _act(xt @ p["shared_wi"], cfg)
        out = out + hs @ p["shared_wo"]
    # load-balance aux loss (Switch): E * sum_e fraction_e * prob_e
    frac = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=F32), axis=0)
    prob = jnp.mean(gates_all, axis=0)
    aux = E * jnp.sum(frac * prob)
    return out.reshape(B, S, d), aux

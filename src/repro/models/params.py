"""Parameter metadata trees: one structure, three materializations.

Models declare their parameters as trees of ``PSpec(shape, axes, scale)``
where ``axes`` are LOGICAL sharding axes ("tp" = tensor-parallel / model,
"fsdp" = fully-sharded data-parallel, "ep" = expert-parallel, None =
replicated). The same tree then yields:

  * ``init_params(tree, key)``     — real arrays (smoke tests, examples)
  * ``sds_params(tree)``           — ShapeDtypeStructs (dry-run, no alloc)
  * ``partition_specs(tree, rules)`` — jax PartitionSpecs for a mesh, via
    rules like {"tp": "model", "fsdp": ("pod", "data"), "ep": "model"}.

Logical->physical indirection is what makes the configs mesh-agnostic
(single pod, multi pod, elastic reshapes) — configs never name mesh axes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]           # logical axis per dim
    scale: float | str = "fan_in"          # init stddev, "fan_in", or "zero"
    dtype: Any = None                      # override model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_pspec(x):
    return isinstance(x, PSpec)


def _stddev(p: PSpec) -> float:
    if p.scale == "zero":
        return 0.0
    if p.scale == "fan_in":
        fan = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        return 1.0 / math.sqrt(max(fan, 1))
    return float(p.scale)


def init_params(tree, key: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_is_pspec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for p, k in zip(leaves, keys):
        dt = p.dtype or dtype
        sd = _stddev(p)
        if sd == 0.0:
            out.append(jnp.zeros(p.shape, dt))
        else:
            out.append((jax.random.normal(k, p.shape, jnp.float32) * sd)
                       .astype(dt))
    return jax.tree_util.tree_unflatten(treedef, out)


def sds_params(tree, dtype=jnp.bfloat16):
    return tmap(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype or dtype),
                tree, is_leaf=_is_pspec)


def resolve_axis(logical, rules: dict):
    if logical is None:
        return None
    phys = rules.get(logical)
    return phys


def partition_specs(tree, rules: dict):
    """rules: logical axis -> mesh axis (str | tuple | None)."""

    def one(p: PSpec):
        return P(*[resolve_axis(a, rules) for a in p.axes])

    return tmap(one, tree, is_leaf=_is_pspec)


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=_is_pspec)
    return sum(math.prod(l.shape) for l in leaves)


DEFAULT_RULES = {          # single-pod (16 data, 16 model)
    "tp": "model",
    "ep": "model",
    "fsdp": "data",
    "dp": "data",
    "seq": "model",
}


def rules_for_mesh(mesh) -> dict:
    """Pick logical->physical rules from the mesh's axis names."""
    names = mesh.axis_names
    if "pod" in names:
        return {"tp": "model", "ep": "model", "fsdp": ("pod", "data"),
                "dp": ("pod", "data"), "seq": "model"}
    if "model" in names:
        return dict(DEFAULT_RULES)
    # 1-device / test meshes: everything replicated
    return {k: None for k in DEFAULT_RULES}

"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

Training scans the selective recurrence over the sequence with lax.scan
(compile-size control; the recurrence FLOPs are <1% of the block's matmul
FLOPs, accounted analytically in the roofline — see roofline/analysis.py).
Decode is the single-step recurrence against (conv_state, ssm_state) caches,
which is why these archs run the 500k-token shape: state is O(1) in seq_len.

d_inner shards over `tp`; states shard (batch->dp, d_inner->tp).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec

F32 = jnp.float32


# --------------------------------------------------------------------------
# Mamba-1
# --------------------------------------------------------------------------

def mamba1_params(cfg: ModelConfig):
    d, di, st = cfg.d_model, cfg.resolved_d_inner, cfg.ssm_state
    dtr, w = cfg.resolved_dt_rank, cfg.conv_width
    return {
        "in_proj": PSpec((d, 2 * di), ("fsdp", "tp")),
        "conv_w": PSpec((w, di), (None, "tp"), scale=1.0),
        "conv_b": PSpec((di,), ("tp",), scale="zero"),
        "x_proj": PSpec((di, dtr + 2 * st), ("tp", None)),
        "dt_proj": PSpec((dtr, di), (None, "tp")),
        "dt_bias": PSpec((di,), ("tp",), scale=1.0),
        "a_log": PSpec((di, st), ("tp", None), scale=1.0),
        "d_skip": PSpec((di,), ("tp",), scale=1.0),
        "out_proj": PSpec((di, d), ("tp", "fsdp")),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B,S,di); w: (W,di) depthwise. state: (B,W-1,di) for decode."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+W-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):]
    return out + b, new_state


def _selective_scan(u, dt, a, b, c, d_skip, h0):
    """u,dt: (B,S,di); a: (di,st); b,c: (B,S,st); h0: (B,di,st)."""

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp                      # (B,di),(B,di),(B,st)
        da = jnp.exp(dt_t[..., None] * a)              # (B,di,st)
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + u * d_skip            # (B,S,di)
    return y, h


def mamba1_forward(p, x, cfg: ModelConfig, cache=None):
    """x: (B,S,d). cache (decode): dict(conv=(B,W-1,di), ssm=(B,di,st))."""
    B, S, _ = x.shape
    di, st, dtr = cfg.resolved_d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    u, z = jnp.split(x @ p["in_proj"], 2, axis=-1)
    conv_state = cache["conv"] if cache else None
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    u = jax.nn.silu(u.astype(F32)).astype(x.dtype)
    proj = u @ p["x_proj"]
    dt_r, b, c = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["dt_proj"] + p["dt_bias"]).astype(F32))
    a = -jnp.exp(p["a_log"].astype(F32))
    # zero init derived from u so shard_map varying-axes match the scan body
    h0 = cache["ssm"].astype(F32) if cache \
        else jnp.zeros((B, di, st), F32) + (u[0, 0, 0] * 0).astype(F32)
    y, h = _selective_scan(u.astype(F32), dt, a, b.astype(F32), c.astype(F32),
                           p["d_skip"].astype(F32), h0)
    y = (y.astype(x.dtype) * jax.nn.silu(z.astype(F32)).astype(x.dtype))
    out = y @ p["out_proj"]
    new_cache = {"conv": new_conv, "ssm": h.astype(x.dtype)} if cache is not None \
        else None
    return out, new_cache


# --------------------------------------------------------------------------
# Mamba-2 (SSD parameterization: scalar per-head decay)
# --------------------------------------------------------------------------

def mamba2_params(cfg: ModelConfig):
    d, di, st = cfg.d_model, cfg.resolved_d_inner, cfg.ssm_state
    hd = cfg.mamba2_head_dim
    nh = di // hd
    g = cfg.mamba2_n_groups
    w = cfg.conv_width
    conv_dim = di + 2 * g * st
    return {
        "in_proj": PSpec((d, 2 * di + 2 * g * st + nh), ("fsdp", "tp")),
        "conv_w": PSpec((w, conv_dim), (None, "tp"), scale=1.0),
        "conv_b": PSpec((conv_dim,), ("tp",), scale="zero"),
        "dt_bias": PSpec((nh,), ("tp",), scale=1.0),
        "a_log": PSpec((nh,), ("tp",), scale=1.0),
        "d_skip": PSpec((nh,), ("tp",), scale=1.0),
        "norm": PSpec((di,), ("tp",), scale="zero"),
        "out_proj": PSpec((di, d), ("tp", "fsdp")),
    }


def mamba2_forward(p, x, cfg: ModelConfig, cache=None):
    """SSD recurrence h_t = exp(dt*a) h_{t-1} + dt * b_t x_t^T per head."""
    B, S, _ = x.shape
    di, st = cfg.resolved_d_inner, cfg.ssm_state
    hd, g = cfg.mamba2_head_dim, cfg.mamba2_n_groups
    nh = di // hd
    proj = x @ p["in_proj"]
    z, xbc, dt_r = jnp.split(proj, [di, 2 * di + 2 * g * st], axis=-1)
    conv_state = cache["conv"] if cache else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc.astype(F32)).astype(x.dtype)
    u, b, c = jnp.split(xbc, [di, di + g * st], axis=-1)
    u = u.reshape(B, S, nh, hd)
    b = b.reshape(B, S, g, st)
    c = c.reshape(B, S, g, st)
    rep = nh // g
    b = jnp.repeat(b, rep, axis=2)                      # (B,S,nh,st)
    c = jnp.repeat(c, rep, axis=2)
    dt = jax.nn.softplus((dt_r + p["dt_bias"]).astype(F32))   # (B,S,nh)
    a = -jnp.exp(p["a_log"].astype(F32))                      # (nh,)

    def step(h, inp):                                   # h: (B,nh,hd,st)
        u_t, b_t, c_t, dt_t = inp
        da = jnp.exp(dt_t * a)                          # (B,nh)
        h = (h * da[..., None, None]
             + (dt_t[..., None] * u_t)[..., None] * b_t[:, :, None, :])
        y = jnp.einsum("bhds,bhs->bhd", h, c_t)
        return h, y

    h0 = cache["ssm"].astype(F32) if cache \
        else jnp.zeros((B, nh, hd, st), F32) + (u[0, 0, 0, 0] * 0).astype(F32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in
               (u.astype(F32), b.astype(F32), c.astype(F32), dt))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + u.astype(F32) * p["d_skip"].astype(F32)[:, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    # gated RMSNorm (mamba2)
    yf = y.astype(F32) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["norm"].astype(F32))
    out = yf.astype(x.dtype) @ p["out_proj"]
    new_cache = {"conv": new_conv, "ssm": h.astype(x.dtype)} if cache is not None \
        else None
    return out, new_cache

"""Public model API: one call site for configs -> params/steps/specs.

Everything the launcher and dry-run need for a given (arch, shape, mesh):

  build_model(cfg)                 -> Model (init / loss / prefill / decode)
  batch_specs(cfg, shape)          -> SDS pytree for step inputs
  batch_shardings(cfg, shape, sh)  -> PartitionSpec pytree for those inputs
  cache_sds(cfg, shape)            -> SDS pytree for the decode cache
  cache_shardings(cfg, shape, sh)  -> PartitionSpec pytree for the cache
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import Shardings
from repro.models import transformer as tfm
from repro.models.params import init_params, partition_specs, sds_params

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    tree: Any                                   # PSpec tree

    def init(self, key, dtype=None):
        return init_params(self.tree, key,
                           dtype or jnp.dtype(self.cfg.dtype))

    def sds(self, dtype=None):
        return sds_params(self.tree, dtype or jnp.dtype(self.cfg.dtype))

    def pspecs(self, rules: dict):
        return partition_specs(self.tree, rules)

    def loss(self, params, batch, sh=None, **kw):
        return tfm.loss_fn(params, batch, self.cfg, sh, **kw)

    def prefill(self, params, tokens, sh=None, extras=None, **kw):
        return tfm.prefill(params, tokens, self.cfg, sh, extras, **kw)

    def decode(self, params, cache, tokens, cur_index, sh=None):
        return tfm.decode_step(params, cache, tokens, cur_index, self.cfg, sh)

    def init_cache(self, batch, seq_len, dtype=jnp.bfloat16):
        return tfm.init_cache(self.cfg, batch, seq_len, dtype)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, tree=tfm.param_tree(cfg))


def serve_rule_overrides(cfg: ModelConfig, mesh, kind: str = "decode") -> dict:
    """Serving-time sharding rules (§Perf hillclimb, EXPERIMENTS.md).

    Training shards params FSDP x TP, which forces a full parameter
    all-gather EVERY DECODED TOKEN. For serving:
      * params that fit TP-only (<= ~10 GB/chip) drop the fsdp axis
        (replicated over `data`; zero param collectives per step);
      * MoE expert stacks shard over BOTH axes when divisible (deepseek:
        256 experts / 256 chips = 1/chip) — EP across the cluster, the
        DeepSeek-style serving layout;
      * a too-big-for-TP dense model (nemotron-340b) keeps FSDP and eats
        the gather (documented trade; mitigations: pipeline or int8).
    """
    if mesh is None:
        return {}
    from repro.models.params import count_params

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get("model", 1)
    over: dict = {}
    ep_grid = sizes.get("data", 1) * tp
    # EP over both axes only helps when T <= E (decode dense-local-experts);
    # at prefill scale (T >> E) it multiplies the dispatch gathers (measured
    # 106 -> 893 GB/step on deepseek prefill — §Perf iteration log).
    ep_both = (kind == "decode" and bool(cfg.num_experts)
               and cfg.num_experts % ep_grid == 0)
    if ep_both:
        over["ep"] = ("data", "model")
    # what must fit per chip if fsdp is dropped: TP-sharded non-expert params
    # (+ expert shard, already /ep_grid when ep_both)
    total_bytes = count_params(tfm.param_tree(cfg)) * 2
    expert_bytes = 0
    if cfg.num_experts:
        wi_cols = 2 * cfg.moe_d_ff if cfg.activation == "swiglu" \
            else cfg.moe_d_ff
        per_expert = cfg.d_model * (wi_cols + cfg.moe_d_ff) * 2
        n_moe = cfg.num_layers - cfg.first_dense_layers + cfg.mtp_depth
        expert_bytes = per_expert * cfg.num_experts * n_moe
    dense_bytes = total_bytes - expert_bytes
    per_chip = dense_bytes / tp + (expert_bytes / ep_grid if ep_both
                                   else expert_bytes / tp)
    if per_chip <= 10e9:
        over["fsdp"] = None
    return over


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; weak-type-correct, no allocation)
# --------------------------------------------------------------------------

def _token_sds(cfg: ModelConfig, b: int, s: int):
    if cfg.num_codebooks:
        return jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), jnp.int32)
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        d = {"tokens": _token_sds(cfg, b, s)}
        if cfg.family == "vlm":
            d["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        return d
    # decode: one new token against a seq_len cache
    return {"tokens": _token_sds(cfg, b, 1),
            "cur_index": jax.ShapeDtypeStruct((b,), jnp.int32)}


def _dp_axis(shape: ShapeSpec, sh: Shardings):
    """Batch axis sharding — None when the batch can't cover the dp axes
    (long_500k has batch 1; its parallelism axis is the sequence)."""
    dp = sh.rules.get("dp")
    if dp is None:
        return None
    sizes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))
    need = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        need *= sizes[a]
    return dp if shape.global_batch % need == 0 else None


def _seq_axis(shape: ShapeSpec, sh: Shardings, dp):
    """Cache sequence axis: `model` normally; (data, model) when batch=1."""
    if sh.mesh is None:
        return None
    if dp is None and sh.rules.get("dp") is not None:
        # batch unshardable -> give the sequence both axes
        base = sh.rules.get("seq")
        extra = sh.rules.get("dp")
        if base is None:
            return extra
        base_t = base if isinstance(base, tuple) else (base,)
        extra_t = extra if isinstance(extra, tuple) else (extra,)
        return tuple(extra_t) + tuple(base_t)
    return sh.rules.get("seq")


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, sh: Shardings):
    dp = _dp_axis(shape, sh)
    if shape.kind in ("train", "prefill"):
        d = {"tokens": P(dp, None) if not cfg.num_codebooks
             else P(dp, None, None)}
        if cfg.family == "vlm":
            d["image_embeds"] = P(dp, None, None)
        return d
    return {"tokens": P(dp, None) if not cfg.num_codebooks
            else P(dp, None, None),
            "cur_index": P(dp)}


def cache_sds(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16):
    cache = jax.eval_shape(
        lambda: tfm.init_cache(cfg, shape.global_batch, shape.seq_len, dtype))
    return cache


def cache_shardings(cfg: ModelConfig, shape: ShapeSpec, sh: Shardings):
    """PartitionSpec tree matching init_cache's structure, by path pattern."""
    dp = _dp_axis(shape, sh)
    seq = _seq_axis(shape, sh, dp)
    tp = sh.rules.get("tp")
    cache = cache_sds(cfg, shape)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)

    def spec_for(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        nd = leaf.ndim
        grouped = "ssm_groups" in keys
        if "cross_kv" in keys:
            return P(None, dp, None, None, None)
        if keys[-1] in ("k", "v"):               # (L,B,S,K,hd)
            return P(None, dp, seq, None, None)
        if keys[-1] == "latent":                 # (L,B,S,lat)
            return P(None, dp, seq, None)
        if keys[-1] == "conv":                   # (L,B,W-1,D) | (G,g,B,W-1,D)
            return P(None, None, dp, None, tp) if grouped \
                else P(None, dp, None, tp)
        if keys[-1] == "ssm":
            if grouped:                          # (G,g,B,nh,hd,st) | (G,g,B,di,st)
                return P(*([None, None, dp, tp] + [None] * (nd - 4)))
            return P(*([None, dp, tp] + [None] * (nd - 3)))
        raise KeyError(f"unrecognized cache leaf {keys}")

    specs = [spec_for(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)

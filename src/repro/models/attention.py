"""Attention variants: GQA (+QKV-bias, +qk_norm), MLA, cross-attention.

Training/prefill use a chunked online-softmax (flash-style) causal attention
— O(chunk) score memory, scan over KV chunks with static trip count
(`unroll=True` variant exists for roofline cost units, since XLA's
cost_analysis counts scan bodies once).

Decode uses direct dot attention against the cache; the cache is sharded
along the SEQUENCE axis (DESIGN.md: flash-decoding-style partial softmax,
combined by GSPMD psums) which works for any kv-head count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rms_norm
from repro.models.params import PSpec

F32 = jnp.float32
NEG = -1e30


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------

def gqa_params(cfg: ModelConfig):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    h_ax = "tp" if H % 16 == 0 else None       # musicgen 24H: replicated attn
    k_ax = "tp" if (K % 16 == 0 and h_ax) else None
    p = {
        "wq": PSpec((d, H, hd), ("fsdp", h_ax, None)),
        "wk": PSpec((d, K, hd), ("fsdp", k_ax, None)),
        "wv": PSpec((d, K, hd), ("fsdp", k_ax, None)),
        "wo": PSpec((H, hd, d), (h_ax, None, "fsdp")),
    }
    if cfg.attn_bias:
        p["bq"] = PSpec((H, hd), (h_ax, None), scale="zero")
        p["bk"] = PSpec((K, hd), (k_ax, None), scale="zero")
        p["bv"] = PSpec((K, hd), (k_ax, None), scale="zero")
    if cfg.qk_norm:
        p["q_norm"] = PSpec((hd,), (None,), scale="zero")
        p["k_norm"] = PSpec((hd,), (None,), scale="zero")
    return p


def mla_params(cfg: ModelConfig):
    d, H = cfg.d_model, cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": PSpec((d, qr), ("fsdp", None)),
        "q_norm": PSpec((qr,), (None,), scale="zero"),
        "wq_b": PSpec((qr, H, dn + dr), (None, "tp", None)),
        "wkv_a": PSpec((d, kr + dr), ("fsdp", None)),
        "kv_norm": PSpec((kr,), (None,), scale="zero"),
        "wk_b": PSpec((kr, H, dn), (None, "tp", None)),
        "wv_b": PSpec((kr, H, dv), (None, "tp", None)),
        "wo": PSpec((H, dv, d), ("tp", None, "fsdp")),
    }


def cross_attn_params(cfg: ModelConfig):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    h_ax = "tp" if H % 16 == 0 else None
    k_ax = "tp" if (K % 16 == 0 and h_ax) else None
    return {
        "wq": PSpec((d, H, hd), ("fsdp", h_ax, None)),
        "wk": PSpec((d, K, hd), ("fsdp", k_ax, None)),
        "wv": PSpec((d, K, hd), ("fsdp", k_ax, None)),
        "wo": PSpec((H, hd, d), (h_ax, None, "fsdp")),
        "gate": PSpec((), (), scale="zero"),
    }


# --------------------------------------------------------------------------
# Core attention math
# --------------------------------------------------------------------------

def _group(q, K):
    """(B, S, H, D) -> (B, S, K, G, D)."""
    B, S, H, D = q.shape
    return q.reshape(B, S, K, H // K, D)


def chunked_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                      chunk: int = 512, unroll: bool = False):
    """Online-softmax attention. q: (B,Sq,K,G,D); k: (B,Sk,K,D);
    v: (B,Sk,K,Dv) — Dv may differ from D (MLA)."""
    B, Sq, K, G, D = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    chunk = min(chunk, Sk)
    if Sk % chunk:
        chunk = Sk  # fallback for odd smoke shapes
    nchunks = Sk // chunk
    qf = q.astype(F32) * (D ** -0.5)
    q_pos = q_offset + jnp.arange(Sq)

    kc = jnp.moveaxis(k.reshape(B, nchunks, chunk, K, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nchunks, chunk, K, Dv), 1, 0)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kb.astype(F32))
        if causal:
            col = ci * chunk + jnp.arange(chunk)
            mask = col[None, :] <= q_pos[:, None]          # (Sq, chunk)
            s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vb.astype(F32))
        return (m_new, l_new, acc_new), None

    # carry inits derive from qf/v so their varying-manual-axes (vma) match
    # the body outputs when this runs inside shard_map (consensus trainer)
    zero_q = jnp.moveaxis(qf[..., 0], 1, 3) * 0.0          # (B,K,G,Sq)
    zero_v = (v[(0,) * v.ndim] * 0.0).astype(F32)
    init = (zero_q + zero_v + NEG,
            zero_q + zero_v,
            jnp.broadcast_to((zero_q + zero_v)[..., None],
                             (B, K, G, Sq, Dv)))
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, jnp.arange(nchunks)),
                                  unroll=nchunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # (B,K,G,Sq,Dv)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, K * G, Dv)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_index):
    """q: (B,1,K,G,D); caches (B,S,K,D) seq-sharded; masked at > cur_index."""
    B, _, K, G, D = q.shape
    S = k_cache.shape[1]
    qf = q.astype(F32) * (D ** -0.5)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_cache.astype(F32))
    mask = jnp.arange(S)[None, :] <= cur_index[:, None]    # (B, S)
    s = jnp.where(mask[:, None, None, None], s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p, v_cache.astype(F32))
    out = out / jnp.maximum(jnp.sum(p, axis=-1), 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(B, 1, K * G, D).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA block forward (train/prefill + decode)
# --------------------------------------------------------------------------

def _qkv(p, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm({"scale": p["q_norm"]}, q)
        k = rms_norm({"scale": p["k_norm"]}, k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(p, x, cfg: ModelConfig, *, chunk: int = 512,
                unroll: bool = False, return_kv: bool = False):
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(p, x, cfg, positions)
    out = chunked_attention(_group(q, cfg.num_kv_heads), k, v, causal=True,
                            chunk=chunk, unroll=unroll)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return (out, (k, v)) if return_kv else out


def gqa_decode(p, x, cfg: ModelConfig, cache, cur_index):
    """x: (B,1,d); cache: dict(k=(B,S,K,D), v=...); returns (out, new_cache)."""
    B = x.shape[0]
    positions = cur_index[:, None]
    q, k, v = _qkv(p, x, cfg, positions)
    k_cache = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u.astype(c.dtype), (i, 0, 0)))(cache["k"], k, cur_index)
    v_cache = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u.astype(c.dtype), (i, 0, 0)))(cache["v"], v, cur_index)
    out = decode_attention(_group(q, cfg.num_kv_heads), k_cache, v_cache,
                           cur_index)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": k_cache, "v": v_cache}


# --------------------------------------------------------------------------
# MLA (deepseek)
# --------------------------------------------------------------------------

def _mla_qc(p, x, cfg: ModelConfig, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq_a"])
    q = rms_norm({"scale": p["q_norm"]}, q)
    q = jnp.einsum("bsq,qhk->bshk", q, p["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c, k_rope = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c = rms_norm({"scale": p["kv_norm"]}, c)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c, k_rope


def mla_forward(p, x, cfg: ModelConfig, *, chunk: int = 512,
                unroll: bool = False):
    """Training/prefill: materialize per-head k/v (standard path)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope, c, k_rope = _mla_qc(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c, p["wv_b"])
    H = cfg.num_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (*k_rope.shape[:2], H, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # MHA (K = H, G = 1); pad v head dim to match out reshape later
    out = chunked_attention(q[:, :, :, None, :], k, v, causal=True,
                            chunk=chunk, unroll=unroll)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_decode(p, x, cfg: ModelConfig, cache, cur_index):
    """Absorbed-matrices decode against the latent cache (B,S,kr+dr)."""
    positions = cur_index[:, None]
    q_nope, q_rope, c, k_rope = _mla_qc(p, x, cfg, positions)
    new_entry = jnp.concatenate([c, k_rope], axis=-1)      # (B,1,kr+dr)
    latent = jax.vmap(lambda cc, u, i: jax.lax.dynamic_update_slice(
        cc, u.astype(cc.dtype), (i, 0)))(cache["latent"], new_entry, cur_index)
    kr = cfg.kv_lora_rank
    c_cache, kr_cache = latent[..., :kr], latent[..., kr:]
    # absorb W_uk into the query:  q_lat = q_nope @ W_uk  -> (B,1,H,kr)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])
    s = (jnp.einsum("bshr,bSr->bhsS", q_lat.astype(F32), c_cache.astype(F32))
         + jnp.einsum("bshk,bSk->bhsS", q_rope.astype(F32),
                      kr_cache.astype(F32)))
    s *= (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    S = latent.shape[1]
    mask = jnp.arange(S)[None, :] <= cur_index[:, None]
    s = jnp.where(mask[:, None, None], s, NEG)
    p_attn = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhsS,bSr->bshr", p_attn, c_cache.astype(F32))
    out = jnp.einsum("bshr,rhk->bshk", out_lat.astype(x.dtype), p["wv_b"])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {"latent": latent}


# --------------------------------------------------------------------------
# Cross-attention (VLM)
# --------------------------------------------------------------------------

def cross_attn_forward(p, x, kv_src, cfg: ModelConfig):
    """x: (B,S,d) text; kv_src: (B,N,d) image embeddings. Non-causal."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bnd,dhk->bnhk", kv_src, p["wk"])
    v = jnp.einsum("bnd,dhk->bnhk", kv_src, p["wv"])
    out = chunked_attention(_group(q, cfg.num_kv_heads), k, v, causal=False,
                            chunk=k.shape[1])
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return jnp.tanh(p["gate"].astype(F32)).astype(x.dtype) * out

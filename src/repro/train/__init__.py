from repro.train.optimizer import OptConfig, OptState, init, update
from repro.train.train_loop import make_train_step, split_microbatches

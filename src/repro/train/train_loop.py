"""Training step factory: microbatched gradient accumulation (scan) + remat
+ sharded AdamW, jit'd with explicit in/out shardings.

The microbatch loop is a lax.scan with static trip count (compile-size
control; the roofline corrects its FLOPs by the trip count). Gradients
accumulate in fp32 and are sharded like the parameters.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import Shardings
from repro.models.api import Model, batch_shardings
from repro.train import optimizer as opt_mod

tmap = jax.tree_util.tree_map


def split_microbatches(batch, num_microbatches: int):
    """(B, ...) -> (mb, B/mb, ...) for every leaf."""
    def f(x):
        b = x.shape[0]
        assert b % num_microbatches == 0, (b, num_microbatches)
        return x.reshape((num_microbatches, b // num_microbatches)
                         + x.shape[1:])
    return tmap(f, batch)


def make_train_step(model: Model, shape: ShapeSpec, sh: Shardings,
                    opt_cfg: opt_mod.OptConfig | None = None,
                    *, unroll: bool = False, donate: bool = True):
    """Returns (train_step, in_shardings, out_shardings) — jit-ready.

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    cfg = model.cfg
    opt_cfg = opt_cfg or opt_mod.OptConfig(state_dtype=cfg.opt_state_dtype)
    mb = cfg.microbatches_train
    rules = sh.rules

    def loss_microbatch(params, microbatch):
        return model.loss(params, microbatch, sh, unroll=unroll)

    def train_step(params, opt_state, batch):
        batches = split_microbatches(batch, mb)
        grad_fn = jax.value_and_grad(loss_microbatch)

        def accum(carry, microbatch):
            loss_acc, grads_acc = carry
            loss, grads = grad_fn(params, microbatch)
            grads = tmap(lambda a, g: a + g.astype(jnp.float32),
                         grads_acc, grads)
            if sh.mesh is not None:
                pspecs = model.pspecs(rules)
                grads = tmap(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, jax.sharding.NamedSharding(sh.mesh, s)),
                    grads, pspecs)
            return (loss_acc + loss, grads), None

        zeros = tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            accum, (jnp.zeros((), jnp.float32), zeros), batches)
        grads = tmap(lambda g: g / mb, grads)
        new_params, new_opt, metrics = opt_mod.update(grads, opt_state,
                                                      params, opt_cfg)
        metrics = dict(metrics, loss=loss_sum / mb)
        return new_params, new_opt, metrics

    if sh.mesh is None:
        return jax.jit(train_step, donate_argnums=(0, 1) if donate else ()), \
            None, None

    pspecs = model.pspecs(rules)
    named = lambda spec_tree: tmap(
        lambda s: jax.sharding.NamedSharding(sh.mesh, s), spec_tree)
    param_sh = named(pspecs)
    opt_sh = opt_mod.OptState(
        step=jax.sharding.NamedSharding(sh.mesh, jax.sharding.PartitionSpec()),
        m=named(pspecs), v=named(pspecs) if opt_cfg.name != "sgd" else ())
    batch_sh = named(batch_shardings(cfg, shape, sh))
    repl = jax.sharding.NamedSharding(sh.mesh, jax.sharding.PartitionSpec())
    metrics_sh = {"grad_norm": repl, "lr": repl, "loss": repl}
    in_sh = (param_sh, opt_sh, batch_sh)
    out_sh = (param_sh, opt_sh, metrics_sh)
    step = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 1) if donate else ())
    return step, in_sh, out_sh

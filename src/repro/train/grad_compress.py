"""Gradient compression with error feedback (beyond-paper, for the slow
`pod` axis / DCN links at 1000+ nodes).

Block-wise int8 quantization: each contiguous block of `block` values shares
one fp32 scale (max-abs), i.e. ~8.13 bits/value on the wire vs 32 for the
fp32 gradient accumulators — a ~3.9x wire reduction on the gradient
all-reduce when applied inside a shard_map'd reduce (see
repro.distributed.collectives.psum_compressed). Error feedback
keeps the quantization residual locally and re-injects it next step, which
preserves convergence (Karimireddy et al. 2019).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


class EFState(NamedTuple):
    residual: Any           # same structure as grads, fp32


def init_error_feedback(grads_like) -> EFState:
    return EFState(residual=tmap(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize_int8(x: jax.Array, block: int = 256):
    """x (flat fp32) -> (int8 codes, fp32 scales per block, pad)."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), pad


def dequantize_int8(q, scale, pad, n):
    x = (q.astype(jnp.float32) * scale).reshape(-1)
    return x[:n] if pad else x.reshape(-1)[:n]


def compress_tree(grads, ef: EFState, block: int = 256):
    """Returns (quantized pytree of (q, scale, meta), new EFState)."""
    def one(g, r):
        x = g.astype(jnp.float32).reshape(-1) + r.reshape(-1)
        q, s, pad = quantize_int8(x, block)
        deq = dequantize_int8(q, s, pad, x.shape[0])
        new_r = (x - deq).reshape(g.shape)
        return (q, s), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(ef.residual)
    qs, rs = [], []
    for g, r in zip(flat_g, flat_r):
        (q, s), nr = one(g, r)
        qs.append((q, s))
        rs.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, qs),
            EFState(residual=jax.tree_util.tree_unflatten(treedef, rs)))


def decompress_tree(qtree, shapes_like):
    flat_q, treedef = jax.tree_util.tree_flatten(
        qtree, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and hasattr(x[0], "dtype"))
    flat_s = jax.tree_util.tree_leaves(shapes_like)
    out = []
    for (q, s), like in zip(flat_q, flat_s):
        n = like.size
        pad = q.size - n
        out.append(dequantize_int8(q, s, pad, n).reshape(like.shape))
    return jax.tree_util.tree_unflatten(treedef, out)

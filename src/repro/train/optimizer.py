"""Sharded AdamW (+SGD) with dtype-configurable moments.

Moments inherit the parameter sharding (FSDP x TP) — the optimizer is fully
sharded state, ZeRO-style. ``state_dtype="bfloat16"`` halves optimizer HBM
(used by the 340B/671B configs to fit a single 16-GB/chip pod; fp32 is the
default elsewhere).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params, cfg: OptConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    if cfg.name == "sgd":
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=tmap(zeros, params), v=())
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=tmap(zeros, params), v=tmap(zeros, params))


def _schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    sq = tmap(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq))


def update(grads, state: OptState, params, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = _schedule(step, cfg)
    dt = jnp.dtype(cfg.state_dtype)

    if cfg.name == "sgd":
        def upd(p, g, m):
            g32 = g.astype(jnp.float32) * scale
            m32 = 0.9 * m.astype(jnp.float32) + g32
            newp = p.astype(jnp.float32) - lr * m32
            return newp.astype(p.dtype), m32.astype(dt)

        out = tmap(upd, params, grads, state.m)
        new_params = tmap(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = tmap(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step, new_m, ()), {"grad_norm": gnorm,
                                                       "lr": lr}

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        den = jnp.sqrt(v32 / bc2) + cfg.eps
        step_ = (m32 / bc1) / den + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step_
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = tmap(upd, params, grads, state.m, state.v)
    is3 = lambda x: isinstance(x, tuple)
    new_params = tmap(lambda o: o[0], out, is_leaf=is3)
    new_m = tmap(lambda o: o[1], out, is_leaf=is3)
    new_v = tmap(lambda o: o[2], out, is_leaf=is3)
    return new_params, OptState(step, new_m, new_v), {"grad_norm": gnorm,
                                                      "lr": lr}

"""One-shot deprecation warnings for the pre-facade entry points.

The repo grew five hand-wired solver entry points before the declarative
``Problem -> plan -> Result`` facade (repro.api / repro.plan) existed.  The
low-level drivers stay as the kernel layer the plans compile to; the *old
signatures* that callers used to wire by hand (``dense_ops``, ``ell_ops``,
``solve_distributed``, ``serve.Engine``) are kept working as thin shims that
emit a single ``DeprecationWarning`` per process pointing at the facade.
"""
from __future__ import annotations

import warnings

_SEEN: set[str] = set()


def warn_once(old: str, new: str) -> None:
    """Emit one DeprecationWarning per process for ``old`` (repeat calls are
    silent), pointing callers at the facade replacement ``new``."""
    if old in _SEEN:
        return
    _SEEN.add(old)
    warnings.warn(
        f"{old} is deprecated; use {new} — see the Problem -> plan -> Result "
        "facade in repro.api",
        DeprecationWarning, stacklevel=3)


def reset() -> None:
    """Clear the emitted-warning registry (tests only)."""
    _SEEN.clear()

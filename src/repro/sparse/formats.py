"""Sparse matrix formats, TPU-adapted.

The paper streams (i, j, a_ij) text tuples through HDFS; a TPU wants dense,
aligned tiles. We provide:

  * ``COO``        — host/construction format (also the jnp oracle format).
  * ``ELL``        — padded fixed-width rows: ``vals (m, k)``, ``cols (m, k)``.
                     Regular tiling; padding entries have col=0, val=0 so they
                     contribute nothing. The forward operator's format.
  * ``BandedELL``  — column-major ELL with rows bucketed into bands so the
                     needed slice of ``y`` fits VMEM during ``A^T y``:
                     ``vals (B, n, kb)``, ``rows (B, n, kb)`` (row indices are
                     band-local). The backward operator's kernel format.
  * ``BCSR``       — block-compressed-sparse-row with dense ``(bm, bn)`` tiles
                     padded to a fixed number of tiles per block-row (an
                     ELL-of-blocks): ``vals (nbr, kb, bm, bn)``,
                     ``bcols (nbr, kb)``. Each tile is a dense matrix, so the
                     spmv contracts tiles with ``dot_general`` on the MXU
                     instead of VPU gathers — the format of choice when
                     nonzeros cluster (see repro.operators.select).
  * ``StackedELL`` / ``StackedBCSR`` — B independent same-shape matrices with
                     a leading batch axis (``vals (B, m, k)`` etc.), the
                     storage of the batched solver serving engine
                     (repro.serve.solver_engine): problems bucketed to a
                     common padded shape stack into one array so a single
                     vmapped/batch-grid kernel serves the whole bucket.

All formats are registered pytrees: they pass through jit/shard_map/lower and
can be built from ``jax.ShapeDtypeStruct`` leaves for allocation-free dry-runs.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.tree_util.register_dataclass, data_fields=["rows", "cols", "vals"],
         meta_fields=["m", "n"])
@dataclasses.dataclass
class COO:
    rows: jax.Array      # (nnz,) int32
    cols: jax.Array      # (nnz,) int32
    vals: jax.Array      # (nnz,) float
    m: int
    n: int

    @property
    def nnz(self) -> int:
        return self.vals.shape[0]


@partial(jax.tree_util.register_dataclass, data_fields=["vals", "cols"],
         meta_fields=["n"])
@dataclasses.dataclass
class ELL:
    """Row-major padded sparse. vals/cols: (m, k)."""

    vals: jax.Array
    cols: jax.Array
    n: int

    @property
    def m(self) -> int:
        return self.vals.shape[0]

    @property
    def k(self) -> int:
        return self.vals.shape[1]


@partial(jax.tree_util.register_dataclass, data_fields=["vals", "rows"],
         meta_fields=["m", "band_size"])
@dataclasses.dataclass
class BandedELL:
    """Column-major padded sparse, rows bucketed into bands of ``band_size``.

    vals/rows: (num_bands, n, kb); ``rows`` are band-local indices.
    """

    vals: jax.Array
    rows: jax.Array
    m: int
    band_size: int

    @property
    def num_bands(self) -> int:
        return self.vals.shape[0]

    @property
    def n(self) -> int:
        return self.vals.shape[1]

    @property
    def kb(self) -> int:
        return self.vals.shape[2]


@partial(jax.tree_util.register_dataclass, data_fields=["vals", "bcols"],
         meta_fields=["m", "n"])
@dataclasses.dataclass
class BCSR:
    """Tiled block-sparse rows, padded ELL-of-blocks layout.

    vals:  (nbr, kb, bm, bn)  dense tiles (padding tiles are all-zero)
    bcols: (nbr, kb)          block-column index of each tile (padding: 0)
    m, n:  logical (unpadded) matrix shape; rows/cols beyond m/n inside the
           edge tiles are zero-padded and contribute nothing.
    """

    vals: jax.Array
    bcols: jax.Array
    m: int
    n: int

    @property
    def nbr(self) -> int:
        return self.vals.shape[0]

    @property
    def kb(self) -> int:
        return self.vals.shape[1]

    @property
    def bm(self) -> int:
        return self.vals.shape[2]

    @property
    def bn(self) -> int:
        return self.vals.shape[3]

    @property
    def nbc(self) -> int:
        return -(-self.n // self.bn)

    @property
    def nnz_blocks(self) -> int:
        return self.nbr * self.kb


@partial(jax.tree_util.register_dataclass, data_fields=["vals", "cols"],
         meta_fields=["n"])
@dataclasses.dataclass
class StackedELL:
    """B independent row-ELL matrices of identical padded shape.

    vals/cols: (B, m, k). All matrices share the logical column count ``n``
    (smaller problems are zero-padded: extra entries have col=0, val=0 and
    contribute nothing, exactly like single-ELL padding).
    """

    vals: jax.Array
    cols: jax.Array
    n: int

    @property
    def batch(self) -> int:
        return self.vals.shape[0]

    @property
    def m(self) -> int:
        return self.vals.shape[1]

    @property
    def k(self) -> int:
        return self.vals.shape[2]


@partial(jax.tree_util.register_dataclass, data_fields=["vals", "bcols"],
         meta_fields=["m", "n"])
@dataclasses.dataclass
class StackedBCSR:
    """B independent tiled-BCSR matrices of identical padded shape.

    vals: (B, nbr, kb, bm, bn);  bcols: (B, nbr, kb).
    """

    vals: jax.Array
    bcols: jax.Array
    m: int
    n: int

    @property
    def batch(self) -> int:
        return self.vals.shape[0]

    @property
    def nbr(self) -> int:
        return self.vals.shape[1]

    @property
    def kb(self) -> int:
        return self.vals.shape[2]

    @property
    def bm(self) -> int:
        return self.vals.shape[3]

    @property
    def bn(self) -> int:
        return self.vals.shape[4]

    @property
    def nbc(self) -> int:
        return -(-self.n // self.bn)


@partial(jax.tree_util.register_dataclass, data_fields=["vals", "rows"],
         meta_fields=["m"])
@dataclasses.dataclass
class CSC:
    """Column-major padded sparse: one fixed-width row per COLUMN.

    vals/rows: (n, k) — entry ``(j, s)`` is the s-th stored nonzero of
    column j, ``rows[j, s]`` its row index (padding: row=0, val=0).
    Structurally this is ``ELL(A^T)``; it exists as its own type because
    the coordinate-descent solver family (repro.solvers.rcd) indexes
    OPERAND COLUMNS — one dynamic-slice gather per picked coordinate —
    which the row-major ELL layout cannot serve contiguously.
    """

    vals: jax.Array
    rows: jax.Array
    m: int               # logical row count of A

    @property
    def n(self) -> int:
        return self.vals.shape[0]

    @property
    def k(self) -> int:
        return self.vals.shape[1]


@partial(jax.tree_util.register_dataclass, data_fields=["vals", "rows"],
         meta_fields=["m"])
@dataclasses.dataclass
class StackedCSC:
    """B independent CSC matrices of identical padded shape.

    vals/rows: (B, n, k); all matrices share the logical row count ``m``
    (padding entries have row=0, val=0 and contribute nothing).
    """

    vals: jax.Array
    rows: jax.Array
    m: int

    @property
    def batch(self) -> int:
        return self.vals.shape[0]

    @property
    def n(self) -> int:
        return self.vals.shape[1]

    @property
    def k(self) -> int:
        return self.vals.shape[2]


def stack_cscs(cscs: list[CSC], m: int | None = None) -> StackedCSC:
    """Stack same-shape CSC matrices along a new leading batch axis."""
    shapes = {tuple(c.vals.shape) for c in cscs}
    if len(shapes) != 1:
        raise ValueError(f"cannot stack ragged CSC shapes {sorted(shapes)}; "
                         "pad to a common (n, k) first")
    m = m if m is not None else max(c.m for c in cscs)
    return StackedCSC(vals=jnp.stack([c.vals for c in cscs]),
                      rows=jnp.stack([c.rows for c in cscs]), m=m)


def stack_ells(ells: list[ELL], n: int | None = None) -> StackedELL:
    """Stack same-shape ELL matrices along a new leading batch axis."""
    shapes = {tuple(e.vals.shape) for e in ells}
    if len(shapes) != 1:
        raise ValueError(f"cannot stack ragged ELL shapes {sorted(shapes)}; "
                         "pad to a common (m, k) first")
    n = n if n is not None else max(e.n for e in ells)
    return StackedELL(vals=jnp.stack([e.vals for e in ells]),
                      cols=jnp.stack([e.cols for e in ells]), n=n)


def stack_bcsrs(bcsrs: list[BCSR], m: int | None = None,
                n: int | None = None) -> StackedBCSR:
    """Stack same-shape BCSR matrices along a new leading batch axis."""
    shapes = {tuple(b.vals.shape) for b in bcsrs}
    if len(shapes) != 1:
        raise ValueError(f"cannot stack ragged BCSR shapes {sorted(shapes)}; "
                         "pad to a common (nbr, kb, bm, bn) first")
    m = m if m is not None else max(b.m for b in bcsrs)
    n = n if n is not None else max(b.n for b in bcsrs)
    return StackedBCSR(vals=jnp.stack([b.vals for b in bcsrs]),
                       bcols=jnp.stack([b.bcols for b in bcsrs]), m=m, n=n)


# --------------------------------------------------------------------------
# Host-side conversions (numpy; construction path, not jit code)
# --------------------------------------------------------------------------

def coo_to_dense(a: COO) -> np.ndarray:
    out = np.zeros((a.m, a.n), dtype=np.asarray(a.vals).dtype)
    np.add.at(out, (np.asarray(a.rows), np.asarray(a.cols)), np.asarray(a.vals))
    return out


def ell_to_dense(a: ELL) -> np.ndarray:
    out = np.zeros((a.m, a.n), dtype=np.asarray(a.vals).dtype)
    rows = np.repeat(np.arange(a.m), a.k)
    np.add.at(out, (rows, np.asarray(a.cols).reshape(-1)),
              np.asarray(a.vals).reshape(-1))
    return out


def banded_to_dense(a: BandedELL) -> np.ndarray:
    out = np.zeros((a.m, a.n), dtype=np.asarray(a.vals).dtype)
    vals = np.asarray(a.vals)
    rows = np.asarray(a.rows)
    for b in range(a.num_bands):
        cols = np.repeat(np.arange(a.n), a.kb)
        r = rows[b].reshape(-1) + b * a.band_size
        r = np.minimum(r, a.m - 1)  # padding rows are (0-val) anyway
        np.add.at(out, (r, cols), vals[b].reshape(-1))
    return out


def coo_to_ell(a: COO, k: int | None = None, pad_to: int = 1) -> ELL:
    """Pad each row to the max row-nnz (or given k), k rounded up to pad_to."""
    rows = np.asarray(a.rows)
    cols = np.asarray(a.cols)
    vals = np.asarray(a.vals)
    counts = np.bincount(rows, minlength=a.m)
    kmax = int(counts.max()) if counts.size else 0
    k = max(k or 0, kmax)
    k = max(1, -(-k // pad_to) * pad_to)
    order = np.argsort(rows, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    # slot within row: position - row_start
    row_start = np.zeros(a.m, dtype=np.int64)
    np.cumsum(counts[:-1], out=row_start[1:])
    slot = np.arange(len(rows)) - row_start[rows]
    ev = np.zeros((a.m, k), dtype=vals.dtype)
    ec = np.zeros((a.m, k), dtype=np.int32)
    ev[rows, slot] = vals
    ec[rows, slot] = cols
    return ELL(vals=jnp.asarray(ev), cols=jnp.asarray(ec), n=a.n)


def coo_to_csc(a: COO, k: int | None = None, pad_to: int = 1) -> CSC:
    """Pad each COLUMN to the max column-nnz (or given k).  Implemented as
    ``coo_to_ell`` on the transpose, rewrapped — a CSC of A and an ELL of
    A^T are the same arrays under different index names."""
    e = coo_to_ell(transpose_coo(a), k=k, pad_to=pad_to)
    return CSC(vals=e.vals, rows=e.cols, m=a.m)


def csc_to_dense(a: CSC) -> np.ndarray:
    out = np.zeros((a.m, a.n), dtype=np.asarray(a.vals).dtype)
    cols = np.repeat(np.arange(a.n), a.k)
    np.add.at(out, (np.asarray(a.rows).reshape(-1), cols),
              np.asarray(a.vals).reshape(-1))
    return out


def transpose_coo(a: COO) -> COO:
    return COO(rows=a.cols, cols=a.rows, vals=a.vals, m=a.n, n=a.m)


def pad_coo(a: COO, m: int, n: int) -> COO:
    """Embed A in the top-left of an (m, n) zero matrix (bucket padding).

    Padded rows are all-zero (their dual coordinate stays 0 when b=0 there);
    padded columns are all-zero (their primal coordinate stays at the prox
    center) — so padding does not perturb the solver iterates.
    """
    if m < a.m or n < a.n:
        raise ValueError(f"pad target ({m}, {n}) smaller than ({a.m}, {a.n})")
    return COO(rows=a.rows, cols=a.cols, vals=a.vals, m=m, n=n)


def coo_to_banded(a: COO, band_size: int, kb: int | None = None,
                  pad_to: int = 1) -> BandedELL:
    """Column-major banded ELL: bucket nonzeros by (row // band_size), pad the
    per-(band, column) lists to the max count."""
    rows = np.asarray(a.rows)
    cols = np.asarray(a.cols)
    vals = np.asarray(a.vals)
    num_bands = -(-a.m // band_size)
    band = rows // band_size
    local = rows - band * band_size
    # counts per (band, col)
    key = band.astype(np.int64) * a.n + cols
    order = np.argsort(key, kind="stable")
    key, local, vals = key[order], local[order], vals[order]
    counts = np.bincount(key, minlength=num_bands * a.n)
    kmax = int(counts.max()) if counts.size else 0
    kb = max(kb or 0, kmax)
    kb = max(1, -(-kb // pad_to) * pad_to)
    start = np.zeros(num_bands * a.n, dtype=np.int64)
    np.cumsum(counts[:-1], out=start[1:])
    slot = np.arange(len(key)) - start[key]
    ev = np.zeros((num_bands * a.n, kb), dtype=vals.dtype)
    er = np.zeros((num_bands * a.n, kb), dtype=np.int32)
    ev[key, slot] = vals
    er[key, slot] = local
    return BandedELL(
        vals=jnp.asarray(ev.reshape(num_bands, a.n, kb)),
        rows=jnp.asarray(er.reshape(num_bands, a.n, kb)),
        m=a.m, band_size=band_size)


def coo_bcsr_width(a: COO, bm: int = 8, bn: int = 128) -> int:
    """The natural kb ``coo_to_bcsr(a, bm, bn)`` would produce — max count
    of nonzero (bm, bn) tiles over block-rows — without materializing any
    tiles.  Used for bucket sizing before the real conversion."""
    rows = np.asarray(a.rows)
    cols = np.asarray(a.cols)
    if rows.size == 0:
        return 1
    nbr = max(1, -(-a.m // bm))
    nbc = max(1, -(-a.n // bn))
    uniq = np.unique((rows // bm).astype(np.int64) * nbc + cols // bn)
    counts = np.bincount((uniq // nbc).astype(np.int64), minlength=nbr)
    return max(1, int(counts.max()))


def coo_to_bcsr(a: COO, bm: int = 8, bn: int = 128, kb: int | None = None,
                pad_to: int = 1) -> BCSR:
    """Tile the matrix into dense (bm, bn) blocks; keep only nonzero blocks,
    padded per block-row to the max block count (ELL-of-blocks).

    Duplicate (i, j) entries accumulate, matching ``coo_to_dense``.
    """
    rows = np.asarray(a.rows)
    cols = np.asarray(a.cols)
    vals = np.asarray(a.vals)
    nbr = max(1, -(-a.m // bm))
    nbc = max(1, -(-a.n // bn))
    bi = rows // bm
    bj = cols // bn
    block_key = bi.astype(np.int64) * nbc + bj
    uniq = np.unique(block_key) if block_key.size else np.zeros(0, np.int64)
    ubi = (uniq // nbc).astype(np.int64)
    ubj = (uniq % nbc).astype(np.int64)
    counts = np.bincount(ubi, minlength=nbr)
    kmax = int(counts.max()) if counts.size else 0
    kb = max(kb or 0, kmax)
    kb = max(1, -(-kb // pad_to) * pad_to)
    start = np.zeros(nbr, dtype=np.int64)
    np.cumsum(counts[:-1], out=start[1:])
    slot_of_uniq = np.arange(len(uniq)) - start[ubi]
    ev = np.zeros((nbr, kb, bm, bn), dtype=vals.dtype)
    ec = np.zeros((nbr, kb), dtype=np.int32)
    ec[ubi, slot_of_uniq] = ubj.astype(np.int32)
    if block_key.size:
        slot = slot_of_uniq[np.searchsorted(uniq, block_key)]
        np.add.at(ev, (bi, slot, rows - bi * bm, cols - bj * bn), vals)
    return BCSR(vals=jnp.asarray(ev), bcols=jnp.asarray(ec), m=a.m, n=a.n)


def bcsr_to_dense(a: BCSR) -> np.ndarray:
    vals = np.asarray(a.vals)
    bcols = np.asarray(a.bcols)
    m_pad, n_pad = a.nbr * a.bm, a.nbc * a.bn
    out = np.zeros((m_pad, n_pad), dtype=vals.dtype)
    for i in range(a.nbr):
        for s in range(a.kb):
            j = int(bcols[i, s])
            out[i * a.bm:(i + 1) * a.bm, j * a.bn:(j + 1) * a.bn] += vals[i, s]
    return out[:a.m, :a.n]


def dense_to_coo(d: np.ndarray) -> COO:
    r, c = np.nonzero(d)
    return COO(rows=jnp.asarray(r, jnp.int32), cols=jnp.asarray(c, jnp.int32),
               vals=jnp.asarray(d[r, c]), m=d.shape[0], n=d.shape[1])


def ell_to_coo(a: ELL) -> COO:
    """Drop ELL padding back to coordinates — O(stored entries), never
    densifies (the facade's conversion path for ELL-held Problems).
    Explicitly-stored zeros are dropped (they contribute nothing)."""
    vals = np.asarray(a.vals)
    cols = np.asarray(a.cols)
    rows = np.broadcast_to(np.arange(a.m, dtype=np.int32)[:, None],
                           vals.shape)
    keep = vals != 0
    return COO(rows=jnp.asarray(rows[keep], jnp.int32),
               cols=jnp.asarray(cols[keep], jnp.int32),
               vals=jnp.asarray(vals[keep]), m=a.m, n=a.n)


def bcsr_to_coo(a: BCSR) -> COO:
    """Expand BCSR tiles back to coordinates — O(stored tile entries),
    never densifies.  Zero fill inside tiles (and padding tiles) is
    dropped; edge-tile rows/cols beyond (m, n) are all-zero by
    construction, so filtering zeros also trims them."""
    vals = np.asarray(a.vals)                         # (nbr, kb, bm, bn)
    bcols = np.asarray(a.bcols)
    rows = np.broadcast_to(
        np.arange(a.nbr, dtype=np.int32)[:, None, None, None] * a.bm
        + np.arange(a.bm, dtype=np.int32)[None, None, :, None], vals.shape)
    cols = np.broadcast_to(
        (bcols.astype(np.int32) * a.bn)[:, :, None, None]
        + np.arange(a.bn, dtype=np.int32)[None, None, None, :], vals.shape)
    keep = vals != 0
    return COO(rows=jnp.asarray(rows[keep], jnp.int32),
               cols=jnp.asarray(cols[keep], jnp.int32),
               vals=jnp.asarray(vals[keep]), m=a.m, n=a.n)


# --------------------------------------------------------------------------
# Dry-run stand-ins (ShapeDtypeStruct leaves; no allocation)
# --------------------------------------------------------------------------

def ell_spec(m: int, n: int, k: int, dtype=jnp.float32) -> ELL:
    return ELL(vals=jax.ShapeDtypeStruct((m, k), dtype),
               cols=jax.ShapeDtypeStruct((m, k), jnp.int32), n=n)


def banded_spec(m: int, n: int, band_size: int, kb: int,
                dtype=jnp.float32) -> BandedELL:
    bands = -(-m // band_size)
    return BandedELL(vals=jax.ShapeDtypeStruct((bands, n, kb), dtype),
                     rows=jax.ShapeDtypeStruct((bands, n, kb), jnp.int32),
                     m=m, band_size=band_size)


def bcsr_spec(m: int, n: int, bm: int, bn: int, kb: int,
              dtype=jnp.float32) -> BCSR:
    nbr = max(1, -(-m // bm))
    return BCSR(vals=jax.ShapeDtypeStruct((nbr, kb, bm, bn), dtype),
                bcols=jax.ShapeDtypeStruct((nbr, kb), jnp.int32), m=m, n=n)

"""jnp sparse linear algebra (the non-Pallas reference path).

These are the operators the solver uses when ``use_kernels=False`` (and the
oracles the Pallas kernels are tested against live in ``repro.kernels.ref``,
which calls into here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.formats import (
    BCSR, COO, CSC, ELL, BandedELL, StackedBCSR, StackedCSC, StackedELL,
)


def ell_matvec(a: ELL, x: jax.Array) -> jax.Array:
    """y = A @ x, A in row-ELL. Padding entries (val=0) contribute nothing."""
    gathered = jnp.take(x, a.cols, axis=0)            # (m, k)
    return jnp.sum(a.vals * gathered, axis=1)


def ell_rmatvec(at: ELL, y: jax.Array) -> jax.Array:
    """z = A^T y given the ELL of A^T (n rows of A^T indexed by columns of A)."""
    return ell_matvec(at, y)


def banded_rmatvec(a: BandedELL, y: jax.Array) -> jax.Array:
    """z = A^T y, A stored column-major in row bands.

    y is split per band; each band gathers only its local slice — the VMEM
    locality structure the Pallas kernel exploits.
    """
    pad = a.num_bands * a.band_size - y.shape[0]
    ypad = jnp.pad(y, (0, pad)) if pad else y
    ybands = ypad.reshape(a.num_bands, a.band_size)

    def band_contrib(vals_b, rows_b, y_b):
        return jnp.sum(vals_b * jnp.take(y_b, rows_b, axis=0), axis=1)

    contribs = jax.vmap(band_contrib)(a.vals, a.rows, ybands)  # (B, n)
    return jnp.sum(contribs, axis=0)


def bcsr_matvec(a: BCSR, x: jax.Array) -> jax.Array:
    """y = A @ x, A in tiled BCSR. Tiles are dense, so the contraction is a
    batched (bm, bn) @ (bn,) — MXU-shaped work; this jnp path is the oracle
    the Pallas kernel (repro.kernels.bcsr_spmv) is tested against."""
    pad = a.nbc * a.bn - x.shape[0]
    xt = (jnp.pad(x, (0, pad)) if pad else x).reshape(a.nbc, a.bn)
    g = jnp.take(xt, a.bcols, axis=0)                 # (nbr, kb, bn)
    y = jax.lax.dot_general(
        a.vals.astype(jnp.float32), g.astype(jnp.float32),
        dimension_numbers=(((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)           # (nbr, kb, bm)
    return jnp.sum(y, axis=1).reshape(-1)[:a.m].astype(x.dtype)


def bcsr_rmatvec(at: BCSR, y: jax.Array) -> jax.Array:
    """z = A^T y given the BCSR of A^T (the dual-copy trade: store both
    orientations so the backward pass is also gather+dot, never scatter)."""
    return bcsr_matvec(at, y)


def stacked_ell_matvec(a: StackedELL, x: jax.Array) -> jax.Array:
    """y = A_b @ x_b per batch slot: (B, n) -> (B, m), B independent matrices.

    The jnp reference for the batched serving path (and the oracle the
    batch-grid Pallas kernel is tested against).  The batch gather is
    flattened — slot offsets baked into the indices so XLA sees ONE flat
    gather instead of a batched one (measurably faster on CPU than the
    vmap-of-take lowering)."""
    bsz, n = x.shape
    off = (jnp.arange(bsz, dtype=a.cols.dtype) * n)[:, None, None]
    gathered = jnp.take(x.reshape(-1), a.cols + off, axis=0)   # (B, m, k)
    return jnp.sum(a.vals * gathered, axis=2)


def stacked_bcsr_matvec(a: StackedBCSR, x: jax.Array) -> jax.Array:
    """y = A_b @ x_b per batch slot over stacked tiled-BCSR: (B, n) -> (B, m)."""
    def one(vals, bcols, xb):
        return bcsr_matvec(BCSR(vals=vals, bcols=bcols, m=a.m, n=a.n), xb)

    return jax.vmap(one)(a.vals, a.bcols, x)


def csc_gather_matvec(c: CSC, v: jax.Array) -> jax.Array:
    """z = A^T v from the CSC of A — the flat-gather column matvec.

    Row j of the CSC holds column j of A, so gathering ``v`` at the stored
    row indices and reducing along the width computes ``(A^T v)_j``:
    identical arithmetic to ``ell_matvec`` on the transpose view.  The
    same function applied to ``CSC(A^T)`` computes ``A x`` — the
    ("csc", backend) operators pair both orientations exactly like the
    ELL operators do."""
    gathered = jnp.take(v, c.rows, axis=0)            # (n, k)
    return jnp.sum(c.vals * gathered, axis=1)


def stacked_csc_gather_matvec(c: StackedCSC, v: jax.Array) -> jax.Array:
    """Per-slot ``csc_gather_matvec``: (B, m) -> (B, n), slot offsets baked
    into the indices so XLA sees one flat gather (same trick as
    ``stacked_ell_matvec``)."""
    bsz, mlen = v.shape
    off = (jnp.arange(bsz, dtype=c.rows.dtype) * mlen)[:, None, None]
    gathered = jnp.take(v.reshape(-1), c.rows + off, axis=0)   # (B, n, k)
    return jnp.sum(c.vals * gathered, axis=2)


def coo_matvec(a: COO, x: jax.Array) -> jax.Array:
    return jax.ops.segment_sum(a.vals * x[a.cols], a.rows, num_segments=a.m)


def coo_rmatvec(a: COO, y: jax.Array) -> jax.Array:
    return jax.ops.segment_sum(a.vals * y[a.rows], a.cols, num_segments=a.n)


def col_norms_sq(a: COO) -> jax.Array:
    """L_g_i = ||A_i||^2 per column (paper init step 1)."""
    return jax.ops.segment_sum(a.vals * a.vals, a.cols, num_segments=a.n)


def ell_col_norms_sq(at: ELL) -> jax.Array:
    """Per-column ||A_i||^2 from the transpose-ELL (each row of A^T is a column
    of A) — local, no comm; the paper computes this with MapReduce counters."""
    return jnp.sum(at.vals * at.vals, axis=1)

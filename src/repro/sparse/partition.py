"""Partitioners: build the sharded operand layouts for each strategy.

The paper partitions A by HDFS chunks and re-keys in the shuffle; here the
partitioning is *explicit and static*: row blocks, column blocks, or a 2-D
block grid matching the device mesh. All builders return **global** arrays
whose leading dims are divisible by the mesh axes; sharding is applied by
`shard_map` in_specs / NamedSharding at the call site.

Padding is harmless for the solver: padded rows of A are all-zero with b=0
(their dual coordinate stays 0); padded columns are all-zero with l1 prox at
a zero center (their primal coordinate stays 0).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.sparse.formats import (
    COO, ELL, coo_bcsr_width, coo_to_bcsr, coo_to_ell, transpose_coo,
)


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def pad_vector(v, size: int):
    pad = size - v.shape[0]
    return jnp.pad(v, (0, pad)) if pad else v


def row_partitioned_ell(a: COO, parts: int, pad_to: int = 8) -> ELL:
    """ELL of A with m padded to a multiple of ``parts`` (row-shard dim 0)."""
    m_pad = _ceil_to(a.m, parts)
    padded = COO(rows=a.rows, cols=a.cols, vals=a.vals, m=m_pad, n=a.n)
    return coo_to_ell(padded, pad_to=pad_to)


def col_partitioned_ell(a: COO, parts: int, pad_to: int = 8) -> ELL:
    """ELL of A^T with n padded to a multiple of ``parts`` (col-shard dim 0)."""
    at = transpose_coo(a)
    m_pad = _ceil_to(at.m, parts)
    padded = COO(rows=at.rows, cols=at.cols, vals=at.vals, m=m_pad, n=at.n)
    return coo_to_ell(padded, pad_to=pad_to)


def block_partitioned_ell(a: COO, grid_rows: int, grid_cols: int,
                          pad_to: int = 8, k: int | None = None):
    """2-D block grid: returns (vals, cols) of shape (R, C, mb, k) with
    block-local column indices, plus (m_pad, n_pad).

    Device (i, j) of a (data=R, model=C) mesh owns block (i, j) — the
    scalable generalization of the paper's row/col RDD caches.  ``k``
    fixes the shared pad width (callers stacking several matrices to one
    bucket shape pass the bucket maximum); by default it is the data's
    own max per-(block, row) count rounded to ``pad_to``.
    """
    R, C = grid_rows, grid_cols
    m_pad, n_pad = _ceil_to(a.m, R), _ceil_to(a.n, C)
    mb, nb = m_pad // R, n_pad // C
    rows = np.asarray(a.rows)
    cols = np.asarray(a.cols)
    vals = np.asarray(a.vals)
    bi, bj = rows // mb, cols // nb
    lr, lc = rows - bi * mb, cols - bj * nb
    # per-(block, local row) counts decide the shared pad width k
    key = ((bi.astype(np.int64) * C + bj) * mb + lr)
    order = np.argsort(key, kind="stable")
    key, lc_s, vals_s = key[order], lc[order], vals[order]
    counts = np.bincount(key, minlength=R * C * mb)
    kmax = int(counts.max()) if counts.size else 1
    if k is None:
        k = max(1, _ceil_to(kmax, pad_to))
    elif kmax > k:
        raise ValueError(f"fixed width k={k} < max block-row count {kmax}")
    start = np.zeros(R * C * mb, dtype=np.int64)
    np.cumsum(counts[:-1], out=start[1:])
    slot = np.arange(len(key)) - start[key]
    ev = np.zeros((R * C * mb, k), dtype=vals.dtype)
    ec = np.zeros((R * C * mb, k), dtype=np.int32)
    ev[key, slot] = vals_s
    ec[key, slot] = lc_s
    return (jnp.asarray(ev.reshape(R, C, mb, k)),
            jnp.asarray(ec.reshape(R, C, mb, k)), m_pad, n_pad)


def rowshard_transpose_width(a: COO, parts: int) -> int:
    """Max per-(row-shard, column) entry count — the ELL width
    ``rowshard_transpose_ell`` needs; callers take bucket maxima."""
    if np.asarray(a.vals).size == 0:
        return 1
    rows = np.asarray(a.rows)
    cols = np.asarray(a.cols).astype(np.int64)
    mb = _ceil_to(a.m, parts) // parts
    key = (rows // mb) * a.n + cols
    return int(np.bincount(key).max())


def rowshard_transpose_ell(a: COO, parts: int, k: int | None = None,
                           pad_to: int = 8):
    """Per-row-shard transpose blocks — the dual-copy trade applied to row
    partitioning: returns (vals, rows) of shape (parts, n, k) where block
    d is the column-ELL of ``A[d*mb:(d+1)*mb, :]^T`` with row indices
    LOCAL to the shard, so a row-sharded backward pass is gather-only
    (kernel-friendly) instead of scatter-add, then psum'd over shards.
    """
    m_pad = _ceil_to(a.m, parts)
    at = COO(rows=a.cols, cols=a.rows, vals=a.vals, m=a.n, n=m_pad)
    vals, rows, _, _ = block_partitioned_ell(at, 1, parts, pad_to=pad_to,
                                             k=k)
    return vals[0], rows[0]          # (parts, n, k) each


def _row_shard(a: COO, parts: int, d: int) -> COO:
    """Transpose of row shard ``d``: ``A[d*mb:(d+1)*mb, :]^T`` as an
    (n, mb) COO with column indices LOCAL to the shard."""
    mb = _ceil_to(a.m, parts) // parts
    rows = np.asarray(a.rows)
    sel = (rows // mb) == d
    return COO(rows=np.asarray(a.cols)[sel],
               cols=rows[sel] - d * mb,
               vals=np.asarray(a.vals)[sel], m=a.n, n=mb)


def rowshard_transpose_bcsr_width(a: COO, parts: int, bm: int = 8,
                                  bn: int = 128) -> int:
    """Max nonzero-tile count per block-row over every shard's transpose —
    the BCSR ``kb`` that ``rowshard_transpose_bcsr`` needs; callers take
    bucket maxima (the tiled analogue of ``rowshard_transpose_width``,
    and like it a single vectorized pass: this sits on the engine's
    per-request admission path)."""
    rows = np.asarray(a.rows)
    if rows.size == 0:
        return 1
    cols = np.asarray(a.cols)
    mb = _ceil_to(a.m, parts) // parts
    shard = rows // mb
    local = rows - shard * mb          # shard-local row = transpose column
    nbr = max(1, -(-a.n // bm))        # transpose block-rows
    nbc = max(1, -(-mb // bn))         # transpose block-cols (shard-local)
    key = ((shard.astype(np.int64) * nbr + cols // bm) * nbc + local // bn)
    uniq = np.unique(key)
    counts = np.bincount(uniq // nbc)  # nonzero tiles per (shard, brow)
    return max(1, int(counts.max()))


def rowshard_transpose_bcsr(a: COO, parts: int, bm: int = 8, bn: int = 128,
                            kb: int | None = None):
    """Per-row-shard transpose TILE blocks — the dual-copy trade of
    ``rowshard_transpose_ell`` in the MXU-path format: returns
    (vals, bcols) of shape (parts, nbt, kb, bm, bn) / (parts, nbt, kb)
    where block d is the tiled BCSR of ``A[d*mb:(d+1)*mb, :]^T`` with
    block-column indices LOCAL to the shard (into [0, mb/bn)), so a
    row-sharded backward pass is a per-shard tile contraction
    (gather + dot_general, kernel-friendly) psum'd over shards."""
    if kb is None:
        kb = rowshard_transpose_bcsr_width(a, parts, bm=bm, bn=bn)
    shards = [coo_to_bcsr(_row_shard(a, parts, d), bm=bm, bn=bn, kb=kb)
              for d in range(parts)]
    return (jnp.stack([s.vals for s in shards]),
            jnp.stack([s.bcols for s in shards]))


def blockgrid_ell_width(a: COO, grid_rows: int, grid_cols: int) -> int:
    """Max per-(block, local row) entry count over an R x C block grid —
    the shared ELL width ``block_partitioned_ell`` needs; callers take
    bucket maxima.  One vectorized pass (admission path): the block row is
    implied by the global row, so the key is (column block, global row)."""
    if np.asarray(a.vals).size == 0:
        return 1
    rows = np.asarray(a.rows).astype(np.int64)
    cols = np.asarray(a.cols).astype(np.int64)
    nb = _ceil_to(a.n, grid_cols) // grid_cols
    key = (cols // nb) * _ceil_to(a.m, grid_rows) + rows
    return int(np.bincount(key).max())


def blockgrid_transpose_ell_width(a: COO, grid_rows: int,
                                  grid_cols: int) -> int:
    """Max per-(block, local column) entry count — the width of the
    per-block TRANSPOSE tiles ``blockgrid_transpose_ell`` builds."""
    if np.asarray(a.vals).size == 0:
        return 1
    rows = np.asarray(a.rows).astype(np.int64)
    cols = np.asarray(a.cols).astype(np.int64)
    mb = _ceil_to(a.m, grid_rows) // grid_rows
    key = (rows // mb) * _ceil_to(a.n, grid_cols) + cols
    return int(np.bincount(key).max())


def blockgrid_transpose_ell(a: COO, grid_rows: int, grid_cols: int,
                            k: int | None = None, pad_to: int = 8):
    """Per-block transpose tiles of the 2-D grid — the dual-copy trade
    applied per block: returns (vals, rows) of shape (R, C, nb, k) where
    tile (i, j) is the column-ELL of ``block(i, j)^T`` with row indices
    LOCAL to the block's row slice (into [0, mb)), so a grid-sharded
    backward pass is gather-only per block, then psum_scatter'd over the
    row axis.  Built by block-partitioning A^T over the transposed (C, R)
    grid and swapping the grid dims so slot [i, j] holds block (i, j)^T.
    """
    at = COO(rows=a.cols, cols=a.rows, vals=a.vals, m=a.n, n=a.m)
    vt, rt, _, _ = block_partitioned_ell(at, grid_cols, grid_rows,
                                         pad_to=pad_to, k=k)
    return jnp.swapaxes(vt, 0, 1), jnp.swapaxes(rt, 0, 1)


def _block_coo(a: COO, grid_rows: int, grid_cols: int, i: int,
               j: int) -> COO:
    """Block (i, j) of the R x C grid as an (mb, nb) COO with indices
    LOCAL to the block."""
    mb = _ceil_to(a.m, grid_rows) // grid_rows
    nb = _ceil_to(a.n, grid_cols) // grid_cols
    rows = np.asarray(a.rows)
    cols = np.asarray(a.cols)
    sel = (rows // mb == i) & (cols // nb == j)
    return COO(rows=rows[sel] - i * mb, cols=cols[sel] - j * nb,
               vals=np.asarray(a.vals)[sel], m=mb, n=nb)


def blockgrid_bcsr_width(a: COO, grid_rows: int, grid_cols: int,
                         bm: int = 8, bn: int = 128) -> int:
    """Max nonzero-tile count per (block, block-row) over the R x C grid —
    the BCSR ``kb`` that ``blockgrid_bcsr`` needs; callers take bucket
    maxima (one vectorized pass, like ``rowshard_transpose_bcsr_width``)."""
    rows = np.asarray(a.rows)
    if rows.size == 0:
        return 1
    cols = np.asarray(a.cols)
    R, C = grid_rows, grid_cols
    mb = _ceil_to(a.m, R) // R
    nb = _ceil_to(a.n, C) // C
    bi, bj = rows // mb, cols // nb
    lr, lc = rows - bi * mb, cols - bj * nb
    nbr = max(1, -(-mb // bm))
    nbc = max(1, -(-nb // bn))
    key = (((bi.astype(np.int64) * C + bj) * nbr + lr // bm) * nbc
           + lc // bn)
    uniq = np.unique(key)
    counts = np.bincount(uniq // nbc)   # nonzero tiles per (block, brow)
    return max(1, int(counts.max()))


def blockgrid_bcsr(a: COO, grid_rows: int, grid_cols: int, bm: int = 8,
                   bn: int = 128, kb: int | None = None):
    """2-D grid of BCSR tile stacks: returns (vals, bcols) of shape
    (R, C, nbr_b, kb, bm, bn) / (R, C, nbr_b, kb) where cell (i, j) is
    the tiled BCSR of block (i, j) with block-column indices LOCAL to the
    block (into [0, nb/bn)) — the MXU-path operand of the gridpart body."""
    if kb is None:
        kb = blockgrid_bcsr_width(a, grid_rows, grid_cols, bm=bm, bn=bn)
    cells = [[coo_to_bcsr(_block_coo(a, grid_rows, grid_cols, i, j),
                          bm=bm, bn=bn, kb=kb)
              for j in range(grid_cols)] for i in range(grid_rows)]
    return (jnp.stack([jnp.stack([c.vals for c in row]) for row in cells]),
            jnp.stack([jnp.stack([c.bcols for c in row]) for row in cells]))


def blockgrid_transpose_bcsr_width(a: COO, grid_rows: int, grid_cols: int,
                                   bm: int = 8, bn: int = 128) -> int:
    """``blockgrid_bcsr_width`` of the per-block transposes — the ``kb``
    of ``blockgrid_transpose_bcsr``; callers take bucket maxima."""
    at = COO(rows=a.cols, cols=a.rows, vals=a.vals, m=a.n, n=a.m)
    return blockgrid_bcsr_width(at, grid_cols, grid_rows, bm=bm, bn=bn)


def blockgrid_transpose_bcsr(a: COO, grid_rows: int, grid_cols: int,
                             bm: int = 8, bn: int = 128,
                             kb: int | None = None):
    """Per-block transpose BCSR tiles: cell (i, j) is the tiled BCSR of
    ``block(i, j)^T`` (shapes (R, C, nbt_b, kb, bm, bn)), block-columns
    LOCAL to the block's row slice — the tiled analogue of
    ``blockgrid_transpose_ell``, so the gridpart backward is a per-block
    tile contraction psum_scatter'd over the row axis."""
    at = COO(rows=a.cols, cols=a.rows, vals=a.vals, m=a.n, n=a.m)
    if kb is None:
        kb = blockgrid_bcsr_width(at, grid_cols, grid_rows, bm=bm, bn=bn)
    vt, ct = blockgrid_bcsr(at, grid_cols, grid_rows, bm=bm, bn=bn, kb=kb)
    return jnp.swapaxes(vt, 0, 1), jnp.swapaxes(ct, 0, 1)


# ---------------------------------------------------------------------------
# Dry-run ShapeDtypeStruct stand-ins (no allocation; shardable)
# ---------------------------------------------------------------------------

def block_ell_spec(m: int, n: int, grid_rows: int, grid_cols: int, k: int,
                   dtype=jnp.float32):
    R, C = grid_rows, grid_cols
    mb = _ceil_to(m, R) // R
    return (jax.ShapeDtypeStruct((R, C, mb, k), dtype),
            jax.ShapeDtypeStruct((R, C, mb, k), jnp.int32),
            _ceil_to(m, R), _ceil_to(n, C))


def row_ell_spec(m: int, n: int, parts: int, k: int, dtype=jnp.float32) -> ELL:
    m_pad = _ceil_to(m, parts)
    return ELL(vals=jax.ShapeDtypeStruct((m_pad, k), dtype),
               cols=jax.ShapeDtypeStruct((m_pad, k), jnp.int32), n=n)

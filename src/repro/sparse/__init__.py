from repro.sparse.formats import (
    BCSR, COO, CSC, ELL, BandedELL, StackedBCSR, StackedCSC, StackedELL,
    banded_spec, banded_to_dense, bcsr_spec, bcsr_to_coo, bcsr_to_dense,
    coo_to_banded, coo_to_bcsr, coo_bcsr_width, coo_to_csc, coo_to_dense,
    coo_to_ell, csc_to_dense, dense_to_coo, ell_spec, ell_to_coo,
    ell_to_dense, pad_coo, stack_bcsrs, stack_cscs, stack_ells,
    transpose_coo,
)
from repro.sparse.linalg import (
    banded_rmatvec, bcsr_matvec, bcsr_rmatvec, col_norms_sq, coo_matvec,
    coo_rmatvec, csc_gather_matvec, ell_col_norms_sq, ell_matvec,
    ell_rmatvec, stacked_bcsr_matvec, stacked_csc_gather_matvec,
    stacked_ell_matvec,
)
from repro.sparse.partition import (
    block_ell_spec, block_partitioned_ell, col_partitioned_ell, pad_vector,
    row_ell_spec, row_partitioned_ell,
)
from repro.sparse.random import make_lasso, random_coo

__all__ = [n for n in dir() if not n.startswith("_")]

"""Generators reproducing the paper's Table 1 datasets (uniform-sparse A).

Each row draws exactly ``row_nnz`` column indices uniformly (the paper's
matrices have tightly concentrated row/col degrees — e.g. D1: rows 1/10/29
min/mean/max, cols 876/1000/1119 — which is what uniform placement gives).
Values are N(0, 1)/sqrt(row_nnz) so ||A_col||^2 concentrates near m/n.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.configs.base import PaperProblemConfig
from repro.sparse.formats import COO


def random_coo(m: int, n: int, row_nnz: int, seed: int = 0,
               dtype=np.float32) -> COO:
    if row_nnz > n:
        raise ValueError("row_nnz > n")
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(m, dtype=np.int32), row_nnz)
    # distinct columns per row (duplicates would make ||A_i||^2 bookkeeping
    # diverge from the effective matrix): resample colliding rows — fast
    # because collision probability ~ row_nnz^2 / 2n per row.
    cols = rng.integers(0, n, size=(m, row_nnz), dtype=np.int32)
    for _ in range(64):
        s = np.sort(cols, axis=1)
        bad = np.nonzero((s[:, 1:] == s[:, :-1]).any(axis=1))[0]
        if bad.size == 0:
            break
        cols[bad] = rng.integers(0, n, size=(bad.size, row_nnz), dtype=np.int32)
    else:  # pathological density: fall back to exact per-row choice
        for r in np.nonzero((np.sort(cols, 1)[:, 1:] == np.sort(cols, 1)[:, :-1]).any(1))[0]:
            cols[r] = rng.choice(n, size=row_nnz, replace=False)
    vals = (rng.standard_normal(m * row_nnz) / np.sqrt(row_nnz)).astype(dtype)
    return COO(rows=jnp.asarray(rows), cols=jnp.asarray(cols.reshape(-1)),
               vals=jnp.asarray(vals), m=m, n=n)


def make_lasso(cfg: PaperProblemConfig, seed: int = 0, x_density: float = 0.05,
               noise: float = 0.0):
    """A LASSO instance with planted sparse x_true: b = A @ x_true (+ noise).

    Returns (coo, b, x_true). Basis-pursuit-style ground truth so convergence
    of the feasibility gap ||Ax - b|| is meaningful.
    """
    coo = random_coo(cfg.m, cfg.n, cfg.row_nnz, seed=seed)
    rng = np.random.default_rng(seed + 1)
    x_true = np.zeros(cfg.n, dtype=np.float32)
    nz = rng.choice(cfg.n, size=max(1, int(cfg.n * x_density)), replace=False)
    x_true[nz] = rng.standard_normal(len(nz)).astype(np.float32)
    dense_rows = np.asarray(coo.rows)
    b = np.zeros(cfg.m, dtype=np.float32)
    np.add.at(b, dense_rows, np.asarray(coo.vals) * x_true[np.asarray(coo.cols)])
    if noise:
        b += noise * rng.standard_normal(cfg.m).astype(np.float32)
    return coo, jnp.asarray(b), jnp.asarray(x_true)

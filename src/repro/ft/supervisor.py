"""Fault-tolerance supervisor: heartbeats, straggler detection, restart.

Hadoop gives the paper's system task-rerun and speculative execution for
free; an SPMD JAX job has neither — a slow or dead host stalls every
collective. The production equivalent (and what this module implements,
host-side) is:

  * Heartbeat: each host (or simulated worker) reports step completions;
    a worker silent for `dead_after` seconds is declared dead.
  * Straggler detection: a worker whose step latency exceeds
    `straggler_factor` x the rolling median is flagged (the speculative-
    execution criterion). The policy response at cluster scale is restart-
    without-it (elastic shrink) from the last checkpoint, not task rerun —
    recorded per event.
  * run_with_restarts: wraps a step loop; on failure restores the latest
    checkpoint and continues, up to `max_restarts`, optionally shrinking
    the mesh via the caller-provided `rebuild` hook (elastic restore is
    handled by repro.checkpoint — full logical arrays re-shard onto any
    mesh).

Tests drive it with an injectable clock and simulated failures; on a real
cluster the heartbeat feed comes from per-host agents.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable


@dataclasses.dataclass
class SupervisorConfig:
    dead_after: float = 60.0            # s without heartbeat -> dead
    straggler_factor: float = 2.0       # x median latency -> straggler
    window: int = 32                    # rolling latency window
    max_restarts: int = 3


class Supervisor:
    def __init__(self, cfg: SupervisorConfig | None = None, clock=time.monotonic):
        self.cfg = cfg or SupervisorConfig()
        self.clock = clock
        self.last_beat: dict[str, float] = {}
        self.latencies: dict[str, deque] = defaultdict(
            lambda: deque(maxlen=self.cfg.window))
        self.events: list[dict] = []

    def heartbeat(self, worker: str, step_latency: float | None = None):
        self.last_beat[worker] = self.clock()
        if step_latency is not None:
            self.latencies[worker].append(step_latency)

    def dead_workers(self) -> list[str]:
        now = self.clock()
        return [w for w, t in self.last_beat.items()
                if now - t > self.cfg.dead_after]

    def stragglers(self) -> list[str]:
        meds = []
        for lat in self.latencies.values():
            if lat:
                meds.append(sorted(lat)[len(lat) // 2])
        if not meds:
            return []
        cluster_median = sorted(meds)[len(meds) // 2]
        out = []
        for w, lat in self.latencies.items():
            if lat and sorted(lat)[len(lat) // 2] > \
                    self.cfg.straggler_factor * cluster_median:
                out.append(w)
        return out

    def check(self) -> dict:
        """One policy evaluation; records and returns the decision."""
        dead = self.dead_workers()
        slow = self.stragglers()
        decision = {"dead": dead, "stragglers": slow,
                    "action": ("restart_without" if dead or slow else "none"),
                    "time": self.clock()}
        if dead or slow:
            self.events.append(decision)
        return decision


def run_with_restarts(step_loop: Callable[[int], int],
                      restore_fn: Callable[[], int],
                      max_restarts: int = 3,
                      on_restart: Callable[[int], None] | None = None) -> int:
    """Run `step_loop(start_step) -> final_step`; on exception restore the
    latest checkpoint (restore_fn -> start step) and retry."""
    restarts = 0
    start = restore_fn()
    while True:
        try:
            return step_loop(start)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            start = restore_fn()
            if on_restart:
                on_restart(restarts)

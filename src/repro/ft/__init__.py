from repro.ft.supervisor import Supervisor, SupervisorConfig, run_with_restarts

# Pallas TPU kernels for the compute hot-spots the paper optimizes: the
# forward/backward sparse operators and the two fused update passes that
# realize pseudocode A2's "one forward application" observation in-kernel.
# The batched_* variants carry a leading batch axis (batch grid dimension /
# vmap-over-pallas_call) for the solver serving engine. Validated in
# interpret mode on CPU (no TPU in this container); written with explicit
# BlockSpec VMEM tiling for the v5e target.  Interpret mode is resolved in
# exactly one place — ``default_interpret`` (explicit flag > env
# REPRO_PALLAS_INTERPRET > jax.default_backend() != "tpu") — so the
# "pallas" backend compiles through Mosaic on a real TPU instead of
# silently running under the interpreter.
from repro.kernels.fused_check_block import (
    FUSED_CHECK_PROXES, fused_check_block,
)
from repro.kernels.interpret import default_interpret
from repro.kernels.ops import (
    banded_spmv_t, batched_bcsr_spmv, batched_ell_spmv,
    batched_fused_dual_update, bcsr_spmv, ell_spmv, fused_dual_update,
    kernel_ops, prox_update,
)
from repro.kernels.rcd_update import rcd_update

__all__ = ["FUSED_CHECK_PROXES", "banded_spmv_t", "batched_bcsr_spmv",
           "batched_ell_spmv", "batched_fused_dual_update", "bcsr_spmv",
           "default_interpret", "ell_spmv", "fused_check_block",
           "fused_dual_update", "kernel_ops", "prox_update", "rcd_update"]

# Pallas TPU kernels for the compute hot-spots the paper optimizes: the
# forward/backward sparse operators and the two fused update passes that
# realize pseudocode A2's "one forward application" observation in-kernel.
# The batched_* variants carry a leading batch axis (batch grid dimension /
# vmap-over-pallas_call) for the solver serving engine. Validated in
# interpret mode on CPU (no TPU in this container); written with explicit
# BlockSpec VMEM tiling for the v5e target.
from repro.kernels.ops import (
    banded_spmv_t, batched_bcsr_spmv, batched_ell_spmv,
    batched_fused_dual_update, bcsr_spmv, ell_spmv, fused_dual_update,
    kernel_ops, prox_update,
)

__all__ = ["banded_spmv_t", "batched_bcsr_spmv", "batched_ell_spmv",
           "batched_fused_dual_update", "bcsr_spmv", "ell_spmv",
           "fused_dual_update", "kernel_ops", "prox_update"]

"""Fused primal update (paper step 14 inner block) — soft-threshold prox +
heavy-ball averaging in one elementwise HBM pass:

    xstar_new = soft( xc - zhat/gamma, reg/gamma )
    xbar_new  = (1 - tau) * xbar + tau * xstar_new

Two outputs from one read of (zhat, xbar, xc): saves a full n-vector round
trip vs. running prox and averaging as separate XLA ops. l1 prox only (the
paper's choice); other proxes use the jnp fallback path in the solver.

Scalars (gamma, tau, reg) as a (3,)-vector operand.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import default_interpret


def _kernel(coef_ref, zhat_ref, xbar_ref, xc_ref, xstar_out, xbar_out):
    c = coef_ref[...].astype(jnp.float32)
    gamma, tau, reg = c[0], c[1], c[2]
    v = xc_ref[...].astype(jnp.float32) - zhat_ref[...].astype(jnp.float32) / gamma
    thr = reg / gamma
    xstar = jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)
    xbar = (1.0 - tau) * xbar_ref[...].astype(jnp.float32) + tau * xstar
    xstar_out[...] = xstar.astype(xstar_out.dtype)
    xbar_out[...] = xbar.astype(xbar_out.dtype)


def prox_update_pallas(coefs: jax.Array, zhat: jax.Array, xbar: jax.Array,
                       xc: jax.Array, *, block: int = 1024,
                       interpret: bool | None = None):
    n = zhat.shape[0]
    assert n % block == 0, (n, block)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    out_sds = jax.ShapeDtypeStruct((n,), zhat.dtype)
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((3,), lambda i: (0,)), vec, vec, vec],
        out_specs=(vec, vec),
        out_shape=(out_sds, out_sds),
        interpret=default_interpret(interpret),
    )(coefs, zhat, xbar, xc)

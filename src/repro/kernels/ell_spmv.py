"""Forward operator  y = A @ x  over row-ELL, as a Pallas TPU kernel.

TPU adaptation (vs. the paper's Hadoop map-side join): A is streamed
HBM->VMEM in row tiles of shape (block_rows, k) — one contiguous, aligned
pass over the matrix — while x stays VMEM-resident for the whole kernel
(index_map is constant; at the paper's scales n <= 1e5 -> <= 400 KB fp32,
far under the ~16 MB v5e VMEM budget). The gather x[cols] happens from
VMEM (vector gather), never from HBM — this is the "bring the computation
to the data" locality argument executed at the memory-hierarchy level.

Grid: (m // block_rows,). block_rows should be a multiple of 8 (sublane);
k a multiple of the lane tile where possible (wrappers pad).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import default_interpret


def _kernel(vals_ref, cols_ref, x_ref, out_ref):
    vals = vals_ref[...]                       # (TM, k)
    cols = cols_ref[...]                       # (TM, k) int32
    x = x_ref[...]                             # (n,) resident
    gathered = jnp.take(x, cols, axis=0)       # VMEM vector gather
    acc = jnp.sum(vals.astype(jnp.float32) * gathered.astype(jnp.float32),
                  axis=1)
    out_ref[...] = acc.astype(out_ref.dtype)


def ell_spmv_pallas(vals: jax.Array, cols: jax.Array, x: jax.Array,
                    *, block_rows: int = 512, interpret: bool | None = None):
    m, k = vals.shape
    assert m % block_rows == 0, (m, block_rows)
    n = x.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(m // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=default_interpret(interpret),
    )(vals, cols, x)

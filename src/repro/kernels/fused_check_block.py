"""The whole A2 check block as ONE batch-grid Pallas kernel.

The serving engine's inner loop used to lower each of the ``check_every``
iterations to separate spmv / fused-dual / prox / mask kernels with an HBM
round-trip between every pair — per-tick overhead, not math, dominated
(exactly the decomposition Dünner et al. prescribe measuring first).  Here
the entire check block runs inside a single ``pallas_call`` per (format,
prox) pair: grid ``(B,)`` — one program per slot, like ``batched_ell_spmv``
gains the slot dimension — with that slot's operands (both orientations,
b, per-slot scalars) VMEM-resident across all inner iterations.  Each
program runs ``steps`` masked A2 iterations (eq. 15 dual update, backward
pass, closed-form prox, heavy-ball averaging, per-slot freeze at
``max_iterations``) inside a ``jax.lax.fori_loop`` and emits only the final
state plus the per-slot relative-feasibility residual — the one number the
engine's harvest needs per block.

The iteration body mirrors ``core.solver.batched_step`` term for term
(including the eq-13 ``k == 0`` effective-gamma case) and the prox closed
forms mirror ``core.prox``; the equality tests in
tests/test_fused_check_block.py enforce both pairings at 1e-5.

Supported prox families are the closed forms that inline into the kernel
(``FUSED_CHECK_PROXES``); the engine falls back to the unfused step loop
for the rest.  interpret=None resolves through
``repro.kernels.default_interpret`` (interpreter off-TPU, Mosaic on TPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.solver import PDState
from repro.kernels.interpret import default_interpret
from repro.sparse.formats import StackedBCSR, StackedELL

#: prox families with an inlined closed form (xc = 0, per-slot scalar reg).
FUSED_CHECK_PROXES = ("l1", "sq_l2", "zero", "nonneg")


def _prox_body(name: str):
    """x* = prox_{f/gamma}(-zhat/gamma) — core.prox closed forms at xc=0."""
    if name == "l1":
        def body(zhat, gamma, reg):
            v = -zhat / gamma
            thr = reg / gamma
            return jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)
    elif name == "sq_l2":
        def body(zhat, gamma, reg):
            return (-zhat / gamma) / (1.0 + reg / gamma)
    elif name == "zero":
        def body(zhat, gamma, reg):
            return -zhat / gamma
    elif name == "nonneg":
        def body(zhat, gamma, reg):
            return jnp.maximum(-zhat / gamma, 0.0)
    else:
        raise KeyError(f"prox family {name!r} has no fused closed form; "
                       f"supported: {FUSED_CHECK_PROXES}")
    return body


def _make_kernel(steps: int, prox_name: str, c: float, fmt: str,
                 geom: tuple):
    """Kernel factory: the (format, prox) pair is baked in statically."""
    prox_fn = _prox_body(prox_name)
    c2p = c + 2.0

    def run_block(fwd, bwd, bvec, fscal_ref, iscal_ref, state_in, refs_out):
        lg = fscal_ref[0, 0]
        g0 = fscal_ref[0, 1]
        reg = fscal_ref[0, 2]
        gamma0_in = fscal_ref[0, 3]
        k0 = iscal_ref[0, 0]
        maxit = iscal_ref[0, 1]
        active = iscal_ref[0, 2] > 0
        xbar0, xstar0, yhat0 = state_in
        beta0 = lg * c * c * (c + 3.0) / (g0 * c2p * c2p * 2.0)

        def body(_, carry):
            xbar, xstar, yhat, gamma, k = carry
            kf = k.astype(jnp.float32)
            tk = c / (kf + c2p)
            gk1 = g0 * c2p / (kf + 1.0 + c2p)
            bk = (lg * c * c * (kf + c + 3.0)
                  / (g0 * c2p * (kf + c2p) * (kf + 2.0)))
            gk_eff = jnp.where(k == 0, lg / beta0, gamma)      # eq (13)
            c0 = 1.0 - tk
            c1 = (1.0 - tk) * gk_eff / lg
            c2 = tk / bk
            c3 = c1 + c2
            # eq (15): ONE forward application on the combined vector
            yhat_new = c0 * yhat + fwd(c1 * xstar + c2 * xbar) - c3 * bvec
            zhat = bwd(yhat_new)
            xstar_new = prox_fn(zhat, gk1, reg)
            xbar_new = (1.0 - tk) * xbar + tk * xstar_new
            # per-slot freeze: occupancy mask AND the max_iterations cap
            live = active & (k < maxit)
            return (jnp.where(live, xbar_new, xbar),
                    jnp.where(live, xstar_new, xstar),
                    jnp.where(live, yhat_new, yhat),
                    jnp.where(live, gk1, gamma),
                    jnp.where(live, k + 1, k))

        xbar, xstar, yhat, gamma, k = jax.lax.fori_loop(
            0, steps, body, (xbar0, xstar0, yhat0, gamma0_in, k0))
        r = fwd(xbar) - bvec
        feas = (jnp.sqrt(jnp.sum(r * r))
                / jnp.maximum(jnp.sqrt(jnp.sum(bvec * bvec)), 1.0))
        (xbar_ref, xstar_ref, yhat_ref, gamma_ref, k_ref, feas_ref) = refs_out
        xbar_ref[0, :] = xbar
        xstar_ref[0, :] = xstar
        yhat_ref[0, :] = yhat
        gamma_ref[0, 0] = gamma
        k_ref[0, 0] = k
        feas_ref[0, 0] = feas

    if fmt == "ell":
        def kernel(vals_ref, cols_ref, tvals_ref, tcols_ref, b_ref,
                   fscal_ref, iscal_ref, xbar_ref, xstar_ref, yhat_ref,
                   oxbar_ref, oxstar_ref, oyhat_ref, gamma_ref, k_ref,
                   feas_ref):
            vals = vals_ref[0].astype(jnp.float32)        # (m, k) resident
            cols = cols_ref[0]
            tvals = tvals_ref[0].astype(jnp.float32)      # (n, kt) resident
            tcols = tcols_ref[0]
            bvec = b_ref[0].astype(jnp.float32)

            def fwd(x):
                return jnp.sum(vals * jnp.take(x, cols, axis=0), axis=1)

            def bwd(y):
                return jnp.sum(tvals * jnp.take(y, tcols, axis=0), axis=1)

            run_block(fwd, bwd, bvec, fscal_ref, iscal_ref,
                      (xbar_ref[0], xstar_ref[0], yhat_ref[0]),
                      (oxbar_ref, oxstar_ref, oyhat_ref, gamma_ref, k_ref,
                       feas_ref))
    else:
        nbc, bn, nbc_t, bn_t = geom

        def kernel(vals_ref, bcols_ref, tvals_ref, tbcols_ref, b_ref,
                   fscal_ref, iscal_ref, xbar_ref, xstar_ref, yhat_ref,
                   oxbar_ref, oxstar_ref, oyhat_ref, gamma_ref, k_ref,
                   feas_ref):
            vals = vals_ref[0].astype(jnp.float32)    # (nbr, kb, bm, bn)
            bcols = bcols_ref[0]
            tvals = tvals_ref[0].astype(jnp.float32)  # (nbt, kbt, bm, bn_t)
            tbcols = tbcols_ref[0]
            bvec = b_ref[0].astype(jnp.float32)
            dn = (((3,), (2,)), ((0, 1), (0, 1)))

            def fwd(x):                               # (n,) -> (m,), MXU
                g = jnp.take(x.reshape(nbc, bn), bcols, axis=0)
                acc = jax.lax.dot_general(
                    vals, g, dimension_numbers=dn,
                    preferred_element_type=jnp.float32)
                return jnp.sum(acc, axis=1).reshape(-1)

            def bwd(y):                               # (m,) -> (n,), MXU
                g = jnp.take(y.reshape(nbc_t, bn_t), tbcols, axis=0)
                acc = jax.lax.dot_general(
                    tvals, g, dimension_numbers=dn,
                    preferred_element_type=jnp.float32)
                return jnp.sum(acc, axis=1).reshape(-1)

            run_block(fwd, bwd, bvec, fscal_ref, iscal_ref,
                      (xbar_ref[0], xstar_ref[0], yhat_ref[0]),
                      (oxbar_ref, oxstar_ref, oyhat_ref, gamma_ref, k_ref,
                       feas_ref))

    return kernel


def _slot_spec(shape):
    """Per-slot BlockSpec: leading (1,) slot block, whole operand resident."""
    nd = len(shape)
    return pl.BlockSpec((1, *shape),
                        lambda b, _nd=nd: (b, *([0] * _nd)))


def fused_check_block_pallas(a_vals, a_idx, at_vals, at_idx, b, fscal, iscal,
                             xbar, xstar, yhat, *, fmt: str, prox: str,
                             steps: int, c: float = 3.0,
                             interpret: bool | None = None):
    """One launch: B slots x ``steps`` fused A2 iterations + residuals.

    fscal (B, 4) f32: [lg, gamma0, reg, gamma_in] per slot.
    iscal (B, 3) i32: [k_in, max_iterations, active] per slot.
    Returns (xbar, xstar, yhat, gamma (B,), k (B,) i32, feas (B,)).
    """
    bsz, m = b.shape
    n = xbar.shape[1]
    if fmt == "bcsr":
        bn, bn_t = a_vals.shape[4], at_vals.shape[4]
        assert n % bn == 0 and m % bn_t == 0, (n, bn, m, bn_t)
        geom = (n // bn, bn, m // bn_t, bn_t)
    else:
        geom = None
    kernel = _make_kernel(steps, prox, c, fmt, geom)
    out = pl.pallas_call(
        kernel,
        grid=(bsz,),
        in_specs=[_slot_spec(a_vals.shape[1:]), _slot_spec(a_idx.shape[1:]),
                  _slot_spec(at_vals.shape[1:]), _slot_spec(at_idx.shape[1:]),
                  _slot_spec((m,)), _slot_spec((4,)), _slot_spec((3,)),
                  _slot_spec((n,)), _slot_spec((n,)), _slot_spec((m,))],
        out_specs=(_slot_spec((n,)), _slot_spec((n,)), _slot_spec((m,)),
                   _slot_spec((1,)), _slot_spec((1,)), _slot_spec((1,))),
        out_shape=(jax.ShapeDtypeStruct((bsz, n), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, n), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, m), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, 1), jnp.float32),
                   jax.ShapeDtypeStruct((bsz, 1), jnp.int32),
                   jax.ShapeDtypeStruct((bsz, 1), jnp.float32)),
        interpret=default_interpret(interpret),
    )(a_vals, a_idx, at_vals, at_idx, b, fscal, iscal, xbar, xstar, yhat)
    xbar_o, xstar_o, yhat_o, gamma_o, k_o, feas_o = out
    return (xbar_o, xstar_o, yhat_o, gamma_o[:, 0], k_o[:, 0], feas_o[:, 0])


@partial(jax.jit, static_argnames=("prox", "steps", "c", "interpret"))
def fused_check_block(a, at, b, lg, gamma0, reg, state: PDState, active,
                      maxit, *, prox: str, steps: int, c: float = 3.0,
                      interpret: bool | None = None):
    """Engine-facing wrapper: (stacked A, stacked A^T, operands, PDState)
    -> (PDState, per-slot relative feasibility) after ``steps`` fused
    masked A2 iterations — the drop-in fused body for one check block.

    ``a``/``at`` are a ``StackedELL`` or ``StackedBCSR`` pair (the same
    device-resident stacks the engine's buckets cache); ``active`` is the
    per-slot occupancy mask, ``maxit`` the per-slot iteration cap.  The
    state/feasibility contract matches ``check_every`` applications of
    ``core.solver.batched_step`` followed by ``batched_feasibility``.
    """
    bsz = b.shape[0]
    f32 = jnp.float32
    fscal = jnp.stack([
        jnp.broadcast_to(jnp.asarray(lg, f32), (bsz,)),
        jnp.broadcast_to(jnp.asarray(gamma0, f32), (bsz,)),
        jnp.broadcast_to(jnp.asarray(reg, f32), (bsz,)),
        state.gamma.astype(f32)], axis=1)
    iscal = jnp.stack([
        state.k.astype(jnp.int32),
        jnp.broadcast_to(jnp.asarray(maxit, jnp.int32), (bsz,)),
        active.astype(jnp.int32)], axis=1)
    if isinstance(a, StackedELL):
        fmt, a_idx, at_idx = "ell", a.cols, at.cols
    elif isinstance(a, StackedBCSR):
        fmt, a_idx, at_idx = "bcsr", a.bcols, at.bcols
    else:
        raise TypeError(f"fused_check_block needs StackedELL or StackedBCSR "
                        f"operands, got {type(a).__name__}")
    xbar, xstar, yhat, gamma, k, feas = fused_check_block_pallas(
        a.vals, a_idx, at.vals, at_idx, b.astype(f32), fscal, iscal,
        state.xbar.astype(f32), state.xstar.astype(f32),
        state.yhat.astype(f32), fmt=fmt, prox=prox, steps=steps, c=c,
        interpret=interpret)
    return PDState(xbar=xbar, xstar=xstar, yhat=yhat, gamma=gamma, k=k), feas

"""Pure-jnp oracles for every Pallas kernel (tested with assert_allclose)."""
from __future__ import annotations

import jax.numpy as jnp


def ell_spmv_ref(vals, cols, x):
    g = jnp.take(x, cols, axis=0)
    return jnp.sum(vals.astype(jnp.float32) * g.astype(jnp.float32),
                   axis=1).astype(x.dtype)


def banded_spmv_t_ref(vals, rows, y, band_size):
    num_bands, n, kb = vals.shape
    yb = y.reshape(num_bands, band_size)
    out = jnp.zeros((n,), jnp.float32)
    for b in range(num_bands):
        g = jnp.take(yb[b], rows[b], axis=0)
        out = out + jnp.sum(vals[b].astype(jnp.float32) * g.astype(jnp.float32),
                            axis=1)
    return out.astype(y.dtype)


def bcsr_spmv_ref(vals, bcols, xt):
    """(nbr, kb, bm, bn) tiles x (nbc, bn) x-slices -> (nbr, bm)."""
    g = jnp.take(xt, bcols, axis=0)
    acc = jnp.einsum("rkmn,rkn->rm", vals.astype(jnp.float32),
                     g.astype(jnp.float32))
    return acc.astype(xt.dtype)


def fused_dual_update_ref(coefs, vals, cols, xstar, xbar, yhat, b):
    c = coefs.astype(jnp.float32)
    u = c[1] * xstar.astype(jnp.float32) + c[2] * xbar.astype(jnp.float32)
    au = ell_spmv_ref(vals, cols, u).astype(jnp.float32)
    out = c[0] * yhat.astype(jnp.float32) + au - c[3] * b.astype(jnp.float32)
    return out.astype(yhat.dtype)


def prox_update_ref(coefs, zhat, xbar, xc):
    c = coefs.astype(jnp.float32)
    gamma, tau, reg = c[0], c[1], c[2]
    v = xc.astype(jnp.float32) - zhat.astype(jnp.float32) / gamma
    xstar = jnp.sign(v) * jnp.maximum(jnp.abs(v) - reg / gamma, 0.0)
    xbar_new = (1.0 - tau) * xbar.astype(jnp.float32) + tau * xstar
    return xstar.astype(zhat.dtype), xbar_new.astype(zhat.dtype)

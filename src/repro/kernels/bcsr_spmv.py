"""Forward operator  y = A @ x  over tiled BCSR, as a Pallas TPU kernel.

Unlike the ELL kernel (a pure VPU gather+multiply), BCSR stores dense
(bm, bn) tiles, so the per-tile contraction is a real matrix product and
lowers to the MXU:

    y[block-row i] = sum_s  vals[i, s] @ x[bcols[i, s]*bn : +bn]

TPU adaptation: the tile stream vals (nbr, kb, bm, bn) is read HBM->VMEM in
block-row groups of block_brows — one contiguous aligned pass — while x stays
VMEM-resident reshaped to (nbc, bn) so the per-tile slice is a single row
gather (cheap, VPU) feeding the dot_general (MXU). The batched contraction
runs all block_brows * kb tiles of the grid step in one dot_general with
fp32 accumulation (preferred_element_type), then reduces over the kb slots.

A^T y uses the same kernel on the BCSR of A^T (both orientations stored —
the paper's memory-for-network trade applied to the memory hierarchy).

Grid: (nbr // block_brows,). bm should be a multiple of 8 (sublane) and bn
of 128 (lane) for the MXU path; the wrappers in repro.kernels.ops pad the
block-row count, and coo_to_bcsr zero-pads edge tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import default_interpret


def _kernel(vals_ref, bcols_ref, x_ref, out_ref):
    vals = vals_ref[...]                       # (TB, kb, bm, bn)
    bcols = bcols_ref[...]                     # (TB, kb) int32
    xt = x_ref[...]                            # (nbc, bn) resident
    g = jnp.take(xt, bcols, axis=0)            # (TB, kb, bn) VMEM gather
    acc = jax.lax.dot_general(                 # (TB, kb, bm) on the MXU
        vals.astype(jnp.float32), g.astype(jnp.float32),
        dimension_numbers=(((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)
    out_ref[...] = jnp.sum(acc, axis=1).astype(out_ref.dtype)


def bcsr_spmv_pallas(vals: jax.Array, bcols: jax.Array, xt: jax.Array,
                     *, block_brows: int = 8, interpret: bool | None = None):
    nbr, kb, bm, bn = vals.shape
    assert nbr % block_brows == 0, (nbr, block_brows)
    nbc = xt.shape[0]
    assert xt.shape == (nbc, bn), (xt.shape, bn)
    return pl.pallas_call(
        _kernel,
        grid=(nbr // block_brows,),
        in_specs=[
            pl.BlockSpec((block_brows, kb, bm, bn), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((block_brows, kb), lambda i: (i, 0)),
            pl.BlockSpec((nbc, bn), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_brows, bm), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbr, bm), xt.dtype),
        interpret=default_interpret(interpret),
    )(vals, bcols, xt)


def _batched_kernel(vals_ref, bcols_ref, x_ref, out_ref):
    vals = vals_ref[0]                         # (TB, kb, bm, bn)
    bcols = bcols_ref[0]                       # (TB, kb) int32
    xt = x_ref[0]                              # (nbc, bn) resident, this slot
    g = jnp.take(xt, bcols, axis=0)            # (TB, kb, bn) VMEM gather
    acc = jax.lax.dot_general(                 # (TB, kb, bm) on the MXU
        vals.astype(jnp.float32), g.astype(jnp.float32),
        dimension_numbers=(((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)
    out_ref[0] = jnp.sum(acc, axis=1).astype(out_ref.dtype)


def batched_bcsr_spmv_pallas(vals: jax.Array, bcols: jax.Array,
                             xt: jax.Array, *, block_brows: int = 8,
                             interpret: bool | None = None):
    """Stacked BCSR y_b = A_b @ x_b in ONE launch: the grid gains the slot
    dimension (like ``batched_ell_spmv``) instead of vmapping the
    single-slot ``pallas_call`` — one kernel, B * (nbr / block_brows)
    programs, each slot's x tile table VMEM-resident for its row sweep."""
    bsz, nbr, kb, bm, bn = vals.shape
    assert nbr % block_brows == 0, (nbr, block_brows)
    nbc = xt.shape[1]
    assert xt.shape == (bsz, nbc, bn), (xt.shape, bn)
    return pl.pallas_call(
        _batched_kernel,
        grid=(bsz, nbr // block_brows),
        in_specs=[
            pl.BlockSpec((1, block_brows, kb, bm, bn),
                         lambda b, i: (b, i, 0, 0, 0)),
            pl.BlockSpec((1, block_brows, kb), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, nbc, bn), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_brows, bm), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, nbr, bm), xt.dtype),
        interpret=default_interpret(interpret),
    )(vals, bcols, xt)

"""Batched forward operator  y_b = A_b @ x_b  over stacked row-ELL.

The serving-engine kernel: B independent same-shape problems stacked on a
leading batch axis, the grid gaining a batch dimension — grid
``(B, m // block_rows)`` — so one ``pallas_call`` covers the whole slot
batch.  Each (b, i) program streams one row tile of problem b HBM->VMEM and
gathers from that problem's VMEM-resident x_b; problems never read each
other's operands (block index maps select slot b in every spec).

This is the kernel-level version of the multi-tenant batching argument
(Dünner et al.): per-call fixed costs — dispatch, grid setup, pipeline
prologue — are paid once per *bucket* instead of once per *problem*.

interpret=None by default, resolved by ``repro.kernels.default_interpret``
(interpreter off-TPU — this container is CPU-only — Mosaic-compiled on a
real TPU; env REPRO_PALLAS_INTERPRET overrides).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import default_interpret


def _kernel(vals_ref, cols_ref, x_ref, out_ref):
    vals = vals_ref[0]                          # (TM, k)
    cols = cols_ref[0]                          # (TM, k) int32
    x = x_ref[0]                                # (n,) slot-resident
    gathered = jnp.take(x, cols, axis=0)        # VMEM vector gather
    acc = jnp.sum(vals.astype(jnp.float32) * gathered.astype(jnp.float32),
                  axis=1)
    out_ref[0, :] = acc.astype(out_ref.dtype)


def batched_ell_spmv_pallas(vals: jax.Array, cols: jax.Array, x: jax.Array,
                            *, block_rows: int = 512, interpret: bool | None = None):
    """vals/cols: (B, m, k);  x: (B, n)  ->  y: (B, m)."""
    bsz, m, k = vals.shape
    assert m % block_rows == 0, (m, block_rows)
    n = x.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(bsz, m // block_rows),
        in_specs=[
            pl.BlockSpec((1, block_rows, k), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_rows, k), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, n), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, m), x.dtype),
        interpret=default_interpret(interpret),
    )(vals, cols, x)

"""The ONE resolution point for Pallas interpret mode.

Every kernel in this package takes ``interpret: bool | None = None`` and
resolves it here.  Historically the kernels hardcoded ``interpret=True``
(correct for this CPU-only container, silently catastrophic on a real TPU:
the "pallas" backend would run under the interpreter, orders of magnitude
slower than Mosaic-compiled kernels).  The default is now keyed on the
actual runtime backend:

    explicit flag  >  REPRO_PALLAS_INTERPRET env var  >  auto
                                  (auto = jax.default_backend() != "tpu")

The env var accepts 1/0/true/false/yes/no/on/off (case-insensitive;
"auto"/"" fall through to the backend rule) so a deployment can force
either mode without touching call sites.  The planner records the resolved
value in every pallas plan's reasons (repro.plan).

"One resolution point" is enforced, not aspirational: lint rule R1
(``repro.analysis.rules``, CI ``lint`` job) flags any literal
``interpret=True/False`` at a call site outside THIS file — the flag must
flow through as ``interpret=interpret`` so it resolves here.
"""
from __future__ import annotations

import os

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def default_interpret(flag: bool | None = None) -> bool:
    """Resolve an interpret-mode flag: explicit > env > backend-keyed auto.

    >>> default_interpret(True), default_interpret(False)
    (True, False)
    >>> default_interpret() == (__import__("jax").default_backend() != "tpu")
    True
    """
    if flag is not None:
        return bool(flag)
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    import jax

    return jax.default_backend() != "tpu"

"""Fused dual update (paper eq. 15) — the A2 linearity trick AS A KERNEL.

    yhat_new = c0*yhat + A @ (c1*xstar + c2*xbar) - c3*b

One HBM pass over A; the combined vector u = c1*xstar + c2*xbar is formed in
VMEM per row tile and never materialized in HBM; the axpy epilogue
(c0*yhat - c3*b) fuses into the same pass. This is the kernel-level version
of the paper's observation that eq. 15 "is just one application of the
forward matrix operator".

Scalars (c0..c3) arrive as a (4,)-vector operand (per-iteration traced
values, so they cannot be compile-time constants).

``interpret=None`` (the default) resolves through
``repro.kernels.default_interpret``: interpreter execution off-TPU
(functionally exact, orders of magnitude slower than compiled — this
container is CPU-only), Mosaic-compiled on a TPU so the kernel lowers onto
the VPU with real HBM->VMEM pipelining.  ``REPRO_PALLAS_INTERPRET=0|1``
overrides the auto rule; pass an explicit bool only if you are managing
interpret mode yourself.

``batched_fused_dual_update_pallas`` is the serving-engine variant: stacked
operands with a leading batch axis, per-slot coefficient rows (B, 4), and a
batch grid dimension — one launch covers every problem in a bucket.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import default_interpret


def _kernel(coef_ref, vals_ref, cols_ref, xstar_ref, xbar_ref, yhat_ref,
            b_ref, out_ref):
    c = coef_ref[...].astype(jnp.float32)      # (4,)
    u = (c[1] * xstar_ref[...].astype(jnp.float32)
         + c[2] * xbar_ref[...].astype(jnp.float32))          # (n,) in VMEM
    vals = vals_ref[...].astype(jnp.float32)                  # (TM, k)
    gathered = jnp.take(u, cols_ref[...], axis=0)             # VMEM gather
    au = jnp.sum(vals * gathered, axis=1)                     # (TM,)
    out = (c[0] * yhat_ref[...].astype(jnp.float32) + au
           - c[3] * b_ref[...].astype(jnp.float32))
    out_ref[...] = out.astype(out_ref.dtype)


def fused_dual_update_pallas(coefs: jax.Array, vals: jax.Array,
                             cols: jax.Array, xstar: jax.Array,
                             xbar: jax.Array, yhat: jax.Array, b: jax.Array,
                             *, block_rows: int = 512,
                             interpret: bool | None = None):
    m, k = vals.shape
    assert m % block_rows == 0, (m, block_rows)
    n = xstar.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(m // block_rows,),
        in_specs=[
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), yhat.dtype),
        interpret=default_interpret(interpret),
    )(coefs, vals, cols, xstar, xbar, yhat, b)


def _batched_kernel(coef_ref, vals_ref, cols_ref, xstar_ref, xbar_ref,
                    yhat_ref, b_ref, out_ref):
    c = coef_ref[0].astype(jnp.float32)            # (4,) this slot's schedule
    u = (c[1] * xstar_ref[0].astype(jnp.float32)
         + c[2] * xbar_ref[0].astype(jnp.float32))             # (n,) in VMEM
    vals = vals_ref[0].astype(jnp.float32)                     # (TM, k)
    gathered = jnp.take(u, cols_ref[0], axis=0)
    au = jnp.sum(vals * gathered, axis=1)                      # (TM,)
    out = (c[0] * yhat_ref[0].astype(jnp.float32) + au
           - c[3] * b_ref[0].astype(jnp.float32))
    out_ref[0, :] = out.astype(out_ref.dtype)


def batched_fused_dual_update_pallas(coefs: jax.Array, vals: jax.Array,
                                     cols: jax.Array, xstar: jax.Array,
                                     xbar: jax.Array, yhat: jax.Array,
                                     b: jax.Array, *, block_rows: int = 512,
                                     interpret: bool | None = None):
    """Per-slot eq. 15 over stacked ELL: one launch for the whole bucket.

    coefs: (B, 4) per-slot (c0..c3) — each problem sits at its own iteration
    k with its own (lg, gamma0), so the schedule coefficients differ per
    slot.  vals/cols: (B, m, k);  xstar/xbar: (B, n);  yhat/b: (B, m).
    """
    bsz, m, k = vals.shape
    assert m % block_rows == 0, (m, block_rows)
    n = xstar.shape[1]
    return pl.pallas_call(
        _batched_kernel,
        grid=(bsz, m // block_rows),
        in_specs=[
            pl.BlockSpec((1, 4), lambda bi, i: (bi, 0)),
            pl.BlockSpec((1, block_rows, k), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, block_rows, k), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, n), lambda bi, i: (bi, 0)),
            pl.BlockSpec((1, n), lambda bi, i: (bi, 0)),
            pl.BlockSpec((1, block_rows), lambda bi, i: (bi, i)),
            pl.BlockSpec((1, block_rows), lambda bi, i: (bi, i)),
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda bi, i: (bi, i)),
        out_shape=jax.ShapeDtypeStruct((bsz, m), yhat.dtype),
        interpret=default_interpret(interpret),
    )(coefs, vals, cols, xstar, xbar, yhat, b)

"""Batched per-coordinate gather-update kernel for the RCD solver family.

One ``pallas_call`` applies ONE coordinate update to every slot in a
serving bucket — grid ``(B,)``, one program per slot.  Each program holds
its slot's full operand row-block VMEM-resident (the stored column of
CSC(A) for primal RCD / the stored row of CSC(A^T) for dual SDCA is sliced
out with ``dynamic_index_in_dim``), gathers the cached vector at the stored
indices, runs the 1-D loss update, and writes the functionally-updated
iterate and cache back.  The loss math is SHARED with the jnp reference
path — the kernel loads refs and calls the same ``primal_coord_body`` /
``dual_coord_body`` from ``repro.solvers.rcd``, so jnp/pallas parity is
structural rather than re-derived.

The solver's epoch loop (``batched_rcd_step(kernel="pallas")``) places this
call inside a ``fori_loop`` body: one trace, ``updates`` sequential kernel
launches per epoch.  That is the intended shape — a coordinate update is a
sparse O(nnz_col) gather-update, far too small to tile further, and the
batch grid is what amortizes dispatch across slots (the same multi-tenant
argument as ``batched_ell_spmv``).

interpret=None resolves via ``repro.kernels.default_interpret`` (interpret
off-TPU; env REPRO_PALLAS_INTERPRET overrides).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import default_interpret


def _kernel(vals_ref, rows_ref, xbar_ref, aux_ref, b_ref, j_ref, reg_ref,
            xbar_out_ref, aux_out_ref, *, family: str, loss: str):
    from repro.solvers.rcd import dual_coord_body, primal_coord_body

    v = vals_ref[0]                            # (dim_pad, k) slot operand
    r = rows_ref[0]
    xbar = xbar_ref[0]                         # (n,)
    aux = aux_ref[0]                           # (m,)
    b = b_ref[0]                               # (m,)
    j = j_ref[0, 0]                            # picked coordinate (scalar)
    reg = reg_ref[0, 0]
    cv = jax.lax.dynamic_index_in_dim(v, j, axis=0, keepdims=False)
    cr = jax.lax.dynamic_index_in_dim(r, j, axis=0, keepdims=False)
    if family == "rcd_primal":
        new_xbar, new_aux = primal_coord_body(cv, cr, xbar, aux, b, j, reg,
                                              loss)
    else:
        new_xbar, new_aux = dual_coord_body(cv, cr, xbar, aux, b, j, reg,
                                            loss)
    xbar_out_ref[0, :] = new_xbar.astype(xbar_out_ref.dtype)
    aux_out_ref[0, :] = new_aux.astype(aux_out_ref.dtype)


@partial(jax.jit, static_argnames=("family", "loss", "interpret"))
def rcd_update(vals: jax.Array, rows: jax.Array, xbar: jax.Array,
               aux: jax.Array, b: jax.Array, j: jax.Array, reg: jax.Array,
               *, family: str, loss: str,
               interpret: bool | None = None):
    """One batched coordinate update: (new xbar, new aux), both (B, ·).

    vals/rows — (B, dim_pad, k) stored values / gather indices of the
        coordinate-major operand (CSC(A) for rcd_primal, CSC(A^T) for
        rcd_dual).
    xbar/aux  — (B, n) iterate and (B, m) cache (z or beta).
    b         — (B, m) targets/labels.
    j         — (B,) int32 picked coordinate per slot (already hashed).
    reg       — (B,) float32 per-slot regularization.
    """
    bsz, dim_pad, k = vals.shape
    n = xbar.shape[1]
    m = aux.shape[1]
    j2 = j.astype(jnp.int32).reshape(bsz, 1)
    reg2 = reg.astype(jnp.float32).reshape(bsz, 1)
    return pl.pallas_call(
        partial(_kernel, family=family, loss=loss),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, dim_pad, k), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, dim_pad, k), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, n), lambda s: (s, 0)),
            pl.BlockSpec((1, m), lambda s: (s, 0)),
            pl.BlockSpec((1, m), lambda s: (s, 0)),
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda s: (s, 0)),
            pl.BlockSpec((1, m), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n), xbar.dtype),
            jax.ShapeDtypeStruct((bsz, m), aux.dtype),
        ],
        interpret=default_interpret(interpret),
    )(vals, rows, xbar, aux, b, j2, reg2)

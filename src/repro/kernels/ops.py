"""jit'd public wrappers around the Pallas kernels.

Handle padding to tile multiples, resolve interpret mode through the one
``repro.kernels.default_interpret`` helper (interpret=True off-TPU — this
container is CPU-only; on a real TPU the same calls lower through Mosaic;
env REPRO_PALLAS_INTERPRET overrides), and expose a ``kernel_ops`` factory
that wires the kernels into a ``SolverOps`` bundle so the solver's hot loop
runs entirely on fused kernels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.prox import ProxOp
from repro.kernels.banded_spmv_t import banded_spmv_t_pallas
from repro.kernels.interpret import default_interpret
from repro.kernels.batched_ell_spmv import batched_ell_spmv_pallas
from repro.kernels.bcsr_spmv import batched_bcsr_spmv_pallas, bcsr_spmv_pallas
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.fused_dual_update import (
    batched_fused_dual_update_pallas, fused_dual_update_pallas,
)
from repro.kernels.prox_update import prox_update_pallas
from repro.sparse.formats import BCSR, ELL, BandedELL, StackedBCSR, StackedELL


_interp = default_interpret


def _pad_multiple(arr, mult, axis=0):
    """Pad ``axis`` up to a multiple of ``mult``; returns (arr, orig_size)."""
    size = arr.shape[axis]
    pad = (-size) % mult
    if pad:
        widths = [(0, 0)] * arr.ndim
        widths[axis] = (0, pad)
        arr = jnp.pad(arr, widths)
    return arr, size


def _pad_rows(arr, mult):
    return _pad_multiple(arr, mult, axis=0)


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ell_spmv(a: ELL, x: jax.Array, *, block_rows: int = 512,
             interpret: bool | None = None) -> jax.Array:
    """y = A @ x (row-ELL)."""
    block_rows = min(block_rows, max(8, a.m))
    vals, m = _pad_rows(a.vals, block_rows)
    cols, _ = _pad_rows(a.cols, block_rows)
    y = ell_spmv_pallas(vals, cols, x, block_rows=block_rows,
                        interpret=_interp(interpret))
    return y[:m]


@partial(jax.jit, static_argnames=("block_cols", "interpret"))
def banded_spmv_t(at: BandedELL, y: jax.Array, *, block_cols: int = 512,
                  interpret: bool | None = None) -> jax.Array:
    """z = A^T @ y (banded column-ELL)."""
    n = at.n
    block_cols = min(block_cols, max(8, n))
    padn = (-n) % block_cols
    vals = jnp.pad(at.vals, ((0, 0), (0, padn), (0, 0))) if padn else at.vals
    rows = jnp.pad(at.rows, ((0, 0), (0, padn), (0, 0))) if padn else at.rows
    pady = at.num_bands * at.band_size - y.shape[0]
    ypad = jnp.pad(y, (0, pady)) if pady else y
    z = banded_spmv_t_pallas(vals, rows, ypad, at.band_size,
                             block_cols=block_cols,
                             interpret=_interp(interpret))
    return z[:n]


@partial(jax.jit, static_argnames=("block_brows", "interpret"))
def bcsr_spmv(a: BCSR, x: jax.Array, *, block_brows: int = 8,
              interpret: bool | None = None) -> jax.Array:
    """y = A @ x (tiled BCSR, MXU tile contraction)."""
    nbr = a.nbr
    block_brows = max(1, min(block_brows, nbr))
    pad_br = (-nbr) % block_brows
    vals = jnp.pad(a.vals, ((0, pad_br), (0, 0), (0, 0), (0, 0))) \
        if pad_br else a.vals
    bcols = jnp.pad(a.bcols, ((0, pad_br), (0, 0))) if pad_br else a.bcols
    pad_x = a.nbc * a.bn - x.shape[0]
    xt = (jnp.pad(x, (0, pad_x)) if pad_x else x).reshape(a.nbc, a.bn)
    y = bcsr_spmv_pallas(vals, bcols, xt, block_brows=block_brows,
                         interpret=_interp(interpret))
    return y.reshape(-1)[:a.m]


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_dual_update(a: ELL, xstar, xbar, yhat, b, c0, c1, c2, c3,
                      *, block_rows: int = 512,
                      interpret: bool | None = None) -> jax.Array:
    """yhat_new = c0*yhat + A(c1*xstar + c2*xbar) - c3*b  (eq. 15, one pass)."""
    block_rows = min(block_rows, max(8, a.m))
    vals, m = _pad_rows(a.vals, block_rows)
    cols, _ = _pad_rows(a.cols, block_rows)
    yhat_p, _ = _pad_rows(yhat, block_rows)
    b_p, _ = _pad_rows(b, block_rows)
    coefs = jnp.stack([jnp.asarray(v, jnp.float32) for v in (c0, c1, c2, c3)])
    out = fused_dual_update_pallas(coefs, vals, cols, xstar, xbar, yhat_p,
                                   b_p, block_rows=block_rows,
                                   interpret=_interp(interpret))
    return out[:m]


@partial(jax.jit, static_argnames=("block", "interpret"))
def prox_update(zhat, xbar, xc, gamma, tau, reg, *, block: int = 1024,
                interpret: bool | None = None):
    """(xstar_new, xbar_new) — fused l1 prox + averaging."""
    n = zhat.shape[0]
    block = min(block, max(8, n))
    pad = (-n) % block
    zp = jnp.pad(zhat, (0, pad)) if pad else zhat
    xb = jnp.pad(xbar, (0, pad)) if pad else xbar
    xcp = jnp.pad(xc, (0, pad)) if pad else xc
    coefs = jnp.stack([jnp.asarray(v, jnp.float32) for v in (gamma, tau, reg)])
    xs, xb_new = prox_update_pallas(coefs, zp, xb, xcp, block=block,
                                    interpret=_interp(interpret))
    return xs[:n], xb_new[:n]


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def batched_ell_spmv(a: StackedELL, x: jax.Array, *, block_rows: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """y_b = A_b @ x_b over stacked row-ELL: (B, n) -> (B, m), one launch."""
    block_rows = min(block_rows, max(8, a.m))
    vals, m = _pad_multiple(a.vals, block_rows, axis=1)
    cols, _ = _pad_multiple(a.cols, block_rows, axis=1)
    y = batched_ell_spmv_pallas(vals, cols, x, block_rows=block_rows,
                                interpret=_interp(interpret))
    return y[:, :m]


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def batched_fused_dual_update(a: StackedELL, xstar, xbar, yhat, b, coefs,
                              *, block_rows: int = 512,
                              interpret: bool | None = None) -> jax.Array:
    """Per-slot eq. 15 over stacked ELL; coefs (B, 4) = per-slot (c0..c3)."""
    block_rows = min(block_rows, max(8, a.m))
    vals, m = _pad_multiple(a.vals, block_rows, axis=1)
    cols, _ = _pad_multiple(a.cols, block_rows, axis=1)
    yhat_p, _ = _pad_multiple(yhat, block_rows, axis=1)
    b_p, _ = _pad_multiple(b, block_rows, axis=1)
    out = batched_fused_dual_update_pallas(
        jnp.asarray(coefs, jnp.float32), vals, cols, xstar, xbar, yhat_p,
        b_p, block_rows=block_rows, interpret=_interp(interpret))
    return out[:, :m]


@partial(jax.jit, static_argnames=("block_brows", "interpret"))
def batched_bcsr_spmv(a: StackedBCSR, x: jax.Array, *, block_brows: int = 8,
                      interpret: bool | None = None) -> jax.Array:
    """y_b = A_b @ x_b over stacked BCSR: (B, n) -> (B, m), one batch-grid
    launch — the grid carries the slot dimension natively (no more
    vmap-over-``pallas_call``)."""
    nbr = a.nbr
    block_brows = max(1, min(block_brows, nbr))
    pad_br = (-nbr) % block_brows
    vals = jnp.pad(a.vals, ((0, 0), (0, pad_br), (0, 0), (0, 0), (0, 0))) \
        if pad_br else a.vals
    bcols = jnp.pad(a.bcols, ((0, 0), (0, pad_br), (0, 0))) \
        if pad_br else a.bcols
    pad_x = a.nbc * a.bn - x.shape[1]
    xt = (jnp.pad(x, ((0, 0), (0, pad_x))) if pad_x else x) \
        .reshape(x.shape[0], a.nbc, a.bn)
    y = batched_bcsr_spmv_pallas(vals, bcols, xt, block_brows=block_brows,
                                 interpret=_interp(interpret))
    return y.reshape(x.shape[0], -1)[:, :a.m]


def kernel_ops(a: ELL, at: BandedELL, prox: ProxOp, reg: float,
               *, block_rows: int = 512, block_cols: int = 512,
               interpret: bool | None = None):
    """SolverOps running the iteration entirely on the Pallas kernels.

    Thin adapter over the (ell, pallas) registry operator — the fused-pass
    wiring (one-HBM-pass dual update; fused l1 prox, jnp fallback for other
    proxes) lives in repro.operators.builders.
    """
    from repro.operators import make_operator

    return make_operator("ell", "pallas", a, at, prox, reg,
                         block_rows=block_rows, block_cols=block_cols,
                         interpret=interpret).solver_ops()

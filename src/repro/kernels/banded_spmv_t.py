"""Backward operator  z = A^T @ y  over banded column-ELL, as a Pallas kernel.

The hard part of A^T y on TPU: y (length m, up to 1e7 = 40 MB fp32) does NOT
fit VMEM, so a flat column-ELL gather is impossible. TPU adaptation: bucket
nonzeros into row *bands* of band_size rows so each band's y-slice fits VMEM,
and make the band the minor grid dimension so the output column tile stays
resident while the kernel accumulates over bands:

    grid = (n // block_cols, num_bands)        # band minor => out revisited
    z[j-tile] += sum_kb vals[band, j-tile] * y_band[rows[band, j-tile]]

This is the memory-hierarchy answer to the same problem the paper's shuffle
phase solves with per-reducer key grouping (MR2 Job2) — but with *bounded*
staging (VMEM) instead of reducer spill files.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import default_interpret


def _kernel(vals_ref, rows_ref, y_ref, out_ref):
    band = pl.program_id(1)

    @pl.when(band == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[0]                        # (TN, kb)
    rows = rows_ref[0]                        # (TN, kb) band-local int32
    yb = y_ref[...]                           # (band_size,) VMEM slice
    contrib = jnp.sum(vals.astype(jnp.float32)
                      * jnp.take(yb, rows, axis=0).astype(jnp.float32), axis=1)
    out_ref[...] += contrib.astype(out_ref.dtype)


def banded_spmv_t_pallas(vals: jax.Array, rows: jax.Array, y: jax.Array,
                         band_size: int, *, block_cols: int = 512,
                         interpret: bool | None = None):
    num_bands, n, kb = vals.shape
    assert n % block_cols == 0, (n, block_cols)
    assert y.shape[0] == num_bands * band_size
    return pl.pallas_call(
        _kernel,
        grid=(n // block_cols, num_bands),
        in_specs=[
            pl.BlockSpec((1, block_cols, kb), lambda j, b: (b, j, 0)),
            pl.BlockSpec((1, block_cols, kb), lambda j, b: (b, j, 0)),
            pl.BlockSpec((band_size,), lambda j, b: (b,)),
        ],
        out_specs=pl.BlockSpec((block_cols,), lambda j, b: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), y.dtype),
        interpret=default_interpret(interpret),
    )(vals, rows, y)

"""The declarative facade: ``Problem -> plan -> Result``.

One entry point over every solver execution path in the repo.  The caller
states the optimization problem — ``min f(x) s.t. Ax = b`` — and the
planner (repro.plan) picks the execution design: storage format (roofline
selector), backend (jnp vs Pallas kernels), single-device vs shard_map
strategy vs the slot-batched serving engine, and the Lipschitz constant
``Lg`` when none is supplied.  The low-level drivers in ``repro.core`` /
``repro.serve`` remain the kernel layer that plans compile to.

    import repro as pd
    result = pd.Problem(A, b, prox="l1", reg=0.1).solve(tol=1e-4)
    print(result.plan.explain(), result.iterations, result.feasibility)

``A`` may be a dense array, a ``repro.sparse`` COO/ELL/BCSR, or any
``repro.operators.LinearOperator`` (matrix-free).  ``solve_many`` routes a
fleet of Problems through the batched serving engine when they are
servable, falling back to sequential plans otherwise.

>>> import numpy as np
>>> res = solve(np.diag([2.0, 4.0]).astype(np.float32),
...             np.ones(2, np.float32), prox="zero", iterations=300,
...             gamma0=1.0)
>>> [round(float(v), 2) for v in res.x]   # min 0 s.t. diag(2,4) x = 1
[0.5, 0.25]
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.plan import ExecutionPlan, Result, SolveSpec
from repro.plan import plan as _plan
from repro.plan import resolve_spec

__all__ = ["ExecutionPlan", "Problem", "Result", "SolveSpec", "plan",
           "solve", "solve_many"]

#: prox families whose constructor takes a ``reg`` weight.
_REG_FAMILIES = ("l1", "sq_l2", "elastic_net")

_DOWNCAST_WARNED: set = set()


def _warn_downcast(what: str, src) -> None:
    """One warning per (operand, dtype) per process: float64 inputs are
    canonicalized to float32 (jax default), which silently changes the
    caller's tolerance semantics — say so instead."""
    import warnings

    key = (what, str(src))
    if key in _DOWNCAST_WARNED:
        return
    _DOWNCAST_WARNED.add(key)
    warnings.warn(
        f"Problem {what} is {src} but operands are canonicalized to "
        "float32 (jax runs with x64 disabled by default), so float64 "
        "tolerance/conditioning semantics are NOT preserved. Pass "
        "dtype=np.float32 to acknowledge the downcast, or dtype=np.float64 "
        "after jax.config.update('jax_enable_x64', True).",
        UserWarning, stacklevel=4)


def _resolve_dtype(dtype):
    """Explicit dtype > float32 canon; float64 demands jax x64 (otherwise
    jnp.asarray would silently hand back float32 anyway)."""
    if dtype is None:
        return np.dtype(np.float32)
    dt = np.dtype(dtype)
    if dt not in (np.dtype(np.float32), np.dtype(np.float64)):
        raise ValueError(f"dtype must be float32 or float64, got {dt}")
    if dt == np.float64:
        import jax

        if not jax.config.jax_enable_x64:
            raise ValueError(
                "dtype=float64 needs jax x64: call "
                "jax.config.update('jax_enable_x64', True) at startup")
    return dt


class Problem:
    """Declarative ``min f(x) s.t. Ax = b``.

    A      dense (m, n) array, ``repro.sparse`` COO/ELL/BCSR, or a
           ``LinearOperator`` (matrix-free; restricts planning to the
           operator's own execution).
    b      right-hand side, length m.
    prox   a prox-family name from ``repro.core.prox`` (f is built with
           ``reg``/``prox_kwargs``) or a ready ``ProxOp``.
    loss   an ERM loss name ("lasso" | "svm" | "logistic") instead of the
           constraint form: the planner's face-off rule
           (``repro.plan.decide_solver_family``) routes the solve to a
           coordinate-descent family (primal RCD / dual SDCA) over the
           column-major CSC view, and the loss's own composite term
           (l1 for lasso, reg/2 ||.||^2 otherwise) replaces ``prox``.
           ``b`` holds targets (lasso) or +-1 labels (svm/logistic).
    lg     optional Lipschitz constant ``Lg``; when None the planner
           computes ``sum_i ||A_i||^2`` (paper init) or power-iterates.
    gamma0 optional smoothing schedule start; planner default otherwise.
    dtype  operand dtype, canonicalized explicitly: None means float32
           (with a one-time warning when that downcasts float64 inputs —
           the tolerance the caller stated in float64 semantics would
           otherwise silently change); float64 requires jax x64.
    """

    def __init__(self, A: Any, b: Any, prox: Any = "l1",
                 reg: Optional[float] = None, *, loss: str = "",
                 lg: Optional[float] = None,
                 gamma0: Optional[float] = None,
                 prox_kwargs: Optional[dict] = None, dtype: Any = None):
        import jax.numpy as jnp

        from repro.core.prox import ProxOp, get_prox
        from repro.operators.base import LinearOperator
        from repro.sparse.formats import (
            BCSR, COO, ELL, bcsr_to_coo, ell_to_coo,
        )

        self.dtype = _resolve_dtype(dtype)
        self.operator: Optional[LinearOperator] = None
        self._coo = None
        self._dense = None
        if isinstance(A, LinearOperator):
            self.operator = A
            m, n = A.shape
            if m is None or n is None:
                raise ValueError("matrix-free operators must carry a shape")
        elif isinstance(A, COO):
            self._coo = A
            m, n = A.m, A.n
        elif isinstance(A, ELL):
            self._coo = ell_to_coo(A)      # O(stored entries), no densify
            m, n = A.m, A.n
        elif isinstance(A, BCSR):
            self._coo = bcsr_to_coo(A)
            m, n = A.m, A.n
        else:
            arr = np.asarray(A)
            if arr.ndim != 2:
                raise ValueError(f"A must be 2-D, got shape {arr.shape}")
            if dtype is None and arr.dtype == np.float64:
                _warn_downcast("A", arr.dtype)
            self._dense = arr.astype(self.dtype, copy=False)
            m, n = arr.shape
        if self._coo is not None and \
                np.dtype(self._coo.vals.dtype) != self.dtype:
            if dtype is None and \
                    np.dtype(self._coo.vals.dtype) == np.float64:
                _warn_downcast("A.vals", self._coo.vals.dtype)
            self._coo = COO(rows=self._coo.rows, cols=self._coo.cols,
                            vals=jnp.asarray(self._coo.vals, self.dtype),
                            m=self._coo.m, n=self._coo.n)
        self.m, self.n = int(m), int(n)
        self.lg = float(lg) if lg is not None else None
        self.gamma0 = float(gamma0) if gamma0 is not None else None

        b_arr = np.asarray(b)
        if dtype is None and b_arr.dtype == np.float64:
            _warn_downcast("b", b_arr.dtype)
        self.b = jnp.asarray(b_arr, self.dtype)
        if self.b.shape != (self.m,):
            raise ValueError(f"b has shape {self.b.shape}, expected "
                             f"({self.m},)")

        self._stats = None                   # lazy shared MatrixStats
        self.loss = str(loss or "")
        if self.loss:
            from repro.solvers.rcd import LOSSES
            if self.loss not in LOSSES:
                raise ValueError(f"unknown loss {self.loss!r} "
                                 f"(choose from {LOSSES})")
            if self.operator is not None:
                raise ValueError(
                    "loss families need a concrete matrix (the CSC "
                    "coordinate view), not a matrix-free operator")
            # the loss carries its own composite term; the prox records it
            derived = "l1" if self.loss == "lasso" else "sq_l2"
            if not isinstance(prox, str) or prox not in ("l1", derived):
                raise ValueError(
                    f"loss={self.loss!r} carries its own composite term "
                    f"({derived!r}); don't pass a prox")
            prox = derived

        if isinstance(prox, ProxOp):
            # reg=None means the instance's weight is un-introspectable: the
            # planner must not hand it to fused prox kernels (which take a
            # scalar reg) — ExecutionPlan.operator() falls back to the
            # composed ProxOp.apply path, which is always correct.
            self.prox = prox
            self.prox_name = prox.name
            self.reg = float(reg) if reg is not None else None
            self._prox_is_named = False
        else:
            kw = dict(prox_kwargs or {})
            if prox in _REG_FAMILIES:
                kw.setdefault("reg", 1.0 if reg is None else float(reg))
            elif reg is not None:
                raise ValueError(f"prox family {prox!r} takes no reg")
            self.prox = get_prox(prox, **kw)
            self.prox_name = prox
            self.reg = float(kw.get("reg", 0.0))
            self._prox_is_named = not kw or set(kw) == {"reg"}

    # -- canonical views ---------------------------------------------------

    @property
    def coo(self):
        """The COO view (None for matrix-free problems); built lazily from
        a dense input."""
        if self._coo is None and self._dense is not None:
            from repro.sparse.formats import dense_to_coo
            self._coo = dense_to_coo(self._dense)
        return self._coo

    def dense_array(self) -> np.ndarray:
        """The dense (m, n) view; built lazily from COO."""
        if self._dense is None:
            if self._coo is None:
                raise ValueError("matrix-free problem has no dense view")
            from repro.sparse.formats import coo_to_dense
            self._dense = np.asarray(coo_to_dense(self._coo))
        return self._dense

    @property
    def nnz(self) -> Optional[int]:
        if self._coo is not None:
            return int(self._coo.nnz)
        if self._dense is not None:
            return int(np.count_nonzero(self._dense))
        return self.operator.nnz if self.operator is not None else None

    @property
    def density(self) -> float:
        nnz = self.nnz
        if nnz is None:
            return float("nan")
        return nnz / max(1, self.m * self.n)

    @property
    def stats(self):
        """ONE cached ``MatrixStats`` pass (``operators.select``), shared
        by the roofline format selector, the Frobenius Lg estimate, the
        serving cost model, and the solver-family face-off rule (None for
        matrix-free problems)."""
        if self._stats is None and self.coo is not None:
            from repro.operators import MatrixStats
            self._stats = MatrixStats.from_coo(self.coo)
        return self._stats

    def __repr__(self):
        kind = ("operator" if self.operator is not None else
                "coo" if self._coo is not None else "dense")
        extra = f", loss={self.loss!r}" if self.loss else ""
        return (f"Problem({self.m}x{self.n} {kind}, nnz={self.nnz}, "
                f"prox={self.prox_name!r}, reg={self.reg}{extra})")

    # -- the facade --------------------------------------------------------

    def plan(self, spec: SolveSpec | None = None, **overrides) -> ExecutionPlan:
        """Plan without executing — inspect/override, then ``.solve()``."""
        return _plan(self, spec, **overrides)

    def solve(self, spec: SolveSpec | None = None, **overrides) -> Result:
        """Plan and execute in one call; kwargs are SolveSpec fields."""
        return self.plan(spec, **overrides).solve()

    # -- engine admission --------------------------------------------------

    def to_request(self, uid: int = 0, tol: float = 1e-3,
                   max_iterations: int = 10_000,
                   gamma0: Optional[float] = None,
                   solver_family: str = "auto",
                   seed: Optional[int] = None):
        """Adapt to the serving engine's request type (SolveRequest): the
        engine continuous-batches Problems whose prox is a servable named
        family over a concrete sparse matrix.  Loss problems resolve their
        coordinate family through the planner's face-off rule
        (``solver_family`` overrides it) and are stamped with the loss and
        coordinate-hash ``seed`` the engine replays."""
        from repro.serve.solver_engine import (
            BATCHED_PROX_FAMILIES, SolveRequest,
        )

        if self.coo is None:
            raise ValueError("engine admission needs a concrete matrix")
        g0 = gamma0 if gamma0 is not None else \
            (self.gamma0 if self.gamma0 is not None else 100.0)
        if self.loss:
            from repro.plan import decide_solver_family
            family, _ = decide_solver_family(self.loss, self.stats,
                                             solver_family)
            return SolveRequest(uid=uid, coo=self.coo, b=self.b,
                                prox=self.prox_name, reg=self.reg,
                                lg=self.lg, gamma0=float(g0), tol=tol,
                                max_iterations=max_iterations,
                                family=family, loss=self.loss, seed=seed)
        if not self._prox_is_named or \
                self.prox_name not in BATCHED_PROX_FAMILIES:
            raise ValueError(
                f"prox {self.prox_name!r} is not a servable family "
                f"(supported: {BATCHED_PROX_FAMILIES})")
        return SolveRequest(uid=uid, coo=self.coo, b=self.b,
                            prox=self.prox_name, reg=self.reg, lg=self.lg,
                            gamma0=float(g0), tol=tol,
                            max_iterations=max_iterations)

    # -- planner/result helpers (host-side) --------------------------------

    def relative_feasibility(self, x: np.ndarray) -> float:
        """Host-side ||A x - b|| / max(1, ||b||) (solve_tol's criterion)."""
        b = np.asarray(self.b)
        if self._coo is not None:
            coo = self._coo
            # float64 on purpose: host-side residual for the feasibility
            # certificate — exact criterion, never a device operand
            # repro: allow[R4] -- host-side certificate accumulator, not an operand
            r = np.zeros(self.m, np.float64)
            np.add.at(r, np.asarray(coo.rows),
                      # repro: allow[R4] -- same certificate accumulation
                      np.asarray(coo.vals, np.float64)
                      # repro: allow[R4] -- same certificate accumulation
                      * np.asarray(x, np.float64)[np.asarray(coo.cols)])
            r -= b
        elif self._dense is not None:
            r = self._dense @ np.asarray(x, np.float32) - b
        else:
            import jax.numpy as jnp
            r = np.asarray(self.operator.matvec(jnp.asarray(x))) - b
        return float(np.linalg.norm(r) / max(1.0, np.linalg.norm(b)))

    def reference_operator(self):
        """A jnp reference LinearOperator over this matrix (certificates,
        power iteration); the caller-provided operator when matrix-free."""
        if self.operator is not None:
            return self.operator
        from repro.operators import make_operator
        return make_operator("coo", "jnp", self.coo)

    def reference_ops(self):
        return self.reference_operator().solver_ops()


def plan(problem: Problem, spec: SolveSpec | None = None,
         **overrides) -> ExecutionPlan:
    """Module-level alias of ``Problem.plan`` (``repro.plan.plan``)."""
    return _plan(problem, spec, **overrides)


def solve(A, b, prox: Any = "l1", reg: Optional[float] = None,
          **spec_overrides) -> Result:
    """One-shot convenience: ``Problem(A, b, prox, reg).solve(...)``."""
    return Problem(A, b, prox, reg).solve(**spec_overrides)


def solve_many(problems: list[Problem], spec: SolveSpec | None = None,
               **overrides) -> list[Result]:
    """Solve a fleet of Problems, batched when possible.

    When every problem is servable (concrete sparse matrix + named prox
    family in ``BATCHED_PROX_FAMILIES``), a tolerance is set, and no
    distributed strategy was requested, the fleet runs through the
    slot-batched serving engine (``repro.serve.SolverEngine``) — one
    compiled masked A2 step per shape bucket, per-slot early exit.
    Otherwise each problem is planned and solved sequentially.  Results
    come back in input order; engine-batched Results share one descriptive
    ExecutionPlan (execution="engine") and carry no PDState.
    """
    import time

    spec = resolve_spec(spec, overrides)
    from repro.serve.solver_engine import BATCHED_PROX_FAMILIES

    def _servable(p) -> bool:
        if p.coo is None:
            return False
        if getattr(p, "loss", ""):       # rcd requests bucket by family/loss
            return True
        return p._prox_is_named and p.prox_name in BATCHED_PROX_FAMILIES

    servable = (spec.batch != "never" and spec.tol is not None
                and spec.strategy is None and spec.mesh is None
                and len(problems) > 1
                and all(_servable(p) for p in problems))
    if not servable:
        return [_plan(p, spec).solve() for p in problems]

    from repro.serve.solver_engine import SolverEngine

    fmt = spec.format if spec.format in ("ell", "bcsr") else "ell"
    backend = spec.backend if spec.backend in ("jnp", "pallas") else "jnp"
    eng = SolverEngine(slots=spec.slots, fmt=fmt, backend=backend,
                       check_every=spec.check_every,
                       interpret=spec.interpret, devices=spec.devices,
                       shard_above=spec.shard_above)
    requests = [p.to_request(uid=i, tol=spec.tol,
                             max_iterations=spec.max_iterations,
                             gamma0=spec.gamma0,
                             solver_family=spec.solver_family)
                for i, p in enumerate(problems)]
    t0 = time.perf_counter()
    for r in requests:
        eng.submit(r)
    done = {r.uid: r for r in eng.run()}
    wall = time.perf_counter() - t0
    shared = ExecutionPlan(
        problem=None, spec=spec, execution="engine", algorithm="a2",
        format=fmt, backend=backend, strategy=None, mesh=None,
        lg=float("nan"), gamma0=float("nan"),
        params=dict(slots=spec.slots, buckets=len(eng.buckets),
                    devices=len(eng.devices),
                    sharded_admitted=eng.stats["sharded_admitted"]),
        placement="replicated" if len(eng.devices) > 1 else "single",
        reasons=dict(execution=(
            f"{len(problems)} servable problems with tol set: slot-batched "
            "engine (one compiled masked step per shape bucket, "
            f"{len(eng.devices)} device(s))")))
    results = []
    for i, p in enumerate(problems):
        req = done[i]
        import jax.numpy as jnp
        x = jnp.asarray(req.x)
        if p.loss:      # ERM objective, not the composite term alone
            from repro.solvers import reference_objective
            objective = reference_objective(p.dense_array(),
                                            np.asarray(p.b), p.reg,
                                            p.loss, np.asarray(x))
        else:
            objective = float(p.prox.value(x))
        results.append(Result(
            x=x, plan=shared, iterations=req.iterations,
            feasibility=float(req.feasibility),
            objective=objective,
            timings=dict(total_s=wall, per_request_s=wall / len(problems)),
            state=None))
    return results

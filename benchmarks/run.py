"""Benchmark harness — one function per paper table/figure.

  table1   dataset generation + statistics           (paper Table 1)
  spmv_formats  forward/backward operator microbench per registry
           (format, backend): COO vs ELL vs tiled BCSR, jnp and Pallas,
           with the roofline selector's modeled times alongside
  table2_4 stage timings per implementation x dataset (paper Tables 2-4):
           implementations = {coo/segment-sum, ELL/gather (jnp), Pallas
           kernels (interpret)} on CPU at 1/50 scale; stages match the
           paper's definitions (read+Lg, init, then two iterations).
  table5   strong scaling of the dualpart strategy over 1/2/4/8 host
           devices (subprocess per point — device count locks at jax init)
  fig2b    total time vs data size per implementation  (paper Fig. 2b)
  network  per-iteration collective wire bytes per strategy from lowered
           HLO on 8 devices — the quantitative version of the paper's
           MR1-4 shuffle-traffic analysis (+ A1 vs A2 fused comparison)
  solver_serving  requests/sec of the batched solver serving engine
           (bucketed + slot-batched vmapped A2, per-slot early exit) vs a
           sequential solve_tol loop over the same ragged request stream —
           the Dünner-et-al. per-task-overhead comparison; also records a
           jit-cached sequential steelman
  sharded_serving  requests/sec of the serving engine vs device count
           (1/2/4/8 fake CPU devices, subprocess per point) on a mixed
           workload whose oversized requests planner-route to mesh-wide
           sharded buckets, swept over the ``--format`` axis (ell gather
           bodies vs tiled-BCSR MXU bodies) with the chosen bucket body
           and modeled operand bytes recorded per point
  rcd_serving  coordinate-descent face-off through the serving engine:
           rps + iterations-to-tol of primal RCD vs dual SDCA vs the A2
           baseline at >= 3 n/d aspect ratios (logistic fleets in csc
           buckets; a consistent lasso-constraint A2 arm on the same
           matrices), with the planner's recorded ``solver_family``
           reason per point (``--solver-family`` overrides the rule,
           ``--quick`` shrinks the sweep)
  open_loop_serving  tail latency of the OPEN-LOOP service layer
           (serve/frontend.py): seeded Poisson arrivals drive the engine
           at >= 3 offered loads (under / near / over the engine's
           closed-loop capacity); per load p50/p99 arrive-to-done latency
           and goodput-under-SLO land in open_loop_serving.json.  The
           closed-loop solver_serving rps says what the engine can do;
           this says what callers experience when work arrives on its own
           clock (``--quick`` shrinks the sweep for CI smoke)
  api_overhead  the declarative facade (repro.api Problem -> plan ->
           Result) vs the raw kernel layer on identical work; asserts the
           planner + Result assembly cost <5%
  autotune measured autotune tables (benchmarks/autotune.py sweep): spmv
           cells per (format, backend, tile) + fused check-block cells per
           (slot width, check_every) -> autotune.json, consulted by the
           format selector via REPRO_AUTOTUNE_TABLE

Usage: ``python benchmarks/run.py [mode ...] [--format ell|bcsr|both]
[--seed N] [--quick] [--solver-family F] [--arrival-rate R ...]
[--slo S] [--deadline D]``
(default: all modes, both formats).  ``--seed`` threads one base seed
through every request mix and arrival stream, so serving runs are
bit-reproducible run-to-run.
Prints ``name,us_per_call,derived`` CSV; details land in
experiments/bench/*.json (schema documented in benchmarks/README.md).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# REPRO_BENCH_OUT redirects every artifact (CI smokes write to a scratch
# dir instead of clobbering the committed experiments/bench/*.json)
OUT_DIR = (os.environ.get("REPRO_BENCH_OUT")
           or os.path.join(REPO, "experiments", "bench"))
SCALE = 50  # paper datasets / SCALE (CPU container)

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def _small(ds: str):
    from repro.configs.paper_problems import get_config
    cfg = get_config(ds)
    return cfg, max(2000, cfg.m // SCALE), max(200, cfg.n // SCALE)


def table1_datasets():
    from repro.sparse import random_coo
    out = {}
    for ds in ("d1", "d2", "d3", "d4"):
        cfg, m, n = _small(ds)
        t0 = time.perf_counter()
        coo = random_coo(m, n, cfg.row_nnz, seed=0)
        dt = time.perf_counter() - t0
        rows = np.bincount(np.asarray(coo.rows), minlength=m)
        cols = np.bincount(np.asarray(coo.cols), minlength=n)
        rec = dict(m=m, n=n, nnz=int(coo.nnz),
                   row=(int(rows.min()), float(rows.mean()), int(rows.max())),
                   col=(int(cols.min()), float(cols.mean()), int(cols.max())),
                   bytes=int(coo.nnz) * 12)
        out[ds] = rec
        emit(f"table1/{ds}/generate", dt * 1e6,
             f"m={m};n={n};nnz={rec['nnz']};col_mean={rec['col'][1]:.0f}")
    return out


def _implementations(coo, prox, reg):
    from repro.operators import make_solver_ops

    return {
        "coo": make_solver_ops(coo, "coo", "jnp"),
        "ell": make_solver_ops(coo, "ell", "jnp"),
        "pallas": make_solver_ops(coo, "ell", "pallas", prox=prox, reg=reg,
                                  band_size=4096),
    }


def table2_4_stage_timings():
    """Paper stages: 1 read+Lg, 2+3 init (x0 and yhat0 fused in A2),
    4+5 first iteration, 6 second iteration."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core.prox import get_prox
    from repro.core.solver import a2_init, a2_step
    from repro.sparse import col_norms_sq, make_lasso

    results = {}
    for ds in ("d1", "d2", "d3", "d4"):
        cfg, m, n = _small(ds)
        cfg2 = dataclasses.replace(cfg, m=m, n=n, nnz=m * cfg.row_nnz)
        t0 = time.perf_counter()
        coo, b, _ = make_lasso(cfg2, seed=0)
        lg = float(jnp.sum(col_norms_sq(coo)))           # stage 1
        stage1 = time.perf_counter() - t0
        prox = get_prox("l1", reg=cfg.reg)
        for impl, ops in _implementations(coo, prox, cfg.reg).items():
            stages = {"stage1": stage1}
            init = jax.jit(lambda bb: a2_init(ops, prox, bb, lg, 100.0))
            step = jax.jit(lambda s, bb: a2_step(ops, prox, bb, lg, 100.0, s))
            t0 = time.perf_counter()
            state = jax.block_until_ready(init(b))
            stages["stage2_3"] = time.perf_counter() - t0
            for name in ("stage4_5", "stage6"):
                t0 = time.perf_counter()
                state = jax.block_until_ready(step(state, b))
                stages[name] = time.perf_counter() - t0
            total = sum(stages.values())
            results[f"{ds}/{impl}"] = stages
            emit(f"table2_4/{ds}/{impl}/total", total * 1e6,
                 ";".join(f"{k}={v*1e3:.1f}ms" for k, v in stages.items()))
    return results


def spmv_formats():
    """Forward/backward spmv microbenchmarks per (format, backend) — the
    operator-registry comparison table (COO vs ELL vs tiled BCSR, jnp and
    Pallas), plus the roofline selector's modeled times for calibration.
    Emits experiments/bench/spmv_formats.json."""
    import jax
    import jax.numpy as jnp

    from repro.operators import estimate_formats, from_coo, select_format
    from repro.sparse import make_lasso

    import dataclasses

    def _time(fn, arg, reps=5):
        out = jax.block_until_ready(fn(arg))          # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out = jax.block_until_ready(fn(arg))
        return (time.perf_counter() - t0) / reps

    os.makedirs(OUT_DIR, exist_ok=True)
    results = {}
    variants = [("coo", "jnp"), ("ell", "jnp"), ("ell", "pallas"),
                ("bcsr", "jnp"), ("bcsr", "pallas")]
    for ds in ("d1", "d2"):
        cfg, m, n = _small(ds)
        cfg2 = dataclasses.replace(cfg, m=m, n=n, nnz=m * cfg.row_nnz)
        coo, b, _ = make_lasso(cfg2, seed=0)
        x = jnp.ones((n,), jnp.float32)
        y = jnp.ones((m,), jnp.float32)
        est = estimate_formats(coo)
        plan = select_format(coo)
        rec = {"m": m, "n": n, "nnz": int(coo.nnz),
               "selector": {"format": plan.format, "params": plan.params},
               "modeled_s": {k: v["s"] for k, v in est.items()},
               "measured": {}}
        for fmt, backend in variants:
            op = from_coo(coo, fmt, backend, bm=8, bn=128)
            fwd = _time(jax.jit(op.matvec), x)
            bwd = _time(jax.jit(op.rmatvec), y)
            # analytic-vs-measured error per format: the miscalibration the
            # autotune measured tables (benchmarks/autotune.py) correct
            err = est[fmt]["s"] / fwd if fmt in est and fwd > 0 else None
            rec["measured"][f"{fmt}/{backend}"] = {
                "fwd_s": fwd, "bwd_s": bwd, "stats": op.stats,
                "error_ratio": err}
            emit(f"spmv_formats/{ds}/{fmt}/{backend}/fwd", fwd * 1e6,
                 f"bwd_us={bwd*1e6:.1f};nnz={coo.nnz}"
                 + (f";error_ratio={err:.2e}" if err else ""))
        results[ds] = rec
    with open(os.path.join(OUT_DIR, "spmv_formats.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    return results


_SCALING_SNIPPET = r"""
import os, sys, time, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%DEV%"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.sparse import make_lasso
from repro.core.prox import get_prox
from repro.core.distributed import build_problem, make_solve_fn, _pad_to
from repro.configs.paper_problems import PaperProblemConfig
cfg = PaperProblemConfig(name="bench", m=%M%, n=%N%, nnz=%M% * 10, reg=0.1)
coo, b, _ = make_lasso(cfg, seed=0)
prox = get_prox("l1", reg=0.1)
mesh = Mesh(np.array(jax.devices()).reshape(%DEV%), ("p",))
problem = build_problem(coo, mesh, "%STRATEGY%")
fn = make_solve_fn(problem, prox, 100.0, iterations=%ITERS%, algorithm="%ALG%")
bp = _pad_to(b, problem.m_pad)
state = jax.block_until_ready(fn(problem.operands, bp))   # compile + warm
t0 = time.perf_counter()
state = jax.block_until_ready(fn(problem.operands, bp))
dt = time.perf_counter() - t0
print(json.dumps({"dt": dt}))
"""


def _run_scaling(dev, m, n, strategy="dualpart", alg="a2", iters=20):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = (_SCALING_SNIPPET.replace("%DEV%", str(dev))
            .replace("%M%", str(m)).replace("%N%", str(n))
            .replace("%STRATEGY%", strategy).replace("%ALG%", alg)
            .replace("%ITERS%", str(iters)))
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    if p.returncode != 0:
        raise RuntimeError(p.stderr[-2000:])
    return json.loads(p.stdout.strip().splitlines()[-1])["dt"]


def table5_strong_scaling():
    """Fixed problem, 1/2/4/8 host 'nodes' (threads on one CPU, so the curve
    is indicative; the production scaling claim comes from the dry-run
    collective model in EXPERIMENTS.md)."""
    m, n = 40000, 2000
    out = {}
    for dev in (1, 2, 4, 8):
        dt = _run_scaling(dev, m, n)
        out[str(dev)] = dt
        emit(f"table5/strong/dev{dev}", dt / 20 * 1e6,
             f"speedup_vs_1={out['1']/dt:.2f}x")
    return out


def fig2b_datasize_scaling():
    out = {}
    for ds in ("d1", "d2", "d3"):
        cfg, m, n = _small(ds)
        dt = _run_scaling(4, m, n, iters=10)
        out[ds] = dt
        emit(f"fig2b/{ds}/dev4", dt / 10 * 1e6, f"m={m};n={n}")
    return out


_NETWORK_SNIPPET = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.sparse import make_lasso
from repro.core.prox import get_prox
from repro.core.distributed import build_problem, make_step_fn
from repro.core.solver import PDState
from repro.configs.paper_problems import PaperProblemConfig
from repro.roofline.analysis import collective_stats
cfg = PaperProblemConfig(name="net", m=8000, n=800, nnz=80000, reg=0.1)
coo, b, _ = make_lasso(cfg, seed=0)
prox = get_prox("l1", reg=0.1)
out = {}
devs = np.array(jax.devices())
for strategy, mesh in [("rowpart", Mesh(devs.reshape(8), ("p",))),
                       ("colpart", Mesh(devs.reshape(8), ("p",))),
                       ("dualpart", Mesh(devs.reshape(8), ("p",))),
                       ("block2d", Mesh(devs.reshape(2, 4), ("data", "model")))]:
    for alg in ("a1", "a2"):
        problem = build_problem(coo, mesh, strategy)
        step = make_step_fn(problem, prox, 100.0, algorithm=alg)
        xs = jax.ShapeDtypeStruct((problem.n_pad,), jnp.float32)
        ys = jax.ShapeDtypeStruct((problem.m_pad,), jnp.float32)
        state = PDState(xbar=xs, xstar=xs, yhat=ys,
                        gamma=jax.ShapeDtypeStruct((), jnp.float32),
                        k=jax.ShapeDtypeStruct((), jnp.int32))
        bs = jax.ShapeDtypeStruct((problem.m_pad,), jnp.float32)
        compiled = step.lower(problem.operands, bs, state).compile()
        st = collective_stats(compiled.as_text(), default_group=8)
        out[strategy + "/" + alg] = {"wire": st.wire_bytes,
                                     "by_op": st.by_op, "count": st.count}
print(json.dumps(out))
"""


def network_per_strategy():
    """Collective bytes/iteration per strategy x algorithm (HLO-derived) —
    the paper's MR1-4/Spark shuffle-cost comparison, measured exactly."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", _NETWORK_SNIPPET], env=env,
                       capture_output=True, text=True, timeout=900)
    if p.returncode != 0:
        raise RuntimeError(p.stderr[-2000:])
    out = json.loads(p.stdout.strip().splitlines()[-1])
    for key, rec in out.items():
        emit(f"network/{key}", 0.0,
             f"wire_bytes={rec['wire']:.3e};collectives={rec['count']}")
    return out


def solver_serving(check_every=None, fused=None, seed=0):
    """Throughput of the batched solver serving engine vs sequential solves
    over one ragged request stream (3 shape families x 2 regularizers).

    Baselines:
      sequential      — the natural loop: one facade solve per request
                        (re-traces/compiles per call, exactly like the
                        repo's examples) — the per-task overhead the
                        engine amortizes away via bucketing.  Includes the
                        facade's planning cost, which the ``api_overhead``
                        mode separately bounds at <5% of a raw solve_tol
                        call, so the ratio still measures batching.
      sequential_jit  — steelman: one jit-cached solve per shape family
                        (zero per-request compile; only reachable when the
                        operator pytrees are hand-threaded through jit).
    The engine is measured warm (bucket step executables AOT-compiled by a
    first stream — the serving steady state); the measured window's
    per-phase wall time (admit / splice / dispatch / harvest / compile)
    lands in ``tick_breakdown`` — ``compile_s`` ~ 0 there is the proof
    that admission re-uses the AOT bucket executables instead of paying
    per-bucket jit.  The engine runs with ``sanitize=True``, so
    ``tick_breakdown`` also carries the strict counters ``retraces`` and
    ``disallowed_transfers`` — both must be 0 in the measured window
    (every tick executed under ``transfer_guard("disallow")`` without a
    single recompile).  Emits experiments/bench/solver_serving.json.
    """
    import time as _time

    import jax

    from repro.core.prox import get_prox
    from repro.core.solver import solve_tol
    from repro.launch.solver_serve import make_problems, solve_sequentially
    from repro.plan import decide_check_every
    from repro.serve import create_engine

    num, slots, tol = 24, 8, 1e-2
    check_every, ce_reason = decide_check_every(check_every)

    # base seed offsets keep the warm and measured mixes distinct while
    # the whole run stays bit-reproducible per --seed
    warm_seed, measure_seed = seed + 10, seed + 11

    def requests(seed):
        return [p.to_request(uid=i, tol=tol, max_iterations=4000)
                for i, p in enumerate(make_problems(num, seed=seed))]

    # sanitize=True: tick phases run under transfer_guard("disallow") and
    # the engine counts retraces + implicit transfers — the measured
    # (warm) window must report 0/0, turning the AOT claim into data
    eng = create_engine("solver", slots=slots, fmt="ell", backend="jnp",
                        check_every=check_every, fused=fused, sanitize=True)
    for r in requests(seed=warm_seed):                 # warm: compile buckets
        eng.submit(r)
    eng.run()
    warm_phase = dict(eng.phase_s, **eng.tick_counters)
    eng.stats = {"steps": 0, "iterations": 0, "admitted": 0}
    eng.phase_s = {k: 0.0 for k in eng.phase_s}
    eng.tick_counters = {k: 0 for k in eng.tick_counters}
    t0 = _time.perf_counter()
    for r in requests(seed=measure_seed):
        eng.submit(r)
    done = eng.run()
    dt_eng = _time.perf_counter() - t0
    tick = dict(eng.phase_s, **eng.tick_counters)
    assert len(done) == num

    t0 = _time.perf_counter()
    solve_sequentially(make_problems(num, seed=measure_seed), tol=tol,
                       check_every=check_every)
    dt_seq = _time.perf_counter() - t0

    from functools import partial

    from repro.operators import make_operator
    from repro.sparse.formats import ELL, coo_to_ell, transpose_coo

    @partial(jax.jit, static_argnames=("n_ell", "m_ell"))
    def _jit_solve(vals, cols, tvals, tcols, n_ell, m_ell, b, lg, g0, reg):
        ops = make_operator("ell", "jnp", ELL(vals, cols, n_ell),
                            ELL(tvals, tcols, m_ell)).solver_ops()
        return solve_tol(ops, get_prox("l1", reg=reg), b, lg, g0,
                         max_iterations=4000, tol=tol,
                         check_every=check_every)

    def run_jit_seq(reqs):
        for r in reqs:
            e = coo_to_ell(r.coo, pad_to=8)
            et = coo_to_ell(transpose_coo(r.coo), pad_to=8)
            jax.block_until_ready(_jit_solve(
                e.vals, e.cols, et.vals, et.cols, e.n, et.n, r.b, r.lg,
                r.gamma0, r.reg))

    run_jit_seq(requests(seed=warm_seed))                      # warm
    t0 = _time.perf_counter()
    run_jit_seq(requests(seed=measure_seed))
    dt_jit = _time.perf_counter() - t0

    rec = dict(
        requests=num, slots=slots, tol=tol, seed=seed,
        check_every=check_every,
        check_every_reason=ce_reason, fused=eng.fused,
        buckets=len(eng.buckets),
        engine_s=dt_eng, sequential_s=dt_seq, sequential_jit_s=dt_jit,
        rps_engine=num / dt_eng, rps_sequential=num / dt_seq,
        rps_sequential_jit=num / dt_jit,
        speedup_vs_sequential=dt_seq / dt_eng,
        speedup_vs_sequential_jit=dt_jit / dt_eng,
        iterations=eng.stats["iterations"], steps=eng.stats["steps"],
        tick_breakdown=tick, tick_breakdown_warm=warm_phase)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "solver_serving.json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)
    emit("solver_serving/engine", dt_eng / num * 1e6,
         f"rps={rec['rps_engine']:.1f};slots={slots}")
    emit("solver_serving/tick_breakdown",
         sum(v for k, v in tick.items() if k.endswith("_s"))
         / max(1, eng.stats["steps"]) * 1e6,
         ";".join(f"{k}={v*1e3:.1f}ms" for k, v in sorted(tick.items())
                  if k.endswith("_s"))
         + f";retraces={tick['retraces']}"
           f";disallowed_transfers={tick['disallowed_transfers']}"
           f";steps={eng.stats['steps']}")
    emit("solver_serving/sequential", dt_seq / num * 1e6,
         f"rps={rec['rps_sequential']:.1f};"
         f"speedup={rec['speedup_vs_sequential']:.1f}x")
    emit("solver_serving/sequential_jit", dt_jit / num * 1e6,
         f"rps={rec['rps_sequential_jit']:.1f};"
         f"speedup={rec['speedup_vs_sequential_jit']:.2f}x")
    return rec


_SHARDED_SERVING_SNIPPET = r"""
import os, sys, time, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%DEV%"
import numpy as np, jax
from repro.launch.solver_serve import make_problems
from repro.serve import ShardedBucketKey, SolverEngine

NUM, SLOTS, TOL, CHECK = %NUM%, %SLOTS%, 1e-2, 16
SHARD_ABOVE = %SHARD_ABOVE%
GRID = %GRID%

def requests():
    probs = make_problems(NUM, seed=%SEED%, big_every=NUM,
                          big_shape=%BIG%,
                          shapes=[(96, 24), (64, 16), (120, 30)])
    return [p.to_request(uid=i, tol=TOL, max_iterations=4000)
            for i, p in enumerate(probs)]

eng = SolverEngine(slots=SLOTS, fmt="%FMT%", backend="jnp",
                   check_every=CHECK, shard_above=SHARD_ABOVE, grid=GRID)
for r in requests():            # warm: same stream, compile every bucket
    eng.submit(r)
eng.run()
eng.stats = {"steps": 0, "iterations": 0, "admitted": 0,
             "sharded_admitted": 0}
dt = 1e18
for _ in range(2):              # best-of-2 warm repeats (steady state)
    t0 = time.perf_counter()
    for r in requests():
        eng.submit(r)
    done = eng.run()
    dt = min(dt, time.perf_counter() - t0)
    assert len(done) == NUM
sharded = [k for k in eng.buckets if isinstance(k, ShardedBucketKey)]
rec = {"dt": dt, "rps": NUM / dt,
       "devices": len(eng.devices),
       "buckets": len(eng.buckets),
       "sharded_admitted": eng.stats["sharded_admitted"] // 2,
       "bucket_body": (f"{sharded[0].fmt}/{sharded[0].strategy}"
                       if sharded else None),
       "bucket_slot_bytes": (eng.bucket_slot_bytes(sharded[0])
                             if sharded else None)}
if sharded:
    from repro.plan import sharded_wire_bytes
    k = sharded[0]
    wire = sharded_wire_bytes(k.strategy, 1, k.m_pad, k.n_pad, k.ndev,
                              grid=k.grid)
    rec["grid_shape"] = list(k.grid) if k.grid else None
    rec["wire_bytes"] = wire
    rec["wire_reason"] = (
        f"{wire['total']} collective wire bytes/device per iteration per "
        f"slot (fwd {wire['fwd']} + bwd {wire['bwd']}, ring model) for "
        f"{k.strategy}" + (f" {k.grid[0]}x{k.grid[1]}" if k.grid else "")
        + f" over {k.ndev} devices")
print(json.dumps(rec))
"""


def sharded_serving(formats=("ell", "bcsr"), seed=0, grids=None,
                    quick=False):
    """Serving-engine throughput vs device count on one mixed workload:
    ragged small requests (replicated buckets — pinned round-robin or
    slot-axis sharded by queue depth) plus ONE oversized request above
    ``shard_above`` stored entries.  On >= 2 devices the planner routes
    the oversized problem to a mesh-wide sharded bucket whose shards stay
    device-resident across ticks; a 1-device engine cannot hold it
    resident and must stream its operands every tick — the data-locality
    gap (Dünner et al.) this benchmark exists to measure.

    The ``--format`` axis runs the sweep per storage format: "ell" (VPU
    gather bodies, the full 1/2/4/8 curve) and "bcsr" (tiled MXU bodies,
    endpoints 1/8) — the per-device bucket-body choice
    (``repro.plan.decide_bucket_body``) and its modeled operand bytes are
    recorded per point.  The ``--grid`` axis re-runs the 8-device point
    per gridpart sub-mesh shape (default 1x8 / 2x4 / 4x2 / 8x1, on the
    ell body) — each grid point records its ``grid_shape`` and the
    planner's wire-byte reason (``repro.plan.sharded_wire_bytes``, the
    same ring model ``roofline.collective_stats`` charges), so the sweep
    shows where the 2-D layouts beat the 1-D ones on collective bytes.
    One subprocess per point (device count locks at jax init), engine
    measured warm, best of 2 repeats; emits
    experiments/bench/sharded_serving.json.  The acceptance gate is the
    best ``by_grid`` rps over the 1-device rps ``> 1`` with
    ``sharded_admitted >= 1`` — NOT the legacy ``speedup_8v1`` mirror:
    dualpart's shard-resident backward trades its transpose operand for
    a scatter-add the CPU backend runs serially, so that mirror sits
    below 1 on fake host devices even though the wire bytes halved
    (benchmarks/README.md spells out the caveat).  ``--quick`` shrinks
    the mix for a CI smoke (no speedup gate)."""
    num, slots, shard_above = (6, 2, 6_000) if quick else (25, 4, 20_000)
    big_shape = (1024, 128) if quick else (8192, 512)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = {"requests": num, "slots": slots, "big_shape": list(big_shape),
           "shard_above": shard_above, "seed": seed, "quick": bool(quick),
           "formats": {}}

    def run_point(dev, fmt, grid=None):
        code = (_SHARDED_SERVING_SNIPPET
                .replace("%DEV%", str(dev)).replace("%NUM%", str(num))
                .replace("%SLOTS%", str(slots))
                .replace("%SHARD_ABOVE%", str(shard_above))
                .replace("%SEED%", str(seed + 21))
                .replace("%BIG%", repr(tuple(big_shape)))
                .replace("%GRID%", repr(tuple(grid) if grid else None))
                .replace("%FMT%", fmt))
        p = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=900)
        if p.returncode != 0:
            raise RuntimeError(p.stderr[-2000:])
        return json.loads(p.stdout.strip().splitlines()[-1])

    for fmt in formats:
        if quick:
            devs = (1, 8)
        else:
            devs = (1, 2, 4, 8) if fmt == "ell" else (1, 8)
        by_dev = {}
        for dev in devs:
            rec = by_dev[str(dev)] = run_point(dev, fmt)
            emit(f"sharded_serving/{fmt}/dev{dev}", rec["dt"] / num * 1e6,
                 f"rps={rec['rps']:.1f};buckets={rec['buckets']};"
                 f"sharded={rec['sharded_admitted']};"
                 f"body={rec['bucket_body']}")
        one, eight = by_dev["1"], by_dev["8"]
        speedup = eight["rps"] / one["rps"]
        out["formats"][fmt] = {"by_devices": by_dev,
                               "speedup_8v1": speedup}
        emit(f"sharded_serving/{fmt}/speedup_8v1", 0.0,
             f"speedup={speedup:.2f}x;"
             f"sharded_at_8={eight['sharded_admitted']};"
             f"slot_bytes={eight['bucket_slot_bytes']}")
    # the gridpart sub-mesh axis: 8-device points per (rows, cols) shape
    # on the first requested format's body
    grid_fmt = formats[0]
    by_grid = {}
    for grid in (grids or ((1, 8), (2, 4), (4, 2), (8, 1))):
        rec = run_point(8, grid_fmt, grid=grid)
        gname = f"{grid[0]}x{grid[1]}"
        by_grid[gname] = rec
        emit(f"sharded_serving/{grid_fmt}/grid{gname}",
             rec["dt"] / num * 1e6,
             f"rps={rec['rps']:.1f};body={rec['bucket_body']};"
             f"wire={rec.get('wire_bytes', {}).get('total')}")
    out["by_grid"] = by_grid
    out["grid_format"] = grid_fmt
    if "ell" in out["formats"]:
        # legacy top-level mirror of the ell curve (schema compatibility)
        out["by_devices"] = out["formats"]["ell"]["by_devices"]
        out["speedup_8v1"] = out["formats"]["ell"]["speedup_8v1"]
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "sharded_serving.json"), "w") as f:
        json.dump(out, f, indent=1, default=float)
    return out


def open_loop_serving(seed=0, quick=False, arrival_rates=None, slo=None,
                      deadline=None, assert_no_retraces=False):
    """Tail latency of the open-loop service layer: a seeded Poisson
    stream drives the engine through ``serve/frontend.py`` at >= 3
    offered loads — under, near, and over the engine's closed-loop
    capacity (solver_serving measured ~220 rps on this container) — on a
    WallClock (real compute, idle gaps skipped, never slept).  Arrival
    TIMES are fixed per (seed, rate), independent of machine speed, so
    the offered schedule is bit-reproducible; per load the report records
    p50/p99 arrive-to-done latency, goodput-under-SLO (completions within
    ``slo`` seconds of arrival per second of serving time), queue wait,
    and the front-end's phase mirror of the engine's tick breakdown.
    ``--deadline`` adds a relative deadline to every request, so the
    over-saturated points also exercise expiry (reclaimed slots) instead
    of unbounded queueing.  Emits experiments/bench/open_loop_serving.json
    (schema in benchmarks/README.md); ``--quick`` shrinks the stream for
    the CI smoke."""
    from repro.launch.solver_serve import make_problems
    from repro.serve import (OpenLoopFrontend, WallClock, create_engine,
                             poisson_arrivals)

    num = 8 if quick else 24
    slots, tol = 8, 1e-2
    slo = 0.25 if slo is None else slo
    # fixed offered loads (NOT calibrated per machine — calibration would
    # change arrival times run-to-run): under / near / over capacity
    rates = tuple(arrival_rates) if arrival_rates else (60.0, 240.0, 960.0)

    def requests(seed):
        return [p.to_request(uid=i, tol=tol, max_iterations=4000)
                for i, p in enumerate(make_problems(num, seed=seed))]

    eng = create_engine("solver", slots=slots, fmt="ell", backend="jnp")
    for r in requests(seed + 10):          # warm: AOT-compile the buckets
        eng.submit(r)
    eng.run()

    from contextlib import nullcontext
    guard = nullcontext()
    if assert_no_retraces:
        # warm on the exact load stream too (a different seed can draw a
        # different max row width and thus a legitimately new bucket), then
        # demand the measured loads hit only AOT-compiled executables
        from repro.analysis.strict import expect_no_retraces
        for r in requests(seed + 11):
            eng.submit(r)
        eng.run()
        guard = expect_no_retraces("open_loop_serving measured loads")

    loads = []
    with guard:
        for i, rate in enumerate(rates):
            arr = poisson_arrivals(requests(seed + 11), rate=rate,
                                   seed=seed + i, deadline=deadline)
            fe = OpenLoopFrontend(eng, arr, clock=WallClock())
            rep = fe.run(slo=slo)
            rep["offered_rate"] = rate
            loads.append(rep)
            p50 = rep["p50_latency_s"]
            p99 = rep["p99_latency_s"]
            n_rej = (rep["rejected_backpressure"]
                     + rep["rejected_admission"])
            emit(f"open_loop_serving/rate{rate:g}",
                 (p50 or 0.0) * 1e6,
                 f"p99_ms={(p99 or 0) * 1e3:.1f};"
                 f"goodput_rps={rep['goodput_rps']:.1f};"
                 f"completed={rep['completed']};expired={rep['expired']};"
                 f"rejected={n_rej}")
    rec = dict(requests=num, slots=slots, tol=tol, seed=seed,
               slo_s=slo, deadline_s=deadline, quick=bool(quick),
               no_retraces_asserted=bool(assert_no_retraces),
               arrival="poisson", rates=list(rates), loads=loads)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "open_loop_serving.json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return rec


def rcd_serving(seed=0, quick=False, solver_family="auto"):
    """Coordinate-descent serving face-off: rps + iterations-to-tol of
    primal RCD vs dual SDCA vs the A2 smoothing baseline across n/d
    aspect ratios, all through the SAME serving engine (csc buckets for
    the coordinate families, ell buckets for A2).

    Per shape the logistic fleet runs four arms: the face-off-decided
    family ("auto" — what ``Problem(A, b, loss=...)`` routes to;
    ``--solver-family`` overrides it), both forced coordinate sides, and
    an A2 arm on a CONSISTENT lasso constraint (b = A x0) over the same
    matrices — the engine's native workload at the same operand shapes.
    Each point records the planner's ``solver_family`` decision + reason
    (``repro.plan.decide_solver_family``); the expectation is primal on
    tall matrices (few coords), dual on wide ones (few samples).  Engines
    are measured warm (one throwaway stream AOT-compiles the buckets).
    Emits experiments/bench/rcd_serving.json; ``--quick`` shrinks shapes
    and fleet for the CI smoke."""
    from repro.api import Problem
    from repro.plan import decide_solver_family
    from repro.serve import SolverEngine
    from repro.sparse.random import random_coo

    num = 4 if quick else 12
    slots, tol, maxit = 4, 1e-4, 500 if quick else 4000
    shapes = ([(64, 16), (32, 32), (16, 64)] if quick
              else [(256, 32), (96, 96), (32, 256)])

    def fleets(m, n, seed0):
        """(logistic problems, a2-consistent-lasso problems) per shape."""
        loss_p, a2_p = [], []
        for i in range(num):
            coo = random_coo(m, n, row_nnz=min(8, n), seed=seed0 + i)
            rs = np.random.default_rng(seed0 * 7919 + i)
            labels = np.where(rs.random(m) < 0.5, -1.0, 1.0).astype(
                np.float32)
            loss_p.append(Problem(coo, labels, reg=0.3, loss="logistic"))
            x0 = rs.standard_normal(n).astype(np.float64)
            b0 = np.zeros(m, np.float64)
            np.add.at(b0, np.asarray(coo.rows),
                      np.asarray(coo.vals, np.float64)
                      * x0[np.asarray(coo.cols)])
            a2_p.append(Problem(coo, b0.astype(np.float32), prox="l1",
                                reg=0.05))
        return loss_p, a2_p

    def run_arm(probs, family, arm_tol):
        reqs = [p.to_request(uid=i, tol=arm_tol, max_iterations=maxit,
                             solver_family=family, seed=seed + i)
                if p.loss else
                p.to_request(uid=i, tol=arm_tol, max_iterations=maxit)
                for i, p in enumerate(probs)]
        eng = SolverEngine(slots=slots, backend="jnp")
        for r in reqs:                      # warm: AOT-compile the buckets
            eng.submit(r)
        eng.run()
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        dt = time.perf_counter() - t0
        iters = [r.iterations for r in done]
        return dict(rps=len(done) / dt, wall_s=dt, tol=arm_tol,
                    mean_iterations=float(np.mean(iters)),
                    max_iterations_seen=int(np.max(iters)),
                    converged=int(sum(r.feasibility < arm_tol
                                      for r in done)),
                    family=sorted({r.family for r in done}),
                    buckets=len(eng.buckets))

    out = {"requests": num, "slots": slots, "tol": tol,
           "max_iterations": maxit, "seed": seed, "quick": bool(quick),
           "solver_family_flag": solver_family, "loss": "logistic",
           "points": []}
    for si, (m, n) in enumerate(shapes):
        loss_p, a2_p = fleets(m, n, seed0=seed + 100 * (si + 1))
        fam, why = decide_solver_family("logistic", loss_p[0].stats,
                                        solver_family)
        rec = {"m": m, "n": n, "aspect_m_over_n": m / n,
               "solver_family": fam, "reason": why, "arms": {}}
        # the a2 reference arm runs at its native serving operating point
        # (solver_serving's tol: A2 feasibility decays O(1/k)); the
        # within-rcd iterations-to-tol comparison shares the tight tol
        for arm, probs, override, arm_tol in [
                ("auto", loss_p, solver_family, tol),
                ("rcd_primal", loss_p, "rcd_primal", tol),
                ("rcd_dual", loss_p, "rcd_dual", tol),
                ("a2", a2_p, "auto", 1e-2)]:
            r = run_arm(probs, override, arm_tol)
            rec["arms"][arm] = r
            emit(f"rcd_serving/{m}x{n}/{arm}", r["wall_s"] / num * 1e6,
                 f"rps={r['rps']:.1f};iters={r['mean_iterations']:.0f};"
                 f"converged={r['converged']}/{num};"
                 f"family={'+'.join(r['family'])}")
        emit(f"rcd_serving/{m}x{n}/face_off", 0.0,
             f"picked={fam};auto_iters="
             f"{rec['arms']['auto']['mean_iterations']:.0f}")
        out["points"].append(rec)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "rcd_serving.json"), "w") as f:
        json.dump(out, f, indent=1, default=float)
    return out


def api_overhead():
    """Facade overhead vs the raw kernel layer it compiles to.

    Both sides run the *same* cold-start regime (fresh operator closures per
    call -> re-trace, exactly like the sequential serving baseline): raw =
    registry ops + hand-computed Lg + ``solve_tol``; facade =
    ``Problem(...).solve(...)`` pinned to the identical (format, backend,
    tol, check_every) so the only delta is planning + Result assembly.
    Asserts the facade adds <5% and emits
    experiments/bench/api_overhead.json.

    (This benchmark intentionally imports the kernel-layer ``solve_tol``
    directly — it IS the comparison target; everywhere else in the repo the
    facade is the entry point, enforced by tests/test_api.py's grep test.)
    """
    import jax

    from repro.api import Problem
    from repro.core.prox import get_prox
    from repro.core.solver import solve_tol
    from repro.operators import make_solver_ops
    from repro.configs.base import PaperProblemConfig
    from repro.sparse import make_lasso

    cfg = PaperProblemConfig(name="api", m=256, n=64, nnz=256 * 8, reg=0.1)
    coo, b, _ = make_lasso(cfg, seed=0)
    lg = float(np.sum(np.asarray(coo.vals) ** 2))
    tol, gamma0, reps = 1e-3, 1000.0, 21

    def raw_once():
        ops = make_solver_ops(coo, "ell", "jnp")
        s = solve_tol(ops, get_prox("l1", reg=cfg.reg), b, lg, gamma0,
                      max_iterations=20_000, tol=tol, check_every=8)
        jax.block_until_ready(s)

    def facade_once():
        Problem(coo, b, prox="l1", reg=cfg.reg, gamma0=gamma0).solve(
            tol=tol, max_iterations=20_000, check_every=8,
            format="ell", backend="jnp")

    def timed(fn):
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0

    raw_once(); facade_once()                  # one throwaway of each
    # the gate statistic is the MEDIAN OF PER-PAIR RATIOS over
    # interleaved reps with alternating order: machine drift slower than
    # one pair cancels inside each ratio, order bias cancels across
    # pairs, and the median shrugs off outlier pairs — sequential
    # best-of-block swung ±10% run to run on this shared CPU container
    raw_all, fac_all, ratios = [], [], []
    for i in range(reps):
        if i % 2:
            f = timed(facade_once)
            r = timed(raw_once)
        else:
            r = timed(raw_once)
            f = timed(facade_once)
        raw_all.append(r)
        fac_all.append(f)
        ratios.append(f / r)
    raw_s = sorted(raw_all)[reps // 2]
    fac_s = sorted(fac_all)[reps // 2]
    ratio = sorted(ratios)[reps // 2]
    rec = dict(m=cfg.m, n=cfg.n, nnz=int(coo.nnz), tol=tol, reps=reps,
               raw_s=raw_s, facade_s=fac_s, overhead_ratio=ratio,
               raw_all_s=raw_all, facade_all_s=fac_all)  # medians + samples
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "api_overhead.json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)
    emit("api_overhead/raw", raw_s * 1e6, f"tol={tol}")
    emit("api_overhead/facade", fac_s * 1e6,
         f"overhead={100*(ratio-1):+.1f}%")
    assert ratio < 1.05, (
        f"facade overhead {100*(ratio-1):.1f}% exceeds the 5% budget "
        f"(raw {raw_s:.3f}s vs facade {fac_s:.3f}s)")
    return rec


def autotune_tables():
    """Measured autotune tables (delegates to benchmarks/autotune.py):
    spmv cells x (format, backend, tile) + fused check-block cells x
    (slot width, check_every) -> experiments/bench/autotune.json, the
    table ``operators/select.py`` consults via REPRO_AUTOTUNE_TABLE."""
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import autotune as _autotune

    table = _autotune.sweep()
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "autotune.json"), "w") as f:
        json.dump(table, f, indent=1, default=float)
    for c in table["cells"]:
        tag = f"{c['format']}/{c['backend']}"
        if c["kind"] == "spmv":
            tile = (f";bm={c['bm']};bn={c['bn']}" if "bm" in c else "")
            emit(f"autotune/spmv/{tag}", c["measured_s"] * 1e6,
                 f"error_ratio={c['error_ratio']:.2e}{tile}")
        else:
            emit(f"autotune/check_block/{tag}", c["measured_s"] * 1e6,
                 f"slots={c['slots']};check_every={c['check_every']};"
                 f"per_slot_iter_us={c['per_slot_iter_s']*1e6:.1f}")
    return table


MODES = {
    "table1": table1_datasets,
    "spmv_formats": spmv_formats,
    "solver_serving": solver_serving,
    "rcd_serving": rcd_serving,
    "open_loop_serving": open_loop_serving,
    "autotune": autotune_tables,
    "sharded_serving": sharded_serving,
    "api_overhead": api_overhead,
    "table2_4": table2_4_stage_timings,
    "table5": table5_strong_scaling,
    "fig2b": fig2b_datasize_scaling,
    "network": network_per_strategy,
}


def main(argv=None) -> None:
    """``python benchmarks/run.py [mode ...] [--format ell|bcsr|both]`` —
    default: every mode; ``--format`` selects the storage-format axis of
    the ``sharded_serving`` sweep (both by default)."""
    import argparse

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("modes", nargs="*", default=[],
                    help=f"benchmark modes (default: all of {list(MODES)})")
    ap.add_argument("--format", default="both",
                    choices=("ell", "bcsr", "both"),
                    help="sharded_serving format axis (bucket-body kernel)")
    ap.add_argument("--check-every", type=int, default=None,
                    help="solver_serving feasibility-check cadence "
                         "(default: the planner's "
                         "repro.plan.decide_check_every)")
    ap.add_argument("--fused", action="store_true", default=None,
                    help="solver_serving: force one-kernel fused check "
                         "blocks (default: auto — fused iff "
                         "backend=pallas)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed threaded through every serving "
                         "request mix and arrival stream (bit-"
                         "reproducible runs)")
    ap.add_argument("--solver-family", default="auto",
                    choices=("auto", "rcd_primal", "rcd_dual"),
                    help="rcd_serving: override the face-off rule for "
                         "the 'auto' arm (default: let "
                         "repro.plan.decide_solver_family pick)")
    ap.add_argument("--quick", action="store_true",
                    help="rcd_serving/open_loop_serving/sharded_serving: "
                         "shrink the sweep for a fast CI smoke")
    ap.add_argument("--grid", action="append", default=None,
                    metavar="RxC",
                    help="sharded_serving gridpart sub-mesh shape, e.g. "
                         "2x4 (repeatable; default 1x8 2x4 4x2 8x1)")
    ap.add_argument("--assert-no-retraces", action="store_true",
                    help="open_loop_serving: wrap the measured loads in "
                         "repro.analysis.strict.expect_no_retraces — a "
                         "warm engine must serve every offered load "
                         "without a single XLA recompile (the strict CI "
                         "job's enforcement form of the compile_s == 0 "
                         "claim)")
    ap.add_argument("--arrival-rate", type=float, action="append",
                    default=None, metavar="RPS",
                    help="open_loop_serving offered load in req/s "
                         "(repeatable; default 60/240/960)")
    ap.add_argument("--slo", type=float, default=None, metavar="S",
                    help="open_loop_serving latency SLO in seconds for "
                         "the goodput metric (default 0.25)")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="open_loop_serving per-request relative "
                         "deadline in seconds (default: none — requests "
                         "never expire)")
    args = ap.parse_args(argv)
    names = list(args.modes) or list(MODES)
    unknown = [n for n in names if n not in MODES]
    if unknown:
        raise SystemExit(f"unknown modes {unknown}; available: {list(MODES)}")
    formats = ("ell", "bcsr") if args.format == "both" else (args.format,)
    os.makedirs(OUT_DIR, exist_ok=True)
    results = {}
    print("name,us_per_call,derived")
    grids = None
    if args.grid:
        grids = []
        for g in args.grid:
            r, _, c = g.lower().partition("x")
            if not (r.isdigit() and c.isdigit()):
                raise SystemExit(f"--grid takes RxC (e.g. 2x4), got {g!r}")
            grids.append((int(r), int(c)))
    for name in names:
        if name == "sharded_serving":
            results[name] = sharded_serving(formats=formats,
                                            seed=args.seed, grids=grids,
                                            quick=args.quick)
        elif name == "solver_serving":
            results[name] = solver_serving(check_every=args.check_every,
                                           fused=args.fused,
                                           seed=args.seed)
        elif name == "rcd_serving":
            results[name] = rcd_serving(seed=args.seed, quick=args.quick,
                                        solver_family=args.solver_family)
        elif name == "open_loop_serving":
            results[name] = open_loop_serving(
                seed=args.seed, quick=args.quick,
                arrival_rates=args.arrival_rate, slo=args.slo,
                deadline=args.deadline,
                assert_no_retraces=args.assert_no_retraces)
        else:
            results[name] = MODES[name]()
    with open(os.path.join(OUT_DIR, "results.json"), "w") as f:
        json.dump(results, f, indent=1)
    with open(os.path.join(OUT_DIR, "results.csv"), "w") as f:
        f.write("name,us_per_call,derived\n")
        for name, us, der in ROWS:
            f.write(f"{name},{us:.1f},{der}\n")


if __name__ == "__main__":
    main()

"""Measured autotune tables: op-count analyzer + sweep harness.

The roofline selector's analytic model is an arithmetic-intensity argument
tuned for TPU ceilings; on the machine actually running the kernels
(interpret-mode Pallas on CPU most dramatically) it can be off by orders
of magnitude — ``spmv_formats.json`` showed >100x for bcsr/pallas.  The
fix (the dace ``FlopCount`` roofline lesson) is measured tables, not a
better formula.  This harness sweeps

  spmv cells        (format, backend) x tile shapes (bm, bn) x sizes —
                    per-apply forward/backward seconds for the operators
                    the registry builds, with the analytic model's op
                    counts (flops, HBM bytes, modeled seconds) and the
                    achieved utilization alongside, so the table IS the
                    analyzer output;
  check_block cells fused one-kernel check blocks
                    (repro.kernels.fused_check_block) x slot widths x
                    check_every — per-block and per-iteration seconds for
                    the serving engine's fused tick body.

and writes ``experiments/bench/autotune.json``.  ``operators/select.py``
consults the spmv cells (explicit ``table=`` or env
``REPRO_AUTOTUNE_TABLE``) before falling back to the analytic roofline;
each cell records (m, n, row_nnz, seed) so tests can reconstruct the
exact matrix and verify predicted-vs-measured is within tolerance.

  PYTHONPATH=src python benchmarks/autotune.py            # full sweep
  PYTHONPATH=src python benchmarks/autotune.py --quick    # one tiny cell
"""
from __future__ import annotations

import argparse
import json
import os
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DEFAULT = os.path.join(REPO, "experiments", "bench", "autotune.json")


def _stack_ells(coos, n, pad_to=8):
    import numpy as np

    from repro.sparse import coo_to_ell, stack_ells
    from repro.sparse.formats import ELL

    ells = [coo_to_ell(c, pad_to=pad_to) for c in coos]
    width = max(e.vals.shape[1] for e in ells)
    padded = [ELL(vals=np.pad(np.asarray(e.vals),
                              ((0, 0), (0, width - e.vals.shape[1]))),
                  cols=np.pad(np.asarray(e.cols),
                              ((0, 0), (0, width - e.cols.shape[1]))),
                  n=e.n) for e in ells]
    return stack_ells(padded, n=n)


def _stack_bcsrs(coos, m, n, bm, bn):
    import numpy as np

    from repro.sparse import coo_to_bcsr, stack_bcsrs
    from repro.sparse.formats import BCSR

    bs = [coo_to_bcsr(c, bm=bm, bn=bn) for c in coos]
    kb = max(x.vals.shape[1] for x in bs)
    padded = [BCSR(vals=np.pad(np.asarray(x.vals),
                               ((0, 0), (0, kb - x.vals.shape[1]),
                                (0, 0), (0, 0))),
                   bcols=np.pad(np.asarray(x.bcols),
                                ((0, 0), (0, kb - x.bcols.shape[1]))),
                   m=x.m, n=x.n) for x in bs]
    return stack_bcsrs(padded, m=m, n=n)


def _timed(fn, *args, reps=3):
    import jax

    jax.block_until_ready(fn(*args))          # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    del out
    return (time.perf_counter() - t0) / reps


def spmv_cell(fmt: str, backend: str, m: int, n: int, row_nnz: int,
              seed: int, bm: int | None = None, bn: int | None = None,
              reps: int = 3) -> dict:
    """One measured (format, backend[, tile]) spmv cell with the analytic
    op counts alongside: the utilization analyzer's row."""
    import jax
    import jax.numpy as jnp

    from repro.operators import from_coo
    from repro.operators.select import (
        PEAK_FLOPS_MXU_F32, PEAK_FLOPS_VPU, estimate_formats,
    )
    from repro.sparse import random_coo

    coo = random_coo(m, n, row_nnz, seed=seed)
    if fmt == "bcsr":
        est = estimate_formats(coo, bm_bn_candidates=((bm, bn),))["bcsr"]
        op = from_coo(coo, fmt, backend, bm=bm, bn=bn)
        peak = PEAK_FLOPS_MXU_F32
    else:
        est = estimate_formats(coo)[fmt]
        op = from_coo(coo, fmt, backend)
        peak = PEAK_FLOPS_VPU
    x = jnp.ones((n,), jnp.float32)
    y = jnp.ones((m,), jnp.float32)
    fwd_s = _timed(jax.jit(op.matvec), x, reps=reps)
    bwd_s = _timed(jax.jit(op.rmatvec), y, reps=reps)
    flops = 2.0 * est["work"]
    cell = dict(kind="spmv", format=fmt, backend=backend,
                m=m, n=n, row_nnz=row_nnz, seed=seed,
                work=est["work"], flops=flops, bytes=est["bytes"],
                analytic_s=est["s"], measured_s=fwd_s, bwd_s=bwd_s,
                error_ratio=est["s"] / fwd_s if fwd_s > 0 else None,
                utilization=flops / (fwd_s * peak) if fwd_s > 0 else None)
    if fmt == "bcsr":
        cell["bm"], cell["bn"] = bm, bn
    return cell


def check_block_cell(fmt: str, prox: str, slots: int, check_every: int,
                     m: int, n: int, row_nnz: int, seed: int,
                     reps: int = 3) -> dict:
    """One measured fused-check-block cell: seconds per one-kernel block
    (and per iteration) for a ``slots``-wide stacked bucket."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.prox import get_prox
    from repro.core.solver import SolverOps, batched_init
    from repro.kernels.fused_check_block import fused_check_block
    from repro.sparse import random_coo, transpose_coo
    from repro.sparse.linalg import stacked_bcsr_matvec, stacked_ell_matvec

    coos = [random_coo(m, n, row_nnz, seed=seed + i) for i in range(slots)]
    coos_t = [transpose_coo(c) for c in coos]
    if fmt == "ell":
        a, at = _stack_ells(coos, n), _stack_ells(coos_t, m)
        mv = stacked_ell_matvec
    else:
        bm, bn = 8, min(128, n)
        a = _stack_bcsrs(coos, m, n, bm, bn)
        at = _stack_bcsrs(coos_t, n, m, bm, min(128, m))
        mv = stacked_bcsr_matvec
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((slots, m)), jnp.float32)
    lg = jnp.asarray([float(np.sum(np.square(np.asarray(c.vals))))
                      for c in coos], jnp.float32)
    g0 = jnp.full((slots,), 100.0, jnp.float32)
    reg = jnp.full((slots,), 0.1, jnp.float32)
    ops = SolverOps(matvec=lambda v: mv(a, v), rmatvec=lambda u: mv(at, u))
    state = batched_init(ops, get_prox(prox, reg=0.1) if prox in
                         ("l1", "sq_l2") else get_prox(prox), b, lg, g0)
    active = jnp.ones((slots,), bool)
    maxit = jnp.full((slots,), 10_000, jnp.int32)

    def block(st):
        return fused_check_block(a, at, b, lg, g0, reg, st, active, maxit,
                                 prox=prox, steps=check_every)

    per_block = _timed(block, state, reps=reps)
    # 2 passes (fwd + bwd) per iteration + the feasibility pass, per slot
    flops = slots * 2.0 * (check_every * 2.0 + 1.0) * m * row_nnz
    return dict(kind="check_block", format=fmt, backend="pallas", prox=prox,
                slots=slots, check_every=check_every,
                m=m, n=n, row_nnz=row_nnz, seed=seed, flops=flops,
                measured_s=per_block,
                per_iter_s=per_block / check_every,
                per_slot_iter_s=per_block / (check_every * slots))


def sweep(quick: bool = False, reps: int = 3) -> dict:
    """The full (or --quick) sweep; returns the table dict."""
    import jax

    from repro.kernels import default_interpret

    cells = []
    if quick:
        # CI smoke: one tiny spmv cell (on a tile shape the selector's
        # default candidate set contains, so the round-trip test can drive
        # select_format end to end) + one fused check block
        cells.append(spmv_cell("bcsr", "pallas", 256, 128, 4, seed=0,
                               bm=8, bn=128, reps=reps))
        cells.append(check_block_cell("bcsr", "l1", 2, 8, 256, 64, 4,
                                      seed=0, reps=reps))
    else:
        sizes = [(512, 128, 8, 0), (1024, 128, 8, 1)]
        for m, n, k, seed in sizes:
            for backend in ("jnp", "pallas"):
                cells.append(spmv_cell("ell", backend, m, n, k, seed,
                                       reps=reps))
                for bm, bn in ((8, 128), (16, 128)):
                    cells.append(spmv_cell("bcsr", backend, m, n, k, seed,
                                           bm=bm, bn=bn, reps=reps))
        m, n, k = 512, 128, 8
        for fmt in ("ell", "bcsr"):
            for slots in (1, 4, 8):
                for check_every in (8, 16, 32):
                    cells.append(check_block_cell(fmt, "l1", slots,
                                                  check_every, m, n, k,
                                                  seed=2, reps=reps))
    return dict(meta=dict(platform=jax.default_backend(),
                          interpret=bool(default_interpret(None)),
                          reps=reps, quick=bool(quick)),
                cells=cells)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="one tiny (format, prox) cell — the CI smoke")
    ap.add_argument("--out", default=OUT_DEFAULT)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)
    table = sweep(quick=args.quick, reps=args.reps)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(table, f, indent=1, default=float)
    for c in table["cells"]:
        tag = f"{c['format']}/{c['backend']}"
        if c["kind"] == "spmv":
            tile = (f";bm={c['bm']};bn={c['bn']}" if "bm" in c else "")
            print(f"autotune/spmv/{tag},{c['measured_s']*1e6:.1f},"
                  f"analytic_us={c['analytic_s']*1e6:.3f};"
                  f"error_ratio={c['error_ratio']:.2e}{tile}")
        else:
            print(f"autotune/check_block/{tag},{c['measured_s']*1e6:.1f},"
                  f"slots={c['slots']};check_every={c['check_every']};"
                  f"per_slot_iter_us={c['per_slot_iter_s']*1e6:.1f}")
    print(f"[autotune] {len(table['cells'])} cells -> {args.out}")
    return 0


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(REPO, "src"))
    raise SystemExit(main())
